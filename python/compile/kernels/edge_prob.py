"""Layer-1 Pallas kernels: multiplicative-attribute edge probabilities.

The MAGM edge probability (paper eq. 7) is a d-way product of gathered
initiator entries:

    Q_ij = prod_k theta^(k)[f_k(i), f_k(j)]

Evaluated naively this is gather-heavy and hostile to the MXU. Because each
factor is indexed by a *bit pair*, its log is bilinear in the bits:

    log theta[a, b] = c0 + c1*a + c2*b + c3*a*b          (per level k)

with  c0 = log t00, c1 = log t10 - log t00, c2 = log t01 - log t00,
      c3 = log t11 - log t10 - log t01 + log t00.

Summing over k turns the whole [M, N] block into

    log Q = sum_k c0_k  +  F_src @ c1  +  (F_dst @ c2)^T  +  F_src @ diag(c3) @ F_dst^T

i.e. a rank-structured correction plus ONE matmul with contraction dim d —
exactly the shape the MXU wants. The kernels below implement this tiled.

TPU mapping (see DESIGN.md §Hardware-Adaptation): BlockSpec streams (bm, d)
source tiles and (bn, d) destination tiles through VMEM, the dot runs on the
MXU, and the rank-1 corrections + exp run on the VPU fused behind it.
``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust runtime
(xla crate / PJRT CPU) runs directly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 is the MXU systolic dimension; a (128, d<=64)
# operand tile is 32 KB at f32, so src tile + dst tile + out tile stay well
# under VMEM even with double buffering.
BLOCK_M = 128
BLOCK_N = 128
# Pair kernel block (VPU lane-friendly multiple of 128).
BLOCK_P = 1024


def _block_kernel(fs_ref, fd_ref, coef_ref, o_ref):
    """One (bm, bn) output tile of the pairwise probability block.

    fs_ref: [bm, d] source bits, fd_ref: [bn, d] destination bits,
    coef_ref: [4, d] bilinear coefficients, o_ref: [bm, bn] output.
    """
    fs = fs_ref[...]
    fd = fd_ref[...]
    coef = coef_ref[...]
    base = jnp.sum(coef[0, :])                       # scalar: sum_k c0
    row = fs @ coef[1, :]                            # [bm]   : F_src @ c1
    col = fd @ coef[2, :]                            # [bn]   : F_dst @ c2
    # MXU part: (fs * c3) @ fd^T, contraction over d.
    cross = jax.lax.dot_general(
        fs * coef[3, :][None, :],
        fd,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # [bm, bn]
    o_ref[...] = jnp.exp(base + row[:, None] + col[None, :] + cross)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def edge_prob_block(f_src, f_dst, coef, *, block_m=BLOCK_M, block_n=BLOCK_N):
    """Dense [M, N] block of edge probabilities via the Pallas tile kernel.

    Args:
      f_src: [M, d] float32 bits (0.0/1.0). M must be a multiple of block_m.
      f_dst: [N, d] float32 bits. N must be a multiple of block_n.
      coef:  [4, d] float32 bilinear coefficients (theta_to_coef in model.py).

    Returns:
      [M, N] float32 probabilities.
    """
    m, d = f_src.shape
    n, d2 = f_dst.shape
    assert d == d2 and coef.shape == (4, d), (f_src.shape, f_dst.shape, coef.shape)
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        _block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((4, d), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(f_src, f_dst, coef)


def _pairs_kernel(fs_ref, fd_ref, coef_ref, o_ref):
    """One [bp] strip of elementwise pair probabilities."""
    fs = fs_ref[...]
    fd = fd_ref[...]
    coef = coef_ref[...]
    base = jnp.sum(coef[0, :])
    logq = (
        base
        + fs @ coef[1, :]
        + fd @ coef[2, :]
        + jnp.sum(fs * coef[3, :][None, :] * fd, axis=1)
    )
    o_ref[...] = jnp.exp(logq)


@functools.partial(jax.jit, static_argnames=("block_p",))
def edge_prob_pairs(f_src, f_dst, coef, *, block_p=BLOCK_P):
    """Elementwise probabilities for B aligned (src, dst) pairs.

    Args:
      f_src, f_dst: [B, d] float32 bits; B must be a multiple of block_p.
      coef: [4, d] float32.

    Returns:
      [B] float32 probabilities Q for each pair.
    """
    b, d = f_src.shape
    assert f_dst.shape == (b, d) and coef.shape == (4, d)
    assert b % block_p == 0, (b, block_p)
    return pl.pallas_call(
        _pairs_kernel,
        grid=(b // block_p,),
        in_specs=[
            pl.BlockSpec((block_p, d), lambda i: (i, 0)),
            pl.BlockSpec((block_p, d), lambda i: (i, 0)),
            pl.BlockSpec((4, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(f_src, f_dst, coef)


def _degree_kernel(fs_ref, fd_ref, coef_ref, cnt_ref, o_ref):
    """Accumulate Q_tile @ counts_tile into the output strip.

    Grid is (M/bm, N/bn); the j axis is a reduction: o[i] += Q(i,j) @ cnt(j).
    """
    j = pl.program_id(1)

    fs = fs_ref[...]
    fd = fd_ref[...]
    coef = coef_ref[...]
    cnt = cnt_ref[...]
    base = jnp.sum(coef[0, :])
    row = fs @ coef[1, :]
    col = fd @ coef[2, :]
    cross = jax.lax.dot_general(
        fs * coef[3, :][None, :],
        fd,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    q = jnp.exp(base + row[:, None] + col[None, :] + cross)
    contrib = q @ cnt

    @pl.when(j == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(j != 0)
    def _acc():
        o_ref[...] = o_ref[...] + contrib


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def expected_degree_contrib(
    f_src, f_dst, coef, counts_dst, *, block_m=BLOCK_M, block_n=BLOCK_N
):
    """Expected out-degree contributions: (Q @ counts_dst) without
    materializing Q in HBM.

    Args:
      f_src: [M, d] source-configuration bits.
      f_dst: [N, d] destination-configuration bits.
      coef:  [4, d].
      counts_dst: [N] multiplicity of each destination configuration.

    Returns:
      [M] float32: sum_j counts[j] * Q[i, j].
    """
    m, d = f_src.shape
    n, _ = f_dst.shape
    assert m % block_m == 0 and n % block_n == 0
    return pl.pallas_call(
        _degree_kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((4, d), lambda i, j: (0, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(f_src, f_dst, coef, counts_dst)
