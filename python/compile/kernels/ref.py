"""Pure-jnp correctness oracles for the edge-probability kernels.

These implement the MAGM / KPGM edge probability *directly* from the paper's
definition (eq. 6/7):

    Q_ij = prod_{k=1..d} theta^(k)[ f_k(i), f_k(j) ]

with no log-space tricks, so they are the ground truth the Pallas kernels
(which use the bilinear log-space decomposition, see model.py) are tested
against.

Conventions
-----------
* ``F`` matrices hold attribute bits as float32 {0.0, 1.0}, shape [B, d].
* ``theta`` is the per-level initiator stack, shape [d, 2, 2], float32.
* ``coef`` (used by the kernels, produced by :func:`theta_to_coef` in
  model.py) is shape [4, d].
"""

import jax.numpy as jnp


def edge_prob_pairs_ref(f_src, f_dst, theta):
    """Elementwise pair probabilities.

    Args:
      f_src: [B, d] float bits for source nodes.
      f_dst: [B, d] float bits for target nodes.
      theta: [d, 2, 2] per-level initiator matrices.

    Returns:
      [B] probabilities Q_ij for each pair.
    """
    src = f_src.astype(jnp.int32)  # [B, d]
    dst = f_dst.astype(jnp.int32)
    d = theta.shape[0]
    # theta[k, src[:,k], dst[:,k]] for each k, then product over k.
    ks = jnp.arange(d)
    vals = theta[ks[None, :], src, dst]  # [B, d]
    return jnp.prod(vals, axis=1)


def edge_prob_block_ref(f_src, f_dst, theta):
    """Dense pairwise block of edge probabilities.

    Args:
      f_src: [M, d] float bits.
      f_dst: [N, d] float bits.
      theta: [d, 2, 2].

    Returns:
      [M, N] with Q[i, j] = prod_k theta[k, f_src[i,k], f_dst[j,k]].
    """
    src = f_src.astype(jnp.int32)[:, None, :]  # [M, 1, d]
    dst = f_dst.astype(jnp.int32)[None, :, :]  # [1, N, d]
    ks = jnp.arange(theta.shape[0])[None, None, :]
    vals = theta[ks, src, dst]  # [M, N, d]
    return jnp.prod(vals, axis=2)


def expected_degree_contrib_ref(f_src, f_dst, theta, counts_dst):
    """Out-degree contribution of a destination block: (Q_block @ counts).

    counts_dst[j] is the multiplicity of configuration j (how many nodes
    share f_dst[j]); the result is sum_j counts[j] * Q[i, j] for each i.
    """
    q = edge_prob_block_ref(f_src, f_dst, theta)
    return q @ counts_dst


def loglik_block_ref(f_src, f_dst, theta, adj):
    """Bernoulli log-likelihood of an adjacency block under Q.

    sum_ij adj*log(Q) + (1-adj)*log(1-Q), with probabilities clipped away
    from {0,1} for numerical sanity (matching model.loglik_block).
    """
    q = edge_prob_block_ref(f_src, f_dst, theta)
    q = jnp.clip(q, 1e-12, 1.0 - 1e-12)
    return jnp.sum(adj * jnp.log(q) + (1.0 - adj) * jnp.log1p(-q))
