"""Layer-2 JAX model: MAGM/KPGM edge-probability compute graph.

This is the build-time model layer. It owns

* the theta -> bilinear-coefficient transform (``theta_to_coef``) shared by
  the Pallas kernels and the Rust runtime (Rust sends ``coef``, not theta,
  so the transform is done once per model, not per block),
* padding wrappers that lift the tile-aligned Pallas kernels
  (kernels/edge_prob.py) to arbitrary shapes,
* the AOT entry points lowered by aot.py and executed from Rust via PJRT:
  ``edge_prob_block``, ``edge_prob_pairs``, ``expected_degree_contrib``,
  ``loglik_block``.

Everything here must stay jit-lowerable with static shapes: the Rust side
loads fixed-shape HLO and pads its inputs (bits and coefficients pad with
zeros, which contribute exp(0)=1 factors in probability space — i.e. padding
levels are neutral, see ``pad_levels``).
"""

import jax
import jax.numpy as jnp

from .kernels import edge_prob as ek

# Floor for log(theta): theta entries are probabilities in [0, 1]; entries
# exactly 0 would give -inf logs. exp(LOG_FLOOR * d) underflows to 0 for any
# realistic d, so clamping preserves Q == 0 blocks to within f32.
THETA_FLOOR = 1e-30


def theta_to_coef(theta):
    """Convert a [d, 2, 2] initiator stack into [4, d] bilinear coefficients.

    log theta_k[a, b] = c0_k + c1_k*a + c2_k*b + c3_k*a*b  for bits a, b.
    """
    t = jnp.clip(jnp.asarray(theta, jnp.float32), THETA_FLOOR, 1.0)
    l00 = jnp.log(t[:, 0, 0])
    l01 = jnp.log(t[:, 0, 1])
    l10 = jnp.log(t[:, 1, 0])
    l11 = jnp.log(t[:, 1, 1])
    return jnp.stack([l00, l10 - l00, l01 - l00, l11 - l10 - l01 + l00])


def pad_levels(coef, d_pad):
    """Pad [4, d] coefficients to [4, d_pad] with neutral (zero) levels.

    A zero coefficient column contributes log-factor 0 for any bit pair, so
    padded attribute levels (with arbitrary bits) do not change Q.
    """
    d = coef.shape[1]
    assert d_pad >= d
    return jnp.pad(coef, ((0, 0), (0, d_pad - d)))


def _pad_rows(x, mult):
    """Pad axis-0 of ``x`` up to a multiple of ``mult`` with zeros."""
    r = x.shape[0]
    pad = (-r) % mult
    if pad == 0:
        return x
    width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, width)


def edge_prob_block(f_src, f_dst, coef):
    """[M, N] edge-probability block for arbitrary M, N (pads to tiles)."""
    m, n = f_src.shape[0], f_dst.shape[0]
    fs = _pad_rows(jnp.asarray(f_src, jnp.float32), ek.BLOCK_M)
    fd = _pad_rows(jnp.asarray(f_dst, jnp.float32), ek.BLOCK_N)
    q = ek.edge_prob_block(fs, fd, jnp.asarray(coef, jnp.float32))
    return q[:m, :n]


def edge_prob_pairs(f_src, f_dst, coef):
    """[B] elementwise pair probabilities for arbitrary B (pads to tiles)."""
    b = f_src.shape[0]
    fs = _pad_rows(jnp.asarray(f_src, jnp.float32), ek.BLOCK_P)
    fd = _pad_rows(jnp.asarray(f_dst, jnp.float32), ek.BLOCK_P)
    return ek.edge_prob_pairs(fs, fd, jnp.asarray(coef, jnp.float32))[:b]


def expected_degree_contrib(f_src, f_dst, coef, counts_dst):
    """[M] expected-degree contributions sum_j counts[j] Q[i, j].

    Padding destinations is safe because padded counts are 0.
    """
    m = f_src.shape[0]
    fs = _pad_rows(jnp.asarray(f_src, jnp.float32), ek.BLOCK_M)
    fd = _pad_rows(jnp.asarray(f_dst, jnp.float32), ek.BLOCK_N)
    cnt = _pad_rows(jnp.asarray(counts_dst, jnp.float32), ek.BLOCK_N)
    out = ek.expected_degree_contrib(fs, fd, jnp.asarray(coef, jnp.float32), cnt)
    return out[:m]


def loglik_block(f_src, f_dst, coef, adj, mask):
    """Masked Bernoulli log-likelihood of an adjacency block under Q.

    Args:
      f_src: [M, d] source bits, f_dst: [N, d] destination bits.
      coef: [4, d].
      adj:  [M, N] float32 0/1 observed adjacency block.
      mask: [M, N] float32 0/1; cells with mask 0 are excluded (used for
        padding and for excluding the diagonal when self-loops are dropped).

    Returns:
      scalar float32 log-likelihood.
    """
    q = edge_prob_block(f_src, f_dst, coef)
    q = jnp.clip(q, 1e-12, 1.0 - 1e-12)
    ll = adj * jnp.log(q) + (1.0 - adj) * jnp.log1p(-q)
    return jnp.sum(ll * mask)


def kpgm_bits(n_nodes, d):
    """KPGM attribute matrix: node i gets the binary representation of i.

    Row i is the bit vector b(i) with b_k = bit (d-1-k) of i, matching the
    paper's convention that the first attribute selects the coarsest
    quadrisection. Returns [n_nodes, d] float32.
    """
    ids = jnp.arange(n_nodes, dtype=jnp.uint32)[:, None]
    shifts = jnp.arange(d - 1, -1, -1, dtype=jnp.uint32)[None, :]
    return ((ids >> shifts) & 1).astype(jnp.float32)


def kpgm_prob_matrix(theta):
    """Full KPGM edge-probability matrix P = kron(theta_1, ..., theta_d).

    Only used at small n for Figure-1 style visualization and for tests;
    the samplers never materialize P.
    """
    theta = jnp.asarray(theta, jnp.float32)
    d = theta.shape[0]
    n = 2**d
    bits = kpgm_bits(n, d)
    return edge_prob_block(bits, bits, theta_to_coef(theta))
