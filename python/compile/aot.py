"""AOT lowering: JAX/Pallas entry points -> HLO text artifacts for Rust.

Run once at build time (``make artifacts``). Each entry point in
``ENTRIES`` is jitted at a fixed shape, lowered to stablehlo, converted to an
XlaComputation and dumped as HLO **text** — not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

A ``manifest.json`` describing every artifact (entry name, file, input and
output shapes/dtypes) is written alongside so the Rust runtime
(rust/src/runtime/) can validate shapes before execution.

Fixed shapes & padding contract with Rust
-----------------------------------------
All entries are lowered at d = D_PAD attribute levels. The Rust side pads:
  * coefficient columns beyond the model's d with zeros (neutral levels,
    see model.pad_levels),
  * F-bit rows beyond the batch with zeros,
  * counts / adj / mask rows with zeros,
and slices outputs back down. Block entries use (BM, BN) = (512, 512),
pair entries use BP = 8192.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shape contract shared with rust/src/runtime/artifacts.rs.
D_PAD = 32
BM = 512
BN = 512
BP = 8192

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _entry_edge_prob_block(fs, fd, coef):
    return (model.edge_prob_block(fs, fd, coef),)


def _entry_edge_prob_pairs(fs, fd, coef):
    return (model.edge_prob_pairs(fs, fd, coef),)


def _entry_expected_degree_contrib(fs, fd, coef, counts):
    return (model.expected_degree_contrib(fs, fd, coef, counts),)


def _entry_loglik_block(fs, fd, coef, adj, mask):
    return (model.loglik_block(fs, fd, coef, adj, mask),)


# name -> (fn, input specs, output shapes (documentation only))
ENTRIES = {
    "edge_prob_block": (
        _entry_edge_prob_block,
        [_spec(BM, D_PAD), _spec(BN, D_PAD), _spec(4, D_PAD)],
        [[BM, BN]],
    ),
    "edge_prob_pairs": (
        _entry_edge_prob_pairs,
        [_spec(BP, D_PAD), _spec(BP, D_PAD), _spec(4, D_PAD)],
        [[BP]],
    ),
    "expected_degree_contrib": (
        _entry_expected_degree_contrib,
        [_spec(BM, D_PAD), _spec(BN, D_PAD), _spec(4, D_PAD), _spec(BN)],
        [[BM]],
    ),
    "loglik_block": (
        _entry_loglik_block,
        [
            _spec(BM, D_PAD),
            _spec(BN, D_PAD),
            _spec(4, D_PAD),
            _spec(BM, BN),
            _spec(BM, BN),
        ],
        [[]],
    ),
}


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name):
    """Lower one entry point to HLO text. Returns (text, manifest record)."""
    fn, specs, out_shapes = ENTRIES[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    record = {
        "name": name,
        "file": f"{name}.hlo.txt",
        "inputs": [{"shape": list(s.shape), "dtype": "f32"} for s in specs],
        "outputs": [{"shape": list(s), "dtype": "f32"} for s in out_shapes],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, record


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts go to its directory")
    ap.add_argument("--only", nargs="*", help="subset of entry names")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)
    names = args.only or list(ENTRIES)

    records = []
    for name in names:
        text, record = lower_entry(name)
        path = os.path.join(out_dir, record["file"])
        with open(path, "w") as f:
            f.write(text)
        records.append(record)
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "version": 1,
        "d_pad": D_PAD,
        "bm": BM,
        "bn": BN,
        "bp": BP,
        "entries": records,
    }
    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out} ({len(records)} entries)")


if __name__ == "__main__":
    main()
