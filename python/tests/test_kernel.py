"""Pallas kernels vs the pure-jnp oracle (kernels/ref.py).

This is the CORE correctness signal for Layer 1: the bilinear log-space
decomposition used by the kernels must reproduce the direct product-of-
gathers definition of Q (paper eq. 7) for every shape, theta range and bit
pattern. Hypothesis sweeps shapes/d/theta; fixed tests pin the paper's
actual parameter matrices.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import edge_prob as ek
from compile.kernels import ref
from compile import model

RNG = np.random.default_rng(0)

THETA1 = np.array([[0.15, 0.7], [0.7, 0.85]], dtype=np.float32)  # eq. 13
THETA2 = np.array([[0.35, 0.52], [0.52, 0.95]], dtype=np.float32)


def stack(theta2x2, d):
    return np.broadcast_to(np.asarray(theta2x2, np.float32), (d, 2, 2)).copy()


def rand_bits(rng, *shape):
    return rng.integers(0, 2, size=shape).astype(np.float32)


def rand_theta(rng, d, lo=0.05, hi=0.95):
    return rng.uniform(lo, hi, size=(d, 2, 2)).astype(np.float32)


# ---------------------------------------------------------------------------
# Fixed-shape kernel-vs-ref checks (tile-aligned, exercising pallas_call).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("theta2", [THETA1, THETA2])
@pytest.mark.parametrize("d", [1, 3, 8, 16])
def test_block_kernel_matches_ref_paper_thetas(theta2, d):
    theta = stack(theta2, d)
    fs = rand_bits(RNG, ek.BLOCK_M, d)
    fd = rand_bits(RNG, ek.BLOCK_N, d)
    got = ek.edge_prob_block(jnp.asarray(fs), jnp.asarray(fd),
                             model.theta_to_coef(theta))
    want = ref.edge_prob_block_ref(jnp.asarray(fs), jnp.asarray(fd),
                                   jnp.asarray(theta))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-7)


@pytest.mark.parametrize("m_tiles,n_tiles", [(1, 1), (2, 1), (1, 3), (2, 2)])
def test_block_kernel_multi_tile_grid(m_tiles, n_tiles):
    d = 10
    theta = rand_theta(RNG, d)
    fs = rand_bits(RNG, m_tiles * ek.BLOCK_M, d)
    fd = rand_bits(RNG, n_tiles * ek.BLOCK_N, d)
    got = ek.edge_prob_block(jnp.asarray(fs), jnp.asarray(fd),
                             model.theta_to_coef(theta))
    want = ref.edge_prob_block_ref(jnp.asarray(fs), jnp.asarray(fd),
                                   jnp.asarray(theta))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-7)


@pytest.mark.parametrize("d", [1, 5, 16, 32])
def test_pairs_kernel_matches_ref(d):
    theta = rand_theta(RNG, d)
    fs = rand_bits(RNG, ek.BLOCK_P, d)
    fd = rand_bits(RNG, ek.BLOCK_P, d)
    got = ek.edge_prob_pairs(jnp.asarray(fs), jnp.asarray(fd),
                             model.theta_to_coef(theta))
    want = ref.edge_prob_pairs_ref(jnp.asarray(fs), jnp.asarray(fd),
                                   jnp.asarray(theta))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-7)


def test_degree_kernel_matches_ref():
    d = 12
    theta = rand_theta(RNG, d)
    fs = rand_bits(RNG, ek.BLOCK_M, d)
    fd = rand_bits(RNG, 2 * ek.BLOCK_N, d)
    counts = RNG.integers(0, 50, size=2 * ek.BLOCK_N).astype(np.float32)
    got = ek.expected_degree_contrib(jnp.asarray(fs), jnp.asarray(fd),
                                     model.theta_to_coef(theta),
                                     jnp.asarray(counts))
    want = ref.expected_degree_contrib_ref(jnp.asarray(fs), jnp.asarray(fd),
                                           jnp.asarray(theta),
                                           jnp.asarray(counts))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_loglik_block_matches_ref():
    d = 8
    theta = stack(THETA1, d)
    m, n = 96, 64
    fs = rand_bits(RNG, m, d)
    fd = rand_bits(RNG, n, d)
    adj = rand_bits(RNG, m, n)
    mask = np.ones((m, n), np.float32)
    got = model.loglik_block(fs, fd, model.theta_to_coef(theta), adj, mask)
    want = ref.loglik_block_ref(jnp.asarray(fs), jnp.asarray(fd),
                                jnp.asarray(theta), jnp.asarray(adj))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, theta ranges, bit patterns.
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
    lo=st.floats(0.01, 0.4),
    hi=st.floats(0.6, 1.0),
)
def test_block_kernel_hypothesis_theta_sweep(d, seed, lo, hi):
    rng = np.random.default_rng(seed)
    theta = rng.uniform(lo, hi, size=(d, 2, 2)).astype(np.float32)
    fs = rand_bits(rng, ek.BLOCK_M, d)
    fd = rand_bits(rng, ek.BLOCK_N, d)
    got = ek.edge_prob_block(jnp.asarray(fs), jnp.asarray(fd),
                             model.theta_to_coef(theta))
    want = ref.edge_prob_block_ref(jnp.asarray(fs), jnp.asarray(fd),
                                   jnp.asarray(theta))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 300),
    d=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_model_block_wrapper_arbitrary_shapes(m, n, d, seed):
    """model.edge_prob_block pads to tiles and slices back: any (m, n, d)."""
    rng = np.random.default_rng(seed)
    theta = rand_theta(rng, d)
    fs = rand_bits(rng, m, d)
    fd = rand_bits(rng, n, d)
    got = model.edge_prob_block(fs, fd, model.theta_to_coef(theta))
    want = ref.edge_prob_block_ref(jnp.asarray(fs), jnp.asarray(fd),
                                   jnp.asarray(theta))
    assert got.shape == (m, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 5000),
    d=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_model_pairs_wrapper_arbitrary_batch(b, d, seed):
    rng = np.random.default_rng(seed)
    theta = rand_theta(rng, d)
    fs = rand_bits(rng, b, d)
    fd = rand_bits(rng, b, d)
    got = model.edge_prob_pairs(fs, fd, model.theta_to_coef(theta))
    want = ref.edge_prob_pairs_ref(jnp.asarray(fs), jnp.asarray(fd),
                                   jnp.asarray(theta))
    assert got.shape == (b,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# Edge cases.
# ---------------------------------------------------------------------------


def test_theta_entry_zero_gives_zero_prob():
    """theta with an exact 0 entry: clamping must still yield Q ~ 0 when the
    zero entry is selected, and exact values elsewhere."""
    d = 4
    theta = stack(THETA1, d)
    theta[2, 0, 1] = 0.0
    coef = model.theta_to_coef(theta)
    # pair that hits (0,1) at level 2:
    fs = np.zeros((1, d), np.float32)
    fd = np.zeros((1, d), np.float32)
    fd[0, 2] = 1.0
    q = model.edge_prob_pairs(fs, fd, coef)
    assert float(q[0]) < 1e-20
    # pair that avoids the zero entry is unaffected:
    fd2 = np.zeros((1, d), np.float32)
    q2 = model.edge_prob_pairs(fs, fd2, coef)
    np.testing.assert_allclose(float(q2[0]), 0.15**4, rtol=1e-5)


def test_theta_all_ones_gives_prob_one():
    d = 8
    theta = np.ones((d, 2, 2), np.float32)
    coef = model.theta_to_coef(theta)
    fs = rand_bits(RNG, 7, d)
    fd = rand_bits(RNG, 7, d)
    q = model.edge_prob_pairs(fs, fd, coef)
    np.testing.assert_allclose(np.asarray(q), np.ones(7), rtol=1e-6)


def test_pad_levels_is_neutral():
    d, d_pad = 5, 32
    theta = rand_theta(RNG, d)
    coef = model.theta_to_coef(theta)
    padded = model.pad_levels(coef, d_pad)
    fs = rand_bits(RNG, 64, d)
    fd = rand_bits(RNG, 64, d)
    # bits in the padded region must be ignored (zero coefficients):
    fs_pad = np.concatenate([fs, rand_bits(RNG, 64, d_pad - d)], axis=1)
    fd_pad = np.concatenate([fd, rand_bits(RNG, 64, d_pad - d)], axis=1)
    q = model.edge_prob_block(fs, fd, coef)
    q_pad = model.edge_prob_block(fs_pad, fd_pad, padded)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_pad),
                               rtol=1e-5, atol=1e-8)


def test_probabilities_in_unit_interval():
    d = 16
    theta = rand_theta(RNG, d, lo=0.0, hi=1.0)
    fs = rand_bits(RNG, 200, d)
    fd = rand_bits(RNG, 200, d)
    q = np.asarray(model.edge_prob_block(fs, fd, model.theta_to_coef(theta)))
    assert np.all(q >= 0.0) and np.all(q <= 1.0 + 1e-6)


@pytest.mark.parametrize("dtype", ["float64", "bfloat16", "int32", "bool"])
def test_model_wrappers_accept_other_dtypes(dtype):
    """The model wrappers normalize input dtypes to f32 before the kernel."""
    import jax.numpy as jnp_
    d = 6
    rng = np.random.default_rng(5)
    theta = rand_theta(rng, d)
    fs_f32 = rand_bits(rng, 40, d)
    fd_f32 = rand_bits(rng, 40, d)
    cast = jnp_.asarray(fs_f32).astype(dtype), jnp_.asarray(fd_f32).astype(dtype)
    want = model.edge_prob_block(fs_f32, fd_f32, model.theta_to_coef(theta))
    got = model.edge_prob_block(cast[0], cast[1], model.theta_to_coef(theta))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_block_kernel_custom_block_sizes():
    """Non-default tile sizes cover the same numerics (grid correctness)."""
    d = 7
    rng = np.random.default_rng(6)
    theta = rand_theta(rng, d)
    fs = rand_bits(rng, 64, d)
    fd = rand_bits(rng, 96, d)
    coef = model.theta_to_coef(theta)
    got = ek.edge_prob_block(jnp.asarray(fs), jnp.asarray(fd), coef,
                             block_m=32, block_n=32)
    want = ref.edge_prob_block_ref(jnp.asarray(fs), jnp.asarray(fd),
                                   jnp.asarray(theta))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-7)


def test_aot_artifacts_deterministic():
    """Lowering the same entry twice yields byte-identical HLO text (the
    manifest sha256 is meaningful)."""
    from compile import aot
    t1, r1 = aot.lower_entry("edge_prob_pairs")
    t2, r2 = aot.lower_entry("edge_prob_pairs")
    assert t1 == t2
    assert r1["sha256"] == r2["sha256"]
