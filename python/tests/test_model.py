"""Layer-2 model tests: KPGM structure, Kronecker identity, AOT lowering."""

import json
import os
import tempfile

import numpy as np
import pytest
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


THETA1 = np.array([[0.15, 0.7], [0.7, 0.85]], dtype=np.float32)


def stack(theta2x2, d):
    return np.broadcast_to(np.asarray(theta2x2, np.float32), (d, 2, 2)).copy()


def kron_power(theta2x2, d):
    p = np.asarray(theta2x2, np.float64)
    out = np.array([[1.0]])
    for _ in range(d):
        out = np.kron(out, p)
    return out


# ---------------------------------------------------------------------------
# KPGM identities (paper eq. 2 vs eq. 6).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", [1, 2, 3, 5])
def test_kpgm_prob_matrix_equals_kronecker_power(d):
    """model.kpgm_prob_matrix (bit-product form, eq. 6) must equal the
    explicit Kronecker power (eq. 2)."""
    theta = stack(THETA1, d)
    got = np.asarray(model.kpgm_prob_matrix(theta))
    want = kron_power(THETA1, d)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_kpgm_prob_matrix_heterogeneous_levels():
    """Per-level theta matrices: P = theta1 (x) theta2 (x) theta3."""
    rng = np.random.default_rng(7)
    theta = rng.uniform(0.1, 0.9, size=(3, 2, 2)).astype(np.float32)
    want = np.kron(np.kron(theta[0], theta[1]), theta[2])
    got = np.asarray(model.kpgm_prob_matrix(theta))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_kpgm_bits_msb_first():
    bits = np.asarray(model.kpgm_bits(8, 3))
    # node 0 -> 000, node 1 -> 001, node 6 -> 110
    np.testing.assert_array_equal(bits[0], [0, 0, 0])
    np.testing.assert_array_equal(bits[1], [0, 0, 1])
    np.testing.assert_array_equal(bits[6], [1, 1, 0])


def test_magm_equals_kpgm_under_identity_configuration():
    """Q_ij = P_{lambda_i lambda_j} (paper eq. 8): with lambda_i = i the MAGM
    edge-probability block IS the KPGM matrix."""
    d = 4
    theta = stack(THETA1, d)
    bits = model.kpgm_bits(2**d, d)
    q = model.edge_prob_block(bits, bits, model.theta_to_coef(theta))
    p = kron_power(THETA1, d)
    np.testing.assert_allclose(np.asarray(q), p, rtol=1e-5)


def test_magm_permutation_identity():
    """Permuting configurations permutes rows/cols of P — the quilting
    algorithm's central identity."""
    d = 3
    n = 2**d
    rng = np.random.default_rng(3)
    lam = rng.permutation(n)
    theta = stack(THETA1, d)
    bits_all = np.asarray(model.kpgm_bits(n, d))
    f = bits_all[lam]  # node i has configuration lam[i]
    q = np.asarray(model.edge_prob_block(f, f, model.theta_to_coef(theta)))
    p = kron_power(THETA1, d)
    np.testing.assert_allclose(q, p[np.ix_(lam, lam)], rtol=1e-5)


# ---------------------------------------------------------------------------
# AOT lowering.
# ---------------------------------------------------------------------------


def test_aot_lowering_all_entries(tmp_path):
    """Every entry lowers to parseable HLO text and the manifest matches."""
    records = []
    for name in aot.ENTRIES:
        text, record = aot.lower_entry(name)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        records.append(record)
        assert record["file"] == f"{name}.hlo.txt"
        # input arity in the manifest matches the entry spec
        assert len(record["inputs"]) == len(aot.ENTRIES[name][1])


def test_aot_shapes_contract():
    """The shape contract baked into the manifest matches aot constants."""
    _, record = aot.lower_entry("edge_prob_block")
    assert record["inputs"][0]["shape"] == [aot.BM, aot.D_PAD]
    assert record["inputs"][1]["shape"] == [aot.BN, aot.D_PAD]
    assert record["inputs"][2]["shape"] == [4, aot.D_PAD]
    assert record["outputs"][0]["shape"] == [aot.BM, aot.BN]


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "manifest.json"
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out", str(out), "--only", "edge_prob_pairs"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads(out.read_text())
    assert manifest["d_pad"] == aot.D_PAD
    assert len(manifest["entries"]) == 1
    hlo = tmp_path / manifest["entries"][0]["file"]
    assert hlo.exists() and hlo.read_text().startswith("HloModule")


def test_aot_entry_numerics_via_jit():
    """Executing the lowered entry's python fn at the contract shapes matches
    the oracle (the HLO itself is re-checked from Rust in integration tests)."""
    rng = np.random.default_rng(11)
    d = 9
    theta = rng.uniform(0.1, 0.9, size=(d, 2, 2)).astype(np.float32)
    coef = model.pad_levels(model.theta_to_coef(theta), aot.D_PAD)
    fs = np.zeros((aot.BM, aot.D_PAD), np.float32)
    fd = np.zeros((aot.BN, aot.D_PAD), np.float32)
    fs[:, :d] = rng.integers(0, 2, size=(aot.BM, d))
    fd[:, :d] = rng.integers(0, 2, size=(aot.BN, d))
    (q,) = aot.ENTRIES["edge_prob_block"][0](fs, fd, coef)
    want = ref.edge_prob_block_ref(jnp.asarray(fs[:, :d]),
                                   jnp.asarray(fd[:, :d]),
                                   jnp.asarray(theta))
    np.testing.assert_allclose(np.asarray(q), np.asarray(want),
                               rtol=5e-5, atol=1e-7)
