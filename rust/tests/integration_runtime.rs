//! Integration: the AOT artifacts (JAX/Pallas → HLO text) executed by the
//! PJRT runtime must agree numerically with the pure-Rust model — the
//! L1/L2 ↔ L3 contract.
//!
//! These tests need `make artifacts` to have run (the Makefile's `test`
//! target guarantees it).

use magquilt::kpgm::{Initiator, ThetaSeq};
use magquilt::magm::{self, AttributeAssignment, MagmParams};
use magquilt::rng::Rng;
use magquilt::runtime::{expected_out_degrees, naive_xla_sample, MagmKernels, XlaRuntime};

fn runtime() -> XlaRuntime {
    XlaRuntime::load_default().expect("run `make artifacts` before cargo test")
}

fn model(n: usize, d: u32, mu: f64) -> (MagmParams, AttributeAssignment) {
    let params = MagmParams::homogeneous(Initiator::THETA1, mu, n, d);
    let mut rng = Rng::new(11);
    let attrs = AttributeAssignment::sample(&params, &mut rng);
    (params, attrs)
}

#[test]
fn edge_prob_block_matches_pure_rust() {
    let rt = runtime();
    for d in [1u32, 7, 16, 32] {
        let (params, attrs) = model(300, d, 0.5);
        let kernels = MagmKernels::new(&rt, params.thetas());
        let src: Vec<u32> = (0..100).collect();
        let dst: Vec<u32> = (100..300).collect();
        let q = kernels.edge_prob_block(&attrs, &src, &dst).unwrap();
        assert_eq!(q.len(), src.len() * dst.len());
        for (r, &i) in src.iter().enumerate() {
            for (c, &j) in dst.iter().enumerate() {
                let want = magm::edge_probability(&params, &attrs, i, j);
                let got = q[r * dst.len() + c] as f64;
                assert!(
                    (got - want).abs() < 1e-5,
                    "d={d} cell ({i},{j}): {got} vs {want}"
                );
            }
        }
    }
}

#[test]
fn edge_prob_pairs_matches_pure_rust() {
    let rt = runtime();
    let (params, attrs) = model(500, 20, 0.7);
    let kernels = MagmKernels::new(&rt, params.thetas());
    let mut rng = Rng::new(13);
    let pairs: Vec<(u32, u32)> =
        (0..2000).map(|_| (rng.below(500) as u32, rng.below(500) as u32)).collect();
    let q = kernels.edge_prob_pairs(&attrs, &pairs).unwrap();
    for (idx, &(i, j)) in pairs.iter().enumerate() {
        let want = magm::edge_probability(&params, &attrs, i, j);
        assert!((q[idx] as f64 - want).abs() < 1e-5, "pair ({i},{j})");
    }
}

#[test]
fn heterogeneous_thetas_through_runtime() {
    let rt = runtime();
    let mut rng = Rng::new(17);
    let levels: Vec<Initiator> = (0..9)
        .map(|_| {
            Initiator::new([
                rng.uniform() * 0.9 + 0.05,
                rng.uniform() * 0.9 + 0.05,
                rng.uniform() * 0.9 + 0.05,
                rng.uniform() * 0.9 + 0.05,
            ])
        })
        .collect();
    let thetas = ThetaSeq::new(levels);
    let params = MagmParams::new(thetas.clone(), vec![0.5; 9], 200);
    let attrs = AttributeAssignment::sample(&params, &mut rng);
    let kernels = MagmKernels::new(&rt, &thetas);
    let src: Vec<u32> = (0..50).collect();
    let q = kernels.edge_prob_block(&attrs, &src, &src).unwrap();
    for (r, &i) in src.iter().enumerate() {
        for (c, &j) in src.iter().enumerate() {
            let want = magm::edge_probability(&params, &attrs, i, j);
            assert!((q[r * 50 + c] as f64 - want).abs() < 1e-5);
        }
    }
}

#[test]
fn expected_degree_contrib_matches_brute_force() {
    let rt = runtime();
    let (params, attrs) = model(128, 7, 0.5);
    let kernels = MagmKernels::new(&rt, params.thetas());
    let src: Vec<u32> = (0..64).collect();
    let dst: Vec<u32> = (64..128).collect();
    let counts: Vec<f32> = (0..64).map(|i| (i % 5 + 1) as f32).collect();
    let got = kernels.expected_degree_contrib(&attrs, &src, &dst, &counts).unwrap();
    for (r, &i) in src.iter().enumerate() {
        let want: f64 = dst
            .iter()
            .zip(&counts)
            .map(|(&j, &c)| c as f64 * magm::edge_probability(&params, &attrs, i, j))
            .sum();
        assert!(
            (got[r] as f64 - want).abs() < 1e-3 * want.max(1.0),
            "row {i}: {} vs {want}",
            got[r]
        );
    }
}

#[test]
fn expected_out_degrees_sum_matches_expected_edges() {
    let rt = runtime();
    let (params, attrs) = model(600, 10, 0.6);
    let deg = expected_out_degrees(&rt, &params, &attrs).unwrap();
    assert_eq!(deg.len(), 600);
    let total: f64 = deg.iter().sum();
    // Brute-force sum of Q over all pairs.
    let mut want = 0.0;
    for i in 0..600u32 {
        for j in 0..600u32 {
            want += magm::edge_probability(&params, &attrs, i, j);
        }
    }
    assert!((total - want).abs() / want < 1e-4, "{total} vs {want}");
}

#[test]
fn loglik_block_matches_pure_rust() {
    let rt = runtime();
    let (params, attrs) = model(96, 6, 0.5);
    let kernels = MagmKernels::new(&rt, params.thetas());
    let src: Vec<u32> = (0..48).collect();
    let dst: Vec<u32> = (48..96).collect();
    let mut rng = Rng::new(23);
    let adj: Vec<f32> =
        (0..src.len() * dst.len()).map(|_| rng.bernoulli(0.2) as u8 as f32).collect();
    let got = kernels.loglik_block(&attrs, &src, &dst, &adj).unwrap();
    let mut want = 0.0f64;
    for (r, &i) in src.iter().enumerate() {
        for (c, &j) in dst.iter().enumerate() {
            let q = magm::edge_probability(&params, &attrs, i, j).clamp(1e-12, 1.0 - 1e-12);
            let a = adj[r * dst.len() + c] as f64;
            want += a * q.ln() + (1.0 - a) * (1.0 - q).ln();
        }
    }
    assert!(
        (got - want).abs() < 1e-3 * want.abs().max(1.0),
        "{got} vs {want}"
    );
}

#[test]
fn naive_xla_sampler_rate_matches_expectation() {
    let rt = runtime();
    let (params, attrs) = model(700, 10, 0.5);
    // E|E| for the fixed attrs via the runtime itself (validated above).
    let deg = expected_out_degrees(&rt, &params, &attrs).unwrap();
    let want: f64 = deg.iter().sum();
    let trials = 10;
    let mut total = 0usize;
    let mut rng = Rng::new(29);
    for _ in 0..trials {
        let g = naive_xla_sample(&rt, &params, &attrs, &mut rng).unwrap();
        assert!(g.validate().is_ok());
        total += g.num_edges();
    }
    let mean = total as f64 / trials as f64;
    let sigma = (want / trials as f64).sqrt();
    assert!((mean - want).abs() < 6.0 * sigma, "mean={mean} want={want}");
}

#[test]
fn manifest_contract_sane() {
    let rt = runtime();
    let m = rt.manifest();
    assert!(m.d_pad >= 32);
    assert_eq!(m.entries.len(), 4);
    for name in ["edge_prob_block", "edge_prob_pairs", "expected_degree_contrib", "loglik_block"] {
        assert!(m.entry(name).is_ok(), "missing {name}");
    }
}

#[test]
fn missing_artifacts_dir_is_helpful_error() {
    let err = XlaRuntime::load(std::path::Path::new("/nonexistent/artifacts"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("make artifacts"), "{err}");
}

#[test]
fn wrong_input_arity_is_rejected() {
    let rt = runtime();
    let err = rt.execute_f32("edge_prob_block", &[&[0f32; 4]]).unwrap_err().to_string();
    assert!(err.contains("expected 3 inputs"), "{err}");
}

#[test]
fn wrong_input_shape_is_rejected() {
    let rt = runtime();
    let bad = vec![0f32; 7];
    let m = rt.manifest();
    let fs = vec![0f32; m.bm * m.d_pad];
    let fd = vec![0f32; m.bn * m.d_pad];
    let err = rt
        .execute_f32("edge_prob_block", &[&fs, &fd, &bad])
        .unwrap_err()
        .to_string();
    assert!(err.contains("elements"), "{err}");
}

#[test]
fn unknown_entry_is_rejected() {
    let rt = runtime();
    assert!(rt.execute_f32("no_such_entry", &[]).is_err());
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("magquilt_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(XlaRuntime::load(&dir).is_err());
}

#[test]
fn manifest_pointing_at_missing_hlo_is_rejected() {
    let dir = std::env::temp_dir().join("magquilt_missing_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "d_pad": 32, "bm": 512, "bn": 512, "bp": 8192,
            "entries": [{"name": "ghost", "file": "ghost.hlo.txt",
                         "inputs": [], "outputs": []}]}"#,
    )
    .unwrap();
    let err = XlaRuntime::load(&dir).unwrap_err().to_string();
    assert!(err.contains("ghost"), "{err}");
}
