//! Integration: cross-sampler agreement and whole-pipeline invariants.
//!
//! The four samplers (naive, quilt, hybrid, coordinated) implement the
//! same model; their sampled graphs must agree statistically for fixed
//! attribute assignments, across balanced and skewed μ.

use magquilt::coordinator::Coordinator;
use magquilt::graph::{Csr, EdgeList};
use magquilt::kpgm::Initiator;
use magquilt::magm::{naive_sample, AttributeAssignment, MagmParams};
use magquilt::quilt::{HybridSampler, Partition, QuiltSampler};
use magquilt::rng::Rng;
use magquilt::stats::summarize;

fn mean_edges<F: FnMut(u64) -> EdgeList>(trials: u64, mut f: F) -> f64 {
    let mut total = 0usize;
    for t in 0..trials {
        total += f(t).num_edges();
    }
    total as f64 / trials as f64
}

#[test]
fn all_samplers_agree_on_mean_edge_count() {
    for &mu in &[0.5, 0.8] {
        let n = 128;
        let d = 7;
        let params = MagmParams::homogeneous(Initiator::THETA1, mu, n, d);
        let mut rng = Rng::new(31);
        let attrs = AttributeAssignment::sample(&params, &mut rng);

        let trials = 40;
        let p1 = params.clone();
        let a1 = attrs.clone();
        let naive = mean_edges(trials, move |t| {
            let mut r = Rng::new(1000 + t);
            naive_sample(&p1, &a1, &mut r)
        });
        let p2 = params.clone();
        let a2 = attrs.clone();
        let quilt =
            mean_edges(trials, move |t| QuiltSampler::new(p2.clone()).seed(t).sample_with_attrs(&a2));
        let p3 = params.clone();
        let a3 = attrs.clone();
        let hybrid = mean_edges(trials, move |t| {
            HybridSampler::new(p3.clone()).seed(t).sample_with_attrs(&a3)
        });

        // naive is exact Bernoulli; quilting inherits Algorithm 1's
        // normal-approximation, allow 8% relative.
        assert!((quilt - naive).abs() / naive < 0.08, "mu={mu}: quilt {quilt} vs naive {naive}");
        assert!(
            (hybrid - naive).abs() / naive < 0.08,
            "mu={mu}: hybrid {hybrid} vs naive {naive}"
        );
    }
}

#[test]
fn coordinator_matches_sequential_at_scale() {
    let d = 12;
    let params = MagmParams::homogeneous(Initiator::THETA2, 0.5, 1 << d, d);
    let report = Coordinator::new().sample_quilt(&params, 77);
    let seq = QuiltSampler::new(params).seed(77).sample();
    let mut a = report.graph.into_edges();
    let mut b = seq.into_edges();
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b);
}

#[test]
fn sharded_coordinator_equivalence_property() {
    // Property sweep behind the sharded streaming merge: for several
    // seeds, every (shards, workers) combination reproduces the
    // sequential samplers' sorted edge list bit-for-bit, and the merge
    // never holds more than the post-dedup shard plus batch-sized
    // merge overhead.
    let d = 10;
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 1 << d, d);
    let skewed = MagmParams::homogeneous(Initiator::THETA1, 0.85, 1 << d, d);
    for seed in [3u64, 101] {
        let seq_quilt = QuiltSampler::new(params.clone()).seed(seed).sample();
        let seq_hybrid = HybridSampler::new(skewed.clone()).seed(seed).sample();
        for shards in [1usize, 3, 8] {
            for workers in [1usize, 4] {
                let coord = Coordinator::new().workers(workers).shards(shards);
                let rep = coord.sample_quilt(&params, seed);
                assert_eq!(
                    rep.graph, seq_quilt,
                    "quilt seed={seed} S={shards} workers={workers}"
                );
                for s in &rep.shard_stats {
                    assert!(
                        s.peak_resident <= s.edges + 2 * s.max_batch,
                        "seed={seed} S={shards}: shard {} peak {} > {} + 2 * {}",
                        s.shard, s.peak_resident, s.edges, s.max_batch
                    );
                }
                let rep = coord.sample_hybrid(&skewed, seed);
                assert_eq!(
                    rep.graph, seq_hybrid,
                    "hybrid seed={seed} S={shards} workers={workers}"
                );
            }
        }
    }
}

#[test]
fn binary_and_counting_sinks_agree_with_collect() {
    use magquilt::graph::{BinaryFileSink, CountingSink};
    let d = 10;
    let params = MagmParams::homogeneous(Initiator::THETA2, 0.5, 1 << d, d);
    let coord = Coordinator::new().workers(4).shards(4);
    let rep = coord.sample_quilt(&params, 55);

    let dir = std::env::temp_dir().join("magquilt_sink_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("quilt.bin");
    let (written, _) = coord
        .sample_quilt_with_sink(&params, 55, BinaryFileSink::create(&path))
        .unwrap();
    assert_eq!(written, rep.graph.num_edges() as u64);
    let reread = magquilt::graph::read_edge_list_binary(&path).unwrap();
    assert_eq!(reread, rep.graph, "BinaryFileSink re-read must equal CollectSink");

    let (counts, _) = coord
        .sample_quilt_with_sink(&params, 55, CountingSink::new())
        .unwrap();
    assert_eq!(counts.num_edges, rep.graph.num_edges() as u64);
    assert_eq!(counts.out_degrees, rep.graph.out_degrees());
    assert_eq!(counts.in_degrees, rep.graph.in_degrees());
}

#[test]
fn forced_spill_binary_sink_equivalence_sweep() {
    // Satellite of the out-of-order sink rework: with a zero in-memory
    // budget every shard that finishes ahead of the binary file frontier
    // detours through a spill file, and the re-read output must still be
    // bit-for-bit the sequential samplers' — for quilt and hybrid alike.
    use magquilt::graph::BinaryFileSink;
    let d = 10;
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 1 << d, d);
    let skewed = MagmParams::homogeneous(Initiator::THETA1, 0.85, 1 << d, d);
    let seq_quilt = QuiltSampler::new(params.clone()).seed(19).sample();
    let seq_hybrid = HybridSampler::new(skewed.clone()).seed(19).sample();
    let dir = std::env::temp_dir().join("magquilt_spill_integration");
    std::fs::create_dir_all(&dir).unwrap();
    for shards in [1usize, 3, 8] {
        for workers in [1usize, 4] {
            let coord = Coordinator::new().workers(workers).shards(shards);
            let path = dir.join(format!("quilt_{shards}_{workers}.bin"));
            let sink = BinaryFileSink::create(&path).spill_dir(&dir).spill_budget(0);
            let (written, stats) = coord.sample_quilt_with_sink(&params, 19, sink).unwrap();
            assert_eq!(written, seq_quilt.num_edges() as u64);
            let back = magquilt::graph::read_edge_list_binary(&path).unwrap();
            assert_eq!(back, seq_quilt, "quilt S={shards} workers={workers}");
            // Sink-side accounting stays consistent; the merger-side
            // residency bound is unaffected by delivery order.
            assert_eq!(
                stats.spill.spilled_shards,
                stats.shard_stats.iter().filter(|s| s.spill_runs > 0).count()
            );
            for s in &stats.shard_stats {
                assert!(s.peak_resident <= s.edges + 2 * s.max_batch);
            }

            let path = dir.join(format!("hybrid_{shards}_{workers}.bin"));
            let sink = BinaryFileSink::create(&path).spill_dir(&dir).spill_budget(0);
            let (written, _) = coord.sample_hybrid_with_sink(&skewed, 19, sink).unwrap();
            assert_eq!(written, seq_hybrid.num_edges() as u64);
            let back = magquilt::graph::read_edge_list_binary(&path).unwrap();
            assert_eq!(back, seq_hybrid, "hybrid S={shards} workers={workers}");
        }
    }
    // No spill temp files may survive the runs (spill runs live under the
    // shared pid+nonce temp naming scheme, `magquilt-tmp-*`).
    let leftovers = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().file_name().to_string_lossy().starts_with("magquilt-tmp-")
        })
        .count();
    assert_eq!(leftovers, 0, "spill temp files leaked");
}

#[test]
fn partition_size_stays_near_log2n_at_mu_half() {
    // Theorem 4 (statistically): B <= log2 n whp; in practice much lower
    // (paper Fig. 5). Check over several sizes/seeds with slack.
    for d in [10u32, 12, 14] {
        let n = 1usize << d;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
        for seed in 0..5 {
            let mut rng = Rng::new(seed);
            let attrs = AttributeAssignment::sample(&params, &mut rng);
            let b = Partition::build(attrs.configs()).size();
            assert!(b as u32 <= d + 2, "d={d} seed={seed}: B={b}");
        }
    }
}

#[test]
fn partition_grows_like_n_mu_d_at_high_mu() {
    // Fig. 6's regime: at mu = 0.9 the all-ones config dominates and
    // B ≈ n mu^d.
    let d = 10u32;
    let n = 1usize << d;
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.9, n, d);
    let mut rng = Rng::new(3);
    let attrs = AttributeAssignment::sample(&params, &mut rng);
    let b = Partition::build(attrs.configs()).size() as f64;
    let approx = n as f64 * 0.9f64.powi(d as i32);
    assert!(b > 0.5 * approx && b < 2.0 * approx, "B={b} vs n mu^d = {approx:.1}");
}

#[test]
fn generated_graph_statistics_are_consistent() {
    let d = 12;
    let n = 1usize << d;
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
    let g = QuiltSampler::new(params.clone()).seed(8).sample();
    let s = summarize(&g, 500, 9);
    assert_eq!(s.num_nodes, n);
    assert!(s.num_edges > 0);
    assert!(s.scc_fraction > 0.0 && s.scc_fraction <= 1.0);
    assert!(s.wcc_fraction >= s.scc_fraction);
    assert!((s.mean_degree - s.num_edges as f64 / n as f64).abs() < 1e-9);
    // |E| should be within a factor ~2 of the analytic expectation over
    // attribute draws.
    let expect = params.expected_edges();
    let ratio = s.num_edges as f64 / expect;
    assert!(ratio > 0.4 && ratio < 2.5, "edges {} vs E {expect}", s.num_edges);
}

#[test]
fn scc_fraction_increases_with_n() {
    // Paper Fig. 9's shape: fraction of nodes in the largest SCC grows.
    let frac = |d: u32| -> f64 {
        let n = 1usize << d;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
        let g = QuiltSampler::new(params).seed(19).sample();
        let csr = Csr::from_edge_list(&g);
        magquilt::graph::largest_scc_size(&csr) as f64 / n as f64
    };
    let small = frac(7);
    let large = frac(13);
    assert!(
        large > small,
        "SCC fraction should grow with n: {small:.3} -> {large:.3}"
    );
    assert!(large > 0.5, "large-n SCC fraction should approach 1: {large:.3}");
}

#[test]
fn hybrid_handles_extreme_mu_zero_and_one() {
    for &mu in &[0.0, 1.0] {
        let params = MagmParams::homogeneous(Initiator::THETA1, mu, 256, 8);
        let g = HybridSampler::new(params.clone()).seed(1).sample();
        assert!(g.validate().is_ok());
        // all nodes share one config -> Q is constant = theta^d on that
        // config; check edge density roughly.
        let c: u64 = if mu == 1.0 { (1 << 8) - 1 } else { 0 };
        let p = magquilt::kpgm::edge_probability(params.thetas(), c as u32, c as u32);
        let want = p * 256.0 * 256.0;
        let got = g.num_edges() as f64;
        let sigma = (want.max(1.0)).sqrt();
        assert!((got - want).abs() < 6.0 * sigma + 3.0, "mu={mu}: {got} vs {want}");
    }
}

#[test]
fn quilt_sampler_single_node_and_tiny_graphs() {
    for n in [1usize, 2, 3] {
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.5, n, 4);
        let g = QuiltSampler::new(params).seed(5).sample();
        assert_eq!(g.num_nodes(), n);
        assert!(g.validate().is_ok());
    }
}
