//! Integration: the distributed sampling runtime end-to-end.
//!
//! The library-level tests drive the real worker + merge code paths
//! in-process (every worker is just a function of the plan, so spawning
//! OS processes adds nothing but flakiness there); the CLI tests at the
//! bottom spawn the actual `magquilt` binary to cover the
//! driver/subcommand surface, including true multi-process execution.

use std::path::{Path, PathBuf};

use magquilt::config::{ModelSpec, RunSpec, SamplerKind};
use magquilt::coordinator::Coordinator;
use magquilt::dist::{self, ShardPlan};
use magquilt::graph::{read_edge_list_binary, BinaryFileSink, EdgeList, DEFAULT_SPILL_BUDGET};
use magquilt::kpgm::Initiator;
use magquilt::magm::{AttrSampleMode, AttributeAssignment, MagmParams};
use magquilt::quilt::{HybridSampler, PieceMode, QuiltSampler};
use magquilt::rng::Rng;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("magquilt_dist_test").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn model(log2n: u32, mu: f64) -> ModelSpec {
    let mut m = ModelSpec::default_spec();
    m.log2_nodes = log2n;
    m.attributes = log2n;
    m.mu = mu;
    m
}

fn params_of(model: &ModelSpec) -> MagmParams {
    MagmParams::homogeneous(
        Initiator::new(model.theta),
        model.mu,
        model.num_nodes(),
        model.attributes,
    )
}

/// Run every worker of `plan` in-process, then merge into `out`.
///
/// Before the final (input-consuming) merge, the parallel merge is
/// exercised: `--merge-threads` ∈ {2, 8} — plus 8 under a zero spill
/// budget, forcing every out-of-order delivery through a spill file —
/// must write files byte-identical to the serial T = 1 merge, for every
/// sampler, piece mode, and worker count the callers sweep. The scratch
/// outputs live in a sibling directory: the scan owns every name inside
/// the segment dir itself.
fn run_pipeline(plan: &ShardPlan, dir: &Path, out: &Path) -> dist::MergeReport {
    for w in 0..plan.num_workers() {
        let report = dist::run_worker(plan, w, dir).unwrap();
        assert_eq!(report.worker, w);
        assert_eq!(
            report.summary.owned_segments,
            report.owned.1 - report.owned.0,
            "worker {w} wrote every owned shard"
        );
    }
    let aux = dir.with_file_name(format!(
        "{}_aux",
        dir.file_name().unwrap().to_string_lossy()
    ));
    let _ = std::fs::remove_dir_all(&aux);
    std::fs::create_dir_all(&aux).unwrap();
    let serial_out = aux.join("serial.bin");
    let serial = dist::merge_segments_with(
        dir,
        plan,
        &serial_out,
        &dist::MergeOptions { merge_threads: 1, remove_inputs: false, ..Default::default() },
    )
    .unwrap();
    assert_eq!(serial.merge_threads, 1);
    let serial_bytes = std::fs::read(&serial_out).unwrap();
    for (threads, budget) in [(2usize, DEFAULT_SPILL_BUDGET), (8, DEFAULT_SPILL_BUDGET), (8, 0)]
    {
        let par_out = aux.join(format!("t{threads}_b{budget}.bin"));
        let rep = dist::merge_segments_with(
            dir,
            plan,
            &par_out,
            &dist::MergeOptions {
                merge_threads: threads,
                spill_budget: budget,
                remove_inputs: false,
            },
        )
        .unwrap();
        assert_eq!(
            std::fs::read(&par_out).unwrap(),
            serial_bytes,
            "parallel merge T={threads} budget={budget} must be byte-identical"
        );
        assert_eq!(rep.shards, serial.shards, "rows T={threads} budget={budget}");
    }
    let _ = std::fs::remove_dir_all(&aux);
    dist::merge_segments(dir, plan, out, true).unwrap()
}

/// The sequential baseline a distributed run must reproduce bit-for-bit:
/// the plain single-threaded sampler fed the plan's (chunked) attributes.
fn sequential_baseline(plan: &ShardPlan) -> EdgeList {
    let params = params_of(&plan.model);
    let attrs = match plan.attr_mode {
        AttrSampleMode::Chunked => {
            AttributeAssignment::sample_chunked(&params, &Rng::new(plan.seed), 1)
        }
        AttrSampleMode::Sequential => {
            AttributeAssignment::sample(&params, &mut Rng::new(plan.seed))
        }
    };
    match plan.sampler {
        SamplerKind::Hybrid => HybridSampler::new(params)
            .piece_mode(plan.piece_mode)
            .seed(plan.seed)
            .sample_with_attrs(&attrs),
        _ => QuiltSampler::new(params)
            .piece_mode(plan.piece_mode)
            .seed(plan.seed)
            .sample_with_attrs(&attrs),
    }
}

#[test]
fn distributed_equals_sequential_bit_for_bit() {
    // The acceptance matrix: W ∈ {1, 2, 4} worker processes × both
    // samplers × both piece modes must reproduce the sequential samplers'
    // output exactly — same edges, same order — and the merged binary
    // must be byte-identical to the single-process binary sink's file.
    for (sampler, mu, seed) in
        [(SamplerKind::Quilt, 0.5, 17u64), (SamplerKind::Hybrid, 0.85, 23)]
    {
        let m = model(8, mu);
        let mut run = RunSpec::default_spec();
        run.sampler = sampler;
        run.seed = seed;
        run.shards = 5; // deliberately uneven across {1, 2, 4} workers
        for mode in [PieceMode::Conditioned, PieceMode::Rejection] {
            run.piece_mode = mode;
            let mut single_bytes: Option<Vec<u8>> = None;
            for workers in [1usize, 2, 4] {
                let tag = format!("{}_{mode:?}_{workers}", run.sampler.name());
                let plan = ShardPlan::new(&m, &run, workers).unwrap();
                assert_eq!(plan.num_workers(), workers);
                let dir = tmp(&format!("eq_{tag}"));
                let out = dir.join("merged.bin");
                run_pipeline(&plan, &dir, &out);
                let merged = read_edge_list_binary(&out).unwrap();
                let seq = sequential_baseline(&plan);
                assert_eq!(merged, seq, "{tag} vs sequential");

                // Byte-for-byte against the single-process binary sink.
                let single = single_bytes.get_or_insert_with(|| {
                    let path = dir.join("single.bin");
                    let coord = Coordinator::new()
                        .shards(plan.num_shards)
                        .attr_mode(plan.attr_mode)
                        .piece_mode(plan.piece_mode);
                    let params = params_of(&m);
                    let sink = BinaryFileSink::create(&path);
                    match sampler {
                        SamplerKind::Hybrid => {
                            coord.sample_hybrid_with_sink(&params, seed, sink).unwrap()
                        }
                        _ => coord.sample_quilt_with_sink(&params, seed, sink).unwrap(),
                    };
                    std::fs::read(&path).unwrap()
                });
                assert_eq!(
                    &std::fs::read(&out).unwrap(),
                    single,
                    "{tag} merged file vs single-process bytes"
                );
                // The merge drained its inputs.
                let leftover = std::fs::read_dir(&dir)
                    .unwrap()
                    .filter(|e| {
                        let n = e.as_ref().unwrap().file_name();
                        let n = n.to_string_lossy().into_owned();
                        n.ends_with(".seg") || n.ends_with(".ovf")
                    })
                    .count();
                assert_eq!(leftover, 0, "{tag} segment dir drained");
            }
        }
    }
}

#[test]
fn forced_overflow_routes_cross_worker_edges() {
    // With several narrow worker ranges, the multiplicity-1 set D_1 (and
    // any other wide-span job) necessarily samples edges whose source
    // shard belongs to another worker: those must surface as overflow
    // files and still merge to the exact sequential output. The RNG is
    // deterministic, so once a seed exercises the path it does forever.
    let m = model(8, 0.5);
    let mut run = RunSpec::default_spec();
    run.shards = 8;
    let mut saw_overflow = false;
    for seed in [17u64, 18, 19] {
        run.seed = seed;
        let plan = ShardPlan::new(&m, &run, 4).unwrap();
        let dir = tmp(&format!("overflow_{seed}"));
        // Count overflow files before the merge consumes them.
        for w in 0..plan.num_workers() {
            dist::run_worker(&plan, w, &dir).unwrap();
        }
        let ovf_files = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().ends_with(".ovf")
            })
            .count();
        // A parallel merge with a zero spill budget on this
        // overflow-heavy layout (output in a sibling dir: the scan owns
        // every name in the segment dir) …
        let aux = tmp(&format!("overflow_{seed}_aux"));
        let par_out = aux.join("par.bin");
        dist::merge_segments_with(
            &dir,
            &plan,
            &par_out,
            &dist::MergeOptions { merge_threads: 8, spill_budget: 0, remove_inputs: false },
        )
        .unwrap();
        // … must byte-match the serial consuming merge.
        let out = dir.join("merged.bin");
        let report = dist::merge_segments(&dir, &plan, &out, true).unwrap();
        assert_eq!(
            std::fs::read(&par_out).unwrap(),
            std::fs::read(&out).unwrap(),
            "forced-spill parallel merge differs at seed {seed}"
        );
        assert_eq!(report.overflow_runs(), ovf_files);
        assert_eq!(read_edge_list_binary(&out).unwrap(), sequential_baseline(&plan), "seed {seed}");
        if ovf_files > 0 {
            saw_overflow = true;
        }
    }
    assert!(saw_overflow, "no seed exercised the overflow path — widen the sweep");
}

#[test]
fn every_job_is_owned_exactly_once() {
    // The span-ownership rule must partition the job set: each worker's
    // filtered slice is disjoint from the others and their union is the
    // whole plan — for both samplers and any worker count.
    for (sampler, mu) in [(SamplerKind::Quilt, 0.5), (SamplerKind::Hybrid, 0.85)] {
        let m = model(8, mu);
        let mut run = RunSpec::default_spec();
        run.sampler = sampler;
        run.shards = 6;
        for workers in [1usize, 2, 3, 4] {
            let plan = ShardPlan::new(&m, &run, workers).unwrap();
            let coord = dist::worker::plan_coordinator(&plan);
            let (job_plan, _) = dist::worker::build_job_plan(&plan, &coord);
            let owners = dist::job_owners(&plan, &job_plan);
            assert_eq!(owners.len(), job_plan.len());
            assert!(
                owners.iter().all(|&o| o < plan.num_workers()),
                "owner out of range ({} workers)",
                plan.num_workers()
            );
            // Each job has exactly one owner by construction; the
            // per-worker slice sizes must sum back to the plan.
            let mut per_worker = vec![0usize; plan.num_workers()];
            for &o in &owners {
                per_worker[o] += 1;
            }
            assert_eq!(per_worker.iter().sum::<usize>(), job_plan.len(), "{sampler:?} W={workers}");
            if workers == 1 {
                assert_eq!(per_worker[0], job_plan.len(), "single worker owns everything");
            }
        }
    }
}

#[test]
fn plan_manifest_roundtrips_through_disk() {
    let m = model(9, 0.5);
    let mut run = RunSpec::default_spec();
    run.seed = 99;
    run.shards = 7;
    run.piece_mode = PieceMode::Rejection;
    let plan = ShardPlan::new(&m, &run, 3).unwrap();
    let dir = tmp("plan_roundtrip");
    let path = dir.join("plan.toml");
    plan.save(&path).unwrap();
    let back = ShardPlan::load(&path).unwrap();
    assert_eq!(back, plan);
    // The reloaded plan produces the identical job assignment.
    let coord = dist::worker::plan_coordinator(&plan);
    let (jobs_a, _) = dist::worker::build_job_plan(&plan, &coord);
    let (jobs_b, _) = dist::worker::build_job_plan(&back, &coord);
    assert_eq!(dist::job_owners(&plan, &jobs_a), dist::job_owners(&back, &jobs_b));
}

#[test]
fn stats_inspects_segment_directory_and_rejects_mixed_hashes() {
    let m = model(8, 0.5);
    let mut run = RunSpec::default_spec();
    run.seed = 7;
    run.shards = 4;
    let plan = ShardPlan::new(&m, &run, 2).unwrap();
    let dir = tmp("stats_dir");
    plan.save(&dir.join(dist::PLAN_FILE)).unwrap();
    for w in 0..plan.num_workers() {
        dist::run_worker(&plan, w, &dir).unwrap();
    }
    // The stats CLI reads the directory (plan discovered at plan.toml).
    magquilt::cli::run(&["stats".to_string(), dir.to_str().unwrap().to_string()]).unwrap();
    // Validation numbers agree with a real merge (output outside the
    // segment dir — the scan owns every name inside it).
    let inspect = dist::validate_segments(&dir, &plan).unwrap();
    let out = tmp("stats_dir_out").join("merged.bin");
    let merged = dist::merge_segments(&dir, &plan, &out, false).unwrap();
    assert_eq!(inspect.total_edges, merged.total_edges);
    // Drop in a segment from a different plan: inspection must refuse.
    let mut other_run = run.clone();
    other_run.seed = 8;
    let other = ShardPlan::new(&m, &other_run, 2).unwrap();
    let stray = dir.join(dist::segment_file_name(&other.hash_hex(), 0, 0));
    std::fs::write(&stray, b"whatever").unwrap();
    assert!(dist::validate_segments(&dir, &plan).is_err(), "mixed plan hashes accepted");
    assert!(
        magquilt::cli::run(&["stats".to_string(), dir.to_str().unwrap().to_string()]).is_err()
    );
}

#[test]
fn stats_reads_binary_by_magic_not_extension() {
    // A segment file is a complete MAGQEDG1 edge list under a .seg name:
    // stats must recognize it by magic bytes.
    let m = model(7, 0.5);
    let mut run = RunSpec::default_spec();
    run.shards = 2;
    let plan = ShardPlan::new(&m, &run, 1).unwrap();
    let dir = tmp("magic_sniff");
    for w in 0..plan.num_workers() {
        dist::run_worker(&plan, w, &dir).unwrap();
    }
    let seg = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .find(|p| p.extension().is_some_and(|e| e == "seg"))
        .expect("worker wrote a segment");
    magquilt::cli::run(&["stats".to_string(), seg.to_str().unwrap().to_string()]).unwrap();
}

#[test]
fn crash_and_resume_is_byte_identical_across_crash_points() {
    // The fault-tolerance acceptance matrix: crash worker 0 at every
    // reachable window — after K ∈ {0, 1, mid} owned segments, before an
    // atomic rename, mid-body-write, and after everything but the
    // completion marker — then resume it, run the rest, and merge. The
    // result must be byte-identical to the crash-free run, for both
    // samplers × both piece modes × W ∈ {2, 4}.
    for (sampler, mu, seed) in
        [(SamplerKind::Quilt, 0.5, 17u64), (SamplerKind::Hybrid, 0.85, 23)]
    {
        let m = model(7, mu);
        let mut run = RunSpec::default_spec();
        run.sampler = sampler;
        run.seed = seed;
        run.shards = 6;
        for mode in [PieceMode::Conditioned, PieceMode::Rejection] {
            run.piece_mode = mode;
            for workers in [2usize, 4] {
                let plan = ShardPlan::new(&m, &run, workers).unwrap();
                let tag = format!("{}_{mode:?}_{workers}", run.sampler.name());

                // Crash-free baseline.
                let dir = tmp(&format!("crash_base_{tag}"));
                let base_out = dir.join("merged.bin");
                for w in 0..plan.num_workers() {
                    dist::run_worker(&plan, w, &dir).unwrap();
                }
                dist::merge_segments(&dir, &plan, &base_out, true).unwrap();
                let baseline = std::fs::read(&base_out).unwrap();

                let (lo, hi) = plan.worker_range(0).unwrap();
                let width = hi - lo;
                let mut specs = vec![
                    "crash-before-marker".to_string(),
                    "crash-before-rename".to_string(),
                    format!("fail-write-shard={lo}"),
                ];
                for k in [0, 1, width / 2] {
                    let s = format!("crash-after-segments={k}");
                    if k < width && !specs.contains(&s) {
                        specs.push(s);
                    }
                }
                for spec in &specs {
                    let dir = tmp(&format!("crash_{tag}_{spec}"));
                    let opts = dist::WorkerOptions {
                        resume: true,
                        artifact: None,
                        fault: Some(dist::FaultPlan::parse(spec).unwrap()),
                    };
                    let err = dist::run_worker_with(&plan, 0, &dir, &opts)
                        .expect_err(&format!("{tag} {spec}: fault must fire"));
                    assert!(
                        format!("{err:#}").contains("injected fault"),
                        "{tag} {spec}: unexpected error {err:#}"
                    );
                    // A crashed attempt may leak an in-flight temp file —
                    // exactly what the driver sweeps once the process is
                    // provably dead. Do the same before resuming.
                    for e in std::fs::read_dir(&dir).unwrap() {
                        let e = e.unwrap();
                        if e.file_name().to_string_lossy().starts_with("magquilt-tmp-") {
                            std::fs::remove_file(e.path()).unwrap();
                        }
                    }
                    let resumed = dist::run_worker_with(
                        &plan,
                        0,
                        &dir,
                        &dist::WorkerOptions { resume: true, artifact: None, fault: None },
                    )
                    .unwrap();
                    assert_eq!(
                        resumed.summary.owned_segments, width,
                        "{tag} {spec}: resume must land every owned shard"
                    );
                    for w in 1..plan.num_workers() {
                        dist::run_worker(&plan, w, &dir).unwrap();
                    }
                    let out = dir.join("merged.bin");
                    dist::merge_segments(&dir, &plan, &out, true).unwrap();
                    assert_eq!(
                        std::fs::read(&out).unwrap(),
                        baseline,
                        "{tag} {spec}: resumed output differs from crash-free run"
                    );
                    let leftover: Vec<String> = std::fs::read_dir(&dir)
                        .unwrap()
                        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
                        .filter(|n| n != "merged.bin")
                        .collect();
                    assert!(leftover.is_empty(), "{tag} {spec}: not drained: {leftover:?}");
                }
            }
        }
    }
}

#[test]
fn resume_after_marker_skips_all_work_and_changes_nothing() {
    // A worker that already finished (marker on disk) must resume to a
    // no-op: identical directory bytes, zero jobs run.
    let m = model(7, 0.5);
    let mut run = RunSpec::default_spec();
    run.shards = 4;
    let plan = ShardPlan::new(&m, &run, 2).unwrap();
    let dir = tmp("resume_noop");
    let first = dist::run_worker_with(
        &plan,
        0,
        &dir,
        &dist::WorkerOptions { resume: true, artifact: None, fault: None },
    )
    .unwrap();
    let snapshot: Vec<(String, Vec<u8>)> = {
        let mut v: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| {
                let e = e.unwrap();
                (
                    e.file_name().to_string_lossy().into_owned(),
                    std::fs::read(e.path()).unwrap(),
                )
            })
            .collect();
        v.sort();
        v
    };
    let again = dist::run_worker_with(
        &plan,
        0,
        &dir,
        &dist::WorkerOptions { resume: true, artifact: None, fault: None },
    )
    .unwrap();
    assert_eq!(again.jobs_run, 0, "trusted marker must skip every job");
    assert_eq!(again.summary, first.summary);
    let after: Vec<(String, Vec<u8>)> = {
        let mut v: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| {
                let e = e.unwrap();
                (
                    e.file_name().to_string_lossy().into_owned(),
                    std::fs::read(e.path()).unwrap(),
                )
            })
            .collect();
        v.sort();
        v
    };
    assert_eq!(after, snapshot, "marker fast path must not touch the directory");
}

// ---------------------------------------------------------------------
// True multi-process coverage: spawn the real magquilt binary.
// ---------------------------------------------------------------------

fn magquilt_bin() -> &'static str {
    env!("CARGO_BIN_EXE_magquilt")
}

fn run_bin(args: &[&str]) -> std::process::Output {
    std::process::Command::new(magquilt_bin())
        .args(args)
        .output()
        .expect("spawning magquilt")
}

fn assert_success(out: &std::process::Output, what: &str) {
    assert!(
        out.status.success(),
        "{what} failed: {}\n--- stdout\n{}\n--- stderr\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
}

#[test]
fn cli_driver_spawns_workers_and_matches_single_process() {
    let dir = tmp("cli_driver");
    let dist_out = dir.join("dist.bin");
    let seg_dir = dir.join("segs");
    let single_out = dir.join("single.bin");
    let out = run_bin(&[
        "sample", "--log2-nodes", "8", "--seed", "7", "--shards", "6",
        "--dist-workers", "2",
        "--segment-dir", seg_dir.to_str().unwrap(),
        "--out", dist_out.to_str().unwrap(),
    ]);
    assert_success(&out, "dist driver");
    // The single-process baseline with the dist default attribute mode.
    let out = run_bin(&[
        "sample", "--log2-nodes", "8", "--seed", "7", "--shards", "6",
        "--attr-mode", "chunked", "--sink", "binary",
        "--out", single_out.to_str().unwrap(),
    ]);
    assert_success(&out, "single-process baseline");
    assert_eq!(
        std::fs::read(&dist_out).unwrap(),
        std::fs::read(&single_out).unwrap(),
        "distributed output must be byte-identical to the single-process file"
    );
    // The driver drained (and removed) its segment directory.
    assert!(
        !seg_dir.exists() || std::fs::read_dir(&seg_dir).unwrap().next().is_none(),
        "segment dir not drained"
    );
    // And the output validates through stats.
    assert_success(&run_bin(&["stats", dist_out.to_str().unwrap()]), "stats re-read");
}

#[test]
fn cli_standalone_worker_and_merge_pipeline() {
    // The multi-host runbook, executed locally: shard-plan, one
    // shard-worker invocation per worker, stats on the directory, then
    // merge-segments — against the driver's output for the same plan.
    let dir = tmp("cli_runbook");
    let plan_path = dir.join("plan.toml");
    let seg_dir = dir.join("segs");
    std::fs::create_dir_all(&seg_dir).unwrap();
    let out = run_bin(&[
        "shard-plan", "--log2-nodes", "8", "--seed", "11", "--shards", "5",
        "--dist-workers", "2", "--plan-out", plan_path.to_str().unwrap(),
    ]);
    assert_success(&out, "shard-plan");
    for w in ["0", "1"] {
        let out = run_bin(&[
            "shard-worker", "--plan", plan_path.to_str().unwrap(),
            "--worker", w, "--segment-dir", seg_dir.to_str().unwrap(),
        ]);
        assert_success(&out, &format!("shard-worker {w}"));
    }
    // Pre-merge inspection over an explicit plan path.
    let out = run_bin(&[
        "stats", seg_dir.to_str().unwrap(), "--plan", plan_path.to_str().unwrap(),
    ]);
    assert_success(&out, "stats segment dir");
    // A parallel rehearsal merge first (segments kept, output beside —
    // not inside — the segment dir): it must report its thread count
    // and byte-match the consuming serial merge below.
    let merged_par = dir.join("merged_par.bin");
    let out = run_bin(&[
        "merge-segments", "--segments", seg_dir.to_str().unwrap(),
        "--plan", plan_path.to_str().unwrap(),
        "--merge-threads", "4",
        "--out", merged_par.to_str().unwrap(),
    ]);
    assert_success(&out, "merge-segments --merge-threads 4");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("4 merge thread"),
        "merge timing line missing from:\n{stdout}"
    );
    let merged = dir.join("merged.bin");
    let out = run_bin(&[
        "merge-segments", "--segments", seg_dir.to_str().unwrap(),
        "--plan", plan_path.to_str().unwrap(),
        "--out", merged.to_str().unwrap(), "--remove-segments",
    ]);
    assert_success(&out, "merge-segments");
    assert_eq!(std::fs::read_dir(&seg_dir).unwrap().count(), 0, "--remove-segments drained");
    assert_eq!(std::fs::read(&merged_par).unwrap(), std::fs::read(&merged).unwrap());
    // Equal to the all-in-one driver for the same spec.
    let driver_out = dir.join("driver.bin");
    let out = run_bin(&[
        "sample", "--log2-nodes", "8", "--seed", "11", "--shards", "5",
        "--dist-workers", "2", "--out", driver_out.to_str().unwrap(),
    ]);
    assert_success(&out, "driver");
    assert_eq!(std::fs::read(&merged).unwrap(), std::fs::read(&driver_out).unwrap());
}

#[test]
fn cli_driver_supervises_injected_crash_and_matches_single_process() {
    // Inject a deterministic crash into worker 1's first attempt: the
    // supervisor must restart it with --resume and the final file must
    // still be byte-identical to the single-process run.
    let dir = tmp("cli_crash_supervised");
    let seg_dir = dir.join("segs");
    let dist_out = dir.join("dist.bin");
    let single_out = dir.join("single.bin");
    let out = run_bin(&[
        "sample", "--log2-nodes", "8", "--seed", "7", "--shards", "6",
        "--dist-workers", "2",
        "--worker-retries", "2", "--worker-backoff-ms", "10",
        "--inject-fault", "crash-after-segments=1@w1",
        "--segment-dir", seg_dir.to_str().unwrap(),
        "--out", dist_out.to_str().unwrap(),
    ]);
    assert_success(&out, "supervised dist driver");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("1 worker restart(s) recovered by resume"),
        "restart line missing from:\n{stdout}"
    );
    assert!(stdout.contains("from 2 worker(s)"), "merge line missing from:\n{stdout}");
    let out = run_bin(&[
        "sample", "--log2-nodes", "8", "--seed", "7", "--shards", "6",
        "--attr-mode", "chunked", "--sink", "binary",
        "--out", single_out.to_str().unwrap(),
    ]);
    assert_success(&out, "single-process baseline");
    assert_eq!(
        std::fs::read(&dist_out).unwrap(),
        std::fs::read(&single_out).unwrap(),
        "crash-injected supervised run must still be byte-identical"
    );
    assert!(
        !seg_dir.exists() || std::fs::read_dir(&seg_dir).unwrap().next().is_none(),
        "segment dir not drained after supervised recovery"
    );
}

#[test]
fn cli_driver_exhausted_retries_then_rerun_resumes() {
    // With a zero retry budget the injected crash is fatal; the segments
    // survive, and rerunning the same command (no fault) resumes from
    // them and completes byte-identically.
    let dir = tmp("cli_crash_exhausted");
    let seg_dir = dir.join("segs");
    let dist_out = dir.join("dist.bin");
    let single_out = dir.join("single.bin");
    let failing = [
        "sample", "--log2-nodes", "8", "--seed", "7", "--shards", "6",
        "--dist-workers", "2",
        "--worker-retries", "0", "--worker-backoff-ms", "10",
        "--inject-fault", "crash-after-segments=0@w0",
        "--segment-dir", seg_dir.to_str().unwrap(),
        "--out", dist_out.to_str().unwrap(),
    ];
    let out = run_bin(&failing);
    assert!(!out.status.success(), "zero-retry crash must fail the driver");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("retry budget"), "budget message missing from:\n{stderr}");
    assert!(seg_dir.is_dir(), "segments must be left for inspection/resume");

    // Same command without the "--inject-fault <spec>" pair: picks the
    // directory back up.
    let mut retry: Vec<&str> = Vec::new();
    let mut skip_next = false;
    for a in failing {
        if skip_next {
            skip_next = false;
        } else if a == "--inject-fault" {
            skip_next = true;
        } else {
            retry.push(a);
        }
    }
    let out = run_bin(&retry);
    assert_success(&out, "resuming driver rerun");
    let out = run_bin(&[
        "sample", "--log2-nodes", "8", "--seed", "7", "--shards", "6",
        "--attr-mode", "chunked", "--sink", "binary",
        "--out", single_out.to_str().unwrap(),
    ]);
    assert_success(&out, "single-process baseline");
    assert_eq!(
        std::fs::read(&dist_out).unwrap(),
        std::fs::read(&single_out).unwrap(),
        "resumed rerun must be byte-identical"
    );
}

#[test]
fn cli_doctor_classifies_then_fixes_then_merge_succeeds() {
    // Build a real segment directory, contaminate it with every residue
    // class, and check doctor reports then repairs it — after which the
    // merge goes through untouched.
    let dir = tmp("cli_doctor");
    let plan_path = dir.join("plan.toml");
    let seg_dir = dir.join("segs");
    std::fs::create_dir_all(&seg_dir).unwrap();
    assert_success(
        &run_bin(&[
            "shard-plan", "--log2-nodes", "7", "--seed", "3", "--shards", "4",
            "--dist-workers", "2", "--plan-out", plan_path.to_str().unwrap(),
        ]),
        "shard-plan",
    );
    for w in ["0", "1"] {
        assert_success(
            &run_bin(&[
                "shard-worker", "--plan", plan_path.to_str().unwrap(),
                "--worker", w, "--segment-dir", seg_dir.to_str().unwrap(),
            ]),
            "shard-worker",
        );
    }
    // Residue: a dead attempt's temp and a foreign-plan segment.
    std::fs::write(seg_dir.join("magquilt-tmp-99-00aa-0-seg.part"), b"junk").unwrap();
    std::fs::write(
        seg_dir.join("seg-deadbeefdeadbeef-s00000-w0000.seg"),
        b"other plan",
    )
    .unwrap();

    // Dry run reports, changes nothing.
    let out = run_bin(&[
        "doctor", seg_dir.to_str().unwrap(), "--plan", plan_path.to_str().unwrap(),
    ]);
    assert_success(&out, "doctor dry run");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("stale-temp"), "missing stale-temp row:\n{stdout}");
    assert!(stdout.contains("foreign-plan"), "missing foreign-plan row:\n{stdout}");
    assert!(stdout.contains("rerun with --fix"), "missing fix hint:\n{stdout}");
    assert!(seg_dir.join("magquilt-tmp-99-00aa-0-seg.part").exists());

    // Fix, then merge.
    let out = run_bin(&[
        "doctor", seg_dir.to_str().unwrap(), "--plan", plan_path.to_str().unwrap(), "--fix",
    ]);
    assert_success(&out, "doctor --fix");
    assert!(!seg_dir.join("magquilt-tmp-99-00aa-0-seg.part").exists(), "temp removed");
    assert!(
        seg_dir.join("quarantine").join("seg-deadbeefdeadbeef-s00000-w0000.seg").exists(),
        "foreign segment quarantined, not deleted"
    );
    let merged = dir.join("merged.bin");
    assert_success(
        &run_bin(&[
            "merge-segments", "--segments", seg_dir.to_str().unwrap(),
            "--plan", plan_path.to_str().unwrap(),
            "--out", merged.to_str().unwrap(),
        ]),
        "merge after doctor --fix",
    );
}
