//! Integration: CLI command paths (library-level calls; no subprocess
//! needed since `cli::run` is pure over argv).

use std::path::PathBuf;

fn args(v: &[&str]) -> Vec<String> {
    v.iter().map(|s| s.to_string()).collect()
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("magquilt_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn generate_writes_text_and_stats_reads_back() {
    let out = tmp("g.txt");
    magquilt::cli::run(&args(&[
        "generate",
        "--log2-nodes",
        "9",
        "--mu",
        "0.5",
        "--seed",
        "3",
        "--output",
        out.to_str().unwrap(),
    ]))
    .unwrap();
    assert!(out.exists());
    magquilt::cli::run(&args(&["stats", out.to_str().unwrap()])).unwrap();
}

#[test]
fn generate_binary_roundtrip() {
    let out = tmp("g.bin");
    magquilt::cli::run(&args(&[
        "generate",
        "--log2-nodes",
        "8",
        "--sampler",
        "hybrid",
        "--mu",
        "0.8",
        "--output",
        out.to_str().unwrap(),
        "--binary",
    ]))
    .unwrap();
    let g = magquilt::graph::read_edge_list_binary(&out).unwrap();
    assert_eq!(g.num_nodes(), 256);
}

#[test]
fn sample_alias_streams_binary_sink_and_stats_reads_back() {
    // The streaming path end-to-end: `magquilt sample --sink binary --out`
    // writes sorted shards straight to disk; `stats` re-reads the file.
    let out = tmp("streamed.bin");
    magquilt::cli::run(&args(&[
        "sample",
        "--log2-nodes",
        "9",
        "--sampler",
        "quilt",
        "--shards",
        "4",
        "--seed",
        "7",
        "--sink",
        "binary",
        "--out",
        out.to_str().unwrap(),
    ]))
    .unwrap();
    let streamed = magquilt::graph::read_edge_list_binary(&out).unwrap();
    assert_eq!(streamed.num_nodes(), 512);
    assert!(streamed.num_edges() > 0);
    // Must equal the collected graph for the same seed, bit-for-bit.
    let mut model = magquilt::config::ModelSpec::default_spec();
    model.log2_nodes = 9;
    model.attributes = 9;
    let mut run = magquilt::config::RunSpec::default_spec();
    run.seed = 7;
    let collected = magquilt::cli::sample_with(&magquilt::cli::model_params(&model), &run).unwrap();
    assert_eq!(streamed, collected);
    magquilt::cli::run(&args(&["stats", out.to_str().unwrap()])).unwrap();
}

#[test]
fn sample_binary_with_forced_spill_matches_collect() {
    // The CLI spill knobs end-to-end: a zero budget routes every
    // out-of-order shard through a spill file in --spill-dir, and the
    // final file is still bit-for-bit the collected graph.
    let out = tmp("spilled.bin");
    let spill_dir = tmp("spill_dir");
    std::fs::create_dir_all(&spill_dir).unwrap();
    magquilt::cli::run(&args(&[
        "sample",
        "--log2-nodes",
        "9",
        "--sampler",
        "quilt",
        "--workers",
        "4",
        "--shards",
        "8",
        "--seed",
        "7",
        "--sink",
        "binary",
        "--spill-budget",
        "0",
        "--spill-dir",
        spill_dir.to_str().unwrap(),
        "--out",
        out.to_str().unwrap(),
    ]))
    .unwrap();
    let streamed = magquilt::graph::read_edge_list_binary(&out).unwrap();
    let mut model = magquilt::config::ModelSpec::default_spec();
    model.log2_nodes = 9;
    model.attributes = 9;
    let mut run = magquilt::config::RunSpec::default_spec();
    run.seed = 7;
    let collected = magquilt::cli::sample_with(&magquilt::cli::model_params(&model), &run).unwrap();
    assert_eq!(streamed, collected);
    // Spill temp files are removed once concatenated.
    assert_eq!(std::fs::read_dir(&spill_dir).unwrap().count(), 0);
}

#[test]
fn counting_sink_runs_without_holding_graph() {
    magquilt::cli::run(&args(&[
        "generate",
        "--log2-nodes",
        "8",
        "--sampler",
        "hybrid",
        "--mu",
        "0.8",
        "--sink",
        "counting",
        "--shards",
        "3",
    ]))
    .unwrap();
    // The counting sink never writes a graph: combining it with an
    // output path must error rather than silently skip the file.
    assert!(magquilt::cli::run(&args(&[
        "generate",
        "--log2-nodes",
        "6",
        "--sink",
        "counting",
        "--out",
        "/tmp/should_not_exist.bin",
    ]))
    .is_err());
}

#[test]
fn generate_naive_sampler_small() {
    magquilt::cli::run(&args(&[
        "generate",
        "--log2-nodes",
        "6",
        "--sampler",
        "naive",
        "--stats",
    ]))
    .unwrap();
}

#[test]
fn experiment_smoke_fig5() {
    let out_dir = tmp("exp_out");
    magquilt::cli::run(&args(&[
        "experiment",
        "fig5",
        "--max-log2n",
        "8",
        "--trials",
        "2",
        "--out",
        out_dir.to_str().unwrap(),
    ]))
    .unwrap();
    assert!(out_dir.join("fig5.tsv").exists());
    assert!(out_dir.join("fig5.md").exists());
}

#[test]
fn artifacts_check_passes() {
    // Requires `make artifacts` (guaranteed by the Makefile test target).
    magquilt::cli::run(&args(&["artifacts-check"])).unwrap();
}

#[test]
fn info_and_help_run() {
    magquilt::cli::run(&args(&["info"])).unwrap();
    magquilt::cli::run(&args(&["help"])).unwrap();
    magquilt::cli::run(&[]).unwrap();
}

#[test]
fn bad_input_is_an_error_not_a_panic() {
    assert!(magquilt::cli::run(&args(&["generate", "--log2-nodes", "notanumber"])).is_err());
    assert!(magquilt::cli::run(&args(&["generate", "--sampler", "bogus"])).is_err());
    assert!(magquilt::cli::run(&args(&["stats"])).is_err());
    assert!(magquilt::cli::run(&args(&["stats", "/nonexistent/file"])).is_err());
    assert!(magquilt::cli::run(&args(&["experiment", "fig99"])).is_err());
}

#[test]
fn config_file_generate() {
    let cfg = tmp("model.toml");
    std::fs::write(
        &cfg,
        r#"
[model]
theta = [0.35, 0.52, 0.52, 0.95]
mu = 0.6
log2_nodes = 8

[run]
seed = 11
sampler = "hybrid"
"#,
    )
    .unwrap();
    magquilt::cli::run(&args(&["generate", "--config", cfg.to_str().unwrap()])).unwrap();
}
