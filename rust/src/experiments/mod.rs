//! Experiment harnesses: regenerate every figure of the paper's evaluation
//! (§6). The paper has no tables; Figures 5–14 are its quantitative
//! results and Figure 1 is the edge-probability-matrix illustration
//! (Figures 2–4 are method diagrams).
//!
//! Each harness returns one or more [`ExperimentResult`] tables whose rows
//! mirror the series the paper plots; `magquilt experiment <id>` prints
//! them as TSV and records them in markdown form for EXPERIMENTS.md.

mod configs;
mod dims;
mod mu;
mod probmatrix;
mod properties;
mod scaling;

use anyhow::{bail, Result};

pub use configs::fig7_config_frequencies;
pub use dims::fig14_dimension_sweep;
pub use mu::{fig12_relative_runtime, fig13_rho_max};
pub use probmatrix::fig1_probability_matrices;
pub use properties::{fig8_edge_growth, fig9_scc_fraction};
pub use scaling::{fig10_runtime_comparison, fig11_time_per_edge, fig5_partition_balanced,
                  fig6_partition_unbalanced};

/// A regenerated figure series.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    /// Figure id, e.g. "fig5".
    pub id: String,
    /// Human title.
    pub title: String,
    /// Column names.
    pub header: Vec<String>,
    /// Data rows.
    pub rows: Vec<Vec<String>>,
}

impl ExperimentResult {
    /// New empty result.
    pub fn new(id: &str, title: &str, header: &[&str]) -> Self {
        ExperimentResult {
            id: id.to_string(),
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Tab-separated rendering (with `# title` comment and header line).
    pub fn to_tsv(&self) -> String {
        let mut s = format!("# {} — {}\n{}\n", self.id, self.title, self.header.join("\t"));
        for row in &self.rows {
            s.push_str(&row.join("\t"));
            s.push('\n');
        }
        s
    }

    /// GitHub-markdown table rendering.
    pub fn to_markdown(&self) -> String {
        let mut s = format!("**{} — {}**\n\n", self.id, self.title);
        s.push_str(&format!("| {} |\n", self.header.join(" | ")));
        s.push_str(&format!("|{}\n", "---|".repeat(self.header.len())));
        for row in &self.rows {
            s.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        s
    }
}

/// Effort knobs: the paper runs to n = 2^23; the default scale keeps
/// `experiment all` tractable on a container while preserving the shapes.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    /// Largest log2(n) for sweeps.
    pub max_log2n: u32,
    /// Largest log2(n) the naive O(n²) baseline is run at.
    pub naive_max_log2n: u32,
    /// Trials per configuration (the paper uses 10).
    pub trials: u32,
    /// Base RNG seed.
    pub seed: u64,
}

impl Default for Scale {
    fn default() -> Self {
        Scale { max_log2n: 16, naive_max_log2n: 11, trials: 10, seed: 42 }
    }
}

impl Scale {
    /// A fast smoke-scale for tests.
    pub fn smoke() -> Self {
        Scale { max_log2n: 9, naive_max_log2n: 7, trials: 2, seed: 42 }
    }
}

/// All experiment ids in paper order.
pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig1", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14",
];

/// Run one experiment by id.
pub fn run_experiment(id: &str, scale: Scale) -> Result<Vec<ExperimentResult>> {
    Ok(match id {
        "fig1" => fig1_probability_matrices(scale)?,
        "fig5" => vec![fig5_partition_balanced(scale)],
        "fig6" => vec![fig6_partition_unbalanced(scale)],
        "fig7" => vec![fig7_config_frequencies(scale)],
        "fig8" => vec![fig8_edge_growth(scale)],
        "fig9" => vec![fig9_scc_fraction(scale)],
        "fig10" => vec![fig10_runtime_comparison(scale)],
        "fig11" => vec![fig11_time_per_edge(scale)],
        "fig12" => vec![fig12_relative_runtime(scale)],
        "fig13" => vec![fig13_rho_max(scale)],
        "fig14" => vec![fig14_dimension_sweep(scale)],
        _ => bail!("unknown experiment {id:?}; expected one of {ALL_EXPERIMENTS:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn result_rendering() {
        let mut r = ExperimentResult::new("figX", "demo", &["a", "b"]);
        r.push_row(vec!["1".into(), "2".into()]);
        let tsv = r.to_tsv();
        assert!(tsv.contains("figX") && tsv.contains("1\t2"));
        let md = r.to_markdown();
        assert!(md.contains("| a | b |") && md.contains("| 1 | 2 |"));
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("fig99", Scale::smoke()).is_err());
    }
}
