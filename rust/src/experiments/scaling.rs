//! Figures 5, 6 (partition size vs n) and 10, 11 (runtime vs n).

use std::time::Instant;

use crate::kpgm::Initiator;
use crate::magm::{naive_sample, AttributeAssignment, MagmParams};
use crate::quilt::{HybridSampler, Partition, PieceMode, QuiltSampler};
use crate::rng::Rng;
use crate::stats::mean;

use super::{ExperimentResult, Scale};

/// Figure 5: partition size B vs n at μ = 0.5, with the paper's
/// Chernoff-style bound (eq. 12) as reference columns.
pub fn fig5_partition_balanced(scale: Scale) -> ExperimentResult {
    let mut out = ExperimentResult::new(
        "fig5",
        "partition size vs n (mu = 0.5), 10-trial mean + log2(n) reference",
        &["log2_n", "n", "mean_B", "log2_n_bound", "p_bound_exceed"],
    );
    for d in 6..=scale.max_log2n {
        let n = 1usize << d;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
        let mut bs = Vec::new();
        for t in 0..scale.trials {
            let mut rng = Rng::new(scale.seed + t as u64).fork(d as u64);
            let attrs = AttributeAssignment::sample(&params, &mut rng);
            bs.push(Partition::build(attrs.configs()).size() as f64);
        }
        // eq. 12: P(B > log2 n) <= n^2 / (e * log2(n)^{log2 n})
        let log2n = d as f64;
        let bound = (n as f64).powi(2) / (std::f64::consts::E * log2n.powf(log2n));
        out.push_row(vec![
            d.to_string(),
            n.to_string(),
            format!("{:.2}", mean(&bs)),
            format!("{log2n:.0}"),
            format!("{bound:.3e}"),
        ]);
    }
    out
}

/// Figure 6: partition size vs n for unbalanced μ, with the `n·μ^d` and
/// `log2(n)` envelopes the paper plots.
pub fn fig6_partition_unbalanced(scale: Scale) -> ExperimentResult {
    let mut out = ExperimentResult::new(
        "fig6",
        "partition size vs n for mu in {0.55, 0.60, 0.70, 0.90}",
        &["mu", "log2_n", "n", "mean_B", "n_mu_d", "log2_n"],
    );
    for &mu in &[0.55, 0.60, 0.70, 0.90] {
        for d in 6..=scale.max_log2n {
            let n = 1usize << d;
            let params = MagmParams::homogeneous(Initiator::THETA1, mu, n, d);
            let mut bs = Vec::new();
            for t in 0..scale.trials {
                let mut rng = Rng::new(scale.seed + t as u64).fork(d as u64 * 100);
                let attrs = AttributeAssignment::sample(&params, &mut rng);
                bs.push(Partition::build(attrs.configs()).size() as f64);
            }
            out.push_row(vec![
                format!("{mu:.2}"),
                d.to_string(),
                n.to_string(),
                format!("{:.2}", mean(&bs)),
                format!("{:.2}", n as f64 * mu.powi(d as i32)),
                format!("{d}"),
            ]);
        }
    }
    out
}

/// Timing record for one (sampler, n) cell.
pub(crate) struct TimedRun {
    /// Mean wall milliseconds per sample.
    pub ms: f64,
    /// Mean edges per sample.
    pub edges: f64,
}

pub(crate) fn time_quilt(params: &MagmParams, trials: u32, seed: u64) -> TimedRun {
    time_quilt_mode(params, trials, seed, PieceMode::Conditioned)
}

pub(crate) fn time_quilt_mode(
    params: &MagmParams,
    trials: u32,
    seed: u64,
    mode: PieceMode,
) -> TimedRun {
    let mut times = Vec::new();
    let mut edges = Vec::new();
    for t in 0..trials {
        let start = Instant::now();
        let g = QuiltSampler::new(params.clone()).piece_mode(mode).seed(seed + t as u64).sample();
        times.push(start.elapsed().as_secs_f64() * 1e3);
        edges.push(g.num_edges() as f64);
    }
    TimedRun { ms: mean(&times), edges: mean(&edges) }
}

pub(crate) fn time_hybrid(params: &MagmParams, trials: u32, seed: u64) -> TimedRun {
    let mut times = Vec::new();
    let mut edges = Vec::new();
    for t in 0..trials {
        let start = Instant::now();
        let g = HybridSampler::new(params.clone()).seed(seed + t as u64).sample();
        times.push(start.elapsed().as_secs_f64() * 1e3);
        edges.push(g.num_edges() as f64);
    }
    TimedRun { ms: mean(&times), edges: mean(&edges) }
}

pub(crate) fn time_naive(params: &MagmParams, trials: u32, seed: u64) -> TimedRun {
    let mut times = Vec::new();
    let mut edges = Vec::new();
    for t in 0..trials {
        let mut rng = Rng::new(seed + t as u64);
        let attrs = AttributeAssignment::sample(params, &mut rng);
        let start = Instant::now();
        let g = naive_sample(params, &attrs, &mut rng);
        times.push(start.elapsed().as_secs_f64() * 1e3);
        edges.push(g.num_edges() as f64);
    }
    TimedRun { ms: mean(&times), edges: mean(&edges) }
}

/// Figure 10: running time of quilting vs the naive scheme as n grows,
/// for Θ1 and Θ2. The naive sampler is only run up to
/// `scale.naive_max_log2n` (the paper could not push it past 2^18 in 8h).
pub fn fig10_runtime_comparison(scale: Scale) -> ExperimentResult {
    let mut out = ExperimentResult::new(
        "fig10",
        "runtime (ms): quilting (conditioned + rejection pieces) vs naive, mu = 0.5",
        &["theta", "log2_n", "n", "quilt_ms", "quilt_rej_ms", "cond_speedup", "naive_ms", "speedup"],
    );
    for (name, theta) in [("theta1", Initiator::THETA1), ("theta2", Initiator::THETA2)] {
        for d in 6..=scale.max_log2n {
            let n = 1usize << d;
            let params = MagmParams::homogeneous(theta, 0.5, n, d);
            let q = time_quilt(&params, scale.trials, scale.seed);
            let rej =
                time_quilt_mode(&params, scale.trials.min(3), scale.seed, PieceMode::Rejection);
            let (naive_ms, speedup) = if d <= scale.naive_max_log2n {
                let nv = time_naive(&params, scale.trials.min(3), scale.seed);
                (format!("{:.2}", nv.ms), format!("{:.1}", nv.ms / q.ms.max(1e-9)))
            } else {
                ("-".into(), "-".into())
            };
            out.push_row(vec![
                name.into(),
                d.to_string(),
                n.to_string(),
                format!("{:.2}", q.ms),
                format!("{:.2}", rej.ms),
                format!("{:.1}", rej.ms / q.ms.max(1e-9)),
                naive_ms,
                speedup,
            ]);
        }
    }
    out
}

/// Figure 11: runtime **per edge**; the paper's point is that quilting's
/// per-edge cost is ~constant in n while the naive scheme's diverges.
pub fn fig11_time_per_edge(scale: Scale) -> ExperimentResult {
    let mut out = ExperimentResult::new(
        "fig11",
        "runtime per edge (microseconds), mu = 0.5",
        &["theta", "log2_n", "n", "quilt_us_per_edge", "naive_us_per_edge"],
    );
    for (name, theta) in [("theta1", Initiator::THETA1), ("theta2", Initiator::THETA2)] {
        for d in 6..=scale.max_log2n {
            let n = 1usize << d;
            let params = MagmParams::homogeneous(theta, 0.5, n, d);
            let q = time_quilt(&params, scale.trials, scale.seed);
            let naive_col = if d <= scale.naive_max_log2n {
                let nv = time_naive(&params, scale.trials.min(3), scale.seed);
                format!("{:.3}", nv.ms * 1e3 / nv.edges.max(1.0))
            } else {
                "-".into()
            };
            out.push_row(vec![
                name.into(),
                d.to_string(),
                n.to_string(),
                format!("{:.3}", q.ms * 1e3 / q.edges.max(1.0)),
                naive_col,
            ]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_smoke_shape_holds() {
        let r = fig5_partition_balanced(Scale::smoke());
        assert_eq!(r.header.len(), 5);
        assert!(!r.rows.is_empty());
        // B should stay at or below log2(n) + small slack at mu = 0.5.
        for row in &r.rows {
            let d: f64 = row[0].parse().unwrap();
            let b: f64 = row[2].parse().unwrap();
            assert!(b <= d + 3.0, "B={b} log2n={d}");
        }
    }

    #[test]
    fn fig10_smoke_runs_and_quilt_wins_at_top() {
        let r = fig10_runtime_comparison(Scale::smoke());
        assert!(!r.rows.is_empty());
    }
}
