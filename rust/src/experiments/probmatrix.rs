//! Figure 1: edge-probability matrices of KPGM vs MAGM.
//!
//! Writes the two matrices as PGM images (`out/fig1_kpgm.pgm`,
//! `out/fig1_magm.pgm`) — darker = higher probability, like the paper's
//! figure — and returns summary statistics as the result table.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::kpgm::{probability_matrix, Initiator, ThetaSeq};
use crate::magm::{AttributeAssignment, MagmParams};
use crate::rng::Rng;

use super::{ExperimentResult, Scale};

/// Render a probability matrix (values in [0,1]) as a binary PGM.
fn write_pgm(path: &Path, matrix: &[Vec<f64>]) -> Result<()> {
    let n = matrix.len();
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{n} {n}\n255\n")?;
    let mut bytes = Vec::with_capacity(n * n);
    for row in matrix {
        for &p in row {
            // darker = more probable
            bytes.push((255.0 * (1.0 - p.clamp(0.0, 1.0))) as u8);
        }
    }
    f.write_all(&bytes)?;
    Ok(())
}

/// Figure 1: produce P (KPGM, fractal) and Q (MAGM, shuffled) at d = 7 and
/// report their summary stats. Output images go to `out/`.
pub fn fig1_probability_matrices(scale: Scale) -> Result<Vec<ExperimentResult>> {
    let d = 7u32.min(scale.max_log2n);
    let n = 1usize << d;
    let thetas = ThetaSeq::homogeneous(Initiator::THETA1, d);
    let p = probability_matrix(&thetas);

    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
    let mut rng = Rng::new(scale.seed);
    let attrs = AttributeAssignment::sample(&params, &mut rng);
    let q: Vec<Vec<f64>> = (0..n)
        .map(|i| {
            (0..n)
                .map(|j| {
                    crate::magm::edge_probability(&params, &attrs, i as u32, j as u32)
                })
                .collect()
        })
        .collect();

    std::fs::create_dir_all("out")?;
    write_pgm(Path::new("out/fig1_kpgm.pgm"), &p)?;
    write_pgm(Path::new("out/fig1_magm.pgm"), &q)?;

    let sum = |m: &[Vec<f64>]| -> f64 { m.iter().flatten().sum() };
    let mut out = ExperimentResult::new(
        "fig1",
        "edge-probability matrices (PGMs written to out/)",
        &["matrix", "n", "expected_edges", "max_entry", "file"],
    );
    let maxp = p.iter().flatten().cloned().fold(0.0, f64::max);
    let maxq = q.iter().flatten().cloned().fold(0.0, f64::max);
    out.push_row(vec![
        "KPGM P".into(),
        n.to_string(),
        format!("{:.1}", sum(&p)),
        format!("{maxp:.4}"),
        "out/fig1_kpgm.pgm".into(),
    ]);
    out.push_row(vec![
        "MAGM Q".into(),
        n.to_string(),
        format!("{:.1}", sum(&q)),
        format!("{maxq:.4}"),
        "out/fig1_magm.pgm".into(),
    ]);
    Ok(vec![out])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_writes_images_and_stats() {
        let results = fig1_probability_matrices(Scale::smoke()).unwrap();
        assert_eq!(results[0].rows.len(), 2);
        assert!(Path::new("out/fig1_kpgm.pgm").exists());
        assert!(Path::new("out/fig1_magm.pgm").exists());
        // P and Q have the same total mass in expectation over attrs, but
        // for one attribute draw they differ; both must be positive.
        let p: f64 = results[0].rows[0][2].parse().unwrap();
        let q: f64 = results[0].rows[1][2].parse().unwrap();
        assert!(p > 0.0 && q > 0.0);
    }
}
