//! Figures 8 and 9: properties of the generated graphs (edge growth
//! |E| = n^c and largest-SCC fraction → 1).

use crate::graph::{largest_scc_size, Csr};
use crate::kpgm::Initiator;
use crate::magm::MagmParams;
use crate::quilt::QuiltSampler;
use crate::stats::{loglog_slope, mean};

use super::{ExperimentResult, Scale};

/// Figure 8: |E| as a function of n at μ = 0.5 for Θ1 and Θ2; the paper
/// reports near-linear log-log growth, i.e. |E| = n^c. The fitted c is
/// appended as a summary row per theta.
pub fn fig8_edge_growth(scale: Scale) -> ExperimentResult {
    let mut out = ExperimentResult::new(
        "fig8",
        "edge count vs n (mu = 0.5); |E| = n^c",
        &["theta", "log2_n", "n", "mean_edges", "fitted_c"],
    );
    for (name, theta) in [("theta1", Initiator::THETA1), ("theta2", Initiator::THETA2)] {
        let mut points = Vec::new();
        for d in 6..=scale.max_log2n {
            let n = 1usize << d;
            let params = MagmParams::homogeneous(theta, 0.5, n, d);
            let mut es = Vec::new();
            for t in 0..scale.trials {
                let g = QuiltSampler::new(params.clone())
                    .seed(scale.seed + t as u64)
                    .sample();
                es.push(g.num_edges() as f64);
            }
            let m = mean(&es);
            points.push((n as f64, m));
            out.push_row(vec![
                name.into(),
                d.to_string(),
                n.to_string(),
                format!("{m:.1}"),
                String::new(),
            ]);
        }
        let c = loglog_slope(&points);
        out.push_row(vec![name.into(), "fit".into(), "-".into(), "-".into(), format!("{c:.3}")]);
    }
    out
}

/// Figure 9: fraction of nodes in the largest strongly connected component
/// as n grows (→ 1 asymptotically per the paper).
pub fn fig9_scc_fraction(scale: Scale) -> ExperimentResult {
    let mut out = ExperimentResult::new(
        "fig9",
        "largest-SCC node fraction vs n (mu = 0.5)",
        &["theta", "log2_n", "n", "mean_scc_fraction"],
    );
    for (name, theta) in [("theta1", Initiator::THETA1), ("theta2", Initiator::THETA2)] {
        for d in 6..=scale.max_log2n {
            let n = 1usize << d;
            let params = MagmParams::homogeneous(theta, 0.5, n, d);
            let mut fracs = Vec::new();
            for t in 0..scale.trials {
                let g = QuiltSampler::new(params.clone())
                    .seed(scale.seed + 1000 + t as u64)
                    .sample();
                let csr = Csr::from_edge_list(&g);
                fracs.push(largest_scc_size(&csr) as f64 / n as f64);
            }
            out.push_row(vec![
                name.into(),
                d.to_string(),
                n.to_string(),
                format!("{:.4}", mean(&fracs)),
            ]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_growth_exponent_above_one() {
        let r = fig8_edge_growth(Scale::smoke());
        let fits: Vec<f64> = r
            .rows
            .iter()
            .filter(|row| row[1] == "fit")
            .map(|row| row[4].parse().unwrap())
            .collect();
        assert_eq!(fits.len(), 2);
        for c in fits {
            assert!(c > 1.0 && c < 2.2, "c={c}");
        }
    }

    #[test]
    fn fig9_scc_fraction_grows() {
        let r = fig9_scc_fraction(Scale::smoke());
        // last theta1 row >= first theta1 row (asymptotically -> 1)
        let t1: Vec<f64> = r
            .rows
            .iter()
            .filter(|row| row[0] == "theta1")
            .map(|row| row[3].parse().unwrap())
            .collect();
        assert!(t1.last().unwrap() >= t1.first().unwrap());
    }
}
