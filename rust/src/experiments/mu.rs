//! Figures 12 and 13: effect of μ on running time.

use crate::kpgm::Initiator;
use crate::magm::MagmParams;

use super::scaling::time_hybrid;
use super::{ExperimentResult, Scale};

const MU_GRID: [f64; 9] = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

/// Figure 12: relative running time ρ(μ) = T(μ)/T(0.5) for the (hybrid)
/// sampler, for several n and both Θ matrices.
pub fn fig12_relative_runtime(scale: Scale) -> ExperimentResult {
    let mut out = ExperimentResult::new(
        "fig12",
        "relative runtime rho(mu) = T(mu)/T(0.5), hybrid sampler",
        &["theta", "log2_n", "mu", "ms", "rho"],
    );
    let dims: Vec<u32> =
        [scale.max_log2n.saturating_sub(4), scale.max_log2n.saturating_sub(2), scale.max_log2n]
            .into_iter()
            .filter(|&d| d >= 6)
            .collect();
    for (name, theta) in [("theta1", Initiator::THETA1), ("theta2", Initiator::THETA2)] {
        for &d in &dims {
            let n = 1usize << d;
            let t_half =
                time_hybrid(&MagmParams::homogeneous(theta, 0.5, n, d), scale.trials, scale.seed)
                    .ms;
            for &mu in &MU_GRID {
                let t = time_hybrid(
                    &MagmParams::homogeneous(theta, mu, n, d),
                    scale.trials,
                    scale.seed,
                )
                .ms;
                out.push_row(vec![
                    name.into(),
                    d.to_string(),
                    format!("{mu:.1}"),
                    format!("{t:.2}"),
                    format!("{:.2}", t / t_half.max(1e-9)),
                ]);
            }
        }
    }
    out
}

/// Figure 13: ρ_max = max_μ ρ(μ) as a function of n.
pub fn fig13_rho_max(scale: Scale) -> ExperimentResult {
    let mut out = ExperimentResult::new(
        "fig13",
        "rho_max = max over mu of T(mu)/T(0.5) vs n",
        &["theta", "log2_n", "n", "rho_max", "argmax_mu"],
    );
    for (name, theta) in [("theta1", Initiator::THETA1), ("theta2", Initiator::THETA2)] {
        for d in 8..=scale.max_log2n {
            let n = 1usize << d;
            let t_half =
                time_hybrid(&MagmParams::homogeneous(theta, 0.5, n, d), scale.trials, scale.seed)
                    .ms;
            let mut best = (0.0f64, 0.5f64);
            for &mu in &MU_GRID {
                let t = time_hybrid(
                    &MagmParams::homogeneous(theta, mu, n, d),
                    scale.trials,
                    scale.seed,
                )
                .ms;
                let rho = t / t_half.max(1e-9);
                if rho > best.0 {
                    best = (rho, mu);
                }
            }
            out.push_row(vec![
                name.into(),
                d.to_string(),
                n.to_string(),
                format!("{:.2}", best.0),
                format!("{:.1}", best.1),
            ]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_rho_at_half_is_one() {
        let r = fig12_relative_runtime(Scale::smoke());
        for row in r.rows.iter().filter(|row| row[2] == "0.5") {
            let rho: f64 = row[4].parse().unwrap();
            assert!((rho - 1.0).abs() < 0.35, "rho(0.5)={rho}");
        }
    }
}
