//! Figure 14: effect of the attribute dimension d at fixed n.

use crate::kpgm::Initiator;
use crate::magm::MagmParams;

use super::scaling::time_quilt;
use super::{ExperimentResult, Scale};

/// Figure 14: runtime vs d at fixed n (the paper fixes n = 2^15 and sweeps
/// d around log2(n); runtime is flat for d ≤ log2 n and blows up
/// exponentially beyond — §4.2's Ω(4^{d − log2 n}) term).
pub fn fig14_dimension_sweep(scale: Scale) -> ExperimentResult {
    let log2n = scale.max_log2n.min(15);
    let n = 1usize << log2n;
    let mut out = ExperimentResult::new(
        "fig14",
        "runtime vs d at fixed n (mu = 0.5); d = log2(n) highlighted",
        &["d", "log2_n", "ms", "is_log2n"],
    );
    // Sweep d from below log2 n to a couple past it (each step past
    // log2 n quadruples the KPGM work, so +3 is already ~64x).
    let d_min = log2n.saturating_sub(6).max(2);
    let d_max = log2n + 3;
    for d in d_min..=d_max {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
        let trials = if d > log2n { scale.trials.min(3) } else { scale.trials };
        let t = time_quilt(&params, trials, scale.seed);
        out.push_row(vec![
            d.to_string(),
            log2n.to_string(),
            format!("{:.2}", t.ms),
            (d == log2n).to_string(),
        ]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_blows_up_past_log2n() {
        let r = fig14_dimension_sweep(Scale::smoke());
        let ms: Vec<(u32, f64)> = r
            .rows
            .iter()
            .map(|row| (row[0].parse().unwrap(), row[2].parse().unwrap()))
            .collect();
        let log2n: u32 = r.rows[0][1].parse().unwrap();
        let at_log2n = ms.iter().find(|&&(d, _)| d == log2n).unwrap().1;
        let past = ms.iter().find(|&&(d, _)| d == log2n + 3).unwrap().1;
        // 3 levels past log2 n multiplies KPGM balls by 2.4^3 ≈ 14 and the
        // index space by 64; demand a clear slowdown (3x — loose enough
        // for debug-build timing noise at smoke scale).
        assert!(past > 3.0 * at_log2n.max(0.01), "at={at_log2n} past={past}");
    }
}
