//! Figure 7: attribute-configuration frequency vs rank for several μ.

use crate::kpgm::Initiator;
use crate::magm::{AttributeAssignment, MagmParams};
use crate::rng::Rng;

use super::{ExperimentResult, Scale};

/// Figure 7: rank configurations by frequency and report the frequency at
/// log-spaced ranks (the paper's log-log plot) for μ ∈ {0.5 … 0.9} at
/// d = 15, n = 2^15 (capped by the scale).
pub fn fig7_config_frequencies(scale: Scale) -> ExperimentResult {
    let d = scale.max_log2n.min(15);
    let n = 1usize << d;
    let mut out = ExperimentResult::new(
        "fig7",
        "configuration frequency vs rank (log-spaced ranks), n = 2^d",
        &["mu", "rank", "count"],
    );
    for &mu in &[0.5, 0.6, 0.7, 0.8, 0.9] {
        let params = MagmParams::homogeneous(Initiator::THETA1, mu, n, d);
        let mut rng = Rng::new(scale.seed).fork((mu * 100.0) as u64);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let mut counts: Vec<u32> =
            attrs.config_counts().into_iter().map(|(_, c)| c).collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // log-spaced ranks 1, 2, 4, 8, ...
        let mut rank = 1usize;
        while rank <= counts.len() {
            out.push_row(vec![
                format!("{mu:.1}"),
                rank.to_string(),
                counts[rank - 1].to_string(),
            ]);
            rank *= 2;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequencies_flat_at_half_concentrated_at_nine_tenths() {
        let r = fig7_config_frequencies(Scale::smoke());
        // For mu=0.9 the top rank count must dominate the mu=0.5 top rank.
        let top = |mu: &str| -> u32 {
            r.rows
                .iter()
                .find(|row| row[0] == mu && row[1] == "1")
                .map(|row| row[2].parse().unwrap())
                .unwrap()
        };
        assert!(top("0.9") > 3 * top("0.5"), "0.9: {} 0.5: {}", top("0.9"), top("0.5"));
    }
}
