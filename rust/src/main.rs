//! magquilt binary: CLI front-end over the library.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = magquilt::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
