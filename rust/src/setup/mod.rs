//! Setup artifacts: the deterministic prologue, serialized.
//!
//! Every magquilt run front-loads the same expensive, fully deterministic
//! setup pipeline — attribute assignment, the partition `D_1 … D_B`, the
//! hash-consed [`crate::kpgm::ConfigForest`] prefix tries, and (in
//! conditioned mode) the product DAG — before the first ball drops. A
//! [`SetupArtifact`] is that prologue as a file: build it once with
//! [`crate::coordinator::Coordinator::build_setup`] (CLI: `magquilt setup
//! --out F`), then hydrate any number of runs from it (`sample --artifact
//! F`, `shard-worker --artifact F`) with a **bit-for-bit guarantee**: a
//! coordinator hydrated from an artifact produces byte-identical output
//! to one that ran fresh setup, because the hydrated partition, forest,
//! tries, and conditioned DAG *are* the fresh ones (asserted structure by
//! structure in the round-trip tests, and end to end by the equivalence
//! sweeps in [`crate::coordinator`] and [`crate::dist::worker`]).
//!
//! # File format (`MAGQART1`)
//!
//! ```text
//! magic    8 B   b"MAGQART1"
//! version  4 B   u32 LE — readers reject any version they don't know
//! integrity 8 B  u64 LE — FNV-1a over every body byte
//! body_len 8 B   u64 LE — must equal the bytes that follow exactly
//! body     …     header, attrs, partition, conditioner (see [`wire`])
//! ```
//!
//! The body serializes the [`ArtifactHeader`] followed by the attribute
//! configurations, the partition sets **and** per-set `config → node`
//! maps (entries in sorted config order, so the byte stream is canonical),
//! the [`crate::kpgm::ConfigForest`] arena level by level in its exact
//! serial interning order, the per-set tries, and the conditioned DAG's
//! pair nodes and piece roots. Cheaply derivable state is *not* stored
//! and is rebuilt on hydration: the dense lookup tables, the forest's
//! interner maps (reconstructed from the arena), the job list, and the
//! hybrid split (a pure function of the attrs).
//!
//! # Two hashes
//!
//! * The **identity hash** ([`ArtifactHeader::hash64`]) digests the
//!   output-determining header fields — the same fields the
//!   [`crate::dist::ShardPlan`] hash seals (model, seed, sampler, piece
//!   and attr mode) — and is the artifact's content address: consumers
//!   cross-check it against the hash derived from their own plan/config
//!   ([`ArtifactHeader::from_plan`] + [`SetupArtifact::check_matches`])
//!   before trusting the payload. Provenance fields (`setup_threads`,
//!   `setup_ms`) are exempt, with the fate of every field enforced by
//!   maglint's hash-drift tripwire exactly as for `ShardPlan`.
//! * The **integrity hash** (in the file header) digests every body byte
//!   and rejects truncation and tampering — including tampering of the
//!   hash-exempt provenance fields, which the identity hash would miss.
//!
//! Writes go through the atomic temp-file + rename protocol of
//! [`crate::graph::write_atomic`], so a crashed `magquilt setup` never
//! leaves a plausible-looking partial artifact under the final name.

pub mod wire;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{ModelSpec, SamplerKind};
use crate::dist::ShardPlan;
use crate::graph::write_atomic;
use crate::kpgm::ConditionedBallDropSampler;
use crate::magm::{AttrSampleMode, AttributeAssignment};
use crate::quilt::{Partition, PieceMode};

use wire::{Reader, Writer};

/// File magic, first 8 bytes of every artifact.
pub const ARTIFACT_MAGIC: [u8; 8] = *b"MAGQART1";

/// Format version; readers reject anything else.
pub const ARTIFACT_FORMAT: u32 = 1;

/// Artifact file extension (`magquilt stats`/`doctor` recognize it and
/// the workers' resume scan skips it).
pub const ARTIFACT_EXT: &str = "art";

/// Header fields excluded from the identity hash: build provenance that
/// never determines output. Mirrors `ShardPlan`'s `HASH_EXEMPT` contract
/// and is enforced by the same maglint tripwire
/// (`artifact_hash_disposition_witness` is the compile witness).
pub const ART_HASH_EXEMPT: &[&str] = &["setup_threads", "setup_ms"];

/// The output-determining identity of a setup artifact plus build
/// provenance. Every field is either digested by
/// [`ArtifactHeader::canonical`] or listed in [`ART_HASH_EXEMPT`].
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactHeader {
    /// KPGM initiator θ (row-major 2×2).
    pub theta: [f64; 4],
    /// Bernoulli attribute parameter μ.
    pub mu: f64,
    /// log2 of the node count.
    pub log2_nodes: u32,
    /// Attribute depth d.
    pub attributes: u32,
    /// Base RNG seed the attrs were drawn from.
    pub seed: u64,
    /// Sampler the prologue was built for (quilt or hybrid — the
    /// partition differs: full vs the hybrid's W subset).
    pub sampler: SamplerKind,
    /// Piece mode (conditioned artifacts carry the product DAG).
    pub piece_mode: PieceMode,
    /// Attribute sampling mode the assignment was drawn with.
    pub attr_mode: AttrSampleMode,
    /// Setup threads used by the build (provenance only — the prologue
    /// is bit-for-bit identical for every thread count).
    pub setup_threads: usize,
    /// Wall-clock the build spent in fresh setup (provenance only).
    pub setup_ms: f64,
}

impl ArtifactHeader {
    /// Canonical string over the output-determining fields; the identity
    /// hash digests exactly this. Same shape as `ShardPlan::canonical`.
    fn canonical(&self) -> String {
        format!(
            "magquilt-artifact-v{ARTIFACT_FORMAT}|theta={:?}|mu={:?}|log2_nodes={}\
             |attributes={}|seed={}|sampler={}|piece_mode={}|attr_mode={}",
            self.theta,
            self.mu,
            self.log2_nodes,
            self.attributes,
            self.seed,
            self.sampler.name(),
            self.piece_mode.name(),
            self.attr_mode.name(),
        )
    }

    /// The identity (content-address) hash.
    pub fn hash64(&self) -> u64 {
        crate::hashutil::fnv1a64(self.canonical().as_bytes())
    }

    /// The identity hash as 16 hex digits (the `ShardPlan` convention).
    pub fn hash_hex(&self) -> String {
        format!("{:016x}", self.hash64())
    }

    /// Number of nodes `2^log2_nodes`.
    pub fn num_nodes(&self) -> usize {
        1usize << self.log2_nodes
    }

    /// The header a fresh build under this model/config would carry
    /// (provenance fields zeroed — they are hash-exempt either way).
    pub fn from_model(
        model: &ModelSpec,
        seed: u64,
        sampler: SamplerKind,
        piece_mode: PieceMode,
        attr_mode: AttrSampleMode,
    ) -> Self {
        ArtifactHeader {
            theta: model.theta,
            mu: model.mu,
            log2_nodes: model.log2_nodes,
            attributes: model.attributes,
            seed,
            sampler,
            piece_mode,
            attr_mode,
            setup_threads: 0,
            setup_ms: 0.0,
        }
    }

    /// The header a distributed plan expects its shared artifact to
    /// carry — the cross-check workers run before skipping setup.
    pub fn from_plan(plan: &ShardPlan) -> Self {
        Self::from_model(&plan.model, plan.seed, plan.sampler, plan.piece_mode, plan.attr_mode)
    }

    fn encode(&self, w: &mut Writer) {
        for &t in &self.theta {
            w.put_f64(t);
        }
        w.put_f64(self.mu);
        w.put_u32(self.log2_nodes);
        w.put_u32(self.attributes);
        w.put_u64(self.seed);
        w.put_u8(sampler_to_byte(self.sampler));
        w.put_u8(match self.piece_mode {
            PieceMode::Conditioned => 0,
            PieceMode::Rejection => 1,
        });
        w.put_u8(match self.attr_mode {
            AttrSampleMode::Sequential => 0,
            AttrSampleMode::Chunked => 1,
        });
        w.put_u64(self.setup_threads as u64);
        w.put_f64(self.setup_ms);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let mut theta = [0.0f64; 4];
        for slot in &mut theta {
            *slot = r.take_f64("theta")?;
        }
        let mu = r.take_f64("mu")?;
        let log2_nodes = r.take_u32("log2_nodes")?;
        let attributes = r.take_u32("attributes")?;
        if !(1..=48).contains(&log2_nodes) {
            bail!("artifact header corrupt: log2_nodes {log2_nodes} outside [1, 48]");
        }
        if !(1..=63).contains(&attributes) {
            bail!("artifact header corrupt: attributes {attributes} outside [1, 63]");
        }
        let seed = r.take_u64("seed")?;
        let sampler = byte_to_sampler(r.take_u8("sampler")?)?;
        let piece_mode = match r.take_u8("piece_mode")? {
            0 => PieceMode::Conditioned,
            1 => PieceMode::Rejection,
            b => bail!("artifact header corrupt: unknown piece mode byte {b}"),
        };
        let attr_mode = match r.take_u8("attr_mode")? {
            0 => AttrSampleMode::Sequential,
            1 => AttrSampleMode::Chunked,
            b => bail!("artifact header corrupt: unknown attr mode byte {b}"),
        };
        let setup_threads = r.take_u64("setup_threads")? as usize;
        let setup_ms = r.take_f64("setup_ms")?;
        Ok(ArtifactHeader {
            theta,
            mu,
            log2_nodes,
            attributes,
            seed,
            sampler,
            piece_mode,
            attr_mode,
            setup_threads,
            setup_ms,
        })
    }
}

/// Compile-time witness that every [`ArtifactHeader`] field has an
/// explicit hash fate: destructuring is exhaustive, so adding a field
/// without deciding its fate breaks this function, and maglint checks
/// each fate comment against [`ArtifactHeader::canonical`] /
/// [`ART_HASH_EXEMPT`].
#[allow(dead_code)]
fn artifact_hash_disposition_witness(header: &ArtifactHeader) {
    let ArtifactHeader {
        theta: _,         // hashed
        mu: _,            // hashed
        log2_nodes: _,    // hashed
        attributes: _,    // hashed
        seed: _,          // hashed
        sampler: _,       // hashed
        piece_mode: _,    // hashed
        attr_mode: _,     // hashed
        setup_threads: _, // ART_HASH_EXEMPT (per-host knob; output identical for any count)
        setup_ms: _,      // ART_HASH_EXEMPT (wall-clock provenance)
    } = *header;
}

fn sampler_to_byte(s: SamplerKind) -> u8 {
    match s {
        SamplerKind::Quilt => 0,
        SamplerKind::Hybrid => 1,
        SamplerKind::Naive => 2,
        SamplerKind::NaiveXla => 3,
    }
}

fn byte_to_sampler(b: u8) -> Result<SamplerKind> {
    Ok(match b {
        0 => SamplerKind::Quilt,
        1 => SamplerKind::Hybrid,
        2 => SamplerKind::Naive,
        3 => SamplerKind::NaiveXla,
        _ => bail!("artifact header corrupt: unknown sampler byte {b}"),
    })
}

/// Canonical artifact file name for an identity hash.
pub fn artifact_file_name(hash_hex: &str) -> String {
    format!("setup-{hash_hex}.{ARTIFACT_EXT}")
}

/// Whether a segment-directory entry is a setup artifact (by extension —
/// users may name artifacts freely, so recognition must not depend on
/// the canonical name).
pub fn is_artifact_file(name: &str) -> bool {
    std::path::Path::new(name).extension().is_some_and(|e| e == ARTIFACT_EXT)
}

/// The serialized setup prologue: header + attrs + partition (+ the
/// conditioned product DAG). See the module docs for the format and the
/// bit-for-bit hydration guarantee.
#[derive(Debug, Clone)]
pub struct SetupArtifact {
    header: ArtifactHeader,
    attrs: AttributeAssignment,
    partition: Partition,
    conditioner: Option<ConditionedBallDropSampler>,
}

impl SetupArtifact {
    /// Assemble an artifact from freshly built setup state (the
    /// coordinator's `build_setup` is the only intended caller).
    pub fn new(
        header: ArtifactHeader,
        attrs: AttributeAssignment,
        partition: Partition,
        conditioner: Option<ConditionedBallDropSampler>,
    ) -> Self {
        SetupArtifact { header, attrs, partition, conditioner }
    }

    /// The identity header.
    pub fn header(&self) -> &ArtifactHeader {
        &self.header
    }

    /// The hydrated attribute assignment.
    pub fn attrs(&self) -> &AttributeAssignment {
        &self.attrs
    }

    /// The hydrated partition (with forest/tries when conditioned).
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// The hydrated conditioned product DAG, if the artifact carries one.
    pub fn conditioner(&self) -> Option<&ConditionedBallDropSampler> {
        self.conditioner.as_ref()
    }

    /// Identity hash (content address) — see [`ArtifactHeader::hash64`].
    pub fn hash64(&self) -> u64 {
        self.header.hash64()
    }

    /// Identity hash as 16 hex digits.
    pub fn hash_hex(&self) -> String {
        self.header.hash_hex()
    }

    /// Tear down into parts for hydration into a `JobPlan`.
    pub fn into_parts(
        self,
    ) -> (
        ArtifactHeader,
        AttributeAssignment,
        Partition,
        Option<ConditionedBallDropSampler>,
    ) {
        (self.header, self.attrs, self.partition, self.conditioner)
    }

    /// Cross-check this artifact's identity against what a consumer's
    /// own plan/config expects, rejecting with both canonical strings on
    /// mismatch. Consumers MUST call this before trusting the payload —
    /// the integrity hash proves the file is intact, not that it belongs
    /// to this run.
    pub fn check_matches(&self, expected: &ArtifactHeader) -> Result<()> {
        if self.header.hash64() != expected.hash64() {
            bail!(
                "setup artifact does not match this run: artifact is {} ({}), the run expects \
                 {} ({}) — regenerate with `magquilt setup`",
                self.header.hash_hex(),
                self.header.canonical(),
                expected.hash_hex(),
                expected.canonical(),
            );
        }
        Ok(())
    }

    /// Serialize to the `MAGQART1` wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Writer::new();
        self.header.encode(&mut body);
        body.put_u32(self.attrs.depth());
        body.put_u64(self.attrs.configs().len() as u64);
        for &c in self.attrs.configs() {
            body.put_u64(c);
        }
        self.partition.encode(&mut body);
        match &self.conditioner {
            None => body.put_u8(0),
            Some(dag) => {
                body.put_u8(1);
                dag.encode(&mut body);
            }
        }
        let body = body.into_bytes();
        let mut out = Vec::with_capacity(28 + body.len());
        out.extend_from_slice(&ARTIFACT_MAGIC);
        out.extend_from_slice(&ARTIFACT_FORMAT.to_le_bytes());
        out.extend_from_slice(&crate::hashutil::fnv1a64(&body).to_le_bytes());
        out.extend_from_slice(&(body.len() as u64).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Parse and validate the `MAGQART1` wire format: magic, version,
    /// exact length, integrity hash, then the bounds-checked body decode.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 28 {
            bail!("not a setup artifact: {} bytes is shorter than the file header", bytes.len());
        }
        if bytes[0..8] != ARTIFACT_MAGIC {
            bail!("not a setup artifact: bad magic (want MAGQART1)");
        }
        let version = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
        if version != ARTIFACT_FORMAT {
            bail!("unsupported artifact format version {version} (this build reads {ARTIFACT_FORMAT})");
        }
        let stored_integrity = u64::from_le_bytes([
            bytes[12], bytes[13], bytes[14], bytes[15], bytes[16], bytes[17], bytes[18], bytes[19],
        ]);
        let body_len = u64::from_le_bytes([
            bytes[20], bytes[21], bytes[22], bytes[23], bytes[24], bytes[25], bytes[26], bytes[27],
        ]);
        let body = &bytes[28..];
        if (body.len() as u64) < body_len {
            bail!(
                "setup artifact truncated: header claims {body_len} body bytes, file holds {}",
                body.len()
            );
        }
        if (body.len() as u64) > body_len {
            bail!(
                "setup artifact corrupt: {} trailing bytes past the declared body",
                body.len() as u64 - body_len
            );
        }
        let actual = crate::hashutil::fnv1a64(body);
        if actual != stored_integrity {
            bail!(
                "setup artifact corrupt: integrity hash {actual:016x} does not match stored \
                 {stored_integrity:016x} (truncated or tampered)"
            );
        }

        let mut r = Reader::new(body);
        let header = ArtifactHeader::decode(&mut r)?;
        let depth = r.take_u32("attr depth")?;
        if depth != header.attributes {
            bail!("artifact body corrupt: attr depth {depth} disagrees with header {}", header.attributes);
        }
        let n = r.take_len(8, "attr configs")?;
        if n != header.num_nodes() {
            bail!(
                "artifact body corrupt: {n} attr configs but the header's model has {} nodes",
                header.num_nodes()
            );
        }
        let mut configs = Vec::with_capacity(n);
        for _ in 0..n {
            let c = r.take_u64("attr config")?;
            if depth < 64 && c >= (1u64 << depth) {
                bail!("artifact body corrupt: config {c:#x} outside the 2^{depth} space");
            }
            configs.push(c);
        }
        let attrs = AttributeAssignment::from_configs(configs, depth);
        let partition = Partition::decode(&mut r)?;
        let conditioner = match r.take_u8("conditioner flag")? {
            0 => None,
            1 => Some(ConditionedBallDropSampler::decode(&mut r)?),
            b => bail!("artifact body corrupt: conditioner flag byte {b}"),
        };
        if !r.is_empty() {
            bail!("artifact body corrupt: {} undeclared trailing bytes", r.remaining());
        }
        Ok(SetupArtifact { header, attrs, partition, conditioner })
    }

    /// Write to `path` via the atomic temp-file + rename protocol (a
    /// crash never leaves a partial artifact under the final name).
    pub fn save(&self, path: &Path) -> Result<()> {
        let dir = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
            _ => std::path::PathBuf::from("."),
        };
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            bail!("artifact path {} has no file name", path.display());
        };
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating artifact directory {}", dir.display()))?;
        write_atomic(&dir, name, &self.to_bytes())
            .with_context(|| format!("writing setup artifact {}", path.display()))
    }

    /// Read and validate an artifact file.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading setup artifact {}", path.display()))?;
        Self::from_bytes(&bytes)
            .with_context(|| format!("parsing setup artifact {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Coordinator;

    fn model(log2_nodes: u32, attributes: u32) -> ModelSpec {
        // default_spec() theta is Θ1; shrink n and d to test scale.
        let mut m = ModelSpec::default_spec();
        m.mu = 0.55;
        m.log2_nodes = log2_nodes;
        m.attributes = attributes;
        m
    }

    fn header() -> ArtifactHeader {
        ArtifactHeader::from_model(
            &model(8, 8),
            42,
            SamplerKind::Quilt,
            PieceMode::Conditioned,
            AttrSampleMode::Chunked,
        )
    }

    fn build(sampler: SamplerKind, piece_mode: PieceMode) -> SetupArtifact {
        Coordinator::new()
            .piece_mode(piece_mode)
            .attr_mode(AttrSampleMode::Chunked)
            .build_setup(&model(8, 8), 42, sampler)
            .unwrap()
    }

    #[test]
    fn identity_hash_covers_output_fields_and_skips_provenance() {
        let base = header();
        // Provenance fields never move the identity hash...
        let mut h = base.clone();
        h.setup_threads = 16;
        h.setup_ms = 123.4;
        assert_eq!(h.hash64(), base.hash64());
        // ...but every output-determining field does.
        let mut h = base.clone();
        h.seed = 43;
        assert_ne!(h.hash64(), base.hash64());
        let mut h = base.clone();
        h.theta[2] += 1e-9;
        assert_ne!(h.hash64(), base.hash64());
        let mut h = base.clone();
        h.piece_mode = PieceMode::Rejection;
        assert_ne!(h.hash64(), base.hash64());
        let mut h = base.clone();
        h.attr_mode = AttrSampleMode::Sequential;
        assert_ne!(h.hash64(), base.hash64());
        let mut h = base.clone();
        h.sampler = SamplerKind::Hybrid;
        assert_ne!(h.hash64(), base.hash64());
        assert_eq!(base.hash_hex().len(), 16);
    }

    #[test]
    fn round_trip_is_structurally_identical() {
        for (sampler, piece_mode) in [
            (SamplerKind::Quilt, PieceMode::Conditioned),
            (SamplerKind::Quilt, PieceMode::Rejection),
            (SamplerKind::Hybrid, PieceMode::Conditioned),
            (SamplerKind::Hybrid, PieceMode::Rejection),
        ] {
            let art = build(sampler, piece_mode);
            let bytes = art.to_bytes();
            let back = SetupArtifact::from_bytes(&bytes).unwrap();
            assert_eq!(back.header, art.header, "{sampler:?}/{piece_mode:?}");
            assert_eq!(back.attrs, art.attrs, "{sampler:?}/{piece_mode:?}");
            assert_eq!(back.partition, art.partition, "{sampler:?}/{piece_mode:?}");
            assert_eq!(back.conditioner, art.conditioner, "{sampler:?}/{piece_mode:?}");
            assert_eq!(
                piece_mode == PieceMode::Conditioned,
                back.conditioner.is_some(),
                "conditioned artifacts carry the DAG, rejection ones don't"
            );
            // Serialization is canonical: re-encoding reproduces the bytes.
            assert_eq!(back.to_bytes(), bytes);
        }
    }

    #[test]
    fn save_load_round_trips_on_disk() {
        let dir = std::env::temp_dir().join("magquilt_artifact_io_test");
        let _ = std::fs::remove_dir_all(&dir);
        let art = build(SamplerKind::Quilt, PieceMode::Conditioned);
        let path = dir.join(artifact_file_name(&art.hash_hex()));
        art.save(&path).unwrap();
        let back = SetupArtifact::load(&path).unwrap();
        assert_eq!(back.header, art.header);
        assert_eq!(back.partition, art.partition);
        // No temp residue from the atomic write.
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names.len(), 1, "{names:?}");
        assert!(is_artifact_file(&names[0]));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_bad_magic_version_truncation_and_tamper() {
        let art = build(SamplerKind::Quilt, PieceMode::Rejection);
        let good = art.to_bytes();
        assert!(SetupArtifact::from_bytes(&good).is_ok());

        // Too short for the file header.
        let err = SetupArtifact::from_bytes(&good[..10]).unwrap_err().to_string();
        assert!(err.contains("shorter"), "{err}");
        // Wrong magic.
        let mut bad = good.clone();
        bad[0] = b'X';
        let err = SetupArtifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        // Unknown version.
        let mut bad = good.clone();
        bad[8] = 99;
        let err = SetupArtifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        // Truncated body.
        let err = SetupArtifact::from_bytes(&good[..good.len() - 5]).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        // Trailing garbage past the declared body.
        let mut bad = good.clone();
        bad.extend_from_slice(b"junk");
        let err = SetupArtifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
        // A flipped body byte fails the integrity hash — even in the
        // hash-exempt provenance region (the first body bytes are header).
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        let err = SetupArtifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("integrity"), "{err}");
        let mut bad = good.clone();
        bad[28] ^= 0x01;
        let err = SetupArtifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("integrity"), "{err}");
    }

    #[test]
    fn check_matches_cross_checks_the_plan_identity() {
        let art = build(SamplerKind::Quilt, PieceMode::Conditioned);
        let mut run = crate::config::RunSpec::default_spec();
        run.seed = 42;
        run.attr_mode = Some(AttrSampleMode::Chunked);
        let plan = ShardPlan::new(&model(8, 8), &run, 2).unwrap();
        art.check_matches(&ArtifactHeader::from_plan(&plan)).unwrap();
        // A different seed is a different prologue: refuse.
        run.seed = 43;
        let other = ShardPlan::new(&model(8, 8), &run, 2).unwrap();
        let err =
            art.check_matches(&ArtifactHeader::from_plan(&other)).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");
        assert!(err.contains("magquilt setup"), "{err}");
    }

    #[test]
    fn artifact_file_names() {
        let name = artifact_file_name("00ff00ff00ff00ff");
        assert_eq!(name, "setup-00ff00ff00ff00ff.art");
        assert!(is_artifact_file(&name));
        assert!(is_artifact_file("anything.art"));
        assert!(!is_artifact_file("plan.toml"));
        assert!(!is_artifact_file("seg-00-s00000-w0000.seg"));
        assert!(!is_artifact_file("art"));
    }
}
