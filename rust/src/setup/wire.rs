//! Minimal byte-level encoder/decoder for the `MAGQART1` artifact body.
//!
//! Fixed-width little-endian primitives only — no varints, no framing —
//! so every logical value has exactly one byte representation and the
//! artifact's integrity hash is a pure function of its content. The
//! [`Reader`] treats its input as untrusted: every take checks the
//! remaining length, and length prefixes are validated against the bytes
//! actually present *before* any allocation (the same discipline as the
//! `MAGQEDG1` reader in [`crate::graph`]).

use anyhow::{bail, Result};

/// Append-only little-endian byte buffer.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Empty buffer.
    pub fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Consume the writer, yielding the bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append one byte.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u32`, little-endian.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`, little-endian.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` as its exact IEEE-754 bit pattern (round-trips
    /// NaN payloads and signed zeros — the artifact must reproduce the
    /// setup floats bit for bit, not value-approximately).
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }
}

/// Bounds-checked little-endian cursor over an untrusted byte slice.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Cursor at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Whether the cursor is at the end.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("artifact body truncated: {what} needs {n} bytes, {} left", self.remaining());
        }
        let slice = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(slice)
    }

    /// Read one byte.
    pub fn take_u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    /// Read a little-endian `u32`.
    pub fn take_u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn take_u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read an `f64` from its bit pattern.
    pub fn take_f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.take_u64(what)?))
    }

    /// Read a `u64` element count and validate that `count · elem_bytes`
    /// of payload are actually present before the caller allocates for
    /// them — a declared-length-vs-file-size check in the style of the
    /// `MAGQEDG1` header validation.
    pub fn take_len(&mut self, elem_bytes: usize, what: &str) -> Result<usize> {
        let n = self.take_u64(what)?;
        let Ok(n) = usize::try_from(n) else {
            bail!("artifact body corrupt: {what} count {n} exceeds the address space");
        };
        if n.saturating_mul(elem_bytes) > self.remaining() {
            bail!(
                "artifact body truncated: {what} claims {n} entries ({} bytes) but only {} \
                 bytes remain",
                n.saturating_mul(elem_bytes),
                self.remaining()
            );
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip() {
        let mut w = Writer::new();
        assert!(w.is_empty());
        w.put_u8(7);
        w.put_u32(0xdead_beef);
        w.put_u64(u64::MAX - 3);
        w.put_f64(-0.0);
        w.put_f64(f64::NAN);
        assert_eq!(w.len(), 1 + 4 + 8 + 8 + 8);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.take_u8("a").unwrap(), 7);
        assert_eq!(r.take_u32("b").unwrap(), 0xdead_beef);
        assert_eq!(r.take_u64("c").unwrap(), u64::MAX - 3);
        // Bit-exact: -0.0 keeps its sign, NaN keeps its payload.
        assert_eq!(r.take_f64("d").unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.take_f64("e").unwrap().is_nan());
        assert!(r.is_empty());
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let bytes = [1u8, 2, 3];
        let mut r = Reader::new(&bytes);
        let err = r.take_u64("field").unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        assert!(err.contains("field"), "{err}");
        // The failed take consumed nothing.
        assert_eq!(r.remaining(), 3);
    }

    #[test]
    fn length_prefix_validated_before_allocation() {
        // Claims 2^60 8-byte entries in a 16-byte buffer: must fail on the
        // declared-length check, never attempt the allocation.
        let mut w = Writer::new();
        w.put_u64(1u64 << 60);
        w.put_u64(0);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let err = r.take_len(8, "nodes").unwrap_err().to_string();
        assert!(err.contains("claims"), "{err}");
    }

    #[test]
    fn oversize_count_rejected() {
        let mut w = Writer::new();
        w.put_u64(u64::MAX);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        // On 64-bit the usize conversion succeeds and the size check
        // fires; either way it is an error, not a panic.
        assert!(r.take_len(1, "huge").is_err());
    }
}
