//! Minimal deterministic fork–join helper for the setup pipeline.
//!
//! The vendored crate set has no rayon, so the parallel setup phases
//! (chunked attribute sampling, the prefix-sum partition build, the
//! sharded trie build, and the product-DAG mass aggregation) share this
//! one primitive: map a closure over an indexed work list on
//! `std::thread::scope` threads.
//!
//! Determinism contract: work item `i` is processed by thread
//! `i % threads` and results are reassembled **by index**, so the output
//! vector is a pure function of the input — never of the thread count or
//! the OS schedule. Callers additionally keep their chunk sizes fixed
//! (independent of the thread count), which is what makes the whole
//! setup pipeline bit-for-bit reproducible for any `--setup-threads`.

/// Hard cap on spawned threads per fork–join, whatever the caller asks
/// for: `std::thread::scope` aborts the process if a spawn fails, so an
/// oversized `--setup-threads` must not translate into thousands of
/// simultaneous OS threads (workers are capped at 16 and shard mergers at
/// 256 for the same reason).
const MAX_PARALLEL_THREADS: usize = 256;

/// Map `f` over `items` on up to `threads` scoped threads (capped at
/// [`MAX_PARALLEL_THREADS`] and at the item count), preserving index
/// order in the returned vector; `f` receives `(index, item)`.
///
/// `threads <= 1` — or a work list with at most one item — runs inline
/// without spawning anything, so sequential callers pay no overhead.
pub fn map_indexed<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n).min(MAX_PARALLEL_THREADS);
    if threads <= 1 {
        return items.into_iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let mut buckets: Vec<Vec<(usize, T)>> =
        (0..threads).map(|_| Vec::with_capacity(n / threads + 1)).collect();
    for (i, it) in items.into_iter().enumerate() {
        buckets[i % threads].push((i, it));
    }
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = buckets
            .into_iter()
            .map(|bucket| {
                scope.spawn(move || {
                    bucket.into_iter().map(|(i, it)| (i, f(i, it))).collect::<Vec<(usize, R)>>()
                })
            })
            .collect();
        for handle in handles {
            for (i, r) in handle.join().expect("parallel worker panicked") {
                out[i] = Some(r);
            }
        }
    });
    out.into_iter().map(|o| o.expect("every index filled exactly once")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        for threads in [1usize, 2, 3, 8, 64] {
            let items: Vec<u64> = (0..37).collect();
            let out = map_indexed(items, threads, |i, x| i as u64 * 1000 + x * x);
            assert_eq!(out.len(), 37);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i as u64 * 1000 + (i * i) as u64, "threads={threads}");
            }
        }
    }

    #[test]
    fn empty_and_single_item() {
        let out: Vec<u32> = map_indexed(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
        let out = map_indexed(vec![7u32], 4, |i, x| x + i as u32);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn mutable_slices_as_items() {
        // The chunked-attribute pattern: hand out disjoint &mut chunks.
        let mut data = vec![0u64; 100];
        let chunks: Vec<&mut [u64]> = data.chunks_mut(7).collect();
        map_indexed(chunks, 3, |ci, chunk| {
            for (k, slot) in chunk.iter_mut().enumerate() {
                *slot = (ci * 7 + k) as u64;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }
}
