//! Minimal deterministic fork–join helper for the setup pipeline.
//!
//! The vendored crate set has no rayon, so the parallel setup phases
//! (chunked attribute sampling, the prefix-sum partition build, the
//! sharded trie build, and the product-DAG mass aggregation) share two
//! primitives: [`map_indexed`] maps a closure over an indexed work list
//! on `std::thread::scope` threads, and [`tree_reduce`] folds a work
//! list down pairwise in `O(log n)` combining levels.
//!
//! Determinism contract: work item `i` is processed by thread
//! `i % threads` and results are reassembled **by index**, so the output
//! vector is a pure function of the input — never of the thread count or
//! the OS schedule. Callers additionally keep their chunk sizes fixed
//! (independent of the thread count), which is what makes the whole
//! setup pipeline bit-for-bit reproducible for any `--setup-threads`.

/// Hard cap on spawned threads per fork–join, whatever the caller asks
/// for: `std::thread::scope` aborts the process if a spawn fails, so an
/// oversized `--setup-threads` must not translate into thousands of
/// simultaneous OS threads (workers are capped at 16 and shard mergers at
/// 256 for the same reason).
const MAX_PARALLEL_THREADS: usize = 256;

/// Map `f` over `items` on up to `threads` scoped threads (capped at
/// [`MAX_PARALLEL_THREADS`] and at the item count), preserving index
/// order in the returned vector; `f` receives `(index, item)`.
///
/// `threads <= 1` — or a work list with at most one item — runs inline
/// without spawning anything, so sequential callers pay no overhead.
pub fn map_indexed<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n).min(MAX_PARALLEL_THREADS);
    if threads <= 1 {
        return items.into_iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let mut buckets: Vec<Vec<(usize, T)>> =
        (0..threads).map(|_| Vec::with_capacity(n / threads + 1)).collect();
    for (i, it) in items.into_iter().enumerate() {
        buckets[i % threads].push((i, it));
    }
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = buckets
            .into_iter()
            .map(|bucket| {
                scope.spawn(move || {
                    bucket.into_iter().map(|(i, it)| (i, f(i, it))).collect::<Vec<(usize, R)>>()
                })
            })
            .collect();
        for handle in handles {
            for (i, r) in handle.join().expect("parallel worker panicked") {
                out[i] = Some(r);
            }
        }
    });
    out.into_iter().map(|o| o.expect("every index filled exactly once")).collect()
}

/// Fold `items` down to one value by a deterministic pairwise tree
/// reduction: level by level, element `2j` combines with `2j + 1` (an odd
/// leftover passes through unchanged), and each level's pairs run on up
/// to `threads` scoped threads via [`map_indexed`]. Returns `None` for an
/// empty input.
///
/// The pairing is a pure function of the item order — never of the thread
/// count or the OS schedule — so for an **associative** `combine` the
/// result equals the left-to-right serial fold, and even a
/// non-associative combine is at least reproducible for a fixed input.
/// `O(log n)` combining levels replace the serial `O(n)` fold wall.
pub fn tree_reduce<T, F>(items: Vec<T>, threads: usize, combine: F) -> Option<T>
where
    T: Send,
    F: Fn(T, T) -> T + Sync,
{
    let mut level = items;
    while level.len() > 1 {
        let mut pairs: Vec<(T, Option<T>)> = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some(a) = it.next() {
            pairs.push((a, it.next()));
        }
        level = map_indexed(pairs, threads, |_, (a, b)| match b {
            Some(b) => combine(a, b),
            None => a,
        });
    }
    level.pop()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        for threads in [1usize, 2, 3, 8, 64] {
            let items: Vec<u64> = (0..37).collect();
            let out = map_indexed(items, threads, |i, x| i as u64 * 1000 + x * x);
            assert_eq!(out.len(), 37);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i as u64 * 1000 + (i * i) as u64, "threads={threads}");
            }
        }
    }

    #[test]
    fn empty_and_single_item() {
        let out: Vec<u32> = map_indexed(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
        let out = map_indexed(vec![7u32], 4, |i, x| x + i as u32);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn tree_reduce_matches_serial_fold() {
        // String concat is associative but not commutative: any pairing
        // mistake (swapped operands, skipped leftover) changes the result.
        for n in [0usize, 1, 2, 3, 5, 8, 13, 64] {
            let expect: String = (0..n).map(|i| format!("<{i}>")).collect();
            for threads in [1usize, 2, 3, 8] {
                let items: Vec<String> = (0..n).map(|i| format!("<{i}>")).collect();
                let got = tree_reduce(items, threads, |a, b| a + &b);
                if n == 0 {
                    assert!(got.is_none());
                } else {
                    assert_eq!(got.unwrap(), expect, "n={n} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn mutable_slices_as_items() {
        // The chunked-attribute pattern: hand out disjoint &mut chunks.
        let mut data = vec![0u64; 100];
        let chunks: Vec<&mut [u64]> = data.chunks_mut(7).collect();
        map_indexed(chunks, 3, |ci, chunk| {
            for (k, slot) in chunk.iter_mut().enumerate() {
                *slot = (ci * 7 + k) as u64;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }
}
