//! # magquilt
//!
//! Production reproduction of **"Quilting Stochastic Kronecker Product
//! Graphs to Generate Multiplicative Attribute Graphs"** (Yun &
//! Vishwanathan, AISTATS 2012).
//!
//! The library implements, from scratch:
//!
//! * the Kronecker Product Graph Model (KPGM) with the `O(log2(n)·|E|)`
//!   ball-dropping sampler (paper Algorithm 1) — [`kpgm`],
//! * the Multiplicative Attribute Graph Model (MAGM) with its naive
//!   `O(n²)` baseline samplers — [`magm`],
//! * the paper's contribution: the **quilting sampler** (Algorithm 2) and
//!   the §5 hybrid speedup — [`quilt`],
//! * a job coordinator that plans the `B² + R² + …` quilt pieces, routes
//!   them across a worker pool with bounded queues, and merges the edge
//!   streams through a sharded streaming merge into pluggable
//!   [`graph::EdgeSink`]s (in-memory, degree-counting, or direct-to-disk)
//!   — [`coordinator`],
//! * a distributed runtime that splits one run across worker processes —
//!   shard-range ownership, per-shard `MAGQEDG1` segment files, and a
//!   deterministic merge whose output is bit-for-bit the single-process
//!   sampler's — [`dist`],
//! * a PJRT runtime that loads the AOT-compiled JAX/Pallas edge-probability
//!   kernels (`artifacts/*.hlo.txt`) and runs them from Rust — [`runtime`],
//! * graph/RNG/statistics substrates and the experiment harnesses that
//!   regenerate every figure of the paper's evaluation — [`graph`],
//!   [`rng`], [`stats`], [`experiments`].
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.
//!
//! ## Determinism contract
//!
//! Everything above is bit-for-bit reproducible from `(model, seed, S)`
//! across worker/thread counts and across the single-process vs
//! distributed paths. The conventions that make that true — the RNG
//! stream registry ([`rngtags`]), no unordered-container iteration into
//! output order, no wall-clock/environment reads in output-determining
//! modules, and an explicit hash fate for every plan field — are written
//! down in `docs/determinism.md` and enforced statically by [`lint`]
//! (`cargo run --bin maglint`), which runs in CI and in this crate's own
//! test suite.
//!
//! ## Quickstart
//!
//! ```no_run
//! use magquilt::magm::MagmParams;
//! use magquilt::quilt::QuiltSampler;
//! use magquilt::kpgm::Initiator;
//!
//! // Kim & Leskovec's theta, mu = 0.5, n = 2^14 nodes, d = 14 attributes.
//! let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 1 << 14, 14);
//! let graph = QuiltSampler::new(params).seed(42).sample();
//! println!("sampled {} edges", graph.num_edges());
//! ```

#![forbid(unsafe_code)]

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dist;
pub mod experiments;
pub mod fit;
pub mod graph;
pub mod hashutil;
pub mod kpgm;
pub mod lint;
pub mod magm;
pub mod metrics;
pub mod parallel;
pub mod proptest;
pub mod quilt;
pub mod rng;
pub mod rngtags;
pub mod runtime;
pub mod setup;
pub mod stats;
pub mod trace;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
