//! The RNG stream registry: every named fork tag in the crate.
//!
//! Determinism in this crate rests on disjoint RNG streams derived with
//! [`crate::rng::Rng::fork`]. A stream is identified by a tag; two call
//! sites that fork the same parent with the same tag read the *same*
//! stream, so tags must either be unique or be deliberately shared — and
//! "deliberately" must be visible in the code, not an accident of two
//! equal magic numbers.
//!
//! This module is that visibility: the single home of every fork-tag
//! constant. `maglint` (the determinism lint, `cargo run --bin maglint`)
//! parses this file, verifies the tag values are pairwise distinct, and
//! flags any raw hex literal passed to `fork(...)` elsewhere in the tree,
//! so a new stream can only be introduced by naming it here. See
//! `docs/determinism.md` for the full invariant and how to add a stream.
//!
//! Tags only need to be distinct *under the same parent RNG*: per-piece
//! fork ids (small integers derived from job indices) live under a
//! stream-tagged parent, so they never collide with the tags below.

/// The uniform ER-block stream of the §5 hybrid sampler.
///
/// **Deliberately shared** between `quilt::hybrid` (single-threaded
/// sampling) and `coordinator::pool` (the parallel job runner): both
/// derive per-block RNGs as `Rng::new(seed).fork(ER_STREAM).fork(block)`,
/// and the S × workers equivalence sweeps require the parallel path to
/// read bit-for-bit the same stream the sequential sampler reads. One
/// constant, two readers — not two coincidentally-equal literals.
pub const ER_STREAM: u64 = 0xe4b10c;

/// Per-piece streams of the plain quilt sampler (Algorithm 2): piece
/// `p` samples from `Rng::new(seed).fork(QUILT_PIECE_STREAM).fork(p)`.
/// Shared by `quilt::sampler` and the coordinator for the same
/// equivalence reason as [`ER_STREAM`].
pub const QUILT_PIECE_STREAM: u64 = 0x9011_7ed;

/// Per-piece streams of the hybrid sampler's W-pieces. Distinct from
/// [`QUILT_PIECE_STREAM`] so a hybrid run and a quilt run with the same
/// seed stay decorrelated, and distinct from [`ER_STREAM`] so W-piece
/// ids can never collide with ER-block ids under the same seed.
pub const HYBRID_PIECE_STREAM: u64 = 0x4b1d;

/// Per-piece streams of the general (K×K initiator) quilt sampler.
pub const GENERAL_QUILT_STREAM: u64 = 0x9e11_e4a1;

/// The attribute-assignment stream: chunk `c` of the chunked attribute
/// sampler draws from `Rng::new(seed).fork(ATTR_STREAM).fork(c)`,
/// keeping attribute randomness disjoint from every edge-sampling
/// stream under the same seed.
pub const ATTR_STREAM: u64 = 0xa77c_0de5;

/// XOR mask decorrelating the property-test shrink-check streams from
/// the primary per-case streams: case `i` re-checks shrunken inputs on
/// `base.fork(i ^ SHRINK_CHECK_XOR)`.
pub const SHRINK_CHECK_XOR: u64 = 0xdead_beef;

/// Every registered tag as `(name, value)` — the introspection surface
/// the registry tests and maglint's self-checks share.
pub const ALL_TAGS: &[(&str, u64)] = &[
    ("ER_STREAM", ER_STREAM),
    ("QUILT_PIECE_STREAM", QUILT_PIECE_STREAM),
    ("HYBRID_PIECE_STREAM", HYBRID_PIECE_STREAM),
    ("GENERAL_QUILT_STREAM", GENERAL_QUILT_STREAM),
    ("ATTR_STREAM", ATTR_STREAM),
    ("SHRINK_CHECK_XOR", SHRINK_CHECK_XOR),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_are_pairwise_distinct() {
        for (i, &(na, va)) in ALL_TAGS.iter().enumerate() {
            for &(nb, vb) in &ALL_TAGS[i + 1..] {
                assert_ne!(va, vb, "fork tags {na} and {nb} collide on {va:#x}");
            }
        }
    }

    #[test]
    fn all_tags_lists_every_constant() {
        // Keep the introspection list in sync with the constants: each
        // value here must appear in ALL_TAGS under its name.
        let expect = [
            ("ER_STREAM", ER_STREAM),
            ("QUILT_PIECE_STREAM", QUILT_PIECE_STREAM),
            ("HYBRID_PIECE_STREAM", HYBRID_PIECE_STREAM),
            ("GENERAL_QUILT_STREAM", GENERAL_QUILT_STREAM),
            ("ATTR_STREAM", ATTR_STREAM),
            ("SHRINK_CHECK_XOR", SHRINK_CHECK_XOR),
        ];
        assert_eq!(ALL_TAGS, &expect);
    }

    #[test]
    fn forked_streams_differ_per_tag() {
        use crate::rng::Rng;
        let parent = Rng::new(42);
        let firsts: Vec<u64> =
            ALL_TAGS.iter().map(|&(_, tag)| parent.fork(tag).next_u64()).collect();
        for i in 0..firsts.len() {
            for j in i + 1..firsts.len() {
                assert_ne!(
                    firsts[i], firsts[j],
                    "streams {} and {} start identically",
                    ALL_TAGS[i].0, ALL_TAGS[j].0
                );
            }
        }
    }
}
