//! Non-uniform distributions built on the core generator.
//!
//! Only what the samplers need, implemented with well-known algorithms and
//! moment-tested in the suite. All take `&mut Rng` so the Box–Muller cache
//! lives on the Rng itself.

use super::Rng;

/// Box–Muller transform: two independent standard normals per call.
#[inline]
pub fn box_muller(rng: &mut Rng) -> (f64, f64) {
    // u1 in (0, 1] so ln(u1) is finite.
    let u1 = rng.uniform_open();
    let u2 = rng.uniform();
    let r = (-2.0 * u1.ln()).sqrt();
    let theta = 2.0 * std::f64::consts::PI * u2;
    (r * theta.cos(), r * theta.sin())
}

/// Geometric via inversion: number of failures before the first success.
///
/// For p = 1 returns 0; for p <= 0 the distribution is improper — callers
/// must guard, we debug-assert and return u64::MAX as a sentinel in release.
#[inline]
pub fn geometric(rng: &mut Rng, p: f64) -> u64 {
    debug_assert!(p > 0.0 && p <= 1.0, "geometric p out of range: {p}");
    if p >= 1.0 {
        return 0;
    }
    if p <= 0.0 {
        return u64::MAX;
    }
    // floor(ln U / ln(1-p)), U in (0,1].
    let u = rng.uniform_open();
    let k = (u.ln() / (-p).ln_1p()).floor();
    if k >= u64::MAX as f64 {
        u64::MAX
    } else {
        k as u64
    }
}

/// Binomial(n, p).
///
/// * mean <= 30: inversion by sequential CDF walk (exact, O(mean)),
/// * otherwise: normal approximation with continuity correction, clamped to
///   [0, n]. For the sizes this crate draws (edge counts with mean >> 10^3)
///   the approximation error is far below sampling noise — the same
///   approximation the paper itself uses for |E| in Algorithm 1.
pub fn binomial(rng: &mut Rng, n: u64, p: f64) -> u64 {
    if n == 0 || p <= 0.0 {
        return 0;
    }
    if p >= 1.0 {
        return n;
    }
    // Exploit symmetry to keep the walk short.
    if p > 0.5 {
        return n - binomial(rng, n, 1.0 - p);
    }
    let mean = n as f64 * p;
    if mean <= 30.0 {
        // Inversion: walk the CDF from k = 0.
        let q = 1.0 - p;
        let s = p / q;
        let mut f = q.powf(n as f64);
        // Underflow guard: fall through to normal approx if f == 0.
        if f > 0.0 {
            let u = rng.uniform();
            let mut cdf = f;
            let mut k = 0u64;
            while u > cdf && k < n {
                k += 1;
                f *= s * ((n - k + 1) as f64) / k as f64;
                cdf += f;
            }
            return k;
        }
    }
    let var = mean * (1.0 - p);
    let z = rng.normal();
    let x = (mean + var.sqrt() * z + 0.5).floor();
    x.clamp(0.0, n as f64) as u64
}

/// Poisson(lambda).
///
/// * lambda < 30: Knuth's product-of-uniforms method (exact),
/// * otherwise: normal approximation with continuity correction.
pub fn poisson(rng: &mut Rng, lambda: f64) -> u64 {
    if lambda <= 0.0 {
        return 0;
    }
    if lambda < 30.0 {
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= rng.uniform_open();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }
    let z = rng.normal();
    let x = (lambda + lambda.sqrt() * z + 0.5).floor();
    if x < 0.0 {
        0
    } else {
        x as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn geometric_mean_matches() {
        let mut rng = Rng::new(31);
        for &p in &[0.9, 0.5, 0.1, 0.01] {
            let n = 50_000;
            let xs: Vec<f64> = (0..n).map(|_| geometric(&mut rng, p) as f64).collect();
            let (mean, _) = moments(&xs);
            let want = (1.0 - p) / p;
            let tol = 5.0 * ((1.0 - p) / (p * p) / n as f64).sqrt();
            assert!((mean - want).abs() < tol, "p={p} mean={mean} want={want}");
        }
    }

    #[test]
    fn geometric_p_one_is_zero() {
        let mut rng = Rng::new(37);
        for _ in 0..100 {
            assert_eq!(geometric(&mut rng, 1.0), 0);
        }
    }

    #[test]
    fn binomial_small_mean_exact_region() {
        let mut rng = Rng::new(41);
        let (n, p) = (100u64, 0.05);
        let trials = 60_000;
        let xs: Vec<f64> = (0..trials).map(|_| binomial(&mut rng, n, p) as f64).collect();
        let (mean, var) = moments(&xs);
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
        assert!((var - 4.75).abs() < 0.25, "var={var}");
    }

    #[test]
    fn binomial_large_mean_normal_region() {
        let mut rng = Rng::new(43);
        let (n, p) = (1_000_000u64, 0.3);
        let trials = 5_000;
        let xs: Vec<f64> = (0..trials).map(|_| binomial(&mut rng, n, p) as f64).collect();
        let (mean, var) = moments(&xs);
        let want_mean = 300_000.0;
        let want_var = 210_000.0;
        assert!((mean - want_mean).abs() / want_mean < 0.001, "mean={mean}");
        assert!((var - want_var).abs() / want_var < 0.15, "var={var}");
    }

    #[test]
    fn binomial_edge_cases() {
        let mut rng = Rng::new(47);
        assert_eq!(binomial(&mut rng, 0, 0.5), 0);
        assert_eq!(binomial(&mut rng, 10, 0.0), 0);
        assert_eq!(binomial(&mut rng, 10, 1.0), 10);
        for _ in 0..1000 {
            let x = binomial(&mut rng, 5, 0.5);
            assert!(x <= 5);
        }
    }

    #[test]
    fn poisson_small_and_large() {
        let mut rng = Rng::new(53);
        for &lam in &[0.5, 4.0, 25.0, 200.0] {
            let trials = 40_000;
            let xs: Vec<f64> = (0..trials).map(|_| poisson(&mut rng, lam) as f64).collect();
            let (mean, var) = moments(&xs);
            let tol = 6.0 * (lam / trials as f64).sqrt() + 0.02 * lam;
            assert!((mean - lam).abs() < tol, "lam={lam} mean={mean}");
            assert!((var - lam).abs() < 0.1 * lam + tol, "lam={lam} var={var}");
        }
    }

    #[test]
    fn normal_tail_fraction() {
        // ~2.3% of mass beyond +2 sigma.
        let mut rng = Rng::new(59);
        let n = 200_000;
        let beyond = (0..n).filter(|_| rng.normal() > 2.0).count();
        let frac = beyond as f64 / n as f64;
        assert!((frac - 0.02275).abs() < 0.003, "frac={frac}");
    }
}
