//! Core generators: SplitMix64 (seed expansion) and xoshiro256++.
//!
//! References: Vigna, "Further scramblings of Marsaglia's xorshift
//! generators"; Blackman & Vigna, "Scrambled linear pseudorandom number
//! generators". Implemented from the public-domain reference code.

/// SplitMix64: used to expand a 64-bit seed into xoshiro state and to derive
/// fork seeds. Passes through every 64-bit value exactly once per period.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New stream from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — 256 bits of state, period 2^256 − 1.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion (handles seed = 0 correctly: the
    /// expanded state is never all-zero).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::from_splitmix(&mut sm)
    }

    /// Fill state from an existing SplitMix64 stream.
    pub fn from_splitmix(sm: &mut SplitMix64) -> Self {
        let mut s = [0u64; 4];
        loop {
            for slot in &mut s {
                *slot = sm.next_u64();
            }
            if s.iter().any(|&x| x != 0) {
                break;
            }
        }
        Xoshiro256 { s }
    }

    /// A cheap digest of the state, used for fork-stream derivation.
    #[inline]
    pub fn state_hash(&self) -> u64 {
        self.s[0]
            .rotate_left(1)
            .wrapping_add(self.s[1].rotate_left(17))
            .wrapping_add(self.s[2].rotate_left(33))
            .wrapping_add(self.s[3].rotate_left(47))
    }

    /// Next 64-bit output (the ++ scrambler).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (from the public-domain reference).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xe220a8397b1dcdaf);
        assert_eq!(sm.next_u64(), 0x6e789e6aa1b965f4);
        assert_eq!(sm.next_u64(), 0x06c45d188009454f);
    }

    #[test]
    fn xoshiro_nonzero_state_even_for_zero_seed() {
        let mut x = Xoshiro256::seeded(0);
        // Should produce varied output, not a fixed point.
        let a = x.next_u64();
        let b = x.next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn xoshiro_streams_reproducible() {
        let mut a = Xoshiro256::seeded(123);
        let mut b = Xoshiro256::seeded(123);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_hash_changes_with_state() {
        let mut x = Xoshiro256::seeded(5);
        let h0 = x.state_hash();
        x.next_u64();
        assert_ne!(h0, x.state_hash());
    }
}
