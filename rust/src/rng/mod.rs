//! Deterministic random-number substrate.
//!
//! The vendored crate set contains no `rand`, so this module implements the
//! generators the samplers need, from scratch:
//!
//! * [`SplitMix64`] — seed expansion / stream derivation,
//! * [`Xoshiro256`] — xoshiro256++ core generator (Blackman & Vigna),
//! * distributions: uniform, [Bernoulli](Rng::bernoulli),
//!   [Normal](Rng::normal) (Box–Muller), [Geometric](Rng::geometric)
//!   (inversion), [Binomial](Rng::binomial) (inversion / normal tail),
//!   [Poisson](Rng::poisson) (Knuth / PTRS-lite), and 4-way
//!   [categorical](Rng::categorical4) draws used by the quadrisection
//!   descent of Algorithm 1.
//!
//! Determinism contract: every sampler in the crate takes a `u64` seed and
//! derives independent per-shard streams with [`Rng::fork`], so a run is
//! reproducible for a given `(seed, plan)` regardless of worker scheduling.

mod distributions;
mod xoshiro;

pub use xoshiro::{SplitMix64, Xoshiro256};

/// The crate-wide RNG: xoshiro256++ plus distribution methods.
///
/// Cheap to fork, 32 bytes of state, passes BigCrush (per upstream authors);
/// we additionally sanity-test moments and χ² uniformity in the test suite.
#[derive(Debug, Clone)]
pub struct Rng {
    core: Xoshiro256,
    /// Cached second normal variate from Box–Muller.
    normal_spare: Option<f64>,
}

impl Rng {
    /// Create from a seed; seeds 0 and 1 are fine (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        Rng { core: Xoshiro256::seeded(seed), normal_spare: None }
    }

    /// Derive an independent stream for shard `id`.
    ///
    /// Uses SplitMix64 over `(state hash, id)` so forked streams are
    /// decorrelated from the parent and from each other; forking is
    /// deterministic in (parent seed, id) and does NOT advance the parent.
    pub fn fork(&self, id: u64) -> Rng {
        let mut mix = SplitMix64::new(self.core.state_hash() ^ 0x9e37_79b9_7f4a_7c15);
        let a = mix.next_u64();
        let mut mix2 = SplitMix64::new(a ^ id.wrapping_mul(0xbf58_476d_1ce4_e5b9));
        Rng { core: Xoshiro256::from_splitmix(&mut mix2), normal_spare: None }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.core.next_u64()
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // Take the top 53 bits — xoshiro's low bits are its weakest.
        ((self.next_u64() >> 11) as f64) * (1.0 / 9007199254740992.0)
    }

    /// Uniform in `[0, 1]` open at neither end is unnecessary; this gives
    /// `(0, 1]`, convenient for logs.
    #[inline]
    pub fn uniform_open(&mut self) -> f64 {
        1.0 - self.uniform()
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound {
                return (m >> 64) as u64;
            }
            // Rejection zone: low part below threshold.
            let t = bound.wrapping_neg() % bound;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.normal_spare.take() {
            return z;
        }
        let (z0, z1) = distributions::box_muller(self);
        self.normal_spare = Some(z1);
        z0
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.normal()
    }

    /// Geometric: number of failures before the first success for success
    /// probability `p` (support `0, 1, 2, …`), sampled by inversion.
    ///
    /// This powers the ball-skipping trick of the paper's §5 footnote:
    /// instead of k i.i.d. Bernoulli(p) trials, jump directly to the next
    /// success index.
    #[inline]
    pub fn geometric(&mut self, p: f64) -> u64 {
        distributions::geometric(self, p)
    }

    /// Binomial(n, p) — inversion for small mean, normal approximation with
    /// continuity correction and clamping for large mean.
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        distributions::binomial(self, n, p)
    }

    /// Poisson(lambda) — Knuth for small lambda, normal approx for large.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        distributions::poisson(self, lambda)
    }

    /// Categorical draw over 4 weights (the Algorithm-1 quadrisection step).
    /// Returns an index 0..4. Weights need not be normalized.
    #[inline]
    pub fn categorical4(&mut self, w: &[f64; 4]) -> usize {
        let total = w[0] + w[1] + w[2] + w[3];
        let mut u = self.uniform() * total;
        for (i, &wi) in w.iter().enumerate().take(3) {
            if u < wi {
                return i;
            }
            u -= wi;
        }
        3
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_is_deterministic_and_independent() {
        let parent = Rng::new(7);
        let mut f1 = parent.fork(3);
        let mut f2 = parent.fork(3);
        let mut f3 = parent.fork(4);
        let a: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        let c: Vec<u64> = (0..8).map(|_| f3.next_u64()).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn fork_does_not_advance_parent() {
        let mut p1 = Rng::new(9);
        let mut p2 = Rng::new(9);
        let _ = p1.fork(0);
        let _ = p1.fork(1);
        assert_eq!(p1.next_u64(), p2.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut rng = Rng::new(5);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_small_bound() {
        let mut rng = Rng::new(11);
        let mut counts = [0u32; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7) as usize] += 1;
        }
        let expect = n as f64 / 7.0;
        for &c in &counts {
            assert!(((c as f64) - expect).abs() < 5.0 * expect.sqrt());
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Rng::new(13);
        let p = 0.3;
        let n = 100_000;
        let hits = (0..n).filter(|_| rng.bernoulli(p)).count();
        let got = hits as f64 / n as f64;
        assert!((got - p).abs() < 0.01, "got={got}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(17);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn categorical4_proportions() {
        let mut rng = Rng::new(19);
        let w = [0.1, 0.2, 0.3, 0.4];
        let n = 100_000;
        let mut counts = [0u32; 4];
        for _ in 0..n {
            counts[rng.categorical4(&w)] += 1;
        }
        for i in 0..4 {
            let got = counts[i] as f64 / n as f64;
            assert!((got - w[i]).abs() < 0.01, "i={i} got={got}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(23);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
