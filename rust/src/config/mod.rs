//! Configuration system.
//!
//! The vendored crate set has no serde/toml, so [`toml`] implements the
//! TOML subset the CLI needs (sections, scalars, arrays), and [`spec`]
//! defines the typed model/run specifications parsed from it.
//!
//! ```toml
//! [model]
//! theta = [0.15, 0.7, 0.7, 0.85]   # row-major 2x2
//! mu = 0.5
//! log2_nodes = 14
//! attributes = 14                   # d; defaults to log2_nodes
//!
//! [run]
//! seed = 42
//! workers = 4
//! shards = 0                        # shard mergers; 0 = auto (= workers)
//! setup_threads = 0                 # setup pipeline threads; 0 = auto
//! attr_mode = "sequential"          # sequential | chunked
//! sampler = "quilt"                 # quilt | hybrid | naive | naive-xla
//! piece_mode = "conditioned"        # conditioned | rejection
//! output = "out/graph.bin"
//! spill_dir = "/tmp/magquilt"       # binary-sink spill files (default:
//!                                   # next to the output)
//! spill_budget = 268435456          # bytes of out-of-order shards held
//!                                   # in memory before spilling (0 =
//!                                   # spill everything out of order)
//! dist_workers = 0                  # worker processes (0 = single-process;
//!                                   # > 0 runs the distributed pipeline)
//! segment_dir = "/tmp/mq-segments"  # distributed segment files (default:
//!                                   # <output>.segments)
//! ```
//!
//! Complete annotated examples live at `examples/configs/spill_to_disk.toml`
//! and `examples/configs/distributed.toml`.

mod spec;
mod toml;

pub use spec::{parse_attr_mode, parse_piece_mode, ModelSpec, RunSpec, SamplerKind};
pub use toml::{parse_toml, TomlValue};

use std::collections::BTreeMap;
use std::path::Path;

/// A parsed config file: section -> key -> value.
pub type ConfigMap = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Load and parse a config file into typed specs.
pub fn load_config(path: &Path) -> anyhow::Result<(ModelSpec, RunSpec)> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let map = parse_toml(&text)?;
    let model = ModelSpec::from_section(map.get("model"))?;
    let run = RunSpec::from_section(map.get("run"))?;
    Ok((model, run))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_config_end_to_end() {
        let dir = std::env::temp_dir().join("magquilt_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.toml");
        std::fs::write(
            &p,
            r#"
[model]
theta = [0.15, 0.7, 0.7, 0.85]
mu = 0.5
log2_nodes = 10

[run]
seed = 7
sampler = "quilt"
"#,
        )
        .unwrap();
        let (model, run) = load_config(&p).unwrap();
        assert_eq!(model.log2_nodes, 10);
        assert_eq!(model.attributes, 10); // defaults to log2_nodes
        assert_eq!(run.seed, 7);
        assert_eq!(run.sampler, SamplerKind::Quilt);
    }

    #[test]
    fn shipped_example_configs_parse() {
        // The annotated configs under examples/configs are documentation
        // that must stay loadable.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/configs");
        let mut checked = 0;
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            if path.extension().is_some_and(|e| e == "toml") {
                load_config(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                checked += 1;
            }
        }
        assert!(checked >= 1, "no example configs found in {}", dir.display());
        let (_, run) = load_config(&dir.join("spill_to_disk.toml")).unwrap();
        assert_eq!(run.spill_dir.as_deref(), Some("/tmp/magquilt-spill"));
        assert_eq!(run.spill_budget, Some(256 << 20));
        let (_, run) = load_config(&dir.join("distributed.toml")).unwrap();
        assert_eq!(run.dist_workers, 4);
        assert_eq!(run.shards, 32);
        assert_eq!(run.segment_dir.as_deref(), Some("/tmp/magquilt-segments"));
        // attr_mode left unset: distributed plans resolve it to chunked.
        assert_eq!(run.attr_mode, None);
    }
}
