//! Typed model / run specifications parsed from config files or CLI flags.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::magm::AttrSampleMode;
use crate::quilt::PieceMode;

use super::TomlValue;

/// Parse a quilt-piece mode from the CLI / config spelling.
pub fn parse_piece_mode(s: &str) -> Result<PieceMode> {
    PieceMode::parse(s)
        .ok_or_else(|| anyhow!("unknown piece mode {s:?} (expected conditioned|rejection)"))
}

/// Parse an attribute-sampling mode from the CLI / config spelling.
pub fn parse_attr_mode(s: &str) -> Result<AttrSampleMode> {
    AttrSampleMode::parse(s)
        .ok_or_else(|| anyhow!("unknown attr mode {s:?} (expected sequential|chunked)"))
}

/// Which sampler implementation to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    /// Paper Algorithm 2 (quilting).
    Quilt,
    /// §5 hybrid (quilting + uniform blocks), the default for unbalanced mu.
    Hybrid,
    /// O(n²) Bernoulli baseline, pure Rust.
    Naive,
    /// O(n²) baseline with the probability blocks computed by the AOT XLA
    /// kernel (the accelerated baseline).
    NaiveXla,
}

impl SamplerKind {
    /// Parse from the CLI / config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "quilt" => SamplerKind::Quilt,
            "hybrid" => SamplerKind::Hybrid,
            "naive" => SamplerKind::Naive,
            "naive-xla" => SamplerKind::NaiveXla,
            _ => bail!("unknown sampler {s:?} (expected quilt|hybrid|naive|naive-xla)"),
        })
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Quilt => "quilt",
            SamplerKind::Hybrid => "hybrid",
            SamplerKind::Naive => "naive",
            SamplerKind::NaiveXla => "naive-xla",
        }
    }
}

/// MAGM model specification.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Row-major 2×2 initiator, reused at every level (the paper's
    /// experimental setup); heterogeneous levels are available through the
    /// library API.
    pub theta: [f64; 4],
    /// Attribute Bernoulli parameter mu, shared across levels.
    pub mu: f64,
    /// Number of nodes = 2^log2_nodes.
    pub log2_nodes: u32,
    /// Number of attributes d (defaults to log2_nodes).
    pub attributes: u32,
}

impl ModelSpec {
    /// Defaults: Θ1 (Kim & Leskovec), mu = 0.5, n = 2^14, d = 14.
    pub fn default_spec() -> Self {
        ModelSpec { theta: [0.15, 0.7, 0.7, 0.85], mu: 0.5, log2_nodes: 14, attributes: 14 }
    }

    /// Parse from a `[model]` section (missing section = all defaults).
    pub fn from_section(section: Option<&BTreeMap<String, TomlValue>>) -> Result<Self> {
        let mut spec = Self::default_spec();
        let Some(sec) = section else { return Ok(spec) };
        if let Some(v) = sec.get("theta") {
            let arr = v
                .as_float_array()
                .ok_or_else(|| anyhow!("model.theta must be a numeric array"))?;
            if arr.len() != 4 {
                bail!("model.theta must have 4 entries (row-major 2x2), got {}", arr.len());
            }
            spec.theta = [arr[0], arr[1], arr[2], arr[3]];
        }
        if let Some(v) = sec.get("mu") {
            spec.mu = v.as_float().ok_or_else(|| anyhow!("model.mu must be a number"))?;
        }
        if let Some(v) = sec.get("log2_nodes") {
            spec.log2_nodes =
                v.as_int().ok_or_else(|| anyhow!("model.log2_nodes must be an integer"))? as u32;
        }
        spec.attributes = match sec.get("attributes") {
            Some(v) => {
                v.as_int().ok_or_else(|| anyhow!("model.attributes must be an integer"))? as u32
            }
            None => spec.log2_nodes,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Check ranges.
    pub fn validate(&self) -> Result<()> {
        for (i, &t) in self.theta.iter().enumerate() {
            if !(0.0..=1.0).contains(&t) {
                bail!("theta[{i}] = {t} outside [0, 1]");
            }
        }
        if !(0.0..=1.0).contains(&self.mu) {
            bail!("mu = {} outside [0, 1]", self.mu);
        }
        if self.log2_nodes == 0 || self.log2_nodes > 31 {
            bail!("log2_nodes = {} outside [1, 31]", self.log2_nodes);
        }
        if self.attributes == 0 || self.attributes > 63 {
            bail!("attributes = {} outside [1, 63]", self.attributes);
        }
        Ok(())
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        1usize << self.log2_nodes
    }
}

/// Run specification.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSpec {
    /// RNG seed.
    pub seed: u64,
    /// Worker threads for the coordinator (0 = available parallelism).
    pub workers: usize,
    /// Shard mergers for the coordinator's streaming merge (0 = auto,
    /// matching the worker count). The sampled edge set is identical for
    /// every shard count.
    pub shards: usize,
    /// Setup-pipeline threads (0 = auto, matching the worker count). The
    /// built plan and sampled graph are identical for every count.
    pub setup_threads: usize,
    /// How attribute sampling consumes randomness (sequential = legacy
    /// stream, seed-compatible; chunked = parallel, thread-count-stable).
    /// `None` = not specified: single-process runs keep the sequential
    /// legacy default (golden compatibility), distributed runs default to
    /// chunked (no goldens to protect, and the parallel setup pipeline
    /// should engage on every worker host).
    pub attr_mode: Option<AttrSampleMode>,
    /// Sampler implementation.
    pub sampler: SamplerKind,
    /// How quilt pieces place balls (conditioned = rejection-free default;
    /// rejection = the paper's literal sample-then-filter, for A/B runs).
    pub piece_mode: PieceMode,
    /// Optional output path for the sampled edge list.
    pub output: Option<String>,
    /// Directory for the binary sink's out-of-order spill files (None =
    /// next to the output file).
    pub spill_dir: Option<String>,
    /// In-memory budget in bytes for shards that finish ahead of the
    /// binary sink's file frontier before they spill to disk (None =
    /// the sink default, 256 MiB; 0 forces every out-of-order shard to
    /// spill).
    pub spill_budget: Option<u64>,
    /// Distributed mode: number of worker **processes** to split the run
    /// across (0 = off, run single-process). Each worker owns a
    /// contiguous shard range and writes per-shard segment files that a
    /// deterministic merge concatenates — bit-for-bit the single-process
    /// output.
    pub dist_workers: usize,
    /// Directory for distributed segment files and the plan manifest
    /// (None = `<output>.segments` next to the output file).
    pub segment_dir: Option<String>,
    /// Merge worker threads for the distributed segment merge (0 = auto;
    /// the merged file is byte-identical for every thread count).
    pub merge_threads: usize,
    /// Supervised restart budget per distributed worker process: how many
    /// times the driver relaunches a crashed/stalled worker before giving
    /// up on the run. Restarts resume from the segments already on disk,
    /// so this is a robustness knob — it never changes output bytes.
    pub worker_retries: usize,
    /// Base delay in milliseconds between supervised worker restarts
    /// (doubles per retry, capped). Wall-clock only.
    pub worker_backoff_ms: u64,
    /// Number of repeated samples (experiments average over trials).
    pub trials: u32,
    /// Path to a serialized setup artifact (`magquilt setup --out F`).
    /// When set, runs hydrate the deterministic prologue from this file
    /// instead of recomputing it (building and saving it on first use);
    /// distributed drivers hand it to every worker. A cache location
    /// only — the artifact's own identity hash guards against mismatch,
    /// so this field never influences output bytes.
    pub artifact: Option<String>,
    /// Optional path for the run's structured trace stream
    /// (`MAGQTRC1` JSONL, see `trace`). Telemetry is write-only — the
    /// lint's trace-sink invariant guarantees it never influences
    /// output bytes.
    pub trace: Option<String>,
    /// Optional path for the run's machine-readable report
    /// (`MAGQRPT1` JSON, see `trace::report`). Write-only, like
    /// `trace`.
    pub report: Option<String>,
}

impl RunSpec {
    /// Defaults: seed 42, auto workers, auto shards, auto setup threads,
    /// context-default attributes (sequential single-process, chunked
    /// distributed), quilt sampler with conditioned pieces, default spill
    /// budget next to the output, single-process, 1 trial.
    pub fn default_spec() -> Self {
        RunSpec {
            seed: 42,
            workers: 0,
            shards: 0,
            setup_threads: 0,
            attr_mode: None,
            sampler: SamplerKind::Quilt,
            piece_mode: PieceMode::Conditioned,
            output: None,
            spill_dir: None,
            spill_budget: None,
            dist_workers: 0,
            segment_dir: None,
            merge_threads: 0,
            worker_retries: 2,
            worker_backoff_ms: 500,
            trials: 1,
            artifact: None,
            trace: None,
            report: None,
        }
    }

    /// The attribute mode a **single-process** run uses when the spec
    /// leaves it unset: the legacy sequential stream, seed-compatible
    /// with goldens recorded before the chunked pipeline existed.
    /// (Distributed plans default to [`AttrSampleMode::Chunked`] instead
    /// — see `dist::ShardPlan`.)
    pub fn effective_attr_mode(&self) -> AttrSampleMode {
        self.attr_mode.unwrap_or(AttrSampleMode::Sequential)
    }

    /// Parse from a `[run]` section (missing section = all defaults).
    pub fn from_section(section: Option<&BTreeMap<String, TomlValue>>) -> Result<Self> {
        let mut spec = Self::default_spec();
        let Some(sec) = section else { return Ok(spec) };
        if let Some(v) = sec.get("seed") {
            spec.seed = v.as_int().ok_or_else(|| anyhow!("run.seed must be an integer"))? as u64;
        }
        if let Some(v) = sec.get("workers") {
            spec.workers =
                v.as_int().ok_or_else(|| anyhow!("run.workers must be an integer"))? as usize;
        }
        if let Some(v) = sec.get("shards") {
            spec.shards =
                v.as_int().ok_or_else(|| anyhow!("run.shards must be an integer"))? as usize;
        }
        if let Some(v) = sec.get("setup_threads") {
            spec.setup_threads = v
                .as_int()
                .ok_or_else(|| anyhow!("run.setup_threads must be an integer"))?
                as usize;
        }
        if let Some(v) = sec.get("attr_mode") {
            spec.attr_mode = Some(parse_attr_mode(
                v.as_str().ok_or_else(|| anyhow!("run.attr_mode must be a string"))?,
            )?);
        }
        if let Some(v) = sec.get("sampler") {
            spec.sampler = SamplerKind::parse(
                v.as_str().ok_or_else(|| anyhow!("run.sampler must be a string"))?,
            )?;
        }
        if let Some(v) = sec.get("piece_mode") {
            spec.piece_mode = parse_piece_mode(
                v.as_str().ok_or_else(|| anyhow!("run.piece_mode must be a string"))?,
            )?;
        }
        if let Some(v) = sec.get("output") {
            spec.output =
                Some(v.as_str().ok_or_else(|| anyhow!("run.output must be a string"))?.to_string());
        }
        if let Some(v) = sec.get("spill_dir") {
            spec.spill_dir = Some(
                v.as_str().ok_or_else(|| anyhow!("run.spill_dir must be a string"))?.to_string(),
            );
        }
        if let Some(v) = sec.get("spill_budget") {
            let b = v.as_int().ok_or_else(|| anyhow!("run.spill_budget must be an integer"))?;
            if b < 0 {
                bail!("run.spill_budget must be >= 0 bytes, got {b}");
            }
            spec.spill_budget = Some(b as u64);
        }
        if let Some(v) = sec.get("dist_workers") {
            let w = v.as_int().ok_or_else(|| anyhow!("run.dist_workers must be an integer"))?;
            if w < 0 {
                bail!("run.dist_workers must be >= 0, got {w}");
            }
            spec.dist_workers = w as usize;
        }
        if let Some(v) = sec.get("segment_dir") {
            spec.segment_dir = Some(
                v.as_str().ok_or_else(|| anyhow!("run.segment_dir must be a string"))?.to_string(),
            );
        }
        if let Some(v) = sec.get("merge_threads") {
            let w = v.as_int().ok_or_else(|| anyhow!("run.merge_threads must be an integer"))?;
            if w < 0 {
                bail!("run.merge_threads must be >= 0, got {w}");
            }
            spec.merge_threads = w as usize;
        }
        if let Some(v) = sec.get("worker_retries") {
            let r = v.as_int().ok_or_else(|| anyhow!("run.worker_retries must be an integer"))?;
            if r < 0 {
                bail!("run.worker_retries must be >= 0, got {r}");
            }
            spec.worker_retries = r as usize;
        }
        if let Some(v) = sec.get("worker_backoff_ms") {
            let b =
                v.as_int().ok_or_else(|| anyhow!("run.worker_backoff_ms must be an integer"))?;
            if b < 0 {
                bail!("run.worker_backoff_ms must be >= 0, got {b}");
            }
            spec.worker_backoff_ms = b as u64;
        }
        if let Some(v) = sec.get("trials") {
            spec.trials =
                v.as_int().ok_or_else(|| anyhow!("run.trials must be an integer"))? as u32;
        }
        if let Some(v) = sec.get("artifact") {
            spec.artifact = Some(
                v.as_str().ok_or_else(|| anyhow!("run.artifact must be a string"))?.to_string(),
            );
        }
        if let Some(v) = sec.get("trace") {
            spec.trace =
                Some(v.as_str().ok_or_else(|| anyhow!("run.trace must be a string"))?.to_string());
        }
        if let Some(v) = sec.get("report") {
            spec.report =
                Some(v.as_str().ok_or_else(|| anyhow!("run.report must be a string"))?.to_string());
        }
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_toml;

    #[test]
    fn defaults_when_sections_missing() {
        let model = ModelSpec::from_section(None).unwrap();
        assert_eq!(model, ModelSpec::default_spec());
        let run = RunSpec::from_section(None).unwrap();
        assert_eq!(run, RunSpec::default_spec());
    }

    #[test]
    fn attributes_default_to_log2_nodes() {
        let m = parse_toml("[model]\nlog2_nodes = 9\n").unwrap();
        let spec = ModelSpec::from_section(m.get("model")).unwrap();
        assert_eq!(spec.attributes, 9);
    }

    #[test]
    fn validation_rejects_bad_theta() {
        let m = parse_toml("[model]\ntheta = [0.1, 0.2, 0.3, 1.5]\n").unwrap();
        assert!(ModelSpec::from_section(m.get("model")).is_err());
    }

    #[test]
    fn validation_rejects_bad_mu() {
        let m = parse_toml("[model]\nmu = -0.1\n").unwrap();
        assert!(ModelSpec::from_section(m.get("model")).is_err());
    }

    #[test]
    fn piece_mode_parses_from_config() {
        let m = parse_toml("[run]\npiece_mode = \"rejection\"\n").unwrap();
        let spec = RunSpec::from_section(m.get("run")).unwrap();
        assert_eq!(spec.piece_mode, PieceMode::Rejection);
        assert_eq!(RunSpec::default_spec().piece_mode, PieceMode::Conditioned);
        assert!(parse_piece_mode("bogus").is_err());
    }

    #[test]
    fn shards_parse_from_config() {
        let m = parse_toml("[run]\nshards = 8\nworkers = 4\n").unwrap();
        let spec = RunSpec::from_section(m.get("run")).unwrap();
        assert_eq!(spec.shards, 8);
        assert_eq!(spec.workers, 4);
        assert_eq!(RunSpec::default_spec().shards, 0);
        let bad = parse_toml("[run]\nshards = \"many\"\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
    }

    #[test]
    fn setup_threads_and_attr_mode_parse_from_config() {
        let m = parse_toml("[run]\nsetup_threads = 4\nattr_mode = \"chunked\"\n").unwrap();
        let spec = RunSpec::from_section(m.get("run")).unwrap();
        assert_eq!(spec.setup_threads, 4);
        assert_eq!(spec.attr_mode, Some(AttrSampleMode::Chunked));
        assert_eq!(RunSpec::default_spec().setup_threads, 0);
        // Unset = context default: sequential for single-process runs.
        assert_eq!(RunSpec::default_spec().attr_mode, None);
        assert_eq!(RunSpec::default_spec().effective_attr_mode(), AttrSampleMode::Sequential);
        assert!(parse_attr_mode("bogus").is_err());
        let bad = parse_toml("[run]\nattr_mode = \"bogus\"\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
    }

    #[test]
    fn dist_knobs_parse_from_config() {
        let m = parse_toml(
            "[run]\ndist_workers = 4\nsegment_dir = \"/tmp/segs\"\nmerge_threads = 8\n",
        )
        .unwrap();
        let spec = RunSpec::from_section(m.get("run")).unwrap();
        assert_eq!(spec.dist_workers, 4);
        assert_eq!(spec.segment_dir.as_deref(), Some("/tmp/segs"));
        assert_eq!(spec.merge_threads, 8);
        // Defaults: single-process, segments next to the output, auto merge.
        assert_eq!(RunSpec::default_spec().dist_workers, 0);
        assert_eq!(RunSpec::default_spec().segment_dir, None);
        assert_eq!(RunSpec::default_spec().merge_threads, 0);
        let bad = parse_toml("[run]\ndist_workers = -2\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
        let bad = parse_toml("[run]\nsegment_dir = 9\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
        let bad = parse_toml("[run]\nmerge_threads = -1\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
    }

    #[test]
    fn supervision_knobs_parse_from_config() {
        let m = parse_toml("[run]\nworker_retries = 5\nworker_backoff_ms = 125\n").unwrap();
        let spec = RunSpec::from_section(m.get("run")).unwrap();
        assert_eq!(spec.worker_retries, 5);
        assert_eq!(spec.worker_backoff_ms, 125);
        // Defaults: a couple of restarts with a half-second base backoff.
        assert_eq!(RunSpec::default_spec().worker_retries, 2);
        assert_eq!(RunSpec::default_spec().worker_backoff_ms, 500);
        let bad = parse_toml("[run]\nworker_retries = -1\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
        let bad = parse_toml("[run]\nworker_backoff_ms = -10\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
    }

    #[test]
    fn spill_knobs_parse_from_config() {
        let m = parse_toml("[run]\nspill_dir = \"/tmp/spill\"\nspill_budget = 0\n").unwrap();
        let spec = RunSpec::from_section(m.get("run")).unwrap();
        assert_eq!(spec.spill_dir.as_deref(), Some("/tmp/spill"));
        assert_eq!(spec.spill_budget, Some(0));
        // Defaults: sink decides (dir next to the output, 256 MiB budget).
        assert_eq!(RunSpec::default_spec().spill_dir, None);
        assert_eq!(RunSpec::default_spec().spill_budget, None);
        let bad = parse_toml("[run]\nspill_budget = -5\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
        let bad = parse_toml("[run]\nspill_dir = 7\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
    }

    #[test]
    fn artifact_path_parses_from_config() {
        let m = parse_toml("[run]\nartifact = \"setup.art\"\n").unwrap();
        let spec = RunSpec::from_section(m.get("run")).unwrap();
        assert_eq!(spec.artifact.as_deref(), Some("setup.art"));
        assert_eq!(RunSpec::default_spec().artifact, None);
        let bad = parse_toml("[run]\nartifact = 3\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
    }

    #[test]
    fn telemetry_paths_parse_from_config() {
        let m =
            parse_toml("[run]\ntrace = \"run.trace.jsonl\"\nreport = \"report.json\"\n").unwrap();
        let spec = RunSpec::from_section(m.get("run")).unwrap();
        assert_eq!(spec.trace.as_deref(), Some("run.trace.jsonl"));
        assert_eq!(spec.report.as_deref(), Some("report.json"));
        // Telemetry is off by default.
        assert_eq!(RunSpec::default_spec().trace, None);
        assert_eq!(RunSpec::default_spec().report, None);
        let bad = parse_toml("[run]\ntrace = 3\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
        let bad = parse_toml("[run]\nreport = 3\n").unwrap();
        assert!(RunSpec::from_section(bad.get("run")).is_err());
    }

    #[test]
    fn sampler_kinds_parse() {
        assert_eq!(SamplerKind::parse("quilt").unwrap(), SamplerKind::Quilt);
        assert_eq!(SamplerKind::parse("hybrid").unwrap(), SamplerKind::Hybrid);
        assert_eq!(SamplerKind::parse("naive").unwrap(), SamplerKind::Naive);
        assert_eq!(SamplerKind::parse("naive-xla").unwrap(), SamplerKind::NaiveXla);
        assert!(SamplerKind::parse("bogus").is_err());
        assert_eq!(SamplerKind::Quilt.name(), "quilt");
    }
}
