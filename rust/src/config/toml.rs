//! Minimal TOML-subset parser.
//!
//! Supports exactly what our config files use: `[section]` headers,
//! `key = value` lines, `#` comments, and values of type string (double
//! quoted), integer, float, boolean, and flat arrays of those. No nested
//! tables, no multi-line values, no datetimes.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::ConfigMap;

/// A parsed TOML value (subset).
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// Double-quoted string.
    Str(String),
    /// 64-bit integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Flat array.
    Array(Vec<TomlValue>),
}

impl TomlValue {
    /// As i64 (ints only).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// As f64 (ints widen).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As vector of f64 (numeric arrays).
    pub fn as_float_array(&self) -> Option<Vec<f64>> {
        match self {
            TomlValue::Array(xs) => xs.iter().map(|x| x.as_float()).collect(),
            _ => None,
        }
    }
}

/// Parse the TOML subset into section -> key -> value.
/// Keys before any `[section]` land in the "" section.
pub fn parse_toml(text: &str) -> Result<ConfigMap> {
    let mut map: ConfigMap = BTreeMap::new();
    let mut section = String::new();
    map.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            if section.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            map.entry(section.clone()).or_default();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected `key = value`: {line}", lineno + 1);
        };
        let key = line[..eq].trim().to_string();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        map.get_mut(&section).unwrap().insert(key, value);
    }
    Ok(map)
}

/// Strip a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(TomlValue::Array(items));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        if inner.contains('"') {
            bail!("embedded quote in string");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(v) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(v));
        }
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    bail!("cannot parse value: {s}")
}

/// Split on commas that are not inside quotes (arrays are flat; no nesting).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let m = parse_toml("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        let root = &m[""];
        assert_eq!(root["a"], TomlValue::Int(1));
        assert_eq!(root["b"], TomlValue::Float(2.5));
        assert_eq!(root["c"], TomlValue::Str("hi".into()));
        assert_eq!(root["d"], TomlValue::Bool(true));
    }

    #[test]
    fn parses_sections_and_arrays() {
        let m = parse_toml("[model]\ntheta = [0.1, 0.2, 0.3, 0.4]\nn = 8\n").unwrap();
        let model = &m["model"];
        assert_eq!(
            model["theta"].as_float_array().unwrap(),
            vec![0.1, 0.2, 0.3, 0.4]
        );
        assert_eq!(model["n"].as_int(), Some(8));
    }

    #[test]
    fn comments_and_blank_lines() {
        let m = parse_toml("# top\n\n[s] # side\nx = 3 # tail\ny = \"a#b\"\n").unwrap();
        assert_eq!(m["s"]["x"], TomlValue::Int(3));
        assert_eq!(m["s"]["y"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn errors_on_garbage() {
        assert!(parse_toml("not a kv line\n").is_err());
        assert!(parse_toml("x = [1, 2\n").is_err());
        assert!(parse_toml("x = \"unterminated\n").is_err());
        assert!(parse_toml("[]\n").is_err());
    }

    #[test]
    fn underscore_integers() {
        let m = parse_toml("n = 1_000_000\n").unwrap();
        assert_eq!(m[""]["n"].as_int(), Some(1_000_000));
    }
}
