//! Maximum-likelihood parameter fitting for the MAGM.
//!
//! The paper's introduction motivates sampling with model-fitting
//! workflows (goodness of fit, growth prediction); Kim & Leskovec (2011)
//! fit MAGM by variational EM over latent attributes. Here we implement
//! the *observed-attribute* MLE — the inner problem of that EM and the
//! piece needed by `examples/fit_model.rs`: given a graph and the
//! attribute assignment, estimate the shared initiator Θ (and μ̂, which is
//! closed-form).
//!
//! Key trick: with a shared 2×2 Θ across levels, the Bernoulli
//! log-likelihood of a pair `(i, j)` depends on `(λ_i, λ_j)` only through
//! the **agreement profile** `n(i,j) = (n00, n01, n10, n11)` — how many
//! levels exhibit each bit pair — because
//! `log Q_ij = Σ_ab n_ab · log θ_ab`. There are only `O(d³)` distinct
//! profiles, so after one `O(C² d)` aggregation pass over distinct
//! configuration pairs (C = #distinct configs), every likelihood
//! evaluation is `O(#profiles)` and coordinate-wise optimization is cheap
//! and exact.

use crate::graph::EdgeList;
use crate::hashutil::FastMap;
use crate::kpgm::Initiator;
use crate::magm::AttributeAssignment;

/// Sufficient statistics: per agreement profile, total ordered pairs and
/// observed edges.
#[derive(Debug, Clone)]
pub struct SufficientStats {
    /// `(packed profile key, (pair count, edge count))`, sorted by key.
    /// Key packs (n00, n01, n10) base (d+1); n11 = d − the rest. Stored
    /// sorted — not as a hash map — so the float accumulation order in
    /// [`Self::loglik`] is fixed by the data, never by hasher state.
    classes: Vec<(u64, (u64, u64))>,
    depth: u32,
}

/// Pack an agreement profile (n11 is implied).
#[inline]
fn pack(n00: u32, n01: u32, n10: u32, base: u64) -> u64 {
    (n00 as u64 * base + n01 as u64) * base + n10 as u64
}

impl SufficientStats {
    /// Aggregate over all ordered node pairs (including self-pairs, which
    /// the MAGM edge-probability matrix covers) and the observed edges.
    ///
    /// Cost: `O(C² d + |E| d)` where C is the number of distinct
    /// configurations.
    pub fn build(graph: &EdgeList, attrs: &AttributeAssignment) -> Self {
        let d = attrs.depth();
        let base = (d + 1) as u64;
        let counts = attrs.config_counts();
        let mut acc: FastMap<u64, (u64, u64)> = FastMap::default();

        // Pair totals over distinct configuration pairs.
        for &(ci, mi) in &counts {
            for &(cj, mj) in &counts {
                let key = profile_key(ci, cj, d, base);
                acc.entry(key).or_insert((0, 0)).0 += mi as u64 * mj as u64;
            }
        }
        // Edge counts over observed edges.
        for &(s, t) in graph.edges() {
            let key = profile_key(attrs.config(s), attrs.config(t), d, base);
            acc.get_mut(&key)
                .expect("edge profile must exist among pair profiles")
                .1 += 1;
        }
        // Freeze into key order so every later float sum over the classes
        // is order-deterministic regardless of hasher internals.
        let mut classes: Vec<(u64, (u64, u64))> = acc.into_iter().collect(); // lint: order-ok(sorted on the next line)
        classes.sort_unstable_by_key(|&(key, _)| key);
        SufficientStats { classes, depth: d }
    }

    /// Number of distinct profiles.
    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    /// Bernoulli log-likelihood of the graph under a shared 2×2 theta.
    pub fn loglik(&self, theta: &Initiator) -> f64 {
        let base = (self.depth + 1) as u64;
        let l = [
            theta.get(0, 0).max(1e-300).ln(),
            theta.get(0, 1).max(1e-300).ln(),
            theta.get(1, 0).max(1e-300).ln(),
            theta.get(1, 1).max(1e-300).ln(),
        ];
        let mut total = 0.0;
        for &(key, (pairs, edges)) in &self.classes {
            let n10 = (key % base) as f64;
            let n01 = ((key / base) % base) as f64;
            let n00 = (key / (base * base)) as f64;
            let n11 = self.depth as f64 - n00 - n01 - n10;
            let logq = n00 * l[0] + n01 * l[1] + n10 * l[2] + n11 * l[3];
            let q = logq.exp().clamp(1e-12, 1.0 - 1e-12);
            total += edges as f64 * logq + (pairs - edges) as f64 * (1.0 - q).ln();
        }
        total
    }
}

/// Profile of a configuration pair.
#[inline]
fn profile_key(ci: u64, cj: u64, d: u32, base: u64) -> u64 {
    // Count bit pairs across levels via bit tricks: ones where both set,
    // where only src set, where only dst set.
    let both = (ci & cj).count_ones();
    let src_only = (ci & !cj).count_ones();
    let dst_only = (!ci & cj).count_ones();
    let n11 = both;
    let n10 = src_only;
    let n01 = dst_only;
    let n00 = d - n11 - n10 - n01;
    let _ = n11;
    pack(n00, n01, n10, base)
}

/// Options for the coordinate-ascent fit.
#[derive(Debug, Clone, Copy)]
pub struct FitOptions {
    /// Full coordinate sweeps.
    pub max_sweeps: u32,
    /// Stop when a sweep improves log-likelihood by less than this.
    pub tol: f64,
}

impl Default for FitOptions {
    fn default() -> Self {
        FitOptions { max_sweeps: 50, tol: 1e-6 }
    }
}

/// Result of a fit.
#[derive(Debug, Clone)]
pub struct FitResult {
    /// Estimated initiator.
    pub theta: Initiator,
    /// Log-likelihood at the estimate.
    pub loglik: f64,
    /// Sweeps performed.
    pub sweeps: u32,
    /// Log-likelihood after each sweep (monotone non-decreasing).
    pub trajectory: Vec<f64>,
}

/// Closed-form MLE of μ per level: the fraction of 1-bits.
pub fn fit_mu(attrs: &AttributeAssignment) -> Vec<f64> {
    let n = attrs.num_nodes() as f64;
    (0..attrs.depth())
        .map(|k| {
            let ones: u64 =
                (0..attrs.num_nodes()).map(|i| attrs.bit(i as u32, k) as u64).sum();
            ones as f64 / n
        })
        .collect()
}

/// Fit a shared 2×2 Θ by cyclic coordinate ascent with golden-section
/// line search on each entry over `[1e-6, 1 − 1e-6]`.
pub fn fit_theta(
    graph: &EdgeList,
    attrs: &AttributeAssignment,
    init: Initiator,
    opts: FitOptions,
) -> FitResult {
    let stats = SufficientStats::build(graph, attrs);
    let mut entries = init.entries();
    let mut best = stats.loglik(&Initiator::new(entries));
    let mut trajectory = vec![best];
    let mut sweeps = 0;
    for _ in 0..opts.max_sweeps {
        sweeps += 1;
        for idx in 0..4 {
            let eval = |v: f64| -> f64 {
                let mut e = entries;
                e[idx] = v;
                stats.loglik(&Initiator::new(e))
            };
            entries[idx] = golden_max(eval, 1e-6, 1.0 - 1e-6, 1e-7);
        }
        let ll = stats.loglik(&Initiator::new(entries));
        trajectory.push(ll);
        if ll - best < opts.tol {
            best = best.max(ll);
            break;
        }
        best = ll;
    }
    FitResult { theta: Initiator::new(entries), loglik: best, sweeps, trajectory }
}

/// Golden-section maximization of a unimodal function on [lo, hi].
fn golden_max<F: Fn(f64) -> f64>(f: F, mut lo: f64, mut hi: f64, tol: f64) -> f64 {
    const INV_PHI: f64 = 0.618_033_988_749_894_8;
    let mut c = hi - INV_PHI * (hi - lo);
    let mut d = lo + INV_PHI * (hi - lo);
    let mut fc = f(c);
    let mut fd = f(d);
    while (hi - lo).abs() > tol {
        if fc > fd {
            hi = d;
            d = c;
            fd = fc;
            c = hi - INV_PHI * (hi - lo);
            fc = f(c);
        } else {
            lo = c;
            c = d;
            fc = fd;
            d = lo + INV_PHI * (hi - lo);
            fd = f(d);
        }
    }
    (lo + hi) / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::magm::{naive_sample, MagmParams};
    use crate::quilt::QuiltSampler;
    use crate::rng::Rng;

    #[test]
    fn profile_key_counts_bit_pairs() {
        // ci = 0b1100, cj = 0b1010 over d = 4:
        // levels (MSB..): (1,1) (1,0) (0,1) (0,0) -> n11=1 n10=1 n01=1 n00=1
        let base = 5;
        let key = profile_key(0b1100, 0b1010, 4, base);
        assert_eq!(key, pack(1, 1, 1, base));
    }

    #[test]
    fn stats_match_brute_force_loglik() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 24, 5);
        let mut rng = Rng::new(331);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let g = naive_sample(&params, &attrs, &mut rng);
        let stats = SufficientStats::build(&g, &attrs);
        // Brute force over all pairs.
        let theta = Initiator::THETA2; // evaluate at a different theta
        let mut want = 0.0;
        let csr = crate::graph::Csr::from_edge_list(&g);
        for i in 0..24u32 {
            for j in 0..24u32 {
                let q = crate::magm::edge_probability(
                    &MagmParams::homogeneous(theta, 0.5, 24, 5),
                    &attrs,
                    i,
                    j,
                )
                .clamp(1e-12, 1.0 - 1e-12);
                if csr.has_edge(i, j) {
                    want += q.ln();
                } else {
                    want += (1.0 - q).ln();
                }
            }
        }
        let got = stats.loglik(&theta);
        assert!((got - want).abs() < 1e-6 * want.abs(), "{got} vs {want}");
    }

    #[test]
    fn mu_mle_recovers_rate() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.7, 50_000, 6);
        let mut rng = Rng::new(337);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        for mu in fit_mu(&attrs) {
            assert!((mu - 0.7).abs() < 0.01, "mu={mu}");
        }
    }

    #[test]
    fn theta_fit_recovers_generator_parameters() {
        // Generate a decent-size graph from known theta, fit from a
        // neutral start, and require closeness (symmetric theta: the
        // (0,1)/(1,0) entries are exchangeable, compare as a sorted pair).
        let d = 11;
        let n = 1 << d;
        let truth = Initiator::THETA1;
        let params = MagmParams::homogeneous(truth, 0.5, n, d);
        let mut rng = Rng::new(347);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let g = QuiltSampler::new(params.clone()).seed(5).sample_with_attrs(&attrs);
        let init = Initiator::new([0.5, 0.5, 0.5, 0.5]);
        let fit = fit_theta(&g, &attrs, init, FitOptions::default());
        let e = fit.theta.entries();
        let t = truth.entries();
        assert!((e[0] - t[0]).abs() < 0.05, "theta00: {} vs {}", e[0], t[0]);
        assert!((e[3] - t[3]).abs() < 0.05, "theta11: {} vs {}", e[3], t[3]);
        let mut off_got = [e[1], e[2]];
        let mut off_want = [t[1], t[2]];
        off_got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        off_want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((off_got[0] - off_want[0]).abs() < 0.05);
        assert!((off_got[1] - off_want[1]).abs() < 0.05);
    }

    #[test]
    fn fit_trajectory_is_monotone() {
        let d = 8;
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.6, 1 << d, d);
        let mut rng = Rng::new(353);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let g = QuiltSampler::new(params).seed(3).sample_with_attrs(&attrs);
        let fit = fit_theta(&g, &attrs, Initiator::new([0.3; 4]), FitOptions::default());
        for w in fit.trajectory.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "trajectory decreased: {:?}", w);
        }
        assert!(fit.sweeps >= 1);
    }

    #[test]
    fn true_theta_scores_higher_than_wrong_theta() {
        let d = 10;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 1 << d, d);
        let mut rng = Rng::new(359);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let g = QuiltSampler::new(params).seed(11).sample_with_attrs(&attrs);
        let stats = SufficientStats::build(&g, &attrs);
        assert!(stats.loglik(&Initiator::THETA1) > stats.loglik(&Initiator::THETA2));
    }
}
