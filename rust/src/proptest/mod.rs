//! Minimal property-testing framework.
//!
//! The vendored crate set has no `proptest`/`quickcheck`, so this module
//! provides the 10% we need: seeded random case generation, a configurable
//! number of cases, greedy input shrinking for integer tuples, and failure
//! messages that print the offending case and the seed to replay it.
//!
//! ```no_run
//! use magquilt::proptest::{Config, forall};
//!
//! forall(Config::cases(256), |rng| {
//!     let n = 1 + rng.below(1000);
//!     let a = rng.below(n);
//!     (a < n).then_some(()).ok_or_else(|| format!("a={a} n={n}"))
//! });
//! ```

use crate::rng::Rng;

/// Property-run configuration.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case i uses `fork(i)` of it.
    pub seed: u64,
}

impl Config {
    /// `cases` random cases with the default seed.
    pub fn cases(cases: usize) -> Self {
        Config { cases, seed: 0x5eed_cafe }
    }

    /// Override the seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Run `prop` for each case with an independent RNG. The property returns
/// `Ok(())` or a failure description. Panics (test-failing) on the first
/// failing case with its replay seed.
pub fn forall<F>(config: Config, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = Rng::new(config.seed);
    for case in 0..config.cases {
        let mut rng = base.fork(case as u64);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case}/{} (replay: seed={:#x}, fork={case}): {msg}",
                config.cases, config.seed
            );
        }
    }
}

/// Run a property over a shrinkable `u64` drawn from `[lo, hi]`: on failure
/// greedily shrink toward `lo` to report a minimal failing value.
pub fn forall_u64<F>(config: Config, lo: u64, hi: u64, mut prop: F)
where
    F: FnMut(u64, &mut Rng) -> Result<(), String>,
{
    assert!(lo <= hi);
    let base = Rng::new(config.seed);
    for case in 0..config.cases {
        let mut rng = base.fork(case as u64);
        let x = lo + rng.below(hi - lo + 1);
        let mut check_rng = base.fork(case as u64 ^ crate::rngtags::SHRINK_CHECK_XOR);
        if prop(x, &mut check_rng).is_err() {
            // Shrink: bisect toward lo while still failing.
            let mut bad = x;
            let mut floor = lo;
            while floor < bad {
                let mid = floor + (bad - floor) / 2;
                let mut rng2 = base.fork(case as u64 ^ crate::rngtags::SHRINK_CHECK_XOR);
                if prop(mid, &mut rng2).is_err() {
                    bad = mid;
                } else {
                    floor = mid + 1;
                }
            }
            let mut rng3 = base.fork(case as u64 ^ crate::rngtags::SHRINK_CHECK_XOR);
            let msg = prop(bad, &mut rng3).unwrap_err();
            panic!(
                "property failed; minimal x={bad} (case {case}, seed={:#x}): {msg}",
                config.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(Config::cases(64), |rng| {
            let a = rng.below(100);
            if a < 100 { Ok(()) } else { Err(format!("a={a}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        forall(Config::cases(64), |rng| {
            let a = rng.below(100);
            if a < 50 { Ok(()) } else { Err(format!("a={a}")) }
        });
    }

    #[test]
    #[should_panic(expected = "minimal x=70")]
    fn shrinking_finds_boundary() {
        forall_u64(Config::cases(200), 0, 1000, |x, _| {
            if x < 70 { Ok(()) } else { Err(format!("x={x}")) }
        });
    }
}
