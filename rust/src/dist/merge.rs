//! Deterministic segment merge: stitch a segment directory back into one
//! `MAGQEDG1` file, bit-for-bit identical to the single-process sampler.
//!
//! For every shard `s`, the inputs are the owner's `.seg` file (always
//! present — a worker writes even empty owned shards, so absence means an
//! incomplete run) plus zero or more foreign `.ovf` files (edges that
//! wide-span jobs owned by other workers sampled into `s`'s source
//! range). Each input is a sorted, deduplicated run; folding them through
//! the same [`ShardMerger`] the coordinator uses yields the sorted,
//! deduplicated **union** — and set union is order-independent, so the
//! result equals what the single process's shard merger produced from the
//! same batches. Writing the shards in index order through
//! [`BinaryEdgeWriter`] and back-patching one header then reproduces the
//! single-process `BinaryFileSink` file byte for byte.
//!
//! Everything is validated before it is trusted: file names must carry
//! the plan's hash (mixed plan hashes are refused), headers must agree
//! with the plan's node count, runs must be strictly sorted, every source
//! id must fall inside its shard's range, and `read_edge_list_binary`
//! already rejects truncated or unfinalized files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::{read_edge_list_binary, BinaryEdgeWriter, Edge, ShardMerger, ShardSpec};

use super::plan::ShardPlan;
use super::worker::{parse_segment_file_name, SegmentKind};

/// The segment files found for one shard.
#[derive(Debug, Clone, Default)]
pub struct ShardSegments {
    /// The owner's segment file, once discovered.
    pub owner: Option<PathBuf>,
    /// Foreign overflow files, keyed by producing worker (deterministic
    /// fold order for stable stats; the merged *set* is order-free).
    pub overflow: BTreeMap<usize, PathBuf>,
}

/// Everything discovered in a segment directory for one plan.
#[derive(Debug)]
pub struct SegmentCatalog {
    /// Per-shard files, indexed by shard.
    pub shards: Vec<ShardSegments>,
}

impl SegmentCatalog {
    /// Total overflow files across shards.
    pub fn overflow_files(&self) -> usize {
        self.shards.iter().map(|s| s.overflow.len()).sum()
    }
}

/// Scan `dir` for the plan's segment files, validating names, hashes, and
/// topology. Rejects: files from a different plan hash (mixing two runs'
/// segments silently corrupts the output), leftover in-flight temp files
/// (a worker crashed or is still running), duplicate owner segments, a
/// `.seg` written by a non-owner, a `.ovf` claimed by the shard's own
/// owner, and unrecognized file names.
pub fn scan_segments(dir: &Path, plan: &ShardPlan) -> Result<SegmentCatalog> {
    let hash = plan.hash_hex();
    let mut shards: Vec<ShardSegments> = vec![ShardSegments::default(); plan.num_shards];
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("reading segment directory {}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name == super::PLAN_FILE {
            continue;
        }
        if name.starts_with("magquilt-tmp-") {
            bail!(
                "in-flight temp file {name} in {} — a worker is still running or crashed \
                 mid-write; finish or rerun the workers before merging",
                dir.display()
            );
        }
        let Some(info) = parse_segment_file_name(&name) else {
            bail!("unrecognized file {name} in segment directory {}", dir.display());
        };
        if info.hash_hex != hash {
            bail!(
                "segment {name} was produced under plan {} but this plan hashes to {hash} — \
                 refusing to merge mixed plans",
                info.hash_hex
            );
        }
        if info.shard >= plan.num_shards {
            bail!("segment {name} names shard {} but the plan has {}", info.shard, plan.num_shards);
        }
        if info.worker >= plan.num_workers() {
            bail!(
                "segment {name} names worker {} but the plan has {}",
                info.worker,
                plan.num_workers()
            );
        }
        let owner = plan.owner_of_shard(info.shard);
        let slot = &mut shards[info.shard];
        match info.kind {
            SegmentKind::Owned => {
                if info.worker != owner {
                    bail!(
                        "segment {name}: shard {} is owned by worker {owner}, not {}",
                        info.shard,
                        info.worker
                    );
                }
                if slot.owner.replace(entry.path()).is_some() {
                    bail!("duplicate owner segment for shard {}", info.shard);
                }
            }
            SegmentKind::Overflow => {
                if info.worker == owner {
                    bail!(
                        "overflow {name}: worker {owner} owns shard {} and must not \
                         overflow into it",
                        info.shard
                    );
                }
                if slot.overflow.insert(info.worker, entry.path()).is_some() {
                    bail!(
                        "duplicate overflow for shard {} from worker {}",
                        info.shard,
                        info.worker
                    );
                }
            }
        }
    }
    Ok(SegmentCatalog { shards })
}

/// Read one segment/overflow file for `shard`, enforcing the contract:
/// header node count matches the plan, the run is strictly sorted (sorted
/// *and* deduplicated), and every source id falls inside the shard's
/// range. Truncated or unfinalized files are already rejected by
/// [`read_edge_list_binary`].
fn read_validated_run(
    path: &Path,
    plan: &ShardPlan,
    spec: &ShardSpec,
    shard: usize,
) -> Result<Vec<Edge>> {
    let g = read_edge_list_binary(path)
        .with_context(|| format!("reading segment {}", path.display()))?;
    if g.num_nodes() != plan.model.num_nodes() {
        bail!(
            "segment {} claims {} nodes but the plan's model has {}",
            path.display(),
            g.num_nodes(),
            plan.model.num_nodes()
        );
    }
    let edges = g.into_edges();
    if !edges.windows(2).all(|w| w[0] < w[1]) {
        bail!("segment {} is not strictly sorted (corrupt run)", path.display());
    }
    for &(s, _) in &edges {
        if spec.checked_shard_of(s) != Some(shard) {
            bail!(
                "segment {} holds source {s} outside shard {shard}'s range",
                path.display()
            );
        }
    }
    Ok(edges)
}

/// One merged shard's numbers, for reports and `magquilt stats`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MergedShardReport {
    /// Shard index.
    pub shard: usize,
    /// Edges in the owner segment.
    pub owner_edges: usize,
    /// Overflow runs folded in.
    pub overflow_runs: usize,
    /// Edges across those overflow runs (pre-dedup).
    pub overflow_edges: usize,
    /// Cross-file duplicates collapsed (the same edge sampled by jobs on
    /// different workers — the dedup the single process did in-merger).
    pub duplicates_dropped: u64,
    /// Final merged edge count written for this shard.
    pub merged_edges: usize,
}

/// The outcome of a full merge (or a validate-only inspection pass).
#[derive(Debug, Default)]
pub struct MergeReport {
    /// Per-shard rows, in index order.
    pub shards: Vec<MergedShardReport>,
    /// Total edges in the final file.
    pub total_edges: u64,
}

impl MergeReport {
    /// Total overflow runs folded across shards.
    pub fn overflow_runs(&self) -> usize {
        self.shards.iter().map(|s| s.overflow_runs).sum()
    }

    /// Total cross-file duplicates collapsed.
    pub fn duplicates_dropped(&self) -> u64 {
        self.shards.iter().map(|s| s.duplicates_dropped).sum()
    }
}

/// Fold one shard's owner + overflow runs into the final sorted,
/// deduplicated run.
fn merge_shard(
    plan: &ShardPlan,
    spec: &ShardSpec,
    shard: usize,
    segs: &ShardSegments,
) -> Result<(Vec<Edge>, MergedShardReport)> {
    let owner_path = segs.owner.as_ref().ok_or_else(|| {
        anyhow!(
            "no owner segment for shard {shard} (worker {} incomplete?)",
            plan.owner_of_shard(shard)
        )
    })?;
    let mut report = MergedShardReport { shard, ..Default::default() };
    let mut merger = ShardMerger::new(shard);
    let owner_run = read_validated_run(owner_path, plan, spec, shard)?;
    report.owner_edges = owner_run.len();
    merger.absorb(owner_run);
    for path in segs.overflow.values() {
        let run = read_validated_run(path, plan, spec, shard)?;
        report.overflow_runs += 1;
        report.overflow_edges += run.len();
        merger.absorb(run);
    }
    let (run, stats) = merger.finish();
    report.duplicates_dropped = stats.duplicates_dropped;
    report.merged_edges = run.len();
    Ok((run, report))
}

/// Validate a segment directory without writing anything: the read-only
/// pass behind `magquilt stats <segment-dir>`. Performs the full scan +
/// per-file validation + merge accounting (so the reported per-shard
/// counts are exactly what a real merge would write), but keeps only the
/// numbers. Fails on anything [`merge_segments`] would fail on.
pub fn validate_segments(dir: &Path, plan: &ShardPlan) -> Result<MergeReport> {
    let catalog = scan_segments(dir, plan)?;
    let spec = plan.shard_spec();
    let mut report = MergeReport::default();
    for (shard, segs) in catalog.shards.iter().enumerate() {
        let (run, row) = merge_shard(plan, &spec, shard, segs)?;
        report.total_edges += run.len() as u64;
        report.shards.push(row);
    }
    Ok(report)
}

/// Merge a complete segment directory into the final `MAGQEDG1` file at
/// `out` — byte-identical to the single-process binary sink's output for
/// the same plan. With `remove_inputs`, consumed segment/overflow files
/// are deleted after the output is finalized (durable), leaving the
/// directory drained.
pub fn merge_segments(
    dir: &Path,
    plan: &ShardPlan,
    out: &Path,
    remove_inputs: bool,
) -> Result<MergeReport> {
    plan.validate()?;
    let catalog = scan_segments(dir, plan)?;
    // Fail on a missing owner segment *before* truncating the output.
    for (shard, segs) in catalog.shards.iter().enumerate() {
        if segs.owner.is_none() {
            bail!(
                "no owner segment for shard {shard} (worker {} incomplete?)",
                plan.owner_of_shard(shard)
            );
        }
    }
    let spec = plan.shard_spec();
    let mut writer = BinaryEdgeWriter::create(out, plan.model.num_nodes())
        .with_context(|| format!("creating output {}", out.display()))?;
    let mut report = MergeReport::default();
    for (shard, segs) in catalog.shards.iter().enumerate() {
        let (run, row) = merge_shard(plan, &spec, shard, segs)?;
        writer.write_edges(&run).with_context(|| format!("writing shard {shard}"))?;
        report.total_edges += run.len() as u64;
        report.shards.push(row);
    }
    writer
        .finalize(report.total_edges)
        .with_context(|| format!("finalizing output {}", out.display()))?;
    if remove_inputs {
        for segs in &catalog.shards {
            if let Some(p) = &segs.owner {
                std::fs::remove_file(p)
                    .with_context(|| format!("removing consumed segment {}", p.display()))?;
            }
            for p in segs.overflow.values() {
                std::fs::remove_file(p)
                    .with_context(|| format!("removing consumed overflow {}", p.display()))?;
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelSpec, RunSpec};
    use crate::dist::worker::{overflow_file_name, segment_file_name};
    use crate::graph::write_edge_list_binary;
    use crate::graph::EdgeList;

    fn plan_for(log2n: u32, shards: usize, workers: usize) -> ShardPlan {
        let mut model = ModelSpec::default_spec();
        model.log2_nodes = log2n;
        model.attributes = log2n;
        let mut run = RunSpec::default_spec();
        run.shards = shards;
        ShardPlan::new(&model, &run, workers).unwrap()
    }

    fn write_run(dir: &Path, name: &str, n: usize, edges: &[Edge]) {
        write_edge_list_binary(&EdgeList::from_edges(n, edges.to_vec()), &dir.join(name))
            .unwrap();
    }

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("magquilt_merge_test").join(tag);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn merge_folds_owner_and_overflow_with_dedup() {
        // n=16, S=4 (width 4), W=2: worker 0 owns shards {0,1}, worker 1
        // owns {2,3}. Worker 0's wide job spilled edges into shard 2 —
        // including one duplicate of an edge worker 1 sampled itself.
        let plan = plan_for(4, 4, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("fold");
        let n = 16;
        write_run(&dir, &segment_file_name(&hash, 0, 0), n, &[(0, 3), (2, 2)]);
        write_run(&dir, &segment_file_name(&hash, 1, 0), n, &[(5, 1)]);
        write_run(&dir, &segment_file_name(&hash, 2, 1), n, &[(8, 0), (9, 9)]);
        write_run(&dir, &segment_file_name(&hash, 3, 1), n, &[]);
        write_run(&dir, &overflow_file_name(&hash, 2, 0), n, &[(8, 0), (8, 7)]);
        let out = dir.join("merged.bin");
        let report = merge_segments(&dir, &plan, &out, true).unwrap();
        assert_eq!(report.total_edges, 6);
        assert_eq!(report.overflow_runs(), 1);
        assert_eq!(report.duplicates_dropped(), 1, "cross-worker duplicate collapsed");
        let g = read_edge_list_binary(&out).unwrap();
        assert_eq!(g.edges(), &[(0, 3), (2, 2), (5, 1), (8, 0), (8, 7), (9, 9)]);
        // remove_inputs drained everything but the output.
        let left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(left, vec!["merged.bin".to_string()]);
    }

    #[test]
    fn missing_owner_segment_fails() {
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("missing");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[(0, 1)]);
        // Shard 1's owner segment absent.
        let err = merge_segments(&dir, &plan, &dir.join("out.bin"), false).unwrap_err();
        assert!(err.to_string().contains("no owner segment for shard 1"), "{err}");
        assert!(!dir.join("out.bin").exists(), "must fail before touching the output");
    }

    #[test]
    fn mixed_plan_hashes_are_rejected() {
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("mixed");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[]);
        // A stray segment from some other plan.
        write_run(&dir, &segment_file_name("deadbeefdeadbeef", 0, 0), 16, &[]);
        let err = scan_segments(&dir, &plan).unwrap_err();
        assert!(err.to_string().contains("mixed plans"), "{err}");
    }

    #[test]
    fn scan_rejects_malformed_topology() {
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        // Owner segment from the wrong worker.
        let dir = fresh_dir("wrong_owner");
        write_run(&dir, &segment_file_name(&hash, 0, 1), 16, &[]);
        assert!(scan_segments(&dir, &plan).unwrap_err().to_string().contains("owned by"));
        // Overflow from the shard's own owner.
        let dir = fresh_dir("self_overflow");
        write_run(&dir, &overflow_file_name(&hash, 0, 0), 16, &[]);
        assert!(scan_segments(&dir, &plan).unwrap_err().to_string().contains("must not"));
        // Unknown file name.
        let dir = fresh_dir("unknown");
        std::fs::write(dir.join("notes.txt"), "x").unwrap();
        assert!(scan_segments(&dir, &plan).unwrap_err().to_string().contains("unrecognized"));
        // Leftover temp file.
        let dir = fresh_dir("tmpfile");
        std::fs::write(dir.join("magquilt-tmp-1-0-0-seg.part"), "x").unwrap();
        assert!(scan_segments(&dir, &plan).unwrap_err().to_string().contains("in-flight"));
        // Shard index beyond the plan.
        let dir = fresh_dir("shard_oob");
        write_run(&dir, &segment_file_name(&hash, 7, 0), 16, &[]);
        assert!(scan_segments(&dir, &plan).is_err());
    }

    #[test]
    fn out_of_span_source_is_rejected() {
        // n=16, S=2: shard 0 owns sources 0..8. A segment for shard 0
        // holding source 12 is corrupt and must not merge.
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("span");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[(12, 0)]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[]);
        let err = merge_segments(&dir, &plan, &dir.join("out.bin"), false).unwrap_err();
        assert!(err.to_string().contains("outside shard"), "{err}");
    }

    #[test]
    fn wrong_node_count_is_rejected() {
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("nodes");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 8, &[(0, 1)]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[]);
        let err = merge_segments(&dir, &plan, &dir.join("out.bin"), false).unwrap_err();
        assert!(err.to_string().contains("nodes"), "{err}");
    }

    #[test]
    fn validate_matches_merge_numbers() {
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("validate");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[(0, 1), (3, 3)]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[(9, 2)]);
        write_run(&dir, &overflow_file_name(&hash, 1, 0), 16, &[(9, 2), (10, 0)]);
        let inspect = validate_segments(&dir, &plan).unwrap();
        let merged = merge_segments(&dir, &plan, &dir.join("out.bin"), false).unwrap();
        assert_eq!(inspect.total_edges, merged.total_edges);
        assert_eq!(inspect.shards, merged.shards);
        assert_eq!(inspect.duplicates_dropped(), 1);
    }
}
