//! Deterministic segment merge: stitch a segment directory back into one
//! `MAGQEDG1` file, bit-for-bit identical to the single-process sampler.
//!
//! For every shard `s`, the inputs are the owner's `.seg` file (always
//! present — a worker writes even empty owned shards, so absence means an
//! incomplete run) plus zero or more foreign `.ovf` files (edges that
//! wide-span jobs owned by other workers sampled into `s`'s source
//! range). Each input is a sorted, deduplicated run; folding them through
//! the same [`ShardMerger`] the coordinator uses yields the sorted,
//! deduplicated **union** — and set union is order-independent, so the
//! result equals what the single process's shard merger produced from the
//! same batches.
//!
//! Shards are independent by construction, so the fold itself runs on
//! `merge_threads` worker threads (0 = auto): each thread pulls the next
//! unmerged shard off a shared counter, merges it, and hands the finished
//! run to the delivery loop, which is the single-process
//! [`BinaryFileSink`] — the frontier-ordered, spill-budgeted protocol
//! that writes shard `s` the moment shards `0..s` are on disk, holds
//! early finishers in memory within the spill budget, and streams the
//! rest through temp spill files. The final file is therefore
//! byte-identical to the serial merge (and to the single-process sink)
//! for **any** thread count: delivery order changes only where a run
//! waits, never where it lands.
//!
//! Everything is validated before it is trusted, and each segment is
//! opened exactly twice-but-cheaply: once in the scan pass (24-byte
//! header: magic, node count vs the plan, claimed edge count vs file
//! size) and once in the merge pass (one chunked streaming read of the
//! body). File names must carry the plan's hash (mixed plan hashes are
//! refused), runs must be strictly sorted, and every source id must fall
//! inside its shard's range.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::{
    read_binary_body, read_binary_header, BinaryFileSink, BinaryHeader, Edge, EdgeSink,
    ShardDisposition, ShardMerger, ShardSpec, DEFAULT_SPILL_BUDGET,
};
use crate::trace::report::{report_header, JsonObj};
use crate::trace::{Fv, TraceHandle};

use super::plan::ShardPlan;
use super::worker::{parse_meta_file_name, parse_segment_file_name, SegmentKind};

/// Hard cap on merge worker threads, mirroring the coordinator's shard
/// cap: `std::thread::scope` aborts the process if a spawn fails, so an
/// oversized `--merge-threads` must not translate into thousands of OS
/// threads.
const MAX_MERGE_THREADS: usize = 256;

/// One segment file the scan pass validated: its path plus the header it
/// vouched for. Carrying the header to the merge means the body read can
/// pre-size buffers from the known edge count and skip re-validating
/// anything the scan already checked.
#[derive(Debug, Clone)]
pub struct SegmentMeta {
    /// Where the file lives.
    pub path: PathBuf,
    /// Its validated `MAGQEDG1` header (node and edge counts).
    pub header: BinaryHeader,
}

/// The segment files found for one shard.
#[derive(Debug, Clone, Default)]
pub struct ShardSegments {
    /// The owner's segment file, once discovered.
    pub owner: Option<SegmentMeta>,
    /// Foreign overflow files, keyed by producing worker (deterministic
    /// fold order for stable stats; the merged *set* is order-free).
    pub overflow: BTreeMap<usize, SegmentMeta>,
}

impl ShardSegments {
    /// Pre-dedup edge total across this shard's files, from the validated
    /// headers — the capacity hint for the shard's merger.
    fn header_edges(&self) -> u64 {
        self.owner.as_ref().map_or(0, |m| m.header.num_edges)
            + self.overflow.values().map(|m| m.header.num_edges).sum::<u64>()
    }
}

/// Everything discovered in a segment directory for one plan.
#[derive(Debug)]
pub struct SegmentCatalog {
    /// Per-shard files, indexed by shard.
    pub shards: Vec<ShardSegments>,
}

impl SegmentCatalog {
    /// Total overflow files across shards.
    pub fn overflow_files(&self) -> usize {
        self.shards.iter().map(|s| s.overflow.len()).sum()
    }
}

/// Scan `dir` for the plan's segment files, validating names, hashes,
/// topology, and every file's 24-byte header (magic, node count against
/// the plan, claimed edge count against the file size). Rejects: files
/// from a different plan hash (mixing two runs' segments silently
/// corrupts the output), leftover in-flight temp files (a worker crashed
/// or is still running), duplicate owner segments, a `.seg` written by a
/// non-owner, a `.ovf` claimed by the shard's own owner, and unrecognized
/// file names. The returned catalog carries the validated headers so the
/// merge opens each body exactly once, without re-validation.
pub fn scan_segments(dir: &Path, plan: &ShardPlan) -> Result<SegmentCatalog> {
    let hash = plan.hash_hex();
    let mut shards: Vec<ShardSegments> = vec![ShardSegments::default(); plan.num_shards];
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("reading segment directory {}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name == super::PLAN_FILE {
            continue;
        }
        if name == super::doctor::QUARANTINE_DIR && entry.path().is_dir() {
            // The doctor's quarantine holds files already ruled out of
            // this merge; its contents are deliberately not scanned.
            continue;
        }
        if let Some(meta) = parse_meta_file_name(&name) {
            // Completion markers and heartbeats are resume/supervision
            // state, not merge inputs — but a foreign-plan marker is the
            // same mixed-directory mistake as a foreign segment.
            if meta.hash_hex != hash {
                bail!(
                    "marker {name} was produced under plan {} but this plan hashes to {hash} — \
                     refusing to merge mixed plans",
                    meta.hash_hex
                );
            }
            continue;
        }
        if name.starts_with("magquilt-tmp-") {
            bail!(
                "in-flight temp file {name} in {} — a worker is still running or crashed \
                 mid-write; finish or rerun the workers before merging",
                dir.display()
            );
        }
        let Some(info) = parse_segment_file_name(&name) else {
            bail!("unrecognized file {name} in segment directory {}", dir.display());
        };
        if info.hash_hex != hash {
            bail!(
                "segment {name} was produced under plan {} but this plan hashes to {hash} — \
                 refusing to merge mixed plans",
                info.hash_hex
            );
        }
        if info.shard >= plan.num_shards {
            bail!("segment {name} names shard {} but the plan has {}", info.shard, plan.num_shards);
        }
        if info.worker >= plan.num_workers() {
            bail!(
                "segment {name} names worker {} but the plan has {}",
                info.worker,
                plan.num_workers()
            );
        }
        let owner = plan.owner_of_shard(info.shard);
        let path = entry.path();
        let header = read_binary_header(&path)
            .with_context(|| format!("validating segment {}", path.display()))?;
        if header.num_nodes != plan.model.num_nodes() as u64 {
            bail!(
                "segment {name} claims {} nodes but the plan's model has {}",
                header.num_nodes,
                plan.model.num_nodes()
            );
        }
        let meta = SegmentMeta { path, header };
        let slot = &mut shards[info.shard];
        match info.kind {
            SegmentKind::Owned => {
                if info.worker != owner {
                    bail!(
                        "segment {name}: shard {} is owned by worker {owner}, not {}",
                        info.shard,
                        info.worker
                    );
                }
                if slot.owner.replace(meta).is_some() {
                    bail!("duplicate owner segment for shard {}", info.shard);
                }
            }
            SegmentKind::Overflow => {
                if info.worker == owner {
                    bail!(
                        "overflow {name}: worker {owner} owns shard {} and must not \
                         overflow into it",
                        info.shard
                    );
                }
                if slot.overflow.insert(info.worker, meta).is_some() {
                    bail!(
                        "duplicate overflow for shard {} from worker {}",
                        info.shard,
                        info.worker
                    );
                }
            }
        }
    }
    Ok(SegmentCatalog { shards })
}

/// Read the body of one scan-validated segment for `shard`, enforcing the
/// run contract: strictly sorted (sorted *and* deduplicated) and every
/// source id inside the shard's range. Bounds checks against the plan's
/// node count and truncation-since-scan detection happen inside
/// [`read_binary_body`].
fn read_validated_run(meta: &SegmentMeta, spec: &ShardSpec, shard: usize) -> Result<Vec<Edge>> {
    let edges = read_binary_body(&meta.path, &meta.header)
        .with_context(|| format!("reading segment {}", meta.path.display()))?;
    if !edges.windows(2).all(|w| w[0] < w[1]) {
        bail!("segment {} is not strictly sorted (corrupt run)", meta.path.display());
    }
    for &(s, _) in &edges {
        if spec.checked_shard_of(s) != Some(shard) {
            bail!(
                "segment {} holds source {s} outside shard {shard}'s range",
                meta.path.display()
            );
        }
    }
    Ok(edges)
}

/// One merged shard's numbers, for reports and `magquilt stats`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MergedShardReport {
    /// Shard index.
    pub shard: usize,
    /// Edges in the owner segment.
    pub owner_edges: usize,
    /// Overflow runs folded in.
    pub overflow_runs: usize,
    /// Edges across those overflow runs (pre-dedup).
    pub overflow_edges: usize,
    /// Cross-file duplicates collapsed (the same edge sampled by jobs on
    /// different workers — the dedup the single process did in-merger).
    pub duplicates_dropped: u64,
    /// Final merged edge count written for this shard.
    pub merged_edges: usize,
}

/// The outcome of a full merge (or a validate-only inspection pass).
#[derive(Debug, Default)]
pub struct MergeReport {
    /// Per-shard rows, in index order (regardless of completion order).
    pub shards: Vec<MergedShardReport>,
    /// Total edges in the final file.
    pub total_edges: u64,
    /// Merge worker threads actually used (resolved; never 0).
    pub merge_threads: usize,
    /// Wall-clock milliseconds for the whole scan + merge + finalize.
    pub merge_ms: f64,
    /// Shards that finished ahead of the file frontier and were held in
    /// memory within the spill budget.
    pub deferred_shards: usize,
    /// Shards that finished ahead of the frontier over budget and went
    /// through a temp spill file.
    pub spilled_shards: usize,
}

impl MergeReport {
    /// Total overflow runs folded across shards.
    pub fn overflow_runs(&self) -> usize {
        self.shards.iter().map(|s| s.overflow_runs).sum()
    }

    /// Total cross-file duplicates collapsed.
    pub fn duplicates_dropped(&self) -> u64 {
        self.shards.iter().map(|s| s.duplicates_dropped).sum()
    }
}

/// Serialize one merged shard row for `report.json`.
pub fn merged_shard_obj(row: &MergedShardReport) -> JsonObj {
    JsonObj::new()
        .uint("shard", row.shard as u64)
        .uint("owner_edges", row.owner_edges as u64)
        .uint("overflow_runs", row.overflow_runs as u64)
        .uint("overflow_edges", row.overflow_edges as u64)
        .uint("duplicates_dropped", row.duplicates_dropped)
        .uint("merged_edges", row.merged_edges as u64)
}

/// Serialize a [`MergeReport`] (the `merge` object every driver and
/// `merge-segments` report embeds).
pub fn merge_obj(report: &MergeReport) -> JsonObj {
    JsonObj::new()
        .uint("total_edges", report.total_edges)
        .uint("merge_threads", report.merge_threads as u64)
        .float("merge_ms", report.merge_ms)
        .uint("overflow_runs", report.overflow_runs() as u64)
        .uint("duplicates_dropped", report.duplicates_dropped())
        .uint("deferred_shards", report.deferred_shards as u64)
        .uint("spilled_shards", report.spilled_shards as u64)
        .arr("shards", report.shards.iter().map(|s| merged_shard_obj(s).render()).collect())
}

/// Render a standalone `merge-segments` report (kind `merge`).
pub fn merge_report_json(run_id: &str, report: &MergeReport) -> String {
    report_header("merge", run_id).obj("merge", merge_obj(report)).render()
}

/// Emit the per-shard trace event for one delivered row.
fn emit_shard_event(trace: &TraceHandle, row: &MergedShardReport) {
    trace.emit(
        "merge_shard",
        &[
            ("shard", Fv::U(row.shard as u64)),
            ("owner_edges", Fv::U(row.owner_edges as u64)),
            ("overflow_runs", Fv::U(row.overflow_runs as u64)),
            ("overflow_edges", Fv::U(row.overflow_edges as u64)),
            ("duplicates_dropped", Fv::U(row.duplicates_dropped)),
            ("merged_edges", Fv::U(row.merged_edges as u64)),
        ],
    );
}

/// Knobs for [`merge_segments_with`].
#[derive(Debug, Clone)]
pub struct MergeOptions {
    /// Merge worker threads; `0` resolves to the available parallelism
    /// (capped at 16), and the count is always clamped to the shard
    /// count.
    pub merge_threads: usize,
    /// In-memory budget (bytes) for shards that finish ahead of the file
    /// frontier; beyond it they spill to temp files next to the output.
    /// `0` forces every out-of-order shard to spill.
    pub spill_budget: u64,
    /// Delete consumed segment/overflow files after the output is
    /// finalized (durable), leaving the directory drained.
    pub remove_inputs: bool,
    /// Trace sink for `merge_shard` / `merge_done` events (disabled by
    /// default; write-only — see the `trace-sink` lint invariant).
    pub trace: TraceHandle,
}

impl Default for MergeOptions {
    fn default() -> Self {
        MergeOptions {
            merge_threads: 0,
            spill_budget: DEFAULT_SPILL_BUDGET,
            remove_inputs: false,
            trace: TraceHandle::disabled(),
        }
    }
}

/// Resolve the worker-thread count: explicit request, or the machine's
/// available parallelism (capped — merge threads are I/O-heavy), always
/// clamped to the shard count ([`MAX_MERGE_THREADS`] as the hard
/// ceiling).
fn resolved_merge_threads(requested: usize, num_shards: usize) -> usize {
    let t = if requested == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(16)
    } else {
        requested
    };
    t.clamp(1, num_shards.max(1)).min(MAX_MERGE_THREADS)
}

/// Fold one shard's owner + overflow runs into the final sorted,
/// deduplicated run. The merger is pre-sized from the scan-validated
/// header counts (pre-dedup total, a safe upper bound).
fn merge_shard(
    plan: &ShardPlan,
    spec: &ShardSpec,
    shard: usize,
    segs: &ShardSegments,
) -> Result<(Vec<Edge>, MergedShardReport)> {
    let owner_meta = segs.owner.as_ref().ok_or_else(|| {
        anyhow!(
            "no owner segment for shard {shard} (worker {} incomplete?)",
            plan.owner_of_shard(shard)
        )
    })?;
    let mut report = MergedShardReport { shard, ..Default::default() };
    let mut merger = ShardMerger::with_capacity(shard, segs.header_edges() as usize);
    let owner_run = read_validated_run(owner_meta, spec, shard)?;
    report.owner_edges = owner_run.len();
    merger.absorb(owner_run);
    for meta in segs.overflow.values() {
        let run = read_validated_run(meta, spec, shard)?;
        report.overflow_runs += 1;
        report.overflow_edges += run.len();
        merger.absorb(run);
    }
    let (run, stats) = merger.finish();
    report.duplicates_dropped = stats.duplicates_dropped;
    report.merged_edges = run.len();
    Ok((run, report))
}

/// Validate a segment directory without writing anything: the read-only
/// pass behind `magquilt stats <segment-dir>`. Performs the full scan +
/// per-file validation + merge accounting (so the reported per-shard
/// counts are exactly what a real merge would write), but keeps only the
/// numbers. Fails on anything [`merge_segments`] would fail on.
pub fn validate_segments(dir: &Path, plan: &ShardPlan) -> Result<MergeReport> {
    let start = Instant::now();
    let catalog = scan_segments(dir, plan)?;
    let spec = plan.shard_spec();
    let mut report = MergeReport { merge_threads: 1, ..Default::default() };
    for (shard, segs) in catalog.shards.iter().enumerate() {
        let (run, row) = merge_shard(plan, &spec, shard, segs)?;
        report.total_edges += run.len() as u64;
        report.shards.push(row);
    }
    report.merge_ms = start.elapsed().as_secs_f64() * 1e3;
    Ok(report)
}

/// Merge a complete segment directory into the final `MAGQEDG1` file at
/// `out` using the plan's `merge_threads` — byte-identical to the
/// single-process binary sink's output for the same plan. With
/// `remove_inputs`, consumed segment/overflow files are deleted after the
/// output is finalized (durable), leaving the directory drained.
pub fn merge_segments(
    dir: &Path,
    plan: &ShardPlan,
    out: &Path,
    remove_inputs: bool,
) -> Result<MergeReport> {
    let opts =
        MergeOptions { merge_threads: plan.merge_threads, remove_inputs, ..Default::default() };
    merge_segments_with(dir, plan, out, &opts)
}

/// [`merge_segments`] with explicit [`MergeOptions`] — the entry point
/// when the thread count or spill budget comes from the command line
/// rather than the plan manifest.
pub fn merge_segments_with(
    dir: &Path,
    plan: &ShardPlan,
    out: &Path,
    opts: &MergeOptions,
) -> Result<MergeReport> {
    plan.validate()?;
    let start = Instant::now();
    let catalog = scan_segments(dir, plan)?;
    // Fail on a missing owner segment *before* truncating the output.
    for (shard, segs) in catalog.shards.iter().enumerate() {
        if segs.owner.is_none() {
            bail!(
                "no owner segment for shard {shard} (worker {} incomplete?)",
                plan.owner_of_shard(shard)
            );
        }
    }
    let spec = plan.shard_spec();
    let threads = resolved_merge_threads(opts.merge_threads, plan.num_shards);
    let mut sink = BinaryFileSink::create(out).spill_budget(opts.spill_budget);
    sink.begin(plan.model.num_nodes(), plan.num_shards)
        .with_context(|| format!("creating output {}", out.display()))?;
    let mut report = MergeReport { merge_threads: threads, ..Default::default() };

    if threads <= 1 {
        // Serial: merge and write in index order, always at the frontier.
        for (shard, segs) in catalog.shards.iter().enumerate() {
            let (run, row) = merge_shard(plan, &spec, shard, segs)?;
            sink.begin_shard(shard, run.len())?;
            sink.accept_shard(shard, run)
                .with_context(|| format!("writing shard {shard}"))?;
            emit_shard_event(&opts.trace, &row);
            report.shards.push(row);
        }
    } else {
        // Parallel: workers pull shard indices off a shared counter and
        // send finished runs to this (delivery) thread in completion
        // order; the sink's frontier/spill machinery restores index
        // order on disk.
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        type ShardResult = (usize, Result<(Vec<Edge>, MergedShardReport)>);
        std::thread::scope(|scope| -> Result<()> {
            let (tx, rx) = mpsc::sync_channel::<ShardResult>(threads);
            for _ in 0..threads {
                let tx = tx.clone();
                let (next, abort, catalog, spec) = (&next, &abort, &catalog, &spec);
                scope.spawn(move || loop {
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    let shard = next.fetch_add(1, Ordering::Relaxed);
                    if shard >= catalog.shards.len() {
                        break;
                    }
                    let res = merge_shard(plan, spec, shard, &catalog.shards[shard]);
                    if tx.send((shard, res)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            let mut first_err: Option<anyhow::Error> = None;
            for (shard, res) in rx {
                if first_err.is_some() {
                    continue; // drain so workers can exit
                }
                match res {
                    Ok((run, row)) => {
                        let delivered = sink
                            .begin_shard(shard, run.len())
                            .and_then(|()| sink.accept_shard(shard, run));
                        match delivered {
                            Ok(ShardDisposition::Streamed) => {}
                            Ok(ShardDisposition::Deferred { .. }) => {
                                report.deferred_shards += 1;
                            }
                            Ok(ShardDisposition::Spilled { .. }) => {
                                report.spilled_shards += 1;
                            }
                            Err(e) => {
                                abort.store(true, Ordering::Relaxed);
                                first_err = Some(
                                    anyhow::Error::new(e)
                                        .context(format!("writing shard {shard}")),
                                );
                                continue;
                            }
                        }
                        emit_shard_event(&opts.trace, &row);
                        report.shards.push(row);
                    }
                    Err(e) => {
                        abort.store(true, Ordering::Relaxed);
                        first_err = Some(e);
                    }
                }
            }
            match first_err {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })?;
        report.shards.sort_by_key(|r| r.shard);
    }

    report.total_edges = sink
        .finalize()
        .with_context(|| format!("finalizing output {}", out.display()))?;
    if opts.remove_inputs {
        for segs in &catalog.shards {
            if let Some(m) = &segs.owner {
                std::fs::remove_file(&m.path)
                    .with_context(|| format!("removing consumed segment {}", m.path.display()))?;
            }
            for m in segs.overflow.values() {
                std::fs::remove_file(&m.path)
                    .with_context(|| format!("removing consumed overflow {}", m.path.display()))?;
            }
        }
        // Drain this plan's completion markers and heartbeats too — they
        // only describe the segments just consumed, and leaving them
        // behind would make a later run in the same directory look
        // half-resumed.
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if parse_meta_file_name(&name).is_some_and(|m| m.hash_hex == plan.hash_hex()) {
                std::fs::remove_file(entry.path())
                    .with_context(|| format!("removing consumed marker {name}"))?;
            }
        }
    }
    report.merge_ms = start.elapsed().as_secs_f64() * 1e3;
    opts.trace.emit(
        "merge_done",
        &[
            ("shards", Fv::U(report.shards.len() as u64)),
            ("total_edges", Fv::U(report.total_edges)),
            ("overflow_runs", Fv::U(report.overflow_runs() as u64)),
            ("duplicates_dropped", Fv::U(report.duplicates_dropped())),
            ("deferred", Fv::U(report.deferred_shards as u64)),
            ("spilled", Fv::U(report.spilled_shards as u64)),
            ("merge_threads", Fv::U(report.merge_threads as u64)),
            ("merge_ms", Fv::F(report.merge_ms)),
        ],
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelSpec, RunSpec};
    use crate::dist::worker::{overflow_file_name, segment_file_name};
    use crate::graph::read_edge_list_binary;
    use crate::graph::write_edge_list_binary;
    use crate::graph::EdgeList;

    fn plan_for(log2n: u32, shards: usize, workers: usize) -> ShardPlan {
        let mut model = ModelSpec::default_spec();
        model.log2_nodes = log2n;
        model.attributes = log2n;
        let mut run = RunSpec::default_spec();
        run.shards = shards;
        ShardPlan::new(&model, &run, workers).unwrap()
    }

    fn write_run(dir: &Path, name: &str, n: usize, edges: &[Edge]) {
        write_edge_list_binary(&EdgeList::from_edges(n, edges.to_vec()), &dir.join(name))
            .unwrap();
    }

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("magquilt_merge_test").join(tag);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn merge_folds_owner_and_overflow_with_dedup() {
        // n=16, S=4 (width 4), W=2: worker 0 owns shards {0,1}, worker 1
        // owns {2,3}. Worker 0's wide job spilled edges into shard 2 —
        // including one duplicate of an edge worker 1 sampled itself.
        let plan = plan_for(4, 4, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("fold");
        let n = 16;
        write_run(&dir, &segment_file_name(&hash, 0, 0), n, &[(0, 3), (2, 2)]);
        write_run(&dir, &segment_file_name(&hash, 1, 0), n, &[(5, 1)]);
        write_run(&dir, &segment_file_name(&hash, 2, 1), n, &[(8, 0), (9, 9)]);
        write_run(&dir, &segment_file_name(&hash, 3, 1), n, &[]);
        write_run(&dir, &overflow_file_name(&hash, 2, 0), n, &[(8, 0), (8, 7)]);
        let out = dir.join("merged.bin");
        let report = merge_segments(&dir, &plan, &out, true).unwrap();
        assert_eq!(report.total_edges, 6);
        assert_eq!(report.overflow_runs(), 1);
        assert_eq!(report.duplicates_dropped(), 1, "cross-worker duplicate collapsed");
        assert!(report.merge_threads >= 1);
        let g = read_edge_list_binary(&out).unwrap();
        assert_eq!(g.edges(), &[(0, 3), (2, 2), (5, 1), (8, 0), (8, 7), (9, 9)]);
        // remove_inputs drained everything but the output.
        let left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(left, vec!["merged.bin".to_string()]);
    }

    /// Forced-overflow topology (S=8, W=4): every shard gets an owner run
    /// plus an overflow run from a neighboring worker, sharing one
    /// duplicate edge.
    fn build_overflow_dir(tag: &str) -> (ShardPlan, PathBuf) {
        let plan = plan_for(4, 8, 4);
        let hash = plan.hash_hex();
        let dir = fresh_dir(tag);
        let n = 16;
        for shard in 0..8u32 {
            let owner = plan.owner_of_shard(shard as usize);
            let base = 2 * shard; // shard width is 2 sources
            write_run(
                &dir,
                &segment_file_name(&hash, shard as usize, owner),
                n,
                &[(base, 0), (base, 5), (base + 1, 2)],
            );
            let foreign = (owner + 1) % plan.num_workers();
            write_run(
                &dir,
                &overflow_file_name(&hash, shard as usize, foreign),
                n,
                &[(base, 5), (base, 9), (base + 1, 0)],
            );
        }
        (plan, dir)
    }

    #[test]
    fn parallel_merge_is_byte_identical_to_serial() {
        // The tentpole contract: for any merge-thread count, the output
        // file is byte-for-byte the serial merge's file, and the report
        // rows are identical — including under a zero spill budget that
        // forces every out-of-order delivery through a spill file.
        let (plan, dir) = build_overflow_dir("threads");
        let serial_out = dir.parent().unwrap().join("threads_serial.bin");
        let serial = merge_segments_with(
            &dir,
            &plan,
            &serial_out,
            &MergeOptions { merge_threads: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(serial.merge_threads, 1);
        assert_eq!(serial.total_edges, 8 * 5);
        assert_eq!(serial.duplicates_dropped(), 8);
        let serial_bytes = std::fs::read(&serial_out).unwrap();
        for (threads, budget) in [(2, DEFAULT_SPILL_BUDGET), (8, DEFAULT_SPILL_BUDGET), (8, 0)] {
            // Budget 0 forces the spill path whenever a shard finishes
            // early; repeat a few times so the completion-order race
            // actually exercises out-of-order deliveries.
            for round in 0..3 {
                let out = dir
                    .parent()
                    .unwrap()
                    .join(format!("threads_t{threads}_b{budget}_{round}.bin"));
                let report = merge_segments_with(
                    &dir,
                    &plan,
                    &out,
                    &MergeOptions {
                        merge_threads: threads,
                        spill_budget: budget,
                        remove_inputs: false,
                    },
                )
                .unwrap();
                assert_eq!(report.merge_threads, threads.min(8));
                assert_eq!(
                    std::fs::read(&out).unwrap(),
                    serial_bytes,
                    "T={threads} budget={budget} round={round}"
                );
                assert_eq!(report.shards, serial.shards, "rows in index order");
                assert_eq!(report.total_edges, serial.total_edges);
                // Spills only ever happen out of order, and with budget 0
                // anything deferred must have spilled.
                if budget == 0 {
                    assert_eq!(report.deferred_shards, 0, "budget 0 defers nothing in memory");
                }
                // No spill temp files survive the merge.
                let leftovers = std::fs::read_dir(dir.parent().unwrap())
                    .unwrap()
                    .filter(|e| {
                        e.as_ref()
                            .unwrap()
                            .file_name()
                            .to_string_lossy()
                            .starts_with("magquilt-tmp-")
                    })
                    .count();
                assert_eq!(leftovers, 0, "spill files drained");
            }
        }
    }

    #[test]
    fn scan_caches_validated_headers() {
        // The scan pass records each file's validated header so the merge
        // never re-opens a header; truncating a body *after* the scan
        // must still fail loud at merge time.
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("cache");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[(0, 1), (1, 2), (3, 3)]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[(9, 2)]);
        let catalog = scan_segments(&dir, &plan).unwrap();
        let owner0 = catalog.shards[0].owner.as_ref().unwrap();
        assert_eq!(owner0.header.num_edges, 3);
        assert_eq!(owner0.header.num_nodes, 16);
        assert_eq!(catalog.shards[1].owner.as_ref().unwrap().header.num_edges, 1);
        // Truncate shard 0's body behind the catalog's back.
        let f = std::fs::OpenOptions::new().write(true).open(&owner0.path).unwrap();
        f.set_len(24 + 8).unwrap(); // header + one record
        drop(f);
        let err = merge_segments(&dir, &plan, &dir.join("out.bin"), false).unwrap_err();
        assert!(err.to_string().contains("reading segment"), "{err}");
    }

    #[test]
    fn missing_owner_segment_fails() {
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("missing");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[(0, 1)]);
        // Shard 1's owner segment absent.
        let err = merge_segments(&dir, &plan, &dir.join("out.bin"), false).unwrap_err();
        assert!(err.to_string().contains("no owner segment for shard 1"), "{err}");
        assert!(!dir.join("out.bin").exists(), "must fail before touching the output");
    }

    #[test]
    fn mixed_plan_hashes_are_rejected() {
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("mixed");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[]);
        // A stray segment from some other plan.
        write_run(&dir, &segment_file_name("deadbeefdeadbeef", 0, 0), 16, &[]);
        let err = scan_segments(&dir, &plan).unwrap_err();
        assert!(err.to_string().contains("mixed plans"), "{err}");
    }

    #[test]
    fn markers_and_quarantine_are_tolerated_and_drained() {
        use crate::dist::worker::{heartbeat_file_name, marker_file_name};
        // A resumed run's directory also carries completion markers,
        // heartbeat files, and possibly a doctor quarantine subdir. The
        // scan must look past all of them, and remove_inputs must drain
        // this plan's markers so the directory ends up empty of run
        // state — while a *foreign* marker is still a mixed-plan error.
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("markers");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[(0, 1)]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[(9, 2)]);
        std::fs::write(dir.join(marker_file_name(&hash, 0)), "format = 1\n").unwrap();
        std::fs::write(dir.join(heartbeat_file_name(&hash, 1)), "").unwrap();
        std::fs::create_dir_all(dir.join(super::super::doctor::QUARANTINE_DIR)).unwrap();
        std::fs::write(
            dir.join(super::super::doctor::QUARANTINE_DIR).join("junk.seg"),
            "x",
        )
        .unwrap();
        let out = dir.join("merged.bin");
        let report = merge_segments(&dir, &plan, &out, true).unwrap();
        assert_eq!(report.total_edges, 2);
        let mut left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        left.sort();
        assert_eq!(left, vec!["merged.bin".to_string(), "quarantine".to_string()]);

        // Foreign-plan markers are refused like foreign segments.
        let dir = fresh_dir("foreign_marker");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[]);
        std::fs::write(dir.join(marker_file_name("deadbeefdeadbeef", 0)), "format = 1\n")
            .unwrap();
        let err = scan_segments(&dir, &plan).unwrap_err();
        assert!(err.to_string().contains("mixed plans"), "{err}");
    }

    #[test]
    fn scan_rejects_malformed_topology() {
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        // Owner segment from the wrong worker.
        let dir = fresh_dir("wrong_owner");
        write_run(&dir, &segment_file_name(&hash, 0, 1), 16, &[]);
        assert!(scan_segments(&dir, &plan).unwrap_err().to_string().contains("owned by"));
        // Overflow from the shard's own owner.
        let dir = fresh_dir("self_overflow");
        write_run(&dir, &overflow_file_name(&hash, 0, 0), 16, &[]);
        assert!(scan_segments(&dir, &plan).unwrap_err().to_string().contains("must not"));
        // Unknown file name.
        let dir = fresh_dir("unknown");
        std::fs::write(dir.join("notes.txt"), "x").unwrap();
        assert!(scan_segments(&dir, &plan).unwrap_err().to_string().contains("unrecognized"));
        // Leftover temp file.
        let dir = fresh_dir("tmpfile");
        std::fs::write(dir.join("magquilt-tmp-1-0-0-seg.part"), "x").unwrap();
        assert!(scan_segments(&dir, &plan).unwrap_err().to_string().contains("in-flight"));
        // Shard index beyond the plan.
        let dir = fresh_dir("shard_oob");
        write_run(&dir, &segment_file_name(&hash, 7, 0), 16, &[]);
        assert!(scan_segments(&dir, &plan).is_err());
        // A correctly named file with a corrupt header fails at scan.
        let dir = fresh_dir("bad_header");
        std::fs::write(dir.join(segment_file_name(&hash, 0, 0)), b"NOTMAGIC").unwrap();
        assert!(scan_segments(&dir, &plan).unwrap_err().to_string().contains("validating"));
    }

    #[test]
    fn out_of_span_source_is_rejected() {
        // n=16, S=2: shard 0 owns sources 0..8. A segment for shard 0
        // holding source 12 is corrupt and must not merge.
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("span");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[(12, 0)]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[]);
        let err = merge_segments(&dir, &plan, &dir.join("out.bin"), false).unwrap_err();
        assert!(err.to_string().contains("outside shard"), "{err}");
    }

    #[test]
    fn wrong_node_count_is_rejected() {
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("nodes");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 8, &[(0, 1)]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[]);
        let err = merge_segments(&dir, &plan, &dir.join("out.bin"), false).unwrap_err();
        assert!(err.to_string().contains("nodes"), "{err}");
    }

    #[test]
    fn traced_merge_is_byte_identical_and_reports_render() {
        let (plan, dir) = build_overflow_dir("traced");
        let out_plain = dir.parent().unwrap().join("traced_plain.bin");
        let plain =
            merge_segments_with(&dir, &plan, &out_plain, &MergeOptions::default()).unwrap();
        let trace = TraceHandle::new(&plan.hash_hex(), "merge", None);
        let out_traced = dir.parent().unwrap().join("traced_traced.bin");
        let traced = merge_segments_with(
            &dir,
            &plan,
            &out_traced,
            &MergeOptions { trace: trace.clone(), ..Default::default() },
        )
        .unwrap();
        assert_eq!(
            std::fs::read(&out_plain).unwrap(),
            std::fs::read(&out_traced).unwrap(),
            "tracing never changes the merged bytes"
        );
        assert_eq!(plain.total_edges, traced.total_edges);
        let lines = trace.lines();
        let shard_events =
            lines.iter().filter(|l| l.contains("\"event\":\"merge_shard\"")).count();
        assert_eq!(shard_events, 8, "one merge_shard event per shard");
        assert!(lines.iter().any(|l| l.contains("\"event\":\"merge_done\"")));
        // The merge report renders through the shared serializer and
        // validates as kind `merge`.
        let json = merge_report_json(&plan.hash_hex(), &traced);
        assert_eq!(crate::trace::report::validate_report(&json).unwrap(), "merge");
        assert!(json.contains("\"total_edges\":40"), "{json}");
    }

    #[test]
    fn validate_matches_merge_numbers() {
        let plan = plan_for(4, 2, 2);
        let hash = plan.hash_hex();
        let dir = fresh_dir("validate");
        write_run(&dir, &segment_file_name(&hash, 0, 0), 16, &[(0, 1), (3, 3)]);
        write_run(&dir, &overflow_file_name(&hash, 1, 0), 16, &[(9, 2), (10, 0)]);
        write_run(&dir, &segment_file_name(&hash, 1, 1), 16, &[(9, 2)]);
        let inspect = validate_segments(&dir, &plan).unwrap();
        let merged = merge_segments(&dir, &plan, &dir.join("out.bin"), false).unwrap();
        assert_eq!(inspect.total_edges, merged.total_edges);
        assert_eq!(inspect.shards, merged.shards);
        assert_eq!(inspect.duplicates_dropped(), 1);
    }
}
