//! The distributed worker: one process, one contiguous shard range.
//!
//! `magquilt shard-worker --plan plan.toml --worker i` reloads the
//! [`ShardPlan`], re-runs the full deterministic setup pipeline
//! (attributes → partition → tries → product DAG — bit-for-bit identical
//! on every host), recomputes every job's source span, keeps exactly the
//! jobs the **ownership rule** assigns to worker `i`, and executes them
//! through the ordinary pooled coordinator. With `--artifact F`
//! ([`WorkerOptions::artifact`]) the setup pipeline is **skipped**
//! entirely: the worker loads the shared [`crate::setup::SetupArtifact`],
//! cross-checks its identity hash against the plan's
//! ([`crate::setup::SetupArtifact::check_matches`]), and hydrates the
//! same job plan from it — byte-identical output, witnessed by
//! [`crate::coordinator::SetupStats::artifact_hash`]. The only distributed part is
//! the sink: a [`SegmentSink`] that writes each finished shard to its own
//! `MAGQEDG1` file instead of one growing output.
//!
//! # Ownership rule
//!
//! A job belongs to the worker owning the **first shard of its source
//! span** (`owner_of_shard(span.lo)`; the rare job with no source nodes
//! belongs to worker 0). Since spans are recomputed identically from the
//! plan by every process, each job is assigned to exactly one worker with
//! no coordination. The heavy jobs — small high-multiplicity attribute
//! sets — have narrow spans and land wholly inside one worker's range;
//! wide-span jobs (`D_1`, light ER blocks) necessarily sample some edges
//! whose source shard belongs to *another* worker. Those edges route to
//! this process's merger for the foreign shard as usual and emerge as an
//! **overflow segment** for that shard, which the merge step folds into
//! the owner's segment later.
//!
//! # What a worker writes into the segment directory
//!
//! * one `seg-<hash>-s<shard>-w<worker>.seg` per **owned** shard (even
//!   when empty — emptiness is information; a *missing* owner segment
//!   means an incomplete run and fails the merge),
//! * one `ovf-<hash>-s<shard>-w<worker>.ovf` per **foreign** shard this
//!   worker sampled any edges for, and
//! * one `done-<hash>-w<worker>.ok` **completion marker** once every
//!   segment is durably in place, recording the [`SegmentSummary`].
//!
//! Segments are complete `MAGQEDG1` files (header + sorted deduplicated
//! records), written to a pid+nonce temp name and atomically renamed, so
//! a crashed worker can never leave a half-written file under a final
//! name — and any number of workers can share the directory.
//!
//! # Crash-resume
//!
//! With [`WorkerOptions::resume`], the worker first scans the directory
//! for its own prior output. A trusted completion marker (plan hash,
//! worker index, and per-segment counts all agree with the files on
//! disk) means the previous run finished: nothing re-runs. Otherwise the
//! worker skips work at the granularity of **connected components** of
//! the job↔shard graph (each retained job links every shard in its
//! source span): a component is skipped only when *every* shard in it is
//! owned by this worker and already has a valid final segment. That rule
//! is what makes resumption exact — a surviving owned segment cannot
//! prove that the job which produced it also finished its *overflow*
//! writes into foreign shards, so any job whose span touches a foreign
//! (or missing) shard re-runs in full. Re-runs are idempotent: the same
//! plan re-derives byte-identical runs, and [`SegmentSink`] treats a
//! rewrite that matches the existing file as success (and a mismatch as
//! hard corruption). The net effect, proven by the kill-and-resume tests:
//! for every crash point, crash + resume yields a segment directory
//! byte-identical to a crash-free run. See `docs/fault-tolerance.md`.

use std::collections::BTreeMap;
use std::io::{self, Read};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::SamplerKind;
use crate::coordinator::{Coordinator, RunStats, SetupStats};
use crate::graph::{read_binary_header, unique_temp_path, write_atomic, BinaryEdgeWriter, Edge,
                   EdgeSink, ShardDisposition, SpillSummary};
use crate::kpgm::Initiator;
use crate::magm::{AttributeAssignment, MagmParams};
use crate::rng::Rng;
use crate::trace::progress::ProgressState;
use crate::trace::report::{report_header, run_stats_obj, JsonObj};
use crate::trace::{Fv, TraceHandle};

use super::fault::FaultPlan;
use super::plan::ShardPlan;

/// File name of the owner segment for `shard` written by `worker`.
pub fn segment_file_name(hash_hex: &str, shard: usize, worker: usize) -> String {
    format!("seg-{hash_hex}-s{shard:05}-w{worker:04}.seg")
}

/// File name of the overflow segment for foreign `shard` written by
/// `worker`.
pub fn overflow_file_name(hash_hex: &str, shard: usize, worker: usize) -> String {
    format!("ovf-{hash_hex}-s{shard:05}-w{worker:04}.ovf")
}

/// File name of `worker`'s completion marker.
pub fn marker_file_name(hash_hex: &str, worker: usize) -> String {
    format!("done-{hash_hex}-w{worker:04}.ok")
}

/// File name of `worker`'s liveness heartbeat (touched periodically by a
/// supervised worker; its mtime carries liveness, its body an optional
/// progress record — see [`crate::trace::progress`]).
pub fn heartbeat_file_name(hash_hex: &str, worker: usize) -> String {
    format!("hb-{hash_hex}-w{worker:04}.beat")
}

/// File name of `worker`'s structured trace stream (`MAGQTRC1` JSONL,
/// written once at the end of the run when tracing is enabled).
pub fn trace_file_name(hash_hex: &str, worker: usize) -> String {
    format!("trc-{hash_hex}-w{worker:04}.trace.jsonl")
}

/// File name of `worker`'s machine-readable run report (`MAGQRPT1`
/// JSON, written once at the end of the run when reporting is enabled).
pub fn report_file_name(hash_hex: &str, worker: usize) -> String {
    format!("rpt-{hash_hex}-w{worker:04}.report.json")
}

/// What kind of segment a file in the segment directory holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentKind {
    /// The owner's post-merge run for a shard it owns.
    Owned,
    /// A foreign worker's edges for a shard it does not own.
    Overflow,
}

/// Parsed identity of one segment-directory file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentFileInfo {
    /// Owned segment or overflow run.
    pub kind: SegmentKind,
    /// The plan hash embedded in the name.
    pub hash_hex: String,
    /// Shard index the records belong to.
    pub shard: usize,
    /// Worker process that wrote the file.
    pub worker: usize,
}

/// Parse a segment-directory file name produced by [`segment_file_name`]
/// / [`overflow_file_name`]. Returns `None` for anything else.
pub fn parse_segment_file_name(name: &str) -> Option<SegmentFileInfo> {
    let (kind, rest) = if let Some(r) = name.strip_prefix("seg-") {
        (SegmentKind::Owned, r.strip_suffix(".seg")?)
    } else if let Some(r) = name.strip_prefix("ovf-") {
        (SegmentKind::Overflow, r.strip_suffix(".ovf")?)
    } else {
        return None;
    };
    let mut parts = rest.split('-');
    let hash = parts.next()?;
    if hash.len() != 16 || !hash.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    let shard = parts.next()?.strip_prefix('s')?.parse().ok()?;
    let worker = parts.next()?.strip_prefix('w')?.parse().ok()?;
    if parts.next().is_some() {
        return None;
    }
    Some(SegmentFileInfo { kind, hash_hex: hash.to_string(), shard, worker })
}

/// What kind of metadata file (non-segment run state) a name denotes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetaFileKind {
    /// A `done-…​.ok` completion marker.
    Marker,
    /// A `hb-…​.beat` liveness heartbeat.
    Heartbeat,
    /// A `trc-…​.trace.jsonl` structured trace stream.
    Trace,
    /// A `rpt-…​.report.json` run report.
    Report,
}

/// Parsed identity of a marker/heartbeat file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MetaFileInfo {
    /// Marker or heartbeat.
    pub kind: MetaFileKind,
    /// The plan hash embedded in the name.
    pub hash_hex: String,
    /// The worker the file belongs to.
    pub worker: usize,
}

/// Parse a file name produced by [`marker_file_name`] /
/// [`heartbeat_file_name`] / [`trace_file_name`] / [`report_file_name`].
/// Returns `None` for anything else.
pub fn parse_meta_file_name(name: &str) -> Option<MetaFileInfo> {
    let (kind, rest) = if let Some(r) = name.strip_prefix("done-") {
        (MetaFileKind::Marker, r.strip_suffix(".ok")?)
    } else if let Some(r) = name.strip_prefix("hb-") {
        (MetaFileKind::Heartbeat, r.strip_suffix(".beat")?)
    } else if let Some(r) = name.strip_prefix("trc-") {
        (MetaFileKind::Trace, r.strip_suffix(".trace.jsonl")?)
    } else if let Some(r) = name.strip_prefix("rpt-") {
        (MetaFileKind::Report, r.strip_suffix(".report.json")?)
    } else {
        return None;
    };
    let (hash, worker) = rest.split_once('-')?;
    if hash.len() != 16 || !hash.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    let worker = worker.strip_prefix('w')?.parse().ok()?;
    Some(MetaFileInfo { kind, hash_hex: hash.to_string(), worker })
}

/// What one worker produced: the counters the driver and tests assert on.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SegmentSummary {
    /// Owned shards present as segment files (== the owned range width).
    pub owned_segments: usize,
    /// Edges across the owned segments.
    pub owned_edges: u64,
    /// Overflow files written for foreign shards.
    pub overflow_files: usize,
    /// Edges across the overflow files.
    pub overflow_edges: u64,
}

/// Format tag on the first line of a completion marker.
pub const MARKER_FORMAT: &str = "magquilt-marker-v1";

/// Atomically write `worker`'s completion marker recording `summary`.
/// This is the **last** thing a worker does: its existence asserts that
/// every segment and overflow file is durably under its final name.
pub fn write_marker(
    dir: &Path,
    hash_hex: &str,
    worker: usize,
    summary: &SegmentSummary,
) -> io::Result<()> {
    let body = format!(
        "format = {MARKER_FORMAT}\n\
         plan = {hash_hex}\n\
         worker = {worker}\n\
         owned_segments = {}\n\
         owned_edges = {}\n\
         overflow_files = {}\n\
         overflow_edges = {}\n",
        summary.owned_segments, summary.owned_edges, summary.overflow_files,
        summary.overflow_edges,
    );
    write_atomic(dir, &marker_file_name(hash_hex, worker), body.as_bytes())
}

/// Parse a completion marker's contents into `(plan hash, worker,
/// summary)`. Returns `None` for anything malformed — a marker that does
/// not parse is stale and is simply re-earned by re-running.
pub fn parse_marker(text: &str) -> Option<(String, usize, SegmentSummary)> {
    let mut map: BTreeMap<&str, &str> = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once('=')?;
        map.insert(k.trim(), v.trim());
    }
    if *map.get("format")? != MARKER_FORMAT {
        return None;
    }
    let summary = SegmentSummary {
        owned_segments: map.get("owned_segments")?.parse().ok()?,
        owned_edges: map.get("owned_edges")?.parse().ok()?,
        overflow_files: map.get("overflow_files")?.parse().ok()?,
        overflow_edges: map.get("overflow_edges")?.parse().ok()?,
    };
    let worker = map.get("worker")?.parse().ok()?;
    Some((map.get("plan")?.to_string(), worker, summary))
}

/// Byte-compare two files (length first, then 64 KiB chunks).
fn files_identical(a: &Path, b: &Path) -> io::Result<bool> {
    let (mut fa, mut fb) = (std::fs::File::open(a)?, std::fs::File::open(b)?);
    if fa.metadata()?.len() != fb.metadata()?.len() {
        return Ok(false);
    }
    let (mut ba, mut bb) = (vec![0u8; 64 * 1024], vec![0u8; 64 * 1024]);
    loop {
        let na = fa.read(&mut ba)?;
        if na == 0 {
            return Ok(true);
        }
        fb.read_exact(&mut bb[..na])?;
        if ba[..na] != bb[..na] {
            return Ok(false);
        }
    }
}

/// [`crate::graph::EdgeSink`] that lands every finished shard in its own
/// `MAGQEDG1` file: owned shards as `.seg`, non-empty foreign shards as
/// `.ovf`. Order-indifferent by construction (each shard has its own
/// file), so shards are consumed the moment they finish — no deferral, no
/// spill.
#[derive(Debug)]
pub struct SegmentSink {
    dir: PathBuf,
    hash_hex: String,
    worker: usize,
    /// Owned shard range `[start, end)`.
    owned: (usize, usize),
    num_nodes: usize,
    expected_shards: usize,
    /// Resume: owned shards whose valid segment already exists (shard →
    /// pre-scanned header edge count). Their deliveries must be empty
    /// (every job that could route edges there was skipped) and are
    /// counted into the summary without touching the file.
    satisfied: BTreeMap<usize, u64>,
    /// Owned segments freshly written *by this process* — the counter
    /// the `crash-after-segments=K` fault gates on (satisfied shards
    /// don't advance it: they represent a previous process's writes).
    owned_written: usize,
    fault: Option<FaultPlan>,
    summary: SegmentSummary,
}

impl SegmentSink {
    /// Sink for `worker` owning `owned`, writing into `dir` under the
    /// plan hash `hash_hex`; the run must deliver exactly
    /// `expected_shards` shards.
    pub fn new(
        dir: impl AsRef<Path>,
        hash_hex: String,
        worker: usize,
        owned: (usize, usize),
        expected_shards: usize,
    ) -> Self {
        SegmentSink {
            dir: dir.as_ref().to_path_buf(),
            hash_hex,
            worker,
            owned,
            num_nodes: 0,
            expected_shards,
            satisfied: BTreeMap::new(),
            owned_written: 0,
            fault: None,
            summary: SegmentSummary::default(),
        }
    }

    /// Declare owned shards whose valid final segments already exist
    /// (from a resume scan); they are counted, not rewritten.
    pub fn with_resume(mut self, satisfied: BTreeMap<usize, u64>) -> Self {
        self.satisfied = satisfied;
        self
    }

    /// Arm deterministic fault injection (tests / CI only).
    pub fn with_fault(mut self, fault: Option<FaultPlan>) -> Self {
        self.fault = fault;
        self
    }

    /// Write `run` as a complete `MAGQEDG1` file at `dir/name`, via a
    /// pid+nonce temp name and an atomic rename. If the final name
    /// already exists (a resumed run re-deriving a file a previous
    /// attempt completed), a byte-identical rewrite is success and a
    /// mismatch is a hard error — same plan hash + different bytes can
    /// only mean corruption.
    fn write_segment(&self, shard: usize, name: &str, run: &[Edge]) -> io::Result<()> {
        let tmp = unique_temp_path(&self.dir, "seg", "part");
        let mut w = BinaryEdgeWriter::create(&tmp, self.num_nodes)?;
        if let Some(f) = &self.fault {
            // Fires between temp creation and the body write, leaving the
            // truncated temp behind — exactly a mid-write crash's residue.
            f.before_shard_body(shard)?;
        }
        w.write_edges(run)?;
        w.finalize(run.len() as u64)?;
        if let Some(f) = &self.fault {
            // Fires with the temp complete but un-renamed — the window a
            // crash leaves a finished file under a temp name.
            f.before_rename()?;
        }
        let final_path = self.dir.join(name);
        if final_path.exists() {
            let same = files_identical(&tmp, &final_path)?;
            let _ = std::fs::remove_file(&tmp);
            if same {
                return Ok(());
            }
            return Err(io::Error::other(format!(
                "segment {name} already exists with different contents — the same plan can \
                 only re-derive identical bytes, so the file is corrupt; run \
                 `magquilt doctor --fix` on the directory"
            )));
        }
        let result = std::fs::rename(&tmp, final_path);
        if result.is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
        result
    }
}

impl EdgeSink for SegmentSink {
    type Output = SegmentSummary;

    fn begin(&mut self, num_nodes: usize, num_shards: usize) -> io::Result<()> {
        if num_shards != self.expected_shards {
            return Err(io::Error::other(format!(
                "coordinator resolved {num_shards} shards but the plan fixed {} — \
                 plan and run disagree",
                self.expected_shards
            )));
        }
        self.num_nodes = num_nodes;
        std::fs::create_dir_all(&self.dir)
    }

    fn accept_shard(&mut self, index: usize, run: Vec<Edge>) -> io::Result<ShardDisposition> {
        if index >= self.expected_shards {
            return Err(io::Error::other(format!("shard index {index} out of range")));
        }
        if let Some(&edges) = self.satisfied.get(&index) {
            // Every job that could route edges to a satisfied shard was
            // skipped, so its delivery must be empty; anything else means
            // the component bookkeeping is broken and the on-disk file
            // can no longer be trusted to equal a fresh run's.
            if !run.is_empty() {
                return Err(io::Error::other(format!(
                    "resume error: shard {index} was marked satisfied but received {} fresh \
                     edges",
                    run.len()
                )));
            }
            self.summary.owned_segments += 1;
            self.summary.owned_edges += edges;
            return Ok(ShardDisposition::Streamed);
        }
        if (self.owned.0..self.owned.1).contains(&index) {
            if let Some(f) = &self.fault {
                f.before_owned_segment(self.owned_written)?;
            }
            self.write_segment(index, &segment_file_name(&self.hash_hex, index, self.worker), &run)?;
            self.owned_written += 1;
            self.summary.owned_segments += 1;
            self.summary.owned_edges += run.len() as u64;
        } else if !run.is_empty() {
            // A foreign shard only gets a file when a wide-span owned job
            // actually sampled edges there; an empty foreign delivery is
            // the common case and writes nothing.
            self.write_segment(
                index,
                &overflow_file_name(&self.hash_hex, index, self.worker),
                &run,
            )?;
            self.summary.overflow_files += 1;
            self.summary.overflow_edges += run.len() as u64;
        }
        Ok(ShardDisposition::Streamed)
    }

    fn finalize(self) -> io::Result<SegmentSummary> {
        let owned_width = self.owned.1 - self.owned.0;
        if self.summary.owned_segments != owned_width {
            return Err(io::Error::other(format!(
                "worker {} wrote {} of its {owned_width} owned segments",
                self.worker, self.summary.owned_segments
            )));
        }
        Ok(self.summary)
    }
}

/// What a pre-run scan of the segment directory found for one worker.
#[derive(Debug, Default)]
pub struct ResumeState {
    /// Owned shards whose final segment exists and header-validates:
    /// shard index → edge count claimed by the validated header.
    pub valid_segments: BTreeMap<usize, u64>,
    /// The completion marker's summary, when present and consistent with
    /// the plan, the worker, and the segments actually on disk.
    pub marker: Option<SegmentSummary>,
}

/// Scan `dir` for worker `worker`'s prior output under `plan`. Foreign
/// plan hashes and unrecognized files fail the scan (resuming into a
/// mixed directory silently corrupts the merge); an invalid *final*
/// segment of this worker fails too, pointing at `magquilt doctor` —
/// final names are only ever produced by atomic renames of complete
/// files, so an invalid one means external corruption, not a crash.
/// A stale marker (wrong counts for the files on disk) is deleted and
/// re-earned. Other workers' files and leftover temp files are ignored.
pub fn scan_resume_state(dir: &Path, plan: &ShardPlan, worker: usize) -> Result<ResumeState> {
    let mut state = ResumeState::default();
    if !dir.exists() {
        return Ok(state);
    }
    let hash = plan.hash_hex();
    let owned = plan.worker_range(worker)?;
    let mut marker_path = None;
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("resume scan of {}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name == super::PLAN_FILE || name.starts_with("magquilt-tmp-") {
            // A dead attempt's temps are harmless here (unique names, and
            // never inputs); the driver / doctor sweeps them before merge.
            continue;
        }
        if crate::setup::is_artifact_file(&name) {
            // A shared setup artifact often lives next to the segments;
            // it is an input, not run state, and never blocks a resume.
            continue;
        }
        if name == super::doctor::QUARANTINE_DIR && entry.path().is_dir() {
            continue;
        }
        if let Some(meta) = parse_meta_file_name(&name) {
            if meta.hash_hex != hash {
                bail!(
                    "segment dir {} holds {name} from a different plan ({}) — refusing to \
                     resume into a mixed directory",
                    dir.display(),
                    meta.hash_hex
                );
            }
            if meta.kind == MetaFileKind::Marker && meta.worker == worker {
                marker_path = Some(entry.path());
            }
            continue;
        }
        let Some(info) = parse_segment_file_name(&name) else {
            bail!(
                "unrecognized file {name} in segment directory {} — run `magquilt doctor` to \
                 classify it",
                dir.display()
            );
        };
        if info.hash_hex != hash {
            bail!(
                "segment {name} was produced under plan {} but this plan hashes to {hash} — \
                 refusing to resume into a mixed directory",
                info.hash_hex
            );
        }
        if info.worker != worker {
            continue; // other workers' files are their own resume state
        }
        match info.kind {
            SegmentKind::Owned => {
                if !(owned.0..owned.1).contains(&info.shard) {
                    bail!(
                        "segment {name} says worker {worker} owns shard {} but its range is \
                         {}..{} — run `magquilt doctor`",
                        info.shard,
                        owned.0,
                        owned.1
                    );
                }
                let header = read_binary_header(&entry.path()).with_context(|| {
                    format!(
                        "resume scan: final segment {name} does not validate — run \
                         `magquilt doctor --fix` to quarantine it"
                    )
                })?;
                if header.num_nodes != plan.model.num_nodes() as u64 {
                    bail!(
                        "segment {name} claims {} nodes but the plan's model has {} — run \
                         `magquilt doctor`",
                        header.num_nodes,
                        plan.model.num_nodes()
                    );
                }
                state.valid_segments.insert(info.shard, header.num_edges);
            }
            SegmentKind::Overflow => {
                // Presence of an overflow file cannot prove the producing
                // job's *other* writes landed, so it earns no skip: the
                // component rule re-runs its job, and the idempotent
                // rewrite in `write_segment` absorbs the existing file.
            }
        }
    }
    if let Some(path) = marker_path {
        let owned_width = owned.1 - owned.0;
        let trusted = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| parse_marker(&text))
            .filter(|(h, w, s)| {
                *h == hash
                    && *w == worker
                    && s.owned_segments == owned_width
                    && state.valid_segments.len() == owned_width
                    && s.owned_edges == state.valid_segments.values().sum::<u64>()
            });
        match trusted {
            Some((_, _, summary)) => state.marker = Some(summary),
            None => {
                // Stale marker (e.g. from a plan whose hash-exempt knobs
                // changed the worker count): delete it and re-earn it.
                std::fs::remove_file(&path)
                    .with_context(|| format!("removing stale marker {}", path.display()))?;
            }
        }
    }
    Ok(state)
}

/// Partition the retained jobs and this worker's shards into skippable
/// work: over the connected components of the job↔shard graph (each job
/// links every shard in its inclusive source span `lo..=hi`), a
/// component is satisfied iff **every** shard in it lies in `owned` and
/// appears in `valid`. Returns per-job skip flags (aligned with `spans`)
/// and the satisfied shards with their validated edge counts.
fn satisfied_components(
    num_shards: usize,
    owned: (usize, usize),
    spans: &[Option<(usize, usize)>],
    valid: &BTreeMap<usize, u64>,
) -> (Vec<bool>, BTreeMap<usize, u64>) {
    fn find(parent: &mut [usize], mut x: usize) -> usize {
        while parent[x] != x {
            parent[x] = parent[parent[x]];
            x = parent[x];
        }
        x
    }
    let mut parent: Vec<usize> = (0..num_shards).collect();
    for &(lo, hi) in spans.iter().flatten() {
        for k in lo..hi.min(num_shards - 1) {
            let a = find(&mut parent, k);
            let b = find(&mut parent, k + 1);
            parent[a] = b;
        }
    }
    let mut component_ok = vec![true; num_shards];
    for shard in 0..num_shards {
        if !(owned.0..owned.1).contains(&shard) || !valid.contains_key(&shard) {
            let root = find(&mut parent, shard);
            component_ok[root] = false;
        }
    }
    let skip = spans
        .iter()
        .map(|span| match span {
            // A span-less job emits nothing; re-running it is free and
            // avoids trusting anything.
            None => false,
            Some((lo, _)) => component_ok[find(&mut parent, *lo)],
        })
        .collect();
    let satisfied = (owned.0..owned.1)
        .filter(|&shard| component_ok[find(&mut parent, shard)])
        .filter_map(|shard| valid.get(&shard).map(|&e| (shard, e)))
        .collect();
    (skip, satisfied)
}

/// What [`run_worker`] reports back to the driver / CLI.
#[derive(Debug)]
pub struct WorkerReport {
    /// This worker's index.
    pub worker: usize,
    /// Owned shard range `[start, end)`.
    pub owned: (usize, usize),
    /// Jobs in the full plan (identical on every worker; 0 when the
    /// marker fast path skipped the setup pipeline entirely).
    pub jobs_total: usize,
    /// Jobs this worker owned and actually executed this process.
    pub jobs_run: usize,
    /// Owned shards satisfied by a previous attempt's segments and
    /// skipped (0 on a fresh run).
    pub resumed_shards: usize,
    /// Files + edge counters of what is on disk for this worker.
    pub summary: SegmentSummary,
    /// The underlying coordinated-run statistics.
    pub stats: RunStats,
}

/// Render a worker's `report.json` (kind `worker`) through the shared
/// [`crate::trace::report`] serializer.
pub fn worker_report_json(hash_hex: &str, report: &WorkerReport) -> String {
    report_header("worker", hash_hex)
        .uint("worker", report.worker as u64)
        .uint("owned_lo", report.owned.0 as u64)
        .uint("owned_hi", report.owned.1 as u64)
        .uint("jobs_total", report.jobs_total as u64)
        .uint("jobs_run", report.jobs_run as u64)
        .uint("resumed_shards", report.resumed_shards as u64)
        .obj(
            "summary",
            JsonObj::new()
                .uint("owned_segments", report.summary.owned_segments as u64)
                .uint("owned_edges", report.summary.owned_edges)
                .uint("overflow_files", report.summary.overflow_files as u64)
                .uint("overflow_edges", report.summary.overflow_edges),
        )
        .obj("stats", run_stats_obj(&report.stats))
        .render()
}

/// Model parameters for a plan's model spec.
pub fn plan_params(plan: &ShardPlan) -> MagmParams {
    MagmParams::homogeneous(
        Initiator::new(plan.model.theta),
        plan.model.mu,
        plan.model.num_nodes(),
        plan.model.attributes,
    )
}

/// Setup-thread count for attribute sampling (wall-clock only — chunked
/// draws are bit-for-bit identical for any count).
fn resolved_threads(plan: &ShardPlan) -> usize {
    if plan.setup_threads != 0 {
        plan.setup_threads
    } else if plan.workers != 0 {
        plan.workers
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(16)
    }
}

/// Build the full (unfiltered) deterministic job plan every worker
/// derives from `plan` — the shared object the ownership rule partitions.
pub fn build_job_plan(
    plan: &ShardPlan,
    coord: &Coordinator,
) -> (crate::coordinator::JobPlan, AttributeAssignment) {
    let params = plan_params(plan);
    let mut rng = Rng::new(plan.seed);
    let attrs = AttributeAssignment::sample_with_mode(
        &params,
        &mut rng,
        plan.attr_mode,
        resolved_threads(plan),
    );
    let job_plan = match plan.sampler {
        SamplerKind::Hybrid => coord.plan_hybrid(&params, &attrs, plan.seed),
        _ => coord.plan_quilt(&params, &attrs, plan.seed),
    };
    (job_plan, attrs)
}

/// Build the [`crate::setup::SetupArtifact`] a plan's workers can share,
/// exactly as the plan prescribes (`magquilt setup` builds through this
/// so the hash and the payload match what `--artifact` workers expect).
pub fn build_plan_artifact(plan: &ShardPlan) -> Result<crate::setup::SetupArtifact> {
    plan_coordinator(plan).build_setup(&plan.model, plan.seed, plan.sampler)
}

/// As [`build_job_plan`], but hydrated from a setup artifact file instead
/// of re-running the setup pipeline. The artifact's identity hash is
/// cross-checked against the header the plan expects before anything is
/// trusted; `artifact_load_ms` on the resulting plan's
/// [`crate::coordinator::SetupStats`] records the load + validation cost.
pub fn build_job_plan_from_artifact(
    plan: &ShardPlan,
    coord: &Coordinator,
    artifact_path: &Path,
) -> Result<crate::coordinator::JobPlan> {
    let start = std::time::Instant::now();
    let artifact = crate::setup::SetupArtifact::load(artifact_path)?;
    artifact.check_matches(&crate::setup::ArtifactHeader::from_plan(plan))?;
    let load_ms = start.elapsed().as_secs_f64() * 1e3;
    coord.plan_from_artifact(artifact, load_ms)
}

/// The owner worker of every job in `job_plan` under `plan`'s ownership
/// rule: the worker owning the first shard of the job's source span (a
/// job with no source nodes emits nothing and belongs to worker 0).
pub fn job_owners(plan: &ShardPlan, job_plan: &crate::coordinator::JobPlan) -> Vec<usize> {
    let spec = plan.shard_spec();
    job_plan
        .job_source_spans(&spec)
        .into_iter()
        .map(|span| span.map(|(lo, _)| plan.owner_of_shard(lo)).unwrap_or(0))
        .collect()
}

/// A coordinator configured exactly as `plan` prescribes.
pub fn plan_coordinator(plan: &ShardPlan) -> Coordinator {
    Coordinator::new()
        .workers(plan.workers)
        .shards(plan.num_shards)
        .setup_threads(plan.setup_threads)
        .attr_mode(plan.attr_mode)
        .piece_mode(plan.piece_mode)
}

/// Knobs for [`run_worker_with`].
#[derive(Debug, Clone, Default)]
pub struct WorkerOptions {
    /// Scan the segment directory first and skip work whose output a
    /// previous attempt already landed (see the module docs' resume
    /// rules). Off by default: a plain `run_worker` never reads the
    /// directory.
    pub resume: bool,
    /// Hydrate the job plan from this setup artifact instead of running
    /// the setup pipeline (the file's identity hash must match the plan).
    pub artifact: Option<PathBuf>,
    /// Deterministic fault injection (tests / CI only).
    pub fault: Option<FaultPlan>,
    /// Write a `trc-…​.trace.jsonl` structured trace stream into the
    /// segment directory at the end of the run.
    pub trace: bool,
    /// Write a `rpt-…​.report.json` run report into the segment
    /// directory at the end of the run.
    pub report: bool,
    /// Live progress counters to bump while sampling (a supervised
    /// worker's heartbeat publishes their snapshots; see
    /// [`crate::trace::progress`]).
    pub progress: Option<Arc<ProgressState>>,
}

/// Execute worker `worker`'s slice of `plan`, writing segment and
/// overflow files into `segment_dir`. The whole deterministic prologue
/// runs here (identically on every host); only the owned jobs sample.
pub fn run_worker(plan: &ShardPlan, worker: usize, segment_dir: &Path) -> Result<WorkerReport> {
    run_worker_with(plan, worker, segment_dir, &WorkerOptions::default())
}

/// [`run_worker`] with resume / fault-injection knobs.
pub fn run_worker_with(
    plan: &ShardPlan,
    worker: usize,
    segment_dir: &Path,
    opts: &WorkerOptions,
) -> Result<WorkerReport> {
    plan.validate()?;
    let owned = plan.worker_range(worker)?;
    let resume =
        if opts.resume { scan_resume_state(segment_dir, plan, worker)? } else { ResumeState::default() };

    // Fast path: a trusted completion marker means the previous attempt
    // finished every write — skip even the setup pipeline.
    if let Some(summary) = resume.marker {
        let stats = RunStats {
            partition_size: 0,
            num_jobs: 0,
            workers: 0,
            num_shards: plan.num_shards,
            num_edges: summary.owned_edges + summary.overflow_edges,
            wall_ms: 0.0,
            edges_per_sec: 0.0,
            dropped_resamples: 0,
            shard_stats: Vec::new(),
            spill: SpillSummary::default(),
            setup: SetupStats::default(),
        };
        return Ok(WorkerReport {
            worker,
            owned,
            jobs_total: 0,
            jobs_run: 0,
            resumed_shards: owned.1 - owned.0,
            summary,
            stats,
        });
    }

    let hash = plan.hash_hex();
    let trace = if opts.trace {
        TraceHandle::new(&hash, "worker", Some(worker))
    } else {
        TraceHandle::disabled()
    };
    let mut coord = plan_coordinator(plan);
    let mut job_plan = match &opts.artifact {
        Some(path) => build_job_plan_from_artifact(plan, &coord, path)
            .with_context(|| format!("worker {worker} hydrating its setup artifact"))?,
        None => build_job_plan(plan, &coord).0,
    };
    let owners = job_owners(plan, &job_plan);
    let jobs_total = job_plan.len();
    job_plan.retain_jobs(|i| owners[i] == worker);
    // Resume: spans must be recomputed on the *retained* plan — the
    // retain above shifted job indices.
    let mut satisfied = BTreeMap::new();
    if !resume.valid_segments.is_empty() {
        let spans = job_plan.job_source_spans(&plan.shard_spec());
        let (skip, sat) = satisfied_components(
            plan.num_shards,
            owned,
            &spans,
            &resume.valid_segments,
        );
        job_plan.retain_jobs(|i| !skip[i]);
        satisfied = sat;
    }
    let jobs_run = job_plan.len();
    let resumed_shards = satisfied.len();
    trace.emit(
        "worker_start",
        &[
            ("owned_lo", Fv::U(owned.0 as u64)),
            ("owned_hi", Fv::U(owned.1 as u64)),
            ("jobs_total", Fv::U(jobs_total as u64)),
            ("jobs_owned", Fv::U(jobs_run as u64)),
            ("resumed_shards", Fv::U(resumed_shards as u64)),
        ],
    );
    coord = coord.trace(trace.clone());
    if let Some(progress) = &opts.progress {
        coord = coord.progress(Arc::clone(progress));
    }
    let sink = SegmentSink::new(segment_dir, hash.clone(), worker, owned, plan.num_shards)
        .with_resume(satisfied)
        .with_fault(opts.fault.clone());
    let (summary, stats) = coord
        .run_with_sink(job_plan, sink)
        .with_context(|| format!("worker {worker} sampling its job slice"))?;
    if stats.num_shards != plan.num_shards {
        bail!(
            "worker {worker} ran with {} shards but the plan fixed {}",
            stats.num_shards,
            plan.num_shards
        );
    }
    let report =
        WorkerReport { worker, owned, jobs_total, jobs_run, resumed_shards, summary, stats };
    trace.emit(
        "worker_done",
        &[
            ("jobs_run", Fv::U(report.jobs_run as u64)),
            ("owned_edges", Fv::U(report.summary.owned_edges)),
            ("overflow_files", Fv::U(report.summary.overflow_files as u64)),
            ("overflow_edges", Fv::U(report.summary.overflow_edges)),
        ],
    );
    // Telemetry lands before the completion marker so the marker stays
    // the last write of the run; both are plain overwrites on a re-run.
    if opts.trace {
        trace
            .write_to(&segment_dir.join(trace_file_name(&hash, worker)))
            .with_context(|| format!("worker {worker} writing its trace stream"))?;
    }
    if opts.report {
        write_atomic(
            segment_dir,
            &report_file_name(&hash, worker),
            worker_report_json(&hash, &report).as_bytes(),
        )
        .with_context(|| format!("worker {worker} writing its run report"))?;
    }
    if let Some(f) = &opts.fault {
        // The last crash window: all segments final, marker not yet
        // written.
        f.before_marker()?;
    }
    write_marker(segment_dir, &hash, worker, &report.summary)
        .with_context(|| format!("worker {worker} writing its completion marker"))?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_names_roundtrip() {
        let hash = "00ff00ff00ff00ff";
        let seg = segment_file_name(hash, 3, 1);
        assert_eq!(seg, "seg-00ff00ff00ff00ff-s00003-w0001.seg");
        let info = parse_segment_file_name(&seg).unwrap();
        assert_eq!(info.kind, SegmentKind::Owned);
        assert_eq!((info.shard, info.worker), (3, 1));
        assert_eq!(info.hash_hex, hash);
        let ovf = overflow_file_name(hash, 250, 0);
        let info = parse_segment_file_name(&ovf).unwrap();
        assert_eq!(info.kind, SegmentKind::Overflow);
        assert_eq!((info.shard, info.worker), (250, 0));
    }

    #[test]
    fn meta_names_roundtrip() {
        let hash = "00ff00ff00ff00ff";
        let done = marker_file_name(hash, 7);
        assert_eq!(done, "done-00ff00ff00ff00ff-w0007.ok");
        let info = parse_meta_file_name(&done).unwrap();
        assert_eq!(info.kind, MetaFileKind::Marker);
        assert_eq!((info.hash_hex.as_str(), info.worker), (hash, 7));
        let hb = heartbeat_file_name(hash, 12);
        assert_eq!(hb, "hb-00ff00ff00ff00ff-w0012.beat");
        let info = parse_meta_file_name(&hb).unwrap();
        assert_eq!(info.kind, MetaFileKind::Heartbeat);
        assert_eq!((info.hash_hex.as_str(), info.worker), (hash, 12));
        let trc = trace_file_name(hash, 2);
        assert_eq!(trc, "trc-00ff00ff00ff00ff-w0002.trace.jsonl");
        let info = parse_meta_file_name(&trc).unwrap();
        assert_eq!(info.kind, MetaFileKind::Trace);
        assert_eq!((info.hash_hex.as_str(), info.worker), (hash, 2));
        let rpt = report_file_name(hash, 9);
        assert_eq!(rpt, "rpt-00ff00ff00ff00ff-w0009.report.json");
        let info = parse_meta_file_name(&rpt).unwrap();
        assert_eq!(info.kind, MetaFileKind::Report);
        assert_eq!((info.hash_hex.as_str(), info.worker), (hash, 9));
        // Meta names never parse as segments and vice versa.
        assert!(parse_segment_file_name(&done).is_none());
        assert!(parse_segment_file_name(&trc).is_none());
        assert!(parse_meta_file_name(&segment_file_name(hash, 0, 0)).is_none());
    }

    #[test]
    fn foreign_names_are_rejected() {
        for name in [
            "plan.toml",
            "seg-xyz-s00001-w0000.seg",
            "seg-00ff00ff00ff00ff-s1-w0.bin",
            "ovf-00ff00ff00ff00ff-s00001.ovf",
            "magquilt-tmp-12-00ff00ff00ff00ff-0-seg.part",
            "seg-00ff00ff00ff00ff-s00001-w0000-extra.seg",
        ] {
            assert!(parse_segment_file_name(name).is_none(), "{name}");
        }
        for name in [
            "done-xyz-w0000.ok",
            "done-00ff00ff00ff00ff-0.ok",
            "done-00ff00ff00ff00ff-w0000.beat",
            "hb-00ff00ff00ff00ff-w0000.ok",
            "trc-00ff00ff00ff00ff-w0000.jsonl",
            "trc-xyz-w0000.trace.jsonl",
            "rpt-00ff00ff00ff00ff-0.report.json",
            "rpt-00ff00ff00ff00ff-w0000.json",
            "quarantine",
        ] {
            assert!(parse_meta_file_name(name).is_none(), "{name}");
        }
    }

    #[test]
    fn marker_roundtrips_and_rejects_garbage() {
        let dir = std::env::temp_dir().join("magquilt_marker_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let hash = "0123456789abcdef";
        let summary = SegmentSummary {
            owned_segments: 4,
            owned_edges: 1234,
            overflow_files: 2,
            overflow_edges: 99,
        };
        write_marker(&dir, hash, 3, &summary).unwrap();
        let text = std::fs::read_to_string(dir.join(marker_file_name(hash, 3))).unwrap();
        let (h, w, s) = parse_marker(&text).unwrap();
        assert_eq!((h.as_str(), w), (hash, 3));
        assert_eq!(s, summary);
        assert!(parse_marker("").is_none());
        assert!(parse_marker("format = wrong\nplan = x\n").is_none());
        assert!(parse_marker(&text.replace("owned_edges = 1234", "owned_edges = ten")).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segment_sink_routes_owned_and_overflow() {
        let dir = std::env::temp_dir().join("magquilt_segment_sink_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let hash = "0123456789abcdef".to_string();
        let mut sink = SegmentSink::new(&dir, hash.clone(), 1, (1, 3), 4);
        sink.begin(16, 4).unwrap();
        // Foreign empty: no file. Foreign non-empty: overflow file.
        sink.accept_shard(0, Vec::new()).unwrap();
        sink.accept_shard(3, vec![(12, 0), (13, 5)]).unwrap();
        // Owned shards always get a segment, even empty.
        sink.accept_shard(1, vec![(4, 4)]).unwrap();
        sink.accept_shard(2, Vec::new()).unwrap();
        let summary = sink.finalize().unwrap();
        assert_eq!(summary.owned_segments, 2);
        assert_eq!(summary.owned_edges, 1);
        assert_eq!(summary.overflow_files, 1);
        assert_eq!(summary.overflow_edges, 2);
        assert!(dir.join(segment_file_name(&hash, 1, 1)).exists());
        assert!(dir.join(segment_file_name(&hash, 2, 1)).exists());
        assert!(dir.join(overflow_file_name(&hash, 3, 1)).exists());
        assert!(!dir.join(overflow_file_name(&hash, 0, 1)).exists());
        // Segments are complete, individually valid MAGQEDG1 files.
        let seg = crate::graph::read_edge_list_binary(&dir.join(segment_file_name(&hash, 1, 1)))
            .unwrap();
        assert_eq!(seg.num_nodes(), 16);
        assert_eq!(seg.edges(), &[(4, 4)]);
        // No temp files left behind.
        let leftovers = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().starts_with("magquilt-tmp-")
            })
            .count();
        assert_eq!(leftovers, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segment_sink_missing_owned_shard_fails_finalize() {
        let dir = std::env::temp_dir().join("magquilt_segment_sink_missing");
        std::fs::create_dir_all(&dir).unwrap();
        let mut sink = SegmentSink::new(&dir, "0123456789abcdef".into(), 0, (0, 2), 2);
        sink.begin(8, 2).unwrap();
        sink.accept_shard(0, vec![(0, 1)]).unwrap();
        // Shard 1 never delivered: the summary must not pretend success.
        assert!(sink.finalize().is_err());
    }

    #[test]
    fn rewriting_an_identical_segment_is_success_and_mismatch_is_corruption() {
        let dir = std::env::temp_dir().join("magquilt_segment_sink_idempotent");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let hash = "0123456789abcdef".to_string();
        let run: Vec<Edge> = vec![(0, 3), (1, 1)];
        let write = |run: &[Edge]| -> io::Result<SegmentSummary> {
            let mut sink = SegmentSink::new(&dir, hash.clone(), 0, (0, 1), 2);
            sink.begin(8, 2).unwrap();
            sink.accept_shard(0, run.to_vec())?;
            sink.accept_shard(1, Vec::new())?;
            sink.finalize()
        };
        write(&run).unwrap();
        let path = dir.join(segment_file_name(&hash, 0, 0));
        let bytes = std::fs::read(&path).unwrap();
        // Identical rewrite: success, file untouched, no temps.
        write(&run).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), bytes);
        // Different bytes under the same name: hard error.
        let err = write(&[(0, 5)]).unwrap_err();
        assert!(err.to_string().contains("different contents"), "{err}");
        assert_eq!(std::fs::read(&path).unwrap(), bytes, "existing file untouched");
        let leftovers = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().starts_with("magquilt-tmp-")
            })
            .count();
        assert_eq!(leftovers, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn satisfied_shards_are_counted_not_rewritten() {
        let dir = std::env::temp_dir().join("magquilt_segment_sink_satisfied");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let hash = "0123456789abcdef".to_string();
        let mut satisfied = BTreeMap::new();
        satisfied.insert(0usize, 7u64);
        let mut sink =
            SegmentSink::new(&dir, hash.clone(), 0, (0, 2), 2).with_resume(satisfied.clone());
        sink.begin(8, 2).unwrap();
        sink.accept_shard(0, Vec::new()).unwrap();
        sink.accept_shard(1, vec![(4, 0)]).unwrap();
        let summary = sink.finalize().unwrap();
        assert_eq!(summary.owned_segments, 2);
        assert_eq!(summary.owned_edges, 8, "7 resumed + 1 fresh");
        // The satisfied shard's file was never touched (it doesn't even
        // exist here — the sink trusts the resume scan).
        assert!(!dir.join(segment_file_name(&hash, 0, 0)).exists());
        // A non-empty delivery to a satisfied shard is a hard error.
        let mut sink = SegmentSink::new(&dir, hash, 0, (0, 2), 2).with_resume(satisfied);
        sink.begin(8, 2).unwrap();
        let err = sink.accept_shard(0, vec![(0, 1)]).unwrap_err();
        assert!(err.to_string().contains("marked satisfied"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn components_skip_only_fully_owned_valid_spans() {
        // 6 shards, worker owns [0, 3). Jobs: A spans 0..=1 (owned),
        // B spans 2..=4 (crosses into foreign shards), C spans 5..=5
        // (foreign), D has no span.
        let spans = vec![Some((0, 1)), Some((2, 4)), Some((5, 5)), None];
        let mut valid = BTreeMap::new();
        for s in 0..3usize {
            valid.insert(s, 10 + s as u64);
        }
        let (skip, satisfied) = satisfied_components(6, (0, 3), &spans, &valid);
        // A's component {0,1} is fully owned+valid → skipped.
        // B touches shards 3,4 (foreign) → runs. C foreign → runs.
        // D span-less → runs.
        assert_eq!(skip, vec![true, false, false, false]);
        // Shards 0,1 satisfied; shard 2 sits in B's component → re-run.
        assert_eq!(
            satisfied.into_iter().collect::<Vec<_>>(),
            vec![(0, 10), (1, 11)]
        );

        // Same topology but shard 1's segment is missing: A must re-run.
        valid.remove(&1);
        let (skip, satisfied) = satisfied_components(6, (0, 3), &spans, &valid);
        assert_eq!(skip, vec![false, false, false, false]);
        assert!(satisfied.is_empty());
    }

    #[test]
    fn worker_with_artifact_skips_setup_and_matches_fresh() {
        use crate::config::{ModelSpec, RunSpec};
        let mut model = ModelSpec::default_spec();
        model.log2_nodes = 8;
        model.attributes = 8;
        let mut run = RunSpec::default_spec();
        run.shards = 4;
        run.seed = 21;
        let plan = ShardPlan::new(&model, &run, 2).unwrap();
        let base = std::env::temp_dir().join("magquilt_worker_artifact_test");
        let _ = std::fs::remove_dir_all(&base);

        // Build + save the shared artifact the way `magquilt setup` does.
        let art = build_plan_artifact(&plan).unwrap();
        let art_path =
            base.join("cache").join(crate::setup::artifact_file_name(&art.hash_hex()));
        art.save(&art_path).unwrap();

        let fresh_dir = base.join("fresh");
        let art_dir = base.join("hydrated");
        let opts =
            WorkerOptions { artifact: Some(art_path.clone()), ..WorkerOptions::default() };
        for w in 0..2 {
            let fresh = run_worker(&plan, w, &fresh_dir).unwrap();
            let rep = run_worker_with(&plan, w, &art_dir, &opts).unwrap();
            assert_eq!(rep.summary, fresh.summary, "worker {w}");
            // The artifact path skipped the setup pipeline and says so.
            assert_eq!(rep.stats.setup.artifact_hash, art.hash64());
            assert_eq!(rep.stats.setup.partition_ms, 0.0);
            assert_eq!(rep.stats.setup.dag_ms, 0.0);
            assert!(rep.stats.setup.artifact_load_ms > 0.0);
            assert_eq!(fresh.stats.setup.artifact_hash, 0);
        }
        // Every segment file is byte-identical between the two runs.
        for entry in std::fs::read_dir(&fresh_dir).unwrap() {
            let name = entry.unwrap().file_name();
            let a = std::fs::read(fresh_dir.join(&name)).unwrap();
            let b = std::fs::read(art_dir.join(&name)).unwrap();
            assert_eq!(a, b, "{name:?}");
        }
        // An artifact stored inside the segment directory is skipped by
        // the resume scan (which bails on unrecognized names).
        art.save(&art_dir.join("setup-cache.art")).unwrap();
        let opts_resume = WorkerOptions {
            artifact: Some(art_path),
            resume: true,
            ..WorkerOptions::default()
        };
        let rep = run_worker_with(&plan, 0, &art_dir, &opts_resume).unwrap();
        assert_eq!(rep.jobs_run, 0, "marker fast path after a completed run");
        // An artifact from a different plan is refused before sampling.
        run.seed = 22;
        let other = ShardPlan::new(&model, &run, 2).unwrap();
        let err = run_worker_with(&other, 0, &base.join("x"), &opts_resume).unwrap_err();
        assert!(format!("{err:#}").contains("does not match"), "{err:#}");
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn resume_scan_classifies_markers_and_rejects_foreign_files() {
        use crate::config::{ModelSpec, RunSpec};
        let mut model = ModelSpec::default_spec();
        model.log2_nodes = 4;
        model.attributes = 4;
        let mut run = RunSpec::default_spec();
        run.shards = 4;
        let plan = ShardPlan::new(&model, &run, 2).unwrap();
        let hash = plan.hash_hex();
        let dir = std::env::temp_dir().join("magquilt_resume_scan_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        // Missing directory → empty state.
        let state = scan_resume_state(&dir.join("nope"), &plan, 0).unwrap();
        assert!(state.valid_segments.is_empty() && state.marker.is_none());

        // Worker 0 owns shards {0, 1}. One valid owned segment, one
        // foreign-worker file (ignored), temps and heartbeats (ignored).
        let g = crate::graph::EdgeList::from_edges(16, vec![(0, 1), (3, 2)]);
        crate::graph::write_edge_list_binary(&g, &dir.join(segment_file_name(&hash, 0, 0)))
            .unwrap();
        crate::graph::write_edge_list_binary(
            &crate::graph::EdgeList::from_edges(16, vec![(8, 0)]),
            &dir.join(segment_file_name(&hash, 2, 1)),
        )
        .unwrap();
        std::fs::write(dir.join("magquilt-tmp-1-x-0-seg.part"), "junk").unwrap();
        std::fs::write(dir.join(heartbeat_file_name(&hash, 0)), "").unwrap();
        let state = scan_resume_state(&dir, &plan, 0).unwrap();
        assert_eq!(state.valid_segments.into_iter().collect::<Vec<_>>(), vec![(0, 2)]);
        assert!(state.marker.is_none());

        // A marker whose counts don't match the disk is stale: removed.
        let summary = SegmentSummary {
            owned_segments: 2,
            owned_edges: 99,
            overflow_files: 0,
            overflow_edges: 0,
        };
        write_marker(&dir, &hash, 0, &summary).unwrap();
        let state = scan_resume_state(&dir, &plan, 0).unwrap();
        assert!(state.marker.is_none());
        assert!(!dir.join(marker_file_name(&hash, 0)).exists(), "stale marker removed");

        // Complete worker 0's output and write a consistent marker.
        crate::graph::write_edge_list_binary(
            &crate::graph::EdgeList::from_edges(16, vec![(4, 4)]),
            &dir.join(segment_file_name(&hash, 1, 0)),
        )
        .unwrap();
        let summary = SegmentSummary {
            owned_segments: 2,
            owned_edges: 3,
            overflow_files: 0,
            overflow_edges: 0,
        };
        write_marker(&dir, &hash, 0, &summary).unwrap();
        let state = scan_resume_state(&dir, &plan, 0).unwrap();
        assert_eq!(state.marker, Some(summary));
        assert_eq!(state.valid_segments.len(), 2);

        // A foreign-plan file poisons the scan.
        std::fs::write(
            dir.join(segment_file_name("deadbeefdeadbeef", 0, 0)),
            "other plan",
        )
        .unwrap();
        let err = scan_resume_state(&dir, &plan, 0).unwrap_err();
        assert!(err.to_string().contains("refusing to resume"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_telemetry_is_equivalent_and_resume_tolerates_its_files() {
        use std::sync::atomic::Ordering;

        use crate::config::{ModelSpec, RunSpec};
        let mut model = ModelSpec::default_spec();
        model.log2_nodes = 8;
        model.attributes = 8;
        let mut run = RunSpec::default_spec();
        run.shards = 4;
        run.seed = 31;
        let plan = ShardPlan::new(&model, &run, 2).unwrap();
        let hash = plan.hash_hex();
        let base = std::env::temp_dir().join("magquilt_worker_telemetry_test");
        let _ = std::fs::remove_dir_all(&base);
        let plain_dir = base.join("plain");
        let traced_dir = base.join("traced");
        let progress = Arc::new(ProgressState::new());
        let opts = WorkerOptions {
            trace: true,
            report: true,
            progress: Some(Arc::clone(&progress)),
            ..WorkerOptions::default()
        };
        for w in 0..2 {
            let plain = run_worker(&plan, w, &plain_dir).unwrap();
            let traced = run_worker_with(&plan, w, &traced_dir, &opts).unwrap();
            assert_eq!(traced.summary, plain.summary, "worker {w}");
        }
        // Every run-state file (segments, overflows, markers) is
        // byte-identical; telemetry only ever adds files.
        for entry in std::fs::read_dir(&plain_dir).unwrap() {
            let name = entry.unwrap().file_name();
            let a = std::fs::read(plain_dir.join(&name)).unwrap();
            let b = std::fs::read(traced_dir.join(&name)).unwrap();
            assert_eq!(a, b, "{name:?}");
        }
        // The shared progress counters saw both workers' slices through.
        assert!(progress.jobs_done.load(Ordering::Relaxed) > 0);
        assert_eq!(
            progress.jobs_done.load(Ordering::Relaxed),
            progress.jobs_total.load(Ordering::Relaxed)
        );
        // The trace stream carries the worker lifecycle events.
        let text =
            std::fs::read_to_string(traced_dir.join(trace_file_name(&hash, 0))).unwrap();
        assert!(text.starts_with("{\"format\":\"MAGQTRC1\""), "{text}");
        for event in ["worker_start", "run_done", "worker_done"] {
            assert!(text.contains(&format!("\"event\":\"{event}\"")), "{event}");
        }
        // The report validates as kind `worker`.
        let report =
            std::fs::read_to_string(traced_dir.join(report_file_name(&hash, 1))).unwrap();
        assert_eq!(crate::trace::report::validate_report(&report).unwrap(), "worker");
        // A resume scan tolerates the telemetry files: the marker fast
        // path still short-circuits the whole run.
        let resumed = run_worker_with(
            &plan,
            0,
            &traced_dir,
            &WorkerOptions { resume: true, ..WorkerOptions::default() },
        )
        .unwrap();
        assert_eq!(resumed.jobs_run, 0, "marker fast path with telemetry present");
        let _ = std::fs::remove_dir_all(&base);
    }
}
