//! The distributed worker: one process, one contiguous shard range.
//!
//! `magquilt shard-worker --plan plan.toml --worker i` reloads the
//! [`ShardPlan`], re-runs the full deterministic setup pipeline
//! (attributes → partition → tries → product DAG — bit-for-bit identical
//! on every host), recomputes every job's source span, keeps exactly the
//! jobs the **ownership rule** assigns to worker `i`, and executes them
//! through the ordinary pooled coordinator. The only distributed part is
//! the sink: a [`SegmentSink`] that writes each finished shard to its own
//! `MAGQEDG1` file instead of one growing output.
//!
//! # Ownership rule
//!
//! A job belongs to the worker owning the **first shard of its source
//! span** (`owner_of_shard(span.lo)`; the rare job with no source nodes
//! belongs to worker 0). Since spans are recomputed identically from the
//! plan by every process, each job is assigned to exactly one worker with
//! no coordination. The heavy jobs — small high-multiplicity attribute
//! sets — have narrow spans and land wholly inside one worker's range;
//! wide-span jobs (`D_1`, light ER blocks) necessarily sample some edges
//! whose source shard belongs to *another* worker. Those edges route to
//! this process's merger for the foreign shard as usual and emerge as an
//! **overflow segment** for that shard, which the merge step folds into
//! the owner's segment later.
//!
//! # What a worker writes into the segment directory
//!
//! * one `seg-<hash>-s<shard>-w<worker>.seg` per **owned** shard (even
//!   when empty — emptiness is information; a *missing* owner segment
//!   means an incomplete run and fails the merge), and
//! * one `ovf-<hash>-s<shard>-w<worker>.ovf` per **foreign** shard this
//!   worker sampled any edges for.
//!
//! Both are complete `MAGQEDG1` files (header + sorted deduplicated
//! records), written to a pid+nonce temp name and atomically renamed, so
//! a crashed worker can never leave a half-written file under a final
//! name — and any number of workers can share the directory.

use std::io;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::SamplerKind;
use crate::coordinator::{Coordinator, RunStats};
use crate::graph::{unique_temp_path, BinaryEdgeWriter, Edge, EdgeSink, ShardDisposition};
use crate::kpgm::Initiator;
use crate::magm::{AttributeAssignment, MagmParams};
use crate::rng::Rng;

use super::plan::ShardPlan;

/// File name of the owner segment for `shard` written by `worker`.
pub fn segment_file_name(hash_hex: &str, shard: usize, worker: usize) -> String {
    format!("seg-{hash_hex}-s{shard:05}-w{worker:04}.seg")
}

/// File name of the overflow segment for foreign `shard` written by
/// `worker`.
pub fn overflow_file_name(hash_hex: &str, shard: usize, worker: usize) -> String {
    format!("ovf-{hash_hex}-s{shard:05}-w{worker:04}.ovf")
}

/// What kind of segment a file in the segment directory holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentKind {
    /// The owner's post-merge run for a shard it owns.
    Owned,
    /// A foreign worker's edges for a shard it does not own.
    Overflow,
}

/// Parsed identity of one segment-directory file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentFileInfo {
    /// Owned segment or overflow run.
    pub kind: SegmentKind,
    /// The plan hash embedded in the name.
    pub hash_hex: String,
    /// Shard index the records belong to.
    pub shard: usize,
    /// Worker process that wrote the file.
    pub worker: usize,
}

/// Parse a segment-directory file name produced by [`segment_file_name`]
/// / [`overflow_file_name`]. Returns `None` for anything else.
pub fn parse_segment_file_name(name: &str) -> Option<SegmentFileInfo> {
    let (kind, rest) = if let Some(r) = name.strip_prefix("seg-") {
        (SegmentKind::Owned, r.strip_suffix(".seg")?)
    } else if let Some(r) = name.strip_prefix("ovf-") {
        (SegmentKind::Overflow, r.strip_suffix(".ovf")?)
    } else {
        return None;
    };
    let mut parts = rest.split('-');
    let hash = parts.next()?;
    if hash.len() != 16 || !hash.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    let shard = parts.next()?.strip_prefix('s')?.parse().ok()?;
    let worker = parts.next()?.strip_prefix('w')?.parse().ok()?;
    if parts.next().is_some() {
        return None;
    }
    Some(SegmentFileInfo { kind, hash_hex: hash.to_string(), shard, worker })
}

/// What one worker produced: the counters the driver and tests assert on.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SegmentSummary {
    /// Owned shards written as segment files (== the owned range width).
    pub owned_segments: usize,
    /// Edges across the owned segments.
    pub owned_edges: u64,
    /// Overflow files written for foreign shards.
    pub overflow_files: usize,
    /// Edges across the overflow files.
    pub overflow_edges: u64,
}

/// [`crate::graph::EdgeSink`] that lands every finished shard in its own
/// `MAGQEDG1` file: owned shards as `.seg`, non-empty foreign shards as
/// `.ovf`. Order-indifferent by construction (each shard has its own
/// file), so shards are consumed the moment they finish — no deferral, no
/// spill.
#[derive(Debug)]
pub struct SegmentSink {
    dir: PathBuf,
    hash_hex: String,
    worker: usize,
    /// Owned shard range `[start, end)`.
    owned: (usize, usize),
    num_nodes: usize,
    expected_shards: usize,
    summary: SegmentSummary,
}

impl SegmentSink {
    /// Sink for `worker` owning `owned`, writing into `dir` under the
    /// plan hash `hash_hex`; the run must deliver exactly
    /// `expected_shards` shards.
    pub fn new(
        dir: impl AsRef<Path>,
        hash_hex: String,
        worker: usize,
        owned: (usize, usize),
        expected_shards: usize,
    ) -> Self {
        SegmentSink {
            dir: dir.as_ref().to_path_buf(),
            hash_hex,
            worker,
            owned,
            num_nodes: 0,
            expected_shards,
            summary: SegmentSummary::default(),
        }
    }

    /// Write `run` as a complete `MAGQEDG1` file at `dir/name`, via a
    /// pid+nonce temp name and an atomic rename.
    fn write_segment(&self, name: &str, run: &[Edge]) -> io::Result<()> {
        let tmp = unique_temp_path(&self.dir, "seg", "part");
        let mut w = BinaryEdgeWriter::create(&tmp, self.num_nodes)?;
        w.write_edges(run)?;
        w.finalize(run.len() as u64)?;
        let result = std::fs::rename(&tmp, self.dir.join(name));
        if result.is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
        result
    }
}

impl EdgeSink for SegmentSink {
    type Output = SegmentSummary;

    fn begin(&mut self, num_nodes: usize, num_shards: usize) -> io::Result<()> {
        if num_shards != self.expected_shards {
            return Err(io::Error::other(format!(
                "coordinator resolved {num_shards} shards but the plan fixed {} — \
                 plan and run disagree",
                self.expected_shards
            )));
        }
        self.num_nodes = num_nodes;
        std::fs::create_dir_all(&self.dir)
    }

    fn accept_shard(&mut self, index: usize, run: Vec<Edge>) -> io::Result<ShardDisposition> {
        if index >= self.expected_shards {
            return Err(io::Error::other(format!("shard index {index} out of range")));
        }
        if (self.owned.0..self.owned.1).contains(&index) {
            self.write_segment(&segment_file_name(&self.hash_hex, index, self.worker), &run)?;
            self.summary.owned_segments += 1;
            self.summary.owned_edges += run.len() as u64;
        } else if !run.is_empty() {
            // A foreign shard only gets a file when a wide-span owned job
            // actually sampled edges there; an empty foreign delivery is
            // the common case and writes nothing.
            self.write_segment(&overflow_file_name(&self.hash_hex, index, self.worker), &run)?;
            self.summary.overflow_files += 1;
            self.summary.overflow_edges += run.len() as u64;
        }
        Ok(ShardDisposition::Streamed)
    }

    fn finalize(self) -> io::Result<SegmentSummary> {
        let owned_width = self.owned.1 - self.owned.0;
        if self.summary.owned_segments != owned_width {
            return Err(io::Error::other(format!(
                "worker {} wrote {} of its {owned_width} owned segments",
                self.worker, self.summary.owned_segments
            )));
        }
        Ok(self.summary)
    }
}

/// What [`run_worker`] reports back to the driver / CLI.
#[derive(Debug)]
pub struct WorkerReport {
    /// This worker's index.
    pub worker: usize,
    /// Owned shard range `[start, end)`.
    pub owned: (usize, usize),
    /// Jobs in the full plan (identical on every worker).
    pub jobs_total: usize,
    /// Jobs this worker owned and executed.
    pub jobs_run: usize,
    /// Files + edge counters of what was written.
    pub summary: SegmentSummary,
    /// The underlying coordinated-run statistics.
    pub stats: RunStats,
}

/// Model parameters for a plan's model spec.
pub fn plan_params(plan: &ShardPlan) -> MagmParams {
    MagmParams::homogeneous(
        Initiator::new(plan.model.theta),
        plan.model.mu,
        plan.model.num_nodes(),
        plan.model.attributes,
    )
}

/// Setup-thread count for attribute sampling (wall-clock only — chunked
/// draws are bit-for-bit identical for any count).
fn resolved_threads(plan: &ShardPlan) -> usize {
    if plan.setup_threads != 0 {
        plan.setup_threads
    } else if plan.workers != 0 {
        plan.workers
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(16)
    }
}

/// Build the full (unfiltered) deterministic job plan every worker
/// derives from `plan` — the shared object the ownership rule partitions.
pub fn build_job_plan(
    plan: &ShardPlan,
    coord: &Coordinator,
) -> (crate::coordinator::JobPlan, AttributeAssignment) {
    let params = plan_params(plan);
    let mut rng = Rng::new(plan.seed);
    let attrs = AttributeAssignment::sample_with_mode(
        &params,
        &mut rng,
        plan.attr_mode,
        resolved_threads(plan),
    );
    let job_plan = match plan.sampler {
        SamplerKind::Hybrid => coord.plan_hybrid(&params, &attrs, plan.seed),
        _ => coord.plan_quilt(&params, &attrs, plan.seed),
    };
    (job_plan, attrs)
}

/// The owner worker of every job in `job_plan` under `plan`'s ownership
/// rule: the worker owning the first shard of the job's source span (a
/// job with no source nodes emits nothing and belongs to worker 0).
pub fn job_owners(plan: &ShardPlan, job_plan: &crate::coordinator::JobPlan) -> Vec<usize> {
    let spec = plan.shard_spec();
    job_plan
        .job_source_spans(&spec)
        .into_iter()
        .map(|span| span.map(|(lo, _)| plan.owner_of_shard(lo)).unwrap_or(0))
        .collect()
}

/// A coordinator configured exactly as `plan` prescribes.
pub fn plan_coordinator(plan: &ShardPlan) -> Coordinator {
    Coordinator::new()
        .workers(plan.workers)
        .shards(plan.num_shards)
        .setup_threads(plan.setup_threads)
        .attr_mode(plan.attr_mode)
        .piece_mode(plan.piece_mode)
}

/// Execute worker `worker`'s slice of `plan`, writing segment and
/// overflow files into `segment_dir`. The whole deterministic prologue
/// runs here (identically on every host); only the owned jobs sample.
pub fn run_worker(plan: &ShardPlan, worker: usize, segment_dir: &Path) -> Result<WorkerReport> {
    plan.validate()?;
    let owned = plan.worker_range(worker)?;
    let coord = plan_coordinator(plan);
    let (mut job_plan, _attrs) = build_job_plan(plan, &coord);
    let owners = job_owners(plan, &job_plan);
    let jobs_total = job_plan.len();
    job_plan.retain_jobs(|i| owners[i] == worker);
    let jobs_run = job_plan.len();
    let sink = SegmentSink::new(
        segment_dir,
        plan.hash_hex(),
        worker,
        owned,
        plan.num_shards,
    );
    let (summary, stats) = coord
        .run_with_sink(job_plan, sink)
        .with_context(|| format!("worker {worker} sampling its job slice"))?;
    if stats.num_shards != plan.num_shards {
        bail!(
            "worker {worker} ran with {} shards but the plan fixed {}",
            stats.num_shards,
            plan.num_shards
        );
    }
    Ok(WorkerReport { worker, owned, jobs_total, jobs_run, summary, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_names_roundtrip() {
        let hash = "00ff00ff00ff00ff";
        let seg = segment_file_name(hash, 3, 1);
        assert_eq!(seg, "seg-00ff00ff00ff00ff-s00003-w0001.seg");
        let info = parse_segment_file_name(&seg).unwrap();
        assert_eq!(info.kind, SegmentKind::Owned);
        assert_eq!((info.shard, info.worker), (3, 1));
        assert_eq!(info.hash_hex, hash);
        let ovf = overflow_file_name(hash, 250, 0);
        let info = parse_segment_file_name(&ovf).unwrap();
        assert_eq!(info.kind, SegmentKind::Overflow);
        assert_eq!((info.shard, info.worker), (250, 0));
    }

    #[test]
    fn foreign_names_are_rejected() {
        for name in [
            "plan.toml",
            "seg-xyz-s00001-w0000.seg",
            "seg-00ff00ff00ff00ff-s1-w0.bin",
            "ovf-00ff00ff00ff00ff-s00001.ovf",
            "magquilt-tmp-12-00ff00ff00ff00ff-0-seg.part",
            "seg-00ff00ff00ff00ff-s00001-w0000-extra.seg",
        ] {
            assert!(parse_segment_file_name(name).is_none(), "{name}");
        }
    }

    #[test]
    fn segment_sink_routes_owned_and_overflow() {
        let dir = std::env::temp_dir().join("magquilt_segment_sink_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let hash = "0123456789abcdef".to_string();
        let mut sink = SegmentSink::new(&dir, hash.clone(), 1, (1, 3), 4);
        sink.begin(16, 4).unwrap();
        // Foreign empty: no file. Foreign non-empty: overflow file.
        sink.accept_shard(0, Vec::new()).unwrap();
        sink.accept_shard(3, vec![(12, 0), (13, 5)]).unwrap();
        // Owned shards always get a segment, even empty.
        sink.accept_shard(1, vec![(4, 4)]).unwrap();
        sink.accept_shard(2, Vec::new()).unwrap();
        let summary = sink.finalize().unwrap();
        assert_eq!(summary.owned_segments, 2);
        assert_eq!(summary.owned_edges, 1);
        assert_eq!(summary.overflow_files, 1);
        assert_eq!(summary.overflow_edges, 2);
        assert!(dir.join(segment_file_name(&hash, 1, 1)).exists());
        assert!(dir.join(segment_file_name(&hash, 2, 1)).exists());
        assert!(dir.join(overflow_file_name(&hash, 3, 1)).exists());
        assert!(!dir.join(overflow_file_name(&hash, 0, 1)).exists());
        // Segments are complete, individually valid MAGQEDG1 files.
        let seg = crate::graph::read_edge_list_binary(&dir.join(segment_file_name(&hash, 1, 1)))
            .unwrap();
        assert_eq!(seg.num_nodes(), 16);
        assert_eq!(seg.edges(), &[(4, 4)]);
        // No temp files left behind.
        let leftovers = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().starts_with("magquilt-tmp-")
            })
            .count();
        assert_eq!(leftovers, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segment_sink_missing_owned_shard_fails_finalize() {
        let dir = std::env::temp_dir().join("magquilt_segment_sink_missing");
        std::fs::create_dir_all(&dir).unwrap();
        let mut sink = SegmentSink::new(&dir, "0123456789abcdef".into(), 0, (0, 2), 2);
        sink.begin(8, 2).unwrap();
        sink.accept_shard(0, vec![(0, 1)]).unwrap();
        // Shard 1 never delivered: the summary must not pretend success.
        assert!(sink.finalize().is_err());
    }
}
