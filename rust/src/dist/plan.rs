//! The shard plan: the sealed contract every process of a distributed
//! run derives its work from.
//!
//! A [`ShardPlan`] fixes everything that determines the sampled output —
//! the model, the seed, the sampler and piece/attribute modes, the shard
//! count `S`, and the per-worker contiguous shard ranges. Workers never
//! communicate: each one reloads the plan, re-runs the (bit-for-bit
//! deterministic) setup pipeline, recomputes every job's source span, and
//! keeps exactly the jobs the ownership rule assigns to it. The plan is
//! serialized to a small TOML manifest (`plan.toml`) whose `[model]` and
//! `[run]` sections reuse the config-file schema, plus a `[plan]` section
//! carrying the shard topology and a content hash.
//!
//! # The plan hash
//!
//! [`ShardPlan::hash_hex`] digests the *output-determining* fields (model,
//! seed, sampler, piece/attr mode, shard count, worker ranges) — not the
//! wall-clock knobs (`workers`, `setup_threads`, `merge_threads`), which
//! may legitimately differ per host. Every segment file a worker writes embeds the hash in
//! its name, so the merge step can refuse to stitch segments produced
//! under different plans, and `parse` refuses a manifest whose stored
//! hash does not match its fields (a hand-edited plan must be regenerated
//! with `magquilt shard-plan`, not patched).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{parse_attr_mode, parse_piece_mode, parse_toml, ModelSpec, RunSpec,
                    SamplerKind, TomlValue};
use crate::coordinator::MAX_SHARDS;
use crate::graph::ShardSpec;
use crate::magm::AttrSampleMode;
use crate::quilt::PieceMode;

/// Manifest format version this build writes and accepts.
pub const PLAN_FORMAT: i64 = 1;

use crate::hashutil::fnv1a64;

/// Required key lookup inside one parsed manifest section.
fn required<'a>(
    sec: &'a BTreeMap<String, TomlValue>,
    section: &str,
    key: &str,
) -> Result<&'a TomlValue> {
    sec.get(key).ok_or_else(|| anyhow!("plan manifest: missing {section}.{key}"))
}

/// Required non-negative integer array inside the `[plan]` section.
fn required_index_array(
    sec: &BTreeMap<String, TomlValue>,
    key: &str,
) -> Result<Vec<usize>> {
    match required(sec, "plan", key)? {
        TomlValue::Array(xs) => xs
            .iter()
            .map(|x| {
                x.as_int()
                    .filter(|&v| v >= 0)
                    .map(|v| v as usize)
                    .ok_or_else(|| anyhow!("plan.{key} must hold non-negative integers"))
            })
            .collect(),
        _ => bail!("plan.{key} must be an array"),
    }
}

/// The distributed run contract. See the [module docs](self).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPlan {
    /// The MAGM model every worker samples from.
    pub model: ModelSpec,
    /// RNG seed (workers derive the same per-job forks the sequential
    /// samplers use).
    pub seed: u64,
    /// Sampler (distributed mode supports the coordinated samplers:
    /// quilt and hybrid).
    pub sampler: SamplerKind,
    /// How quilt pieces place balls.
    pub piece_mode: PieceMode,
    /// How the attribute assignment consumes randomness. Recorded
    /// explicitly — resolved at plan time — so every worker draws the
    /// identical assignment. Distributed plans default to
    /// [`AttrSampleMode::Chunked`]: there are no legacy goldens to
    /// protect, and chunked is what lets every worker's setup pipeline
    /// parallelize.
    pub attr_mode: AttrSampleMode,
    /// Worker threads per process (0 = auto per host; wall-clock only).
    pub workers: usize,
    /// Setup-pipeline threads per process (0 = auto; wall-clock only).
    pub setup_threads: usize,
    /// Merge worker threads for `merge-segments` (0 = auto; wall-clock
    /// only — the merged file is byte-identical for any count).
    pub merge_threads: usize,
    /// Supervised restart budget per worker process (driver only;
    /// robustness knob — restarts resume, so output bytes never move).
    pub worker_retries: usize,
    /// Base backoff in milliseconds between supervised restarts (doubles
    /// per retry, capped; wall-clock only).
    pub worker_backoff_ms: u64,
    /// Effective shard count S (already clamped to the merger cap and
    /// the node count, so every process agrees without re-clamping).
    pub num_shards: usize,
    /// Per-worker contiguous shard ranges `[start, end)`, ascending and
    /// partitioning `0..num_shards`. Worker `w` owns `ranges[w]`.
    pub ranges: Vec<(usize, usize)>,
}

/// [`ShardPlan`] fields deliberately left OUT of the content hash: per-host
/// wall-clock knobs that never change a byte of output, so two hosts
/// running the same plan with different thread counts still agree on the
/// segment-file hash tag.
///
/// maglint's plan-hash tripwire parses this list and `canonical()` and
/// requires every `ShardPlan` field to appear in exactly one of them —
/// adding a field without deciding its hash fate fails
/// `cargo run --bin maglint` (and the crate's self-lint test).
pub const HASH_EXEMPT: &[&str] =
    &["workers", "setup_threads", "merge_threads", "worker_retries", "worker_backoff_ms"];

/// [`crate::config::RunSpec`] fields whose values flow into the plan's
/// hashed (output-determining) fields via [`ShardPlan::new`].
pub const RUNSPEC_HASHED: &[&str] =
    &["seed", "shards", "attr_mode", "sampler", "piece_mode", "dist_workers"];

/// [`crate::config::RunSpec`] fields that never influence output bytes:
/// per-host parallelism knobs, output/scratch locations, and the
/// experiment repeat count. maglint requires every `RunSpec` field to
/// appear in exactly one of [`RUNSPEC_HASHED`] / this list.
pub const RUNSPEC_EXEMPT: &[&str] = &[
    "workers",
    "setup_threads",
    "merge_threads",
    "output",
    "spill_dir",
    "spill_budget",
    "segment_dir",
    "worker_retries",
    "worker_backoff_ms",
    "trials",
    "artifact",
    "trace",
    "report",
];

/// Compile-time companion to the fate lists: exhaustively destructures
/// (no `..`) the plan, model, and run structs, so adding a field without
/// visiting this function — and the lists above — fails the build even
/// before the lint runs.
#[allow(dead_code)]
fn hash_disposition_witness(plan: &ShardPlan, run: &RunSpec) {
    let ShardPlan {
        model: ModelSpec { theta: _, mu: _, log2_nodes: _, attributes: _ }, // hashed
        seed: _,          // hashed via canonical()
        sampler: _,       // hashed
        piece_mode: _,    // hashed
        attr_mode: _,     // hashed
        workers: _,           // HASH_EXEMPT
        setup_threads: _,     // HASH_EXEMPT
        merge_threads: _,     // HASH_EXEMPT
        worker_retries: _,    // HASH_EXEMPT
        worker_backoff_ms: _, // HASH_EXEMPT
        num_shards: _,        // hashed
        ranges: _,            // hashed
    } = plan;
    let RunSpec {
        seed: _,          // RUNSPEC_HASHED
        workers: _,       // RUNSPEC_EXEMPT
        shards: _,        // RUNSPEC_HASHED (clamped into num_shards)
        setup_threads: _, // RUNSPEC_EXEMPT
        attr_mode: _,     // RUNSPEC_HASHED (resolved into plan.attr_mode)
        sampler: _,       // RUNSPEC_HASHED
        piece_mode: _,    // RUNSPEC_HASHED
        output: _,        // RUNSPEC_EXEMPT
        spill_dir: _,     // RUNSPEC_EXEMPT
        spill_budget: _,  // RUNSPEC_EXEMPT
        dist_workers: _,      // RUNSPEC_HASHED (shapes num_shards and ranges)
        segment_dir: _,       // RUNSPEC_EXEMPT
        merge_threads: _,     // RUNSPEC_EXEMPT
        worker_retries: _,    // RUNSPEC_EXEMPT
        worker_backoff_ms: _, // RUNSPEC_EXEMPT
        trials: _,            // RUNSPEC_EXEMPT
        artifact: _,          // RUNSPEC_EXEMPT (a cache location; the artifact's own
                              // identity hash covers the output-determining fields)
        trace: _,             // RUNSPEC_EXEMPT (write-only telemetry path; the
                              // trace-sink lint keeps it out of output state)
        report: _,            // RUNSPEC_EXEMPT (write-only report path, ditto)
    } = run;
}

impl ShardPlan {
    /// Build a plan from a model + run spec for `dist_workers` processes.
    ///
    /// Shard count: `run.shards` if set, else `4 × dist_workers` (a few
    /// shards per worker keeps the merge parallel and the segment files
    /// conveniently sized) — clamped to the merger cap and the node
    /// count. The worker count is then clamped to the shard count (a
    /// worker owning zero shards would own zero jobs).
    pub fn new(model: &ModelSpec, run: &RunSpec, dist_workers: usize) -> Result<ShardPlan> {
        model.validate()?;
        match run.sampler {
            SamplerKind::Quilt | SamplerKind::Hybrid => {}
            other => bail!(
                "distributed sampling needs the quilt or hybrid sampler, not {}",
                other.name()
            ),
        }
        if dist_workers == 0 {
            bail!("a distributed plan needs at least 1 worker");
        }
        let n = model.num_nodes();
        let requested = if run.shards == 0 { dist_workers.saturating_mul(4) } else { run.shards };
        let num_shards = requested.min(MAX_SHARDS).min(n).max(1);
        // Clamps are surfaced, never silent — the same invariant the
        // single-process run_with_sink maintains (PR 4). Workers see the
        // pre-clamped count, so their own warning can never fire.
        if run.shards > MAX_SHARDS {
            eprintln!(
                "warning: {requested} shards requested but the merger cap is {MAX_SHARDS}; \
                 planning {num_shards}"
            );
        } else if run.shards != 0 && num_shards < requested {
            eprintln!(
                "warning: {requested} shards requested for {n} nodes; planning {num_shards} \
                 (shards beyond the node count would stay empty)"
            );
        }
        let w = dist_workers.min(num_shards);
        if w < dist_workers {
            eprintln!(
                "warning: {dist_workers} workers requested for {num_shards} shard(s); \
                 planning {w} (a worker must own at least one shard)"
            );
        }
        // Balanced contiguous ranges: worker w owns [wS/W, (w+1)S/W).
        let ranges: Vec<(usize, usize)> = (0..w)
            .map(|i| (i * num_shards / w, (i + 1) * num_shards / w))
            .collect();
        Ok(ShardPlan {
            model: model.clone(),
            seed: run.seed,
            sampler: run.sampler,
            piece_mode: run.piece_mode,
            attr_mode: run.attr_mode.unwrap_or(AttrSampleMode::Chunked),
            workers: run.workers,
            setup_threads: run.setup_threads,
            merge_threads: run.merge_threads,
            worker_retries: run.worker_retries,
            worker_backoff_ms: run.worker_backoff_ms,
            num_shards,
            ranges,
        })
    }

    /// Number of worker processes.
    pub fn num_workers(&self) -> usize {
        self.ranges.len()
    }

    /// The shard range `[start, end)` worker `w` owns.
    pub fn worker_range(&self, w: usize) -> Result<(usize, usize)> {
        self.ranges.get(w).copied().ok_or_else(|| {
            anyhow!("worker index {w} out of range for {} workers", self.num_workers())
        })
    }

    /// The worker owning shard `s`. Ranges are contiguous and ascending,
    /// so this is a binary search.
    pub fn owner_of_shard(&self, s: usize) -> usize {
        debug_assert!(s < self.num_shards, "shard {s} out of range");
        match self.ranges.binary_search_by(|&(start, _)| start.cmp(&s)) {
            Ok(w) => w,
            Err(w) => w - 1,
        }
    }

    /// The source-range spec every process routes with. `num_shards` is
    /// pre-clamped, so this reconstructs identically everywhere.
    pub fn shard_spec(&self) -> ShardSpec {
        ShardSpec::new(self.model.num_nodes(), self.num_shards)
    }

    /// Canonical byte string of the output-determining fields.
    fn canonical(&self) -> String {
        format!(
            "magquilt-plan-v{PLAN_FORMAT}|theta={:?}|mu={:?}|log2_nodes={}|attributes={}\
             |seed={}|sampler={}|piece_mode={}|attr_mode={}|shards={}|ranges={:?}",
            self.model.theta,
            self.model.mu,
            self.model.log2_nodes,
            self.model.attributes,
            self.seed,
            self.sampler.name(),
            self.piece_mode.name(),
            self.attr_mode.name(),
            self.num_shards,
            self.ranges,
        )
    }

    /// 64-bit content hash of the output-determining fields.
    pub fn hash64(&self) -> u64 {
        fnv1a64(self.canonical().as_bytes())
    }

    /// The hash as the 16-hex-digit tag embedded in segment file names.
    pub fn hash_hex(&self) -> String {
        format!("{:016x}", self.hash64())
    }

    /// Serialize to the plan manifest (TOML subset, self-describing).
    pub fn to_toml(&self) -> String {
        let starts: Vec<String> = self.ranges.iter().map(|r| r.0.to_string()).collect();
        let ends: Vec<String> = self.ranges.iter().map(|r| r.1.to_string()).collect();
        format!(
            "# magquilt distributed shard plan (generated by `magquilt shard-plan`;\n\
             # the hash seals the output-determining fields — regenerate, don't edit)\n\
             \n\
             [plan]\n\
             format = {PLAN_FORMAT}\n\
             hash = \"{hash}\"\n\
             shards = {shards}\n\
             shard_starts = [{starts}]\n\
             shard_ends = [{ends}]\n\
             \n\
             [model]\n\
             theta = [{t0:?}, {t1:?}, {t2:?}, {t3:?}]\n\
             mu = {mu:?}\n\
             log2_nodes = {log2n}\n\
             attributes = {attrs}\n\
             \n\
             [run]\n\
             seed = {seed}\n\
             sampler = \"{sampler}\"\n\
             piece_mode = \"{piece}\"\n\
             attr_mode = \"{attr}\"\n\
             workers = {workers}\n\
             setup_threads = {setup}\n\
             merge_threads = {merge}\n\
             worker_retries = {retries}\n\
             worker_backoff_ms = {backoff}\n",
            hash = self.hash_hex(),
            shards = self.num_shards,
            starts = starts.join(", "),
            ends = ends.join(", "),
            t0 = self.model.theta[0],
            t1 = self.model.theta[1],
            t2 = self.model.theta[2],
            t3 = self.model.theta[3],
            mu = self.model.mu,
            log2n = self.model.log2_nodes,
            attrs = self.model.attributes,
            seed = self.seed,
            sampler = self.sampler.name(),
            piece = self.piece_mode.name(),
            attr = self.attr_mode.name(),
            workers = self.workers,
            setup = self.setup_threads,
            merge = self.merge_threads,
            retries = self.worker_retries,
            backoff = self.worker_backoff_ms,
        )
    }

    /// Parse a plan manifest, validating structure and the sealed hash.
    pub fn parse(text: &str) -> Result<ShardPlan> {
        let map = parse_toml(text)?;
        let plan_sec = map.get("plan").ok_or_else(|| anyhow!("plan manifest: missing [plan]"))?;
        let format = required(plan_sec, "plan", "format")?
            .as_int()
            .ok_or_else(|| anyhow!("plan.format must be an integer"))?;
        if format != PLAN_FORMAT {
            bail!("plan format {format} not supported (this build reads format {PLAN_FORMAT})");
        }
        let stored_hash = required(plan_sec, "plan", "hash")?
            .as_str()
            .ok_or_else(|| anyhow!("plan.hash must be a string"))?
            .to_string();
        let num_shards = required(plan_sec, "plan", "shards")?
            .as_int()
            .ok_or_else(|| anyhow!("plan.shards must be an integer"))? as usize;
        let starts = required_index_array(plan_sec, "shard_starts")?;
        let ends = required_index_array(plan_sec, "shard_ends")?;
        if starts.len() != ends.len() || starts.is_empty() {
            bail!(
                "plan worker ranges malformed: {} starts vs {} ends",
                starts.len(),
                ends.len()
            );
        }
        let ranges: Vec<(usize, usize)> = starts.into_iter().zip(ends).collect();

        let model = ModelSpec::from_section(map.get("model"))?;
        let run_sec =
            map.get("run").ok_or_else(|| anyhow!("plan manifest: missing [run]"))?;
        let seed = required(run_sec, "run", "seed")?
            .as_int()
            .ok_or_else(|| anyhow!("run.seed must be an integer"))? as u64;
        let sampler = SamplerKind::parse(
            required(run_sec, "run", "sampler")?
                .as_str()
                .ok_or_else(|| anyhow!("run.sampler must be a string"))?,
        )?;
        let piece_mode = parse_piece_mode(
            required(run_sec, "run", "piece_mode")?
                .as_str()
                .ok_or_else(|| anyhow!("run.piece_mode must be a string"))?,
        )?;
        let attr_mode = parse_attr_mode(
            required(run_sec, "run", "attr_mode")?
                .as_str()
                .ok_or_else(|| anyhow!("run.attr_mode must be a string"))?,
        )?;
        // Per-host knobs are hash-exempt (editing them is the supported
        // way to tune a host), so they must be validated on their own: a
        // negative value would wrap to ~2^64 threads.
        let workers = required(run_sec, "run", "workers")?
            .as_int()
            .filter(|&v| v >= 0)
            .ok_or_else(|| anyhow!("run.workers must be a non-negative integer"))?
            as usize;
        let setup_threads = required(run_sec, "run", "setup_threads")?
            .as_int()
            .filter(|&v| v >= 0)
            .ok_or_else(|| anyhow!("run.setup_threads must be a non-negative integer"))?
            as usize;
        // Optional (manifests written before the parallel merge lack it):
        // another hash-exempt per-host knob, defaulting to 0 = auto.
        let merge_threads = match run_sec.get("merge_threads") {
            None => 0,
            Some(v) => v
                .as_int()
                .filter(|&x| x >= 0)
                .ok_or_else(|| anyhow!("run.merge_threads must be a non-negative integer"))?
                as usize,
        };
        // Optional too (pre-supervision manifests lack them): hash-exempt
        // robustness knobs, defaulting to the RunSpec defaults.
        let worker_retries = match run_sec.get("worker_retries") {
            None => 2,
            Some(v) => v
                .as_int()
                .filter(|&x| x >= 0)
                .ok_or_else(|| anyhow!("run.worker_retries must be a non-negative integer"))?
                as usize,
        };
        let worker_backoff_ms = match run_sec.get("worker_backoff_ms") {
            None => 500,
            Some(v) => v
                .as_int()
                .filter(|&x| x >= 0)
                .ok_or_else(|| anyhow!("run.worker_backoff_ms must be a non-negative integer"))?
                as u64,
        };

        let plan = ShardPlan {
            model,
            seed,
            sampler,
            piece_mode,
            attr_mode,
            workers,
            setup_threads,
            merge_threads,
            worker_retries,
            worker_backoff_ms,
            num_shards,
            ranges,
        };
        plan.validate()?;
        if plan.hash_hex() != stored_hash {
            bail!(
                "plan hash mismatch: manifest says {stored_hash} but the fields hash to {} \
                 (edited by hand? regenerate with `magquilt shard-plan`)",
                plan.hash_hex()
            );
        }
        Ok(plan)
    }

    /// Structural validation (ranges partition `0..S`, sampler legal).
    pub fn validate(&self) -> Result<()> {
        self.model.validate()?;
        match self.sampler {
            SamplerKind::Quilt | SamplerKind::Hybrid => {}
            other => bail!("distributed plan carries unsupported sampler {}", other.name()),
        }
        if self.num_shards == 0 || self.num_shards > MAX_SHARDS {
            bail!("plan shard count {} outside [1, {MAX_SHARDS}]", self.num_shards);
        }
        if self.num_shards > self.model.num_nodes() {
            bail!(
                "plan has {} shards for {} nodes (shards beyond the node count stay empty)",
                self.num_shards,
                self.model.num_nodes()
            );
        }
        let mut expect = 0usize;
        for (w, &(start, end)) in self.ranges.iter().enumerate() {
            if start != expect || end < start {
                bail!(
                    "worker {w} range [{start}, {end}) does not continue the partition at {expect}"
                );
            }
            expect = end;
        }
        if expect != self.num_shards {
            bail!(
                "worker ranges cover {expect} shards but the plan has {}",
                self.num_shards
            );
        }
        Ok(())
    }

    /// Write the manifest to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_toml())
            .with_context(|| format!("writing plan manifest {}", path.display()))
    }

    /// Load and validate a manifest from `path`.
    pub fn load(path: &Path) -> Result<ShardPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan manifest {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing plan manifest {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(log2n: u32) -> ModelSpec {
        let mut m = ModelSpec::default_spec();
        m.log2_nodes = log2n;
        m.attributes = log2n;
        m
    }

    #[test]
    fn plan_roundtrips_through_toml() {
        let mut run = RunSpec::default_spec();
        run.seed = 17;
        run.shards = 6;
        run.sampler = SamplerKind::Hybrid;
        run.piece_mode = PieceMode::Rejection;
        let plan = ShardPlan::new(&model(9), &run, 4).unwrap();
        let text = plan.to_toml();
        let back = ShardPlan::parse(&text).unwrap();
        assert_eq!(back, plan, "parse(to_toml(plan)) must be the identical plan");
        assert_eq!(back.hash_hex(), plan.hash_hex());
    }

    #[test]
    fn plan_defaults_to_chunked_attrs() {
        // Dist mode has no legacy goldens to protect: unset attr_mode
        // resolves to chunked so every worker's setup pipeline
        // parallelizes. An explicit choice is honored and recorded.
        let run = RunSpec::default_spec();
        assert_eq!(run.attr_mode, None);
        let plan = ShardPlan::new(&model(8), &run, 2).unwrap();
        assert_eq!(plan.attr_mode, AttrSampleMode::Chunked);
        let mut run = RunSpec::default_spec();
        run.attr_mode = Some(AttrSampleMode::Sequential);
        let plan = ShardPlan::new(&model(8), &run, 2).unwrap();
        assert_eq!(plan.attr_mode, AttrSampleMode::Sequential);
        // And the manifest round-trips the recorded mode.
        assert_eq!(ShardPlan::parse(&plan.to_toml()).unwrap().attr_mode, plan.attr_mode);
    }

    #[test]
    fn ranges_partition_shards() {
        for (w, s) in [(1usize, 8usize), (2, 8), (3, 8), (4, 6), (8, 8)] {
            let mut run = RunSpec::default_spec();
            run.shards = s;
            let plan = ShardPlan::new(&model(10), &run, w).unwrap();
            assert_eq!(plan.num_shards, s);
            assert_eq!(plan.num_workers(), w.min(s));
            // Every shard owned by exactly the worker whose range holds it.
            for shard in 0..s {
                let owner = plan.owner_of_shard(shard);
                let (start, end) = plan.worker_range(owner).unwrap();
                assert!((start..end).contains(&shard), "shard {shard} owner {owner}");
            }
            plan.validate().unwrap();
        }
    }

    #[test]
    fn run_nonce_never_reaches_hashed_plan_fields() {
        // The spill-path run nonce (graph::run_nonce) is intentionally
        // wall-clock-derived; the plan hash must be blind to it. Drawing
        // the nonce (any number of times) must not move the hash, and the
        // canonical string is a pure function of the plan fields.
        let mut run = RunSpec::default_spec();
        run.seed = 23;
        run.shards = 4;
        let plan = ShardPlan::new(&model(9), &run, 2).unwrap();
        let before = plan.hash64();
        let n1 = crate::graph::run_nonce();
        assert_eq!(plan.hash64(), before, "drawing the nonce moved the plan hash");
        let n2 = crate::graph::run_nonce();
        assert_eq!(n1, n2, "the nonce is per-process state, stable within the process");
        let rebuilt = ShardPlan::new(&model(9), &run, 2).unwrap();
        assert_eq!(
            rebuilt.canonical(),
            plan.canonical(),
            "canonical() must be a pure function of the plan fields"
        );
        // Belt and braces: the manifest text (the full serialized surface)
        // carries no nonce-derived bytes either.
        assert_eq!(rebuilt.to_toml(), plan.to_toml());
    }

    #[test]
    fn auto_shards_scale_with_workers_and_clamp() {
        let plan = ShardPlan::new(&model(10), &RunSpec::default_spec(), 3).unwrap();
        assert_eq!(plan.num_shards, 12, "auto = 4 x dist_workers");
        // Tiny graph: shards clamp to n, workers clamp to shards.
        let plan = ShardPlan::new(&model(1), &RunSpec::default_spec(), 5).unwrap();
        assert_eq!(plan.num_shards, 2);
        assert_eq!(plan.num_workers(), 2);
    }

    #[test]
    fn hash_ignores_wall_clock_knobs_but_seals_output_fields() {
        let mut run = RunSpec::default_spec();
        run.shards = 4;
        let base = ShardPlan::new(&model(9), &run, 2).unwrap();
        // workers / setup_threads / merge_threads never change the sampled
        // output, so two plans differing only there produce
        // interchangeable segments.
        run.workers = 7;
        run.setup_threads = 3;
        run.merge_threads = 5;
        run.worker_retries = 9;
        run.worker_backoff_ms = 10;
        let same = ShardPlan::new(&model(9), &run, 2).unwrap();
        assert_eq!(base.hash_hex(), same.hash_hex());
        // The seed does change the output.
        run.seed = 43;
        let other = ShardPlan::new(&model(9), &run, 2).unwrap();
        assert_ne!(base.hash_hex(), other.hash_hex());
    }

    #[test]
    fn tampered_manifest_is_rejected() {
        let plan = ShardPlan::new(&model(8), &RunSpec::default_spec(), 2).unwrap();
        let text = plan.to_toml().replace("seed = 42", "seed = 43");
        let err = ShardPlan::parse(&text).unwrap_err();
        assert!(err.to_string().contains("hash mismatch"), "{err}");
        // Garbage and missing sections are structured errors too.
        assert!(ShardPlan::parse("[plan]\nformat = 1\n").is_err());
        assert!(ShardPlan::parse("").is_err());
        let future = plan.to_toml().replace("format = 1", "format = 99");
        assert!(ShardPlan::parse(&future).unwrap_err().to_string().contains("format"));
    }

    #[test]
    fn negative_host_knobs_are_rejected() {
        // workers/setup_threads are hash-exempt (per-host tuning is the
        // supported edit), so a negative value is caught by validation,
        // not the seal — it must not wrap into ~2^64 threads.
        let plan = ShardPlan::new(&model(8), &RunSpec::default_spec(), 2).unwrap();
        let text = plan.to_toml().replace("workers = 0", "workers = -1");
        let err = ShardPlan::parse(&text).unwrap_err();
        assert!(err.to_string().contains("non-negative"), "{err}");
        let text = plan.to_toml().replace("setup_threads = 0", "setup_threads = -3");
        assert!(ShardPlan::parse(&text).is_err());
        let text = plan.to_toml().replace("merge_threads = 0", "merge_threads = -2");
        let err = ShardPlan::parse(&text).unwrap_err();
        assert!(err.to_string().contains("non-negative"), "{err}");
        let text = plan.to_toml().replace("worker_retries = 2", "worker_retries = -1");
        assert!(ShardPlan::parse(&text).is_err());
        let text = plan.to_toml().replace("worker_backoff_ms = 500", "worker_backoff_ms = -9");
        assert!(ShardPlan::parse(&text).is_err());
    }

    #[test]
    fn manifests_without_merge_threads_still_parse() {
        // Plans written before the parallel merge omit the knob; it is
        // hash-exempt, so older manifests keep loading with auto threads.
        // Same for the (newer) supervision knobs.
        let plan = ShardPlan::new(&model(8), &RunSpec::default_spec(), 2).unwrap();
        let text = plan
            .to_toml()
            .replace("merge_threads = 0\n", "")
            .replace("worker_retries = 2\n", "")
            .replace("worker_backoff_ms = 500\n", "");
        let back = ShardPlan::parse(&text).unwrap();
        assert_eq!(back.merge_threads, 0);
        assert_eq!(back.worker_retries, 2);
        assert_eq!(back.worker_backoff_ms, 500);
        assert_eq!(back.hash_hex(), plan.hash_hex());
    }

    #[test]
    fn naive_samplers_are_rejected() {
        let mut run = RunSpec::default_spec();
        run.sampler = SamplerKind::Naive;
        assert!(ShardPlan::new(&model(8), &run, 2).is_err());
    }
}
