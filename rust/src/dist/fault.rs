//! Deterministic fault injection for the distributed runtime.
//!
//! A [`FaultPlan`] makes every crash window in the worker's write path
//! reachable on demand, so the kill-and-resume equivalence tests (and the
//! CI crash-inject smoke) can place a failure at an exact point instead
//! of hoping a `kill -9` lands somewhere interesting. Faults surface as
//! ordinary [`io::Error`]s carrying an `injected fault:` message: the
//! coordinator aborts the run exactly as it would for a real disk error,
//! and the CLI worker exits non-zero, which is what the supervisor sees
//! from a genuine crash.
//!
//! The spec grammar (CLI `--inject-fault`, test-only):
//!
//! * `crash-after-segments=K` — let `K` owned segments reach their final
//!   names, then fail the next owned-segment write before it starts.
//! * `crash-before-rename` — write a complete, finalized temp file, then
//!   fail before the atomic rename — deliberately **leaking the temp**,
//!   exactly the on-disk state a real crash in that window leaves.
//! * `crash-before-marker` — finish every segment, then fail before the
//!   completion marker is written (the `K = all-but-marker` case).
//! * `fail-write-shard=I` — fail shard `I`'s body write mid-stream
//!   (disk-full simulation), leaving a truncated, unfinalized temp.
//!
//! The driver form appends `@wN` (e.g. `crash-after-segments=1@w1`):
//! the supervisor injects the fault into worker `N`'s **first attempt
//! only**, so the supervised retry runs clean and must resume.
//!
//! Faults are confined to the I/O/driver layers by construction — maglint
//! rule 6 (`fault-hook`) fails the build if any of these names shows up
//! in an output-determining module. An injected crash can change *when*
//! bytes reach disk, never *which* bytes the sampler derives.

use std::io;

use anyhow::{anyhow, bail, Result};

/// Where in the write path an injected fault fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail before writing the `(K+1)`-th owned segment of this process.
    CrashAfterSegments(usize),
    /// Fail after the temp file is complete, before the atomic rename
    /// (the temp is left behind, as a real crash would leave it).
    CrashBeforeRename,
    /// Fail after every segment is final, before the completion marker.
    CrashBeforeMarker,
    /// Fail shard `I`'s segment body write, leaving a truncated temp.
    FailWriteShard(usize),
}

/// A parsed `--inject-fault` spec. See the [module docs](self).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    /// The crash window to hit.
    pub kind: FaultKind,
    spec: String,
}

impl FaultPlan {
    /// Parse a worker-level spec (no `@wN` suffix).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let kind = if let Some(k) = spec.strip_prefix("crash-after-segments=") {
            FaultKind::CrashAfterSegments(
                k.parse()
                    .map_err(|_| anyhow!("crash-after-segments wants an integer, got {k:?}"))?,
            )
        } else if spec == "crash-before-rename" {
            FaultKind::CrashBeforeRename
        } else if spec == "crash-before-marker" {
            FaultKind::CrashBeforeMarker
        } else if let Some(i) = spec.strip_prefix("fail-write-shard=") {
            FaultKind::FailWriteShard(
                i.parse().map_err(|_| anyhow!("fail-write-shard wants an integer, got {i:?}"))?,
            )
        } else {
            bail!(
                "unknown fault spec {spec:?} (expected crash-after-segments=K | \
                 crash-before-rename | crash-before-marker | fail-write-shard=I)"
            );
        };
        Ok(FaultPlan { kind, spec: spec.to_string() })
    }

    /// The spec string this plan was parsed from (without any `@wN`
    /// suffix) — what a driver forwards to the targeted worker process.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// The error every fired fault returns — distinctive, so test
    /// assertions and log readers can tell an injected crash from a real
    /// one.
    fn fire(&self) -> io::Error {
        io::Error::other(format!("injected fault: {}", self.spec))
    }

    /// Gate before an owned segment is written; `written` counts owned
    /// segments this process has already landed under final names.
    pub fn before_owned_segment(&self, written: usize) -> io::Result<()> {
        match self.kind {
            FaultKind::CrashAfterSegments(k) if written >= k => Err(self.fire()),
            _ => Ok(()),
        }
    }

    /// Gate between a shard's temp-file creation and its body write.
    pub fn before_shard_body(&self, shard: usize) -> io::Result<()> {
        match self.kind {
            FaultKind::FailWriteShard(i) if i == shard => Err(self.fire()),
            _ => Ok(()),
        }
    }

    /// Gate between a finalized temp file and its atomic rename.
    pub fn before_rename(&self) -> io::Result<()> {
        match self.kind {
            FaultKind::CrashBeforeRename => Err(self.fire()),
            _ => Ok(()),
        }
    }

    /// Gate between the last finalized segment and the completion marker.
    pub fn before_marker(&self) -> io::Result<()> {
        match self.kind {
            FaultKind::CrashBeforeMarker => Err(self.fire()),
            _ => Ok(()),
        }
    }

    /// Does firing this fault leave the in-flight temp file on disk (the
    /// crash windows where a real process death would)?
    pub fn leaks_temp(&self) -> bool {
        matches!(self.kind, FaultKind::CrashBeforeRename | FaultKind::FailWriteShard(_))
    }
}

/// Parse a driver-level spec `<fault>[@wN]`: the fault plus the worker
/// whose **first attempt** it is injected into (`None` = no worker
/// targeting, legal only for the standalone `shard-worker` form).
pub fn parse_driver_fault(spec: &str) -> Result<(FaultPlan, Option<usize>)> {
    match spec.rsplit_once("@w") {
        Some((fault, worker)) => {
            let w = worker
                .parse()
                .map_err(|_| anyhow!("fault spec {spec:?}: @w wants a worker index"))?;
            Ok((FaultPlan::parse(fault)?, Some(w)))
        }
        None => Ok((FaultPlan::parse(spec)?, None)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_parse_and_reject() {
        assert_eq!(
            FaultPlan::parse("crash-after-segments=3").unwrap().kind,
            FaultKind::CrashAfterSegments(3)
        );
        assert_eq!(
            FaultPlan::parse("crash-before-rename").unwrap().kind,
            FaultKind::CrashBeforeRename
        );
        assert_eq!(
            FaultPlan::parse("crash-before-marker").unwrap().kind,
            FaultKind::CrashBeforeMarker
        );
        assert_eq!(
            FaultPlan::parse("fail-write-shard=7").unwrap().kind,
            FaultKind::FailWriteShard(7)
        );
        assert!(FaultPlan::parse("crash-after-segments=x").is_err());
        assert!(FaultPlan::parse("explode").is_err());
        assert!(FaultPlan::parse("").is_err());
    }

    #[test]
    fn driver_specs_carry_the_target_worker() {
        let (fault, worker) = parse_driver_fault("crash-after-segments=1@w1").unwrap();
        assert_eq!(fault.kind, FaultKind::CrashAfterSegments(1));
        assert_eq!(worker, Some(1));
        let (fault, worker) = parse_driver_fault("crash-before-marker").unwrap();
        assert_eq!(fault.kind, FaultKind::CrashBeforeMarker);
        assert_eq!(worker, None);
        assert!(parse_driver_fault("crash-before-marker@wtwo").is_err());
    }

    #[test]
    fn gates_fire_exactly_where_aimed() {
        let f = FaultPlan::parse("crash-after-segments=2").unwrap();
        assert!(f.before_owned_segment(0).is_ok());
        assert!(f.before_owned_segment(1).is_ok());
        assert!(f.before_owned_segment(2).is_err());
        assert!(f.before_shard_body(0).is_ok());
        assert!(f.before_rename().is_ok());
        assert!(f.before_marker().is_ok());
        assert!(!f.leaks_temp());

        let f = FaultPlan::parse("fail-write-shard=3").unwrap();
        assert!(f.before_shard_body(2).is_ok());
        let err = f.before_shard_body(3).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
        assert!(f.leaks_temp());

        let f = FaultPlan::parse("crash-before-rename").unwrap();
        assert!(f.before_rename().is_err());
        assert!(f.leaks_temp());

        let f = FaultPlan::parse("crash-before-marker").unwrap();
        assert!(f.before_marker().is_err());
        assert!(f.before_owned_segment(99).is_ok());
    }
}
