//! Driver-side supervision of shard-worker processes: bounded restarts
//! with capped exponential backoff, heartbeat-based stall detection, and
//! kill-everything semantics on the first unrecoverable failure.
//!
//! The original driver spawned `W` children and waited for them one by
//! one — a single crashed worker failed the whole run (after every other
//! worker finished its now-wasted work), and a *hung* worker blocked the
//! driver forever. The supervisor fixes both: every worker gets
//! `worker_retries` restarts (each restart resumes from the segments its
//! predecessor landed — see the worker's resume rules), restarts are
//! spaced by `worker_backoff_ms · 2^(attempt-1)` capped at
//! [`MAX_BACKOFF_MS`], and a worker whose heartbeat file goes quiet for
//! `stall_ms` is killed and counted as [`WorkerFailure::Stalled`]. When
//! any worker exhausts its budget, the remaining children are killed
//! *and reaped* immediately — no orphans, no indefinite waits.
//!
//! Both knobs are hash-exempt: they change when work happens, never
//! which bytes a worker derives.

use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime};

use anyhow::{bail, Context, Result};

use crate::trace::console;
use crate::trace::progress::{aggregate, parse_progress, ProgressState};

use super::plan::ShardPlan;
use super::worker::heartbeat_file_name;

/// Hard ceiling on one backoff delay, whatever the exponent says.
pub const MAX_BACKOFF_MS: u64 = 30_000;

/// Default heartbeat-silence deadline before a worker counts as stalled.
pub const DEFAULT_STALL_MS: u64 = 60_000;

/// How often the supervisor polls its children.
const POLL_MS: u64 = 25;

/// How often a supervised worker touches its heartbeat file.
const BEAT_MS: u64 = 500;

/// Minimum gap between the driver's live-progress lines.
const PROGRESS_MS: u64 = 1_000;

/// Why one worker attempt failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkerFailure {
    /// The process could not be spawned at all.
    Spawn(String),
    /// The process exited with a non-zero code (or an unclassifiable
    /// status, reported as code `-1`).
    Exit(i32),
    /// The process died on a signal (SIGKILL from the OOM killer, a
    /// `kill -9`, …).
    Signal(i32),
    /// The heartbeat file went silent for this many milliseconds; the
    /// supervisor killed the process.
    Stalled(u64),
}

impl std::fmt::Display for WorkerFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkerFailure::Spawn(e) => write!(f, "spawn failed: {e}"),
            WorkerFailure::Exit(code) => write!(f, "exit code {code}"),
            WorkerFailure::Signal(sig) => write!(f, "killed by signal {sig}"),
            WorkerFailure::Stalled(ms) => write!(f, "stalled (no heartbeat for {ms} ms)"),
        }
    }
}

/// Classify a reaped child's exit status.
fn classify(status: std::process::ExitStatus) -> WorkerFailure {
    if let Some(code) = status.code() {
        return WorkerFailure::Exit(code);
    }
    #[cfg(unix)]
    {
        use std::os::unix::process::ExitStatusExt;
        if let Some(sig) = status.signal() {
            return WorkerFailure::Signal(sig);
        }
    }
    WorkerFailure::Exit(-1)
}

/// One worker's supervision history.
#[derive(Debug, Clone)]
pub struct WorkerOutcome {
    /// Worker index.
    pub worker: usize,
    /// Attempts launched (1 = succeeded first try).
    pub attempts: usize,
    /// The failure behind each non-final attempt (empty on a clean run).
    pub failures: Vec<WorkerFailure>,
}

/// Knobs for [`supervise_workers`]. All wall-clock-only.
#[derive(Debug, Clone)]
pub struct SuperviseOptions {
    /// Restarts allowed per worker after its first attempt.
    pub retries: usize,
    /// Base delay before a restart; doubles per consecutive failure,
    /// capped at [`MAX_BACKOFF_MS`].
    pub backoff_ms: u64,
    /// Heartbeat-silence deadline before a worker counts as stalled
    /// (0 disables stall detection).
    pub stall_ms: u64,
    /// Shared setup artifact every spawned worker hydrates from
    /// (`--artifact`), skipping its per-process setup pipeline. `None`
    /// (the default) re-runs setup in every worker. Hash-exempt like the
    /// thread knobs: the artifact is cross-checked against the plan, so
    /// it can never change which bytes a worker derives.
    pub artifact: Option<PathBuf>,
    /// Deterministic fault injection: pass the spec to this worker's
    /// **first** attempt only (tests / CI). Retries run clean.
    pub fault: Option<(usize, String)>,
    /// Print a throttled aggregate `progress:` line while the fleet
    /// runs, built from the workers' heartbeat progress records (see
    /// [`crate::trace::progress`]). Observability only.
    pub live_progress: bool,
}

impl SuperviseOptions {
    /// The plan's supervision knobs with the default stall deadline.
    pub fn from_plan(plan: &ShardPlan) -> Self {
        SuperviseOptions {
            retries: plan.worker_retries,
            backoff_ms: plan.worker_backoff_ms,
            stall_ms: DEFAULT_STALL_MS,
            artifact: None,
            fault: None,
            live_progress: false,
        }
    }
}

/// Read, parse, and aggregate every worker's heartbeat payload under
/// `hash_hex` in `segment_dir` into one console `progress:` line.
/// Workers with no heartbeat — or a legacy empty one — simply don't
/// count as reporting. Shared by the supervising driver and
/// `magquilt top`.
pub fn fleet_progress_line(num_workers: usize, segment_dir: &Path, hash_hex: &str) -> String {
    let mut records = Vec::new();
    for w in 0..num_workers {
        let path = segment_dir.join(heartbeat_file_name(hash_hex, w));
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Some(record) = parse_progress(&text) {
                records.push(record);
            }
        }
    }
    let reporting = records.len();
    let agg = aggregate(&records);
    console::progress_line(reporting, num_workers, agg.jobs_done, agg.jobs_total, agg.edges)
}

/// What the supervisor saw across the whole fleet.
#[derive(Debug)]
pub struct SuperviseReport {
    /// Per-worker histories, in worker order.
    pub outcomes: Vec<WorkerOutcome>,
    /// Total restarts across workers (0 on a clean run).
    pub restarts: usize,
}

/// The delay before restart number `attempt` (1-based count of failures
/// so far): `backoff_ms · 2^(attempt-1)`, saturating, capped at
/// [`MAX_BACKOFF_MS`].
pub fn backoff_delay_ms(backoff_ms: u64, attempt: usize) -> u64 {
    let shift = attempt.saturating_sub(1).min(16) as u32;
    backoff_ms.saturating_mul(1u64 << shift).min(MAX_BACKOFF_MS)
}

/// Milliseconds since the worker last proved liveness: its heartbeat
/// file's mtime, or the attempt start when no heartbeat exists yet.
fn ms_since_alive(hb_path: &Path, started: SystemTime) -> u64 {
    let last = std::fs::metadata(hb_path)
        .and_then(|m| m.modified())
        .unwrap_or(started)
        .max(started); // a stale beat from a *previous* attempt is not liveness
    SystemTime::now() // lint: time-ok(liveness deadline, never output-determining)
        .duration_since(last)
        .unwrap_or(Duration::ZERO)
        .as_millis() as u64
}

/// One supervised child slot.
enum Slot {
    /// Child running; `started` anchors the stall clock.
    Running { child: Child, started: SystemTime, attempt: usize },
    /// Between attempts, waiting out the backoff.
    Waiting { resume_at: Instant, attempt: usize },
    /// Finished successfully.
    Done,
}

/// Spawn and supervise `num_workers` children built by `make_command`
/// (called with the worker index and, when armed for that attempt, the
/// fault spec to inject). Returns when every worker has succeeded;
/// fails — after killing and reaping every remaining child — as soon as
/// any worker exhausts its retry budget.
pub fn supervise_workers(
    num_workers: usize,
    segment_dir: &Path,
    hash_hex: &str,
    opts: &SuperviseOptions,
    mut make_command: impl FnMut(usize, Option<&str>) -> Command,
) -> Result<SuperviseReport> {
    let mut outcomes: Vec<WorkerOutcome> = (0..num_workers)
        .map(|worker| WorkerOutcome { worker, attempts: 0, failures: Vec::new() })
        .collect();
    let mut slots: Vec<Slot> = Vec::with_capacity(num_workers);

    let mut launch = |w: usize, attempt: usize, outcome: &mut WorkerOutcome| -> Slot {
        let fault = match &opts.fault {
            Some((fw, spec)) if *fw == w && attempt == 1 => Some(spec.as_str()),
            _ => None,
        };
        outcome.attempts = attempt;
        match make_command(w, fault).spawn() {
            Ok(child) => Slot::Running { child, started: SystemTime::now(), attempt }, // lint: time-ok(stall clock, never output-determining)
            Err(e) => {
                // A spawn failure consumes an attempt like any other
                // failure; the backoff gives a transient cause (fd/pid
                // exhaustion) room to clear.
                outcome.failures.push(WorkerFailure::Spawn(e.to_string()));
                Slot::Waiting {
                    resume_at: Instant::now()
                        + Duration::from_millis(backoff_delay_ms(opts.backoff_ms, attempt)),
                    attempt,
                }
            }
        }
    };

    for w in 0..num_workers {
        let slot = launch(w, 1, &mut outcomes[w]);
        slots.push(slot);
    }

    let mut last_progress: Option<Instant> = None;

    let kill_all = |slots: &mut [Slot]| {
        for slot in slots.iter_mut() {
            if let Slot::Running { child, .. } = slot {
                let _ = child.kill();
                let _ = child.wait();
            }
            *slot = Slot::Done;
        }
    };

    loop {
        let mut all_done = true;
        for w in 0..num_workers {
            // Take the slot out so the arms below can both consume the
            // child and write a successor state without aliasing.
            let slot = std::mem::replace(&mut slots[w], Slot::Done);
            let next = match slot {
                Slot::Done => Slot::Done,
                Slot::Waiting { resume_at, attempt } => {
                    all_done = false;
                    if Instant::now() < resume_at {
                        Slot::Waiting { resume_at, attempt }
                    } else {
                        launch(w, attempt + 1, &mut outcomes[w])
                    }
                }
                Slot::Running { mut child, started, attempt } => {
                    all_done = false;
                    let reaped = match child.try_wait() {
                        Ok(r) => r,
                        Err(e) => {
                            let _ = child.kill();
                            let _ = child.wait();
                            kill_all(&mut slots);
                            return Err(e).with_context(|| format!("polling worker {w}"));
                        }
                    };
                    let failure = match reaped {
                        Some(status) if status.success() => {
                            slots[w] = Slot::Done;
                            continue;
                        }
                        Some(status) => classify(status),
                        None => {
                            let stalled_for = if opts.stall_ms == 0 {
                                None
                            } else {
                                let hb = segment_dir.join(heartbeat_file_name(hash_hex, w));
                                let silent = ms_since_alive(&hb, started);
                                (silent >= opts.stall_ms).then_some(silent)
                            };
                            match stalled_for {
                                None => {
                                    slots[w] = Slot::Running { child, started, attempt };
                                    continue;
                                }
                                Some(silent) => {
                                    let _ = child.kill();
                                    let _ = child.wait();
                                    WorkerFailure::Stalled(silent)
                                }
                            }
                        }
                    };
                    outcomes[w].failures.push(failure);
                    if attempt > opts.retries {
                        let history = outcomes[w]
                            .failures
                            .iter()
                            .map(|f| f.to_string())
                            .collect::<Vec<_>>()
                            .join("; ");
                        kill_all(&mut slots);
                        bail!(
                            "worker {w} failed {attempt} attempt(s), retry budget of {} \
                             exhausted ({history}); segments left in {} for inspection",
                            opts.retries,
                            segment_dir.display()
                        );
                    }
                    Slot::Waiting {
                        resume_at: Instant::now()
                            + Duration::from_millis(backoff_delay_ms(opts.backoff_ms, attempt)),
                        attempt,
                    }
                }
            };
            slots[w] = next;
        }
        // A spawn failure lands in Waiting without ever running; it can
        // exhaust the budget too, and must fail rather than retry
        // forever against a permanently unspawnable binary.
        for w in 0..num_workers {
            if let Slot::Waiting { attempt, .. } = &slots[w] {
                if *attempt > opts.retries {
                    let attempt = *attempt;
                    let history = outcomes[w]
                        .failures
                        .iter()
                        .map(|f| f.to_string())
                        .collect::<Vec<_>>()
                        .join("; ");
                    kill_all(&mut slots);
                    bail!(
                        "worker {w} failed {attempt} attempt(s), retry budget of {} exhausted \
                         ({history}); segments left in {} for inspection",
                        opts.retries,
                        segment_dir.display()
                    );
                }
            }
        }
        if all_done {
            break;
        }
        if opts.live_progress {
            let due = last_progress
                .map(|t| t.elapsed() >= Duration::from_millis(PROGRESS_MS))
                .unwrap_or(true);
            if due {
                println!("{}", fleet_progress_line(num_workers, segment_dir, hash_hex));
                last_progress = Some(Instant::now());
            }
        }
        std::thread::sleep(Duration::from_millis(POLL_MS));
    }

    let restarts = outcomes.iter().map(|o| o.attempts.saturating_sub(1)).sum();
    Ok(SuperviseReport { outcomes, restarts })
}

/// Liveness beacon for a supervised worker process: a background thread
/// touches the worker's heartbeat file every [`BEAT_MS`] until the guard
/// drops (then the thread is stopped, joined, and the file removed).
/// Only the file's mtime carries information.
#[derive(Debug)]
pub struct Heartbeat {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    path: PathBuf,
}

impl Heartbeat {
    /// Start beating for `worker` under `hash_hex` in `dir` (created if
    /// missing). Never fails: a heartbeat that cannot write simply goes
    /// silent, and the supervisor's stall deadline handles the rest.
    pub fn start(dir: &Path, hash_hex: &str, worker: usize) -> Heartbeat {
        Heartbeat::start_with_progress(dir, hash_hex, worker, None)
    }

    /// As [`Heartbeat::start`], but each beat also publishes the current
    /// [`crate::trace::progress`] counters as the file body, giving the
    /// supervising driver (and `magquilt top`) something to aggregate.
    /// With `None` the body stays empty — a legacy mtime-only beat.
    pub fn start_with_progress(
        dir: &Path,
        hash_hex: &str,
        worker: usize,
        progress: Option<Arc<ProgressState>>,
    ) -> Heartbeat {
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(heartbeat_file_name(hash_hex, worker));
        let stop = Arc::new(AtomicBool::new(false));
        let (stop2, path2) = (Arc::clone(&stop), path.clone());
        let hash = hash_hex.to_string();
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                let body = match &progress {
                    Some(p) => p.render(&hash, worker),
                    None => String::new(),
                };
                let _ = std::fs::write(&path2, body.as_bytes());
                let mut slept = 0;
                while slept < BEAT_MS && !stop2.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(25));
                    slept += 25;
                }
            }
        });
        Heartbeat { stop, handle: Some(handle), path }
    }
}

impl Drop for Heartbeat {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sh(script: &str) -> Command {
        let mut cmd = Command::new("sh");
        cmd.arg("-c").arg(script).stdin(std::process::Stdio::null());
        cmd
    }

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("magquilt_supervise_test").join(tag);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn opts(retries: usize) -> SuperviseOptions {
        SuperviseOptions {
            retries,
            backoff_ms: 1,
            stall_ms: 0,
            artifact: None,
            fault: None,
            live_progress: false,
        }
    }

    #[test]
    fn backoff_doubles_and_caps() {
        assert_eq!(backoff_delay_ms(500, 1), 500);
        assert_eq!(backoff_delay_ms(500, 2), 1000);
        assert_eq!(backoff_delay_ms(500, 3), 2000);
        assert_eq!(backoff_delay_ms(500, 12), MAX_BACKOFF_MS);
        assert_eq!(backoff_delay_ms(0, 5), 0);
        assert_eq!(backoff_delay_ms(u64::MAX, 64), MAX_BACKOFF_MS, "saturates, no overflow");
    }

    #[test]
    fn clean_fleet_reports_no_restarts() {
        let dir = fresh_dir("clean");
        let report =
            supervise_workers(3, &dir, "00ff00ff00ff00ff", &opts(2), |_, _| sh("exit 0"))
                .unwrap();
        assert_eq!(report.restarts, 0);
        assert_eq!(report.outcomes.len(), 3);
        for o in &report.outcomes {
            assert_eq!(o.attempts, 1);
            assert!(o.failures.is_empty());
        }
    }

    #[test]
    fn flaky_worker_is_retried_until_it_succeeds() {
        let dir = fresh_dir("flaky");
        // Worker 1 fails until a state file exists, created on its first
        // failing attempt — so attempt 1 fails, attempt 2 succeeds.
        let state = dir.join("state");
        let state_str = state.to_string_lossy().into_owned();
        let report = supervise_workers(2, &dir, "00ff00ff00ff00ff", &opts(2), |w, _| {
            if w == 1 {
                sh(&format!("if [ -e {state_str} ]; then exit 0; else touch {state_str}; exit 3; fi"))
            } else {
                sh("exit 0")
            }
        })
        .unwrap();
        assert_eq!(report.restarts, 1);
        assert_eq!(report.outcomes[1].attempts, 2);
        assert_eq!(report.outcomes[1].failures, vec![WorkerFailure::Exit(3)]);
        assert_eq!(report.outcomes[0].attempts, 1);
    }

    #[test]
    fn exhausted_budget_fails_and_reports_history() {
        let dir = fresh_dir("exhausted");
        let err = supervise_workers(1, &dir, "00ff00ff00ff00ff", &opts(1), |_, _| sh("exit 7"))
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("retry budget of 1 exhausted"), "{msg}");
        assert!(msg.contains("exit code 7"), "{msg}");
    }

    #[test]
    fn unrecoverable_failure_kills_the_rest_of_the_fleet() {
        let dir = fresh_dir("killrest");
        let long_file = dir.join("long-running");
        let long_str = long_file.to_string_lossy().into_owned();
        // Worker 0 fails instantly with no retries; worker 1 would run
        // for 60s and leave a file when *finishing cleanly*. The
        // supervisor must return quickly (killing worker 1), so the file
        // never appears.
        let start = Instant::now();
        let err = supervise_workers(2, &dir, "00ff00ff00ff00ff", &opts(0), |w, _| {
            if w == 0 {
                sh("exit 9")
            } else {
                sh(&format!("sleep 60; touch {long_str}"))
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("worker 0"), "{err}");
        assert!(start.elapsed() < Duration::from_secs(30), "did not wait for the sleeper");
        assert!(!long_file.exists(), "sleeper was killed, not awaited");
    }

    #[test]
    fn signal_death_is_classified_as_signal() {
        let dir = fresh_dir("signal");
        let err = supervise_workers(
            1,
            &dir,
            "00ff00ff00ff00ff",
            &opts(0),
            // The shell kills itself with SIGKILL (9).
            |_, _| sh("kill -9 $$"),
        )
        .unwrap_err();
        assert!(err.to_string().contains("killed by signal 9"), "{err}");
    }

    #[test]
    fn stalled_worker_is_killed_and_classified() {
        let dir = fresh_dir("stall");
        let opts = SuperviseOptions { stall_ms: 200, ..opts(0) };
        // The worker sleeps far past the stall deadline and never beats.
        let start = Instant::now();
        let err = supervise_workers(1, &dir, "00ff00ff00ff00ff", &opts, |_, _| sh("sleep 60"))
            .unwrap_err();
        assert!(err.to_string().contains("stalled"), "{err}");
        assert!(start.elapsed() < Duration::from_secs(30), "stall deadline enforced");
    }

    #[test]
    fn heartbeat_keeps_a_slow_worker_alive() {
        let dir = fresh_dir("beat");
        let hash = "00ff00ff00ff00ff";
        let opts = SuperviseOptions { stall_ms: 1500, ..opts(0) };
        // The worker runs well past the stall deadline but beats its
        // heartbeat file the whole time (mirroring what the CLI worker's
        // Heartbeat guard does), so it must NOT be classified as stalled.
        let hb = dir.join(heartbeat_file_name(hash, 0));
        let hb_str = hb.to_string_lossy().into_owned();
        let report = supervise_workers(1, &dir, hash, &opts, |_, _| {
            sh(&format!(
                "i=0; while [ $i -lt 25 ]; do touch {hb_str}; sleep 0.1; i=$((i+1)); done"
            ))
        })
        .unwrap();
        assert_eq!(report.restarts, 0);
    }

    #[test]
    fn fault_spec_reaches_only_the_first_attempt_of_the_target() {
        let dir = fresh_dir("fault");
        let opts = SuperviseOptions {
            fault: Some((1, "crash-after-segments=0".to_string())),
            ..opts(1)
        };
        let mut seen: Vec<(usize, Option<String>)> = Vec::new();
        let report = supervise_workers(2, &dir, "00ff00ff00ff00ff", &opts, |w, fault| {
            seen.push((w, fault.map(str::to_string)));
            // The faulted attempt "crashes" (exit 5); everything else
            // succeeds.
            if fault.is_some() {
                sh("exit 5")
            } else {
                sh("exit 0")
            }
        })
        .unwrap();
        assert_eq!(report.restarts, 1);
        assert_eq!(report.outcomes[1].failures, vec![WorkerFailure::Exit(5)]);
        let w1: Vec<_> = seen.iter().filter(|(w, _)| *w == 1).collect();
        assert_eq!(w1.len(), 2);
        assert_eq!(w1[0].1.as_deref(), Some("crash-after-segments=0"));
        assert_eq!(w1[1].1, None, "retry runs clean");
        assert!(seen.iter().filter(|(w, _)| *w == 0).all(|(_, f)| f.is_none()));
    }

    #[test]
    fn heartbeat_guard_beats_and_cleans_up() {
        let dir = fresh_dir("guard");
        let hash = "00ff00ff00ff00ff";
        let path = dir.join(heartbeat_file_name(hash, 4));
        {
            let _guard = Heartbeat::start(&dir, hash, 4);
            // The first beat is written synchronously-ish; give it a beat.
            let deadline = Instant::now() + Duration::from_secs(5);
            while !path.exists() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(10));
            }
            assert!(path.exists(), "guard touches the heartbeat file");
        }
        assert!(!path.exists(), "guard removes the file on drop");
    }

    #[test]
    fn heartbeat_with_progress_publishes_parseable_records() {
        let dir = fresh_dir("hb_progress");
        let hash = "00ff00ff00ff00ff";
        let progress = Arc::new(ProgressState::new());
        progress.jobs_total.store(8, Ordering::Relaxed);
        progress.jobs_done.store(3, Ordering::Relaxed);
        progress.edges.store(1000, Ordering::Relaxed);
        let path = dir.join(heartbeat_file_name(hash, 2));
        {
            let _guard =
                Heartbeat::start_with_progress(&dir, hash, 2, Some(Arc::clone(&progress)));
            let deadline = Instant::now() + Duration::from_secs(5);
            let mut record = None;
            while record.is_none() && Instant::now() < deadline {
                record = std::fs::read_to_string(&path).ok().and_then(|t| parse_progress(&t));
                std::thread::sleep(Duration::from_millis(10));
            }
            let record = record.expect("heartbeat published a progress record");
            assert_eq!(record.plan, hash);
            assert_eq!(record.worker, 2);
            assert_eq!(record.counts.jobs_done, 3);
            assert_eq!(record.counts.jobs_total, 8);
            assert_eq!(record.counts.edges, 1000);
        }
        assert!(!path.exists(), "guard removes the file on drop");
    }

    #[test]
    fn fleet_progress_line_aggregates_heartbeat_payloads() {
        let dir = fresh_dir("fleet_line");
        let hash = "00ff00ff00ff00ff";
        // Worker 0 reports counters; worker 1 is a legacy empty
        // heartbeat; worker 2 has no heartbeat at all. Only worker 0
        // counts as reporting.
        let state = ProgressState::new();
        state.jobs_total.store(512, Ordering::Relaxed);
        state.jobs_done.store(400, Ordering::Relaxed);
        state.edges.store(1_234, Ordering::Relaxed);
        std::fs::write(dir.join(heartbeat_file_name(hash, 0)), state.render(hash, 0)).unwrap();
        std::fs::write(dir.join(heartbeat_file_name(hash, 1)), "").unwrap();
        let line = fleet_progress_line(3, &dir, hash);
        assert_eq!(line, "progress: w1/3 jobs 400/512 edges 1.2k");
    }
}
