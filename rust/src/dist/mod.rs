//! Distributed sampling runtime: shard-range ownership, segment files,
//! and a deterministic concat.
//!
//! The quilting decomposition is embarrassingly partitionable — every
//! KPGM piece and ER block is independent given its RNG fork — and the
//! heavy work concentrates in small high-multiplicity attribute sets
//! whose source spans are *narrow*. This module turns that into a
//! multi-process (and multi-host) runtime: `W` worker processes each own
//! a contiguous range of the `S` source shards, sample only the jobs
//! whose span starts in their range, and write per-shard `MAGQEDG1`
//! segment files; a deterministic merge folds the segments (plus the
//! overflow runs that wide-span jobs scatter into foreign shards) into
//! one output file **bit-for-bit identical** to the single-process
//! sampler's. The merge itself runs shards on `merge_threads` worker
//! threads (another hash-exempt per-host knob, 0 = auto) and delivers
//! them through the spill-budgeted ordered sink, so it scales with the
//! host without changing a byte of the output — see [`merge`].
//!
//! No inter-worker communication exists anywhere: the whole contract is
//! the [`ShardPlan`] manifest (everything output-determining, sealed by a
//! content hash) plus the segment-directory file-name scheme. That is
//! what makes multi-host execution trivial — see the runbook below.
//!
//! # Pipeline
//!
//! ```text
//! shard-plan ──► plan.toml ──► shard-worker 0 ─┐
//!                          ──► shard-worker 1 ─┤──► segment dir ──► merge-segments ──► out.bin
//!                          ──► shard-worker …  ─┘
//! ```
//!
//! `magquilt sample --dist-workers W --out g.bin` runs the whole pipeline
//! on one machine: it builds the plan, spawns `W` local `shard-worker`
//! processes, monitors them, merges, and drains the segment directory.
//! Each stage is equally usable standalone.
//!
//! # Plan manifest (`plan.toml`)
//!
//! A TOML-subset file with three sections (see [`plan::ShardPlan`]):
//! `[plan]` — format version, content hash, shard count `S`, and the
//! per-worker shard ranges (`shard_starts[w] .. shard_ends[w]`);
//! `[model]` and `[run]` — the config-file schema. The hash digests the
//! output-determining fields only (model, seed, sampler, piece/attr mode,
//! `S`, ranges) — never the per-host thread knobs — and every segment
//! file embeds it, so segments from different plans can never be stitched
//! together. Inside a plan the attribute mode defaults to **chunked**
//! (there are no sequential-stream goldens to protect in dist mode, and
//! chunked is what parallelizes each worker's setup pipeline); the
//! resolved mode is recorded in the manifest so every worker agrees.
//!
//! # Segment files
//!
//! Every file a worker writes is a complete, self-validating `MAGQEDG1`
//! edge list (magic, `u64` node count, back-patched `u64` edge count,
//! sorted deduplicated `(u32, u32)` LE records — see [`crate::graph`]):
//!
//! * `seg-<hash>-s<shard:05>-w<worker:04>.seg` — the owner's run for a
//!   shard in its range. Written for **every** owned shard, even empty
//!   ones: a missing owner segment means an incomplete run, and the merge
//!   refuses to guess.
//! * `ovf-<hash>-s<shard:05>-w<worker:04>.ovf` — edges a wide-span job
//!   owned by `worker` sampled into a *foreign* shard's source range,
//!   keyed by that destination shard. Written only when non-empty.
//!
//! Files are written under a pid + run-nonce temp name and atomically
//! renamed, so any number of workers — across hosts on a shared
//! filesystem — can safely share one directory, and a crash never leaves
//! a plausible-looking partial file under a final name.
//!
//! # Why the concat is exact
//!
//! Shard `s`'s single-process result is the sorted deduplicated union of
//! every batch routed to it. Distributed, those same batches (same RNG
//! forks, same jobs) are split between the owner's segment and the
//! foreign overflow runs — each itself a sorted deduplicated union of a
//! subset. Folding them back through the same [`crate::graph::ShardMerger`]
//! rebuilds the union, and union is associative and order-free, so the
//! merged run is identical — and writing the shards in index order
//! through [`crate::graph::BinaryEdgeWriter`] reproduces the
//! single-process file byte for byte.
//!
//! # Multi-host runbook
//!
//! ```text
//! # 1. One plan, anywhere:
//! magquilt shard-plan --log2-nodes 23 --seed 7 --dist-workers 4 \
//!          --shards 64 --plan-out plan.toml
//! # 2. Ship plan.toml to every host; run one worker per host:
//! host0$ magquilt shard-worker --plan plan.toml --worker 0 --segment-dir segs/
//! host1$ magquilt shard-worker --plan plan.toml --worker 1 --segment-dir segs/
//! ...
//! # 3. Collect the segment files onto one host (scp/rsync; names are
//! #    collision-free by construction) and merge. --merge-threads is a
//! #    per-host knob (0 = auto): the output is byte-identical for any
//! #    count, so size it to the merge host alone:
//! magquilt merge-segments --segments segs/ --plan plan.toml \
//!          --merge-threads 8 --out graph.bin
//! # 4. Optional pre-merge inspection (counts, spans, truncation, hashes):
//! magquilt stats segs/
//! ```
//!
//! Workers are stateless: a crashed worker is rerun with the same
//! command and atomically overwrites its own files.

pub mod merge;
pub mod plan;
pub mod worker;

pub use merge::{merge_segments, merge_segments_with, scan_segments, validate_segments,
                MergeOptions, MergeReport, MergedShardReport, SegmentCatalog, SegmentMeta,
                ShardSegments};
pub use plan::{ShardPlan, PLAN_FORMAT};
pub use worker::{job_owners, overflow_file_name, parse_segment_file_name, run_worker,
                 segment_file_name, SegmentFileInfo, SegmentKind, SegmentSink, SegmentSummary,
                 WorkerReport};

use std::path::Path;
use std::process::{Command, Stdio};

use anyhow::{bail, Context, Result};

/// File name of the plan manifest inside a segment directory.
pub const PLAN_FILE: &str = "plan.toml";

/// Outcome of a full local distributed run.
#[derive(Debug)]
pub struct DistReport {
    /// Worker processes spawned.
    pub workers: usize,
    /// The merge outcome (totals + per-shard rows).
    pub merge: MergeReport,
}

/// Remove artifacts a previous attempt at **this same plan** may have
/// left in the directory: segment/overflow files carrying this plan's
/// hash, in-flight temp files, and a stale manifest. Segment files from a
/// *different* plan are never deleted — they may be another run's
/// collected (not yet merged) multi-host work — and instead fail the run
/// up front, before any sampling time is spent.
fn clean_stale_artifacts(dir: &Path, plan: &ShardPlan) -> Result<()> {
    if !dir.exists() {
        return Ok(());
    }
    let hash = plan.hash_hex();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy().into_owned();
        if let Some(info) = parse_segment_file_name(&name) {
            if info.hash_hex != hash {
                bail!(
                    "segment dir {} holds {name} from plan {} — refusing to overwrite another \
                     run's segments; merge or remove them, or pick a different --segment-dir",
                    dir.display(),
                    info.hash_hex
                );
            }
            std::fs::remove_file(entry.path())
                .with_context(|| format!("removing stale {name}"))?;
        } else if name == PLAN_FILE || name.starts_with("magquilt-tmp-") {
            std::fs::remove_file(entry.path())
                .with_context(|| format!("removing stale {name}"))?;
        }
    }
    Ok(())
}

/// Run a whole distributed sample on this machine: write the plan
/// manifest into `segment_dir`, spawn one `shard-worker` process per
/// worker (using `worker_exe`, normally the current `magquilt` binary),
/// wait for all of them, merge the segments into `out`, and drain the
/// segment directory.
///
/// Worker stdout/stderr are inherited, so per-worker progress lines
/// interleave with the driver's. Any worker failing (or dying on a
/// signal) fails the run; its segments are left in place for inspection
/// and are cleaned up by the next attempt.
pub fn run_distributed(
    plan: &ShardPlan,
    segment_dir: &Path,
    out: &Path,
    worker_exe: &Path,
) -> Result<DistReport> {
    plan.validate()?;
    std::fs::create_dir_all(segment_dir)
        .with_context(|| format!("creating segment dir {}", segment_dir.display()))?;
    clean_stale_artifacts(segment_dir, plan)?;
    let plan_path = segment_dir.join(PLAN_FILE);
    plan.save(&plan_path)?;

    let mut children = Vec::new();
    for w in 0..plan.num_workers() {
        let spawned = Command::new(worker_exe)
            .arg("shard-worker")
            .arg("--plan")
            .arg(&plan_path)
            .arg("--worker")
            .arg(w.to_string())
            .arg("--segment-dir")
            .arg(segment_dir)
            .stdin(Stdio::null())
            .spawn()
            .with_context(|| {
                format!("spawning worker {w} ({} shard-worker)", worker_exe.display())
            });
        match spawned {
            Ok(child) => children.push((w, child)),
            Err(e) => {
                // Don't leak the workers already running.
                for (_, mut child) in children {
                    let _ = child.kill();
                    let _ = child.wait();
                }
                return Err(e);
            }
        }
    }
    let mut failed = Vec::new();
    for (w, mut child) in children {
        let status = child.wait().with_context(|| format!("waiting for worker {w}"))?;
        if !status.success() {
            failed.push(format!("worker {w}: {status}"));
        }
    }
    if !failed.is_empty() {
        bail!(
            "{} of {} workers failed ({}); segments left in {} for inspection",
            failed.len(),
            plan.num_workers(),
            failed.join(", "),
            segment_dir.display()
        );
    }

    let merge = merge_segments(segment_dir, plan, out, true)?;
    std::fs::remove_file(&plan_path).ok();
    // Remove the directory if we own all of it (ignore failure: the user
    // may have pointed --segment-dir at a shared location).
    std::fs::remove_dir(segment_dir).ok();
    Ok(DistReport { workers: plan.num_workers(), merge })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_stale_artifacts_only_touches_this_plans_files() {
        let dir = std::env::temp_dir().join("magquilt_dist_clean_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let plan = ShardPlan::new(
            &crate::config::ModelSpec::default_spec(),
            &crate::config::RunSpec::default_spec(),
            2,
        )
        .unwrap();
        let hash = plan.hash_hex();
        std::fs::write(dir.join(PLAN_FILE), "stale").unwrap();
        std::fs::write(dir.join(segment_file_name(&hash, 0, 0)), "stale").unwrap();
        std::fs::write(dir.join(overflow_file_name(&hash, 1, 1)), "stale").unwrap();
        std::fs::write(dir.join("magquilt-tmp-1-x-0-seg.part"), "stale").unwrap();
        std::fs::write(dir.join("keep.txt"), "user data").unwrap();
        clean_stale_artifacts(&dir, &plan).unwrap();
        let left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(left, vec!["keep.txt".to_string()]);

        // Another plan's segments are sacred: the driver must refuse, not
        // silently destroy a different run's collected (unmerged) work.
        let foreign = dir.join("seg-deadbeefdeadbeef-s00000-w0000.seg");
        std::fs::write(&foreign, "another run").unwrap();
        let err = clean_stale_artifacts(&dir, &plan).unwrap_err();
        assert!(err.to_string().contains("refusing to overwrite"), "{err}");
        assert!(foreign.exists(), "foreign segment must survive");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
