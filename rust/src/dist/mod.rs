//! Distributed sampling runtime: shard-range ownership, segment files,
//! and a deterministic concat.
//!
//! The quilting decomposition is embarrassingly partitionable — every
//! KPGM piece and ER block is independent given its RNG fork — and the
//! heavy work concentrates in small high-multiplicity attribute sets
//! whose source spans are *narrow*. This module turns that into a
//! multi-process (and multi-host) runtime: `W` worker processes each own
//! a contiguous range of the `S` source shards, sample only the jobs
//! whose span starts in their range, and write per-shard `MAGQEDG1`
//! segment files; a deterministic merge folds the segments (plus the
//! overflow runs that wide-span jobs scatter into foreign shards) into
//! one output file **bit-for-bit identical** to the single-process
//! sampler's. The merge itself runs shards on `merge_threads` worker
//! threads (another hash-exempt per-host knob, 0 = auto) and delivers
//! them through the spill-budgeted ordered sink, so it scales with the
//! host without changing a byte of the output — see [`merge`].
//!
//! No inter-worker communication exists anywhere: the whole contract is
//! the [`ShardPlan`] manifest (everything output-determining, sealed by a
//! content hash) plus the segment-directory file-name scheme. That is
//! what makes multi-host execution trivial — see the runbook below.
//!
//! # Pipeline
//!
//! ```text
//! shard-plan ──► plan.toml ──► shard-worker 0 ─┐
//!                          ──► shard-worker 1 ─┤──► segment dir ──► merge-segments ──► out.bin
//!                          ──► shard-worker …  ─┘
//! ```
//!
//! `magquilt sample --dist-workers W --out g.bin` runs the whole pipeline
//! on one machine: it builds the plan, spawns `W` local `shard-worker`
//! processes, supervises them (restarting crashed or stalled workers in
//! place — see [`supervise`]), merges, and drains the segment directory.
//! Each stage is equally usable standalone.
//!
//! # Plan manifest (`plan.toml`)
//!
//! A TOML-subset file with three sections (see [`plan::ShardPlan`]):
//! `[plan]` — format version, content hash, shard count `S`, and the
//! per-worker shard ranges (`shard_starts[w] .. shard_ends[w]`);
//! `[model]` and `[run]` — the config-file schema. The hash digests the
//! output-determining fields only (model, seed, sampler, piece/attr mode,
//! `S`, ranges) — never the per-host thread knobs (including the
//! fault-tolerance knobs `worker_retries` / `worker_backoff_ms`) — and
//! every segment file embeds it, so segments from different plans can
//! never be stitched together. Inside a plan the attribute mode defaults
//! to **chunked** (there are no sequential-stream goldens to protect in
//! dist mode, and chunked is what parallelizes each worker's setup
//! pipeline); the resolved mode is recorded in the manifest so every
//! worker agrees.
//!
//! # Segment files
//!
//! Every file a worker writes is a complete, self-validating `MAGQEDG1`
//! edge list (magic, `u64` node count, back-patched `u64` edge count,
//! sorted deduplicated `(u32, u32)` LE records — see [`crate::graph`]):
//!
//! * `seg-<hash>-s<shard:05>-w<worker:04>.seg` — the owner's run for a
//!   shard in its range. Written for **every** owned shard, even empty
//!   ones: a missing owner segment means an incomplete run, and the merge
//!   refuses to guess.
//! * `ovf-<hash>-s<shard:05>-w<worker:04>.ovf` — edges a wide-span job
//!   owned by `worker` sampled into a *foreign* shard's source range,
//!   keyed by that destination shard. Written only when non-empty.
//! * `done-<hash>-w<worker:04>.ok` — the worker's completion marker,
//!   written **after** every segment/overflow file is durable. Records
//!   the [`SegmentSummary`] so a resumed run can trust it without
//!   re-sampling (see [`worker::run_worker_with`]).
//! * `hb-<hash>-w<worker:04>.beat` — a liveness heartbeat the worker
//!   touches while running; the supervisor treats a stale one as a hung
//!   worker. Its body carries a live progress record
//!   ([`crate::trace::progress`]) the driver and `magquilt top`
//!   aggregate into one `progress:` line. Never merged; drained with the
//!   segments.
//! * `trc-<hash>-w<worker:04>.trace.jsonl` /
//!   `rpt-<hash>-w<worker:04>.report.json` — optional telemetry
//!   (`--trace` / `--report`): the worker's structured trace stream and
//!   machine-readable run report (see [`crate::trace`] and
//!   `docs/observability.md`). Write-only observability, never merge
//!   inputs; the driver collects them before draining the directory.
//!
//! Files are written under a pid + run-nonce temp name and atomically
//! renamed, so any number of workers — across hosts on a shared
//! filesystem — can safely share one directory, and a crash never leaves
//! a plausible-looking partial file under a final name.
//!
//! # Why the concat is exact
//!
//! Shard `s`'s single-process result is the sorted deduplicated union of
//! every batch routed to it. Distributed, those same batches (same RNG
//! forks, same jobs) are split between the owner's segment and the
//! foreign overflow runs — each itself a sorted deduplicated union of a
//! subset. Folding them back through the same [`crate::graph::ShardMerger`]
//! rebuilds the union, and union is associative and order-free, so the
//! merged run is identical — and writing the shards in index order
//! through [`crate::graph::BinaryEdgeWriter`] reproduces the
//! single-process file byte for byte.
//!
//! # Crash tolerance
//!
//! Workers are **resumable**, not stateless: the segment directory is an
//! append-only ledger of atomic renames, so whatever survives a crash is
//! trustworthy by construction. A rerun with `--resume` scans the
//! directory, skips every job whose outputs are already complete
//! (component-granular — see [`worker`]), re-runs the rest, and
//! byte-identical idempotent writes make overlap harmless. The local
//! driver supervises its workers with bounded retries, capped exponential
//! backoff, and a heartbeat-based stall detector ([`supervise`]); a
//! directory damaged by external causes is diagnosed and repaired by
//! `magquilt doctor` ([`doctor`]); and every crash window is reachable
//! deterministically through `--inject-fault` ([`fault`]). The full
//! protocol and its determinism argument live in
//! [`docs/fault-tolerance.md`](../../../docs/fault-tolerance.md).
//!
//! # Multi-host runbook
//!
//! ```text
//! # 1. One plan, anywhere:
//! magquilt shard-plan --log2-nodes 23 --seed 7 --dist-workers 4 \
//!          --shards 64 --plan-out plan.toml
//! # 1b. Optional: run the deterministic setup prologue ONCE and ship the
//! #     resulting artifact with the plan, so every worker skips its own
//! #     (identical) setup pipeline. The artifact embeds a content hash
//! #     cross-checked against the plan, so a stale or mismatched file is
//! #     refused, never silently used (docs/setup-artifact.md):
//! magquilt setup --plan plan.toml --out setup.art
//! # 2. Ship plan.toml (and setup.art) to every host; run one worker per
//! #    host (append --artifact setup.art to skip per-worker setup):
//! host0$ magquilt shard-worker --plan plan.toml --worker 0 --segment-dir segs/
//! host1$ magquilt shard-worker --plan plan.toml --worker 1 --segment-dir segs/
//! ...
//! #    A crashed host reruns the same command with --resume appended:
//! #    completed shards are detected on disk and skipped.
//! # 3. Collect the segment files onto one host (scp/rsync; names are
//! #    collision-free by construction) and merge. --merge-threads is a
//! #    per-host knob (0 = auto): the output is byte-identical for any
//! #    count, so size it to the merge host alone:
//! magquilt merge-segments --segments segs/ --plan plan.toml \
//!          --merge-threads 8 --out graph.bin
//! # 4. Optional pre-merge inspection (counts, spans, truncation, hashes):
//! magquilt stats segs/
//! #    If the merge refuses (truncated/foreign files), classify and fix:
//! magquilt doctor segs/ --fix
//! ```

pub mod doctor;
pub mod fault;
pub mod merge;
pub mod plan;
pub mod supervise;
pub mod worker;

pub use doctor::{doctor, DoctorAction, DoctorEntry, DoctorReport, FileStatus, QUARANTINE_DIR};
pub use fault::{parse_driver_fault, FaultKind, FaultPlan};
pub use merge::{merge_obj, merge_report_json, merge_segments, merge_segments_with,
                merged_shard_obj, scan_segments, validate_segments, MergeOptions, MergeReport,
                MergedShardReport, SegmentCatalog, SegmentMeta, ShardSegments};
pub use plan::{ShardPlan, PLAN_FORMAT};
pub use supervise::{backoff_delay_ms, fleet_progress_line, supervise_workers, Heartbeat,
                    SuperviseOptions, SuperviseReport, WorkerFailure, WorkerOutcome,
                    DEFAULT_STALL_MS, MAX_BACKOFF_MS};
pub use worker::{build_job_plan_from_artifact, build_plan_artifact, heartbeat_file_name,
                 job_owners, marker_file_name, overflow_file_name, parse_marker,
                 parse_meta_file_name, parse_segment_file_name, report_file_name, run_worker,
                 run_worker_with, scan_resume_state, segment_file_name, trace_file_name,
                 worker_report_json, write_marker, MetaFileInfo, MetaFileKind, ResumeState,
                 SegmentFileInfo, SegmentKind, SegmentSink, SegmentSummary, WorkerOptions,
                 WorkerReport, MARKER_FORMAT};

use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};

use anyhow::{bail, Context, Result};

use crate::trace::report::report_header;
use crate::trace::{Fv, TraceHandle};

/// File name of the plan manifest inside a segment directory.
pub const PLAN_FILE: &str = "plan.toml";

/// Outcome of a full local distributed run.
#[derive(Debug)]
pub struct DistReport {
    /// Worker processes spawned (not counting restarts).
    pub workers: usize,
    /// Worker restarts the supervisor performed (0 on a clean run).
    /// Restarted workers resume: their own logs report how many shards
    /// they skipped ahead over.
    pub restarts: usize,
    /// The merge outcome (totals + per-shard rows).
    pub merge: MergeReport,
}

/// Telemetry outputs for the local distributed driver. Both default off;
/// either one also turns on the matching worker-side flag so the driver
/// can collect the per-worker artifacts before the merge drains them.
#[derive(Debug, Clone, Default)]
pub struct DistTelemetry {
    /// Write the driver's trace stream (its own events, the merge's, and
    /// every worker's absorbed stream) to this path.
    pub trace: Option<PathBuf>,
    /// Write the driver's `report.json` (kind `driver`) to this path.
    pub report: Option<PathBuf>,
}

/// Render the driver's `report.json` (kind `driver`): fleet shape,
/// restart count, the merge outcome, and the worker reports collected
/// before the merge drained them (raw JSON objects, embedded verbatim).
pub fn driver_report_json(
    hash_hex: &str,
    report: &DistReport,
    worker_reports: Vec<String>,
) -> String {
    report_header("driver", hash_hex)
        .uint("workers", report.workers as u64)
        .uint("restarts", report.restarts as u64)
        .obj("merge", merge_obj(&report.merge))
        .arr("worker_reports", worker_reports)
        .render()
}

/// Prepare a directory for (re)running **this same plan**: remove
/// in-flight temp files, stale heartbeats, and a stale manifest, while
/// **keeping** this plan's segment/overflow files and completion markers
/// — they are exactly the resume state a restarted worker skips ahead
/// on, and rewriting them is byte-identical anyway. Artifacts carrying a
/// *different* plan's hash are never deleted — they may be another run's
/// collected (not yet merged) multi-host work — and instead fail the run
/// up front, before any sampling time is spent.
fn clean_stale_artifacts(dir: &Path, plan: &ShardPlan) -> Result<()> {
    if !dir.exists() {
        return Ok(());
    }
    let hash = plan.hash_hex();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy().into_owned();
        let foreign = if let Some(info) = parse_segment_file_name(&name) {
            (info.hash_hex != hash).then_some(info.hash_hex)
        } else if let Some(meta) = parse_meta_file_name(&name) {
            if meta.hash_hex == hash && meta.kind != MetaFileKind::Marker {
                // Heartbeats and telemetry (trace/report) can only be
                // stale here: our workers are not running yet, a *live*
                // foreign worker would imply a foreign plan hash (caught
                // below), and only markers carry resume state.
                std::fs::remove_file(entry.path())
                    .with_context(|| format!("removing stale {name}"))?;
                continue;
            }
            (meta.hash_hex != hash).then_some(meta.hash_hex)
        } else {
            if name == PLAN_FILE || name.starts_with("magquilt-tmp-") {
                std::fs::remove_file(entry.path())
                    .with_context(|| format!("removing stale {name}"))?;
            }
            continue;
        };
        if let Some(other) = foreign {
            bail!(
                "segment dir {} holds {name} from plan {other} — refusing to overwrite another \
                 run's segments; merge or remove them, or pick a different --segment-dir",
                dir.display(),
            );
        }
    }
    Ok(())
}

/// Remove every `magquilt-tmp-*` leftover in `dir`. Crashed worker
/// attempts leak their in-flight temp file by design (the atomic-rename
/// protocol's whole point), and the merge refuses to run over temps; the
/// driver calls this once all children are provably dead, when deleting
/// them is safe.
fn sweep_temp_files(dir: &Path) -> Result<usize> {
    let mut swept = 0;
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        if name.to_string_lossy().starts_with("magquilt-tmp-") {
            std::fs::remove_file(entry.path())
                .with_context(|| format!("sweeping {}", name.to_string_lossy()))?;
            swept += 1;
        }
    }
    Ok(swept)
}

/// Run a whole distributed sample on this machine: write the plan
/// manifest into `segment_dir`, spawn one `shard-worker` process per
/// worker (using `worker_exe`, normally the current `magquilt` binary),
/// supervise them to completion, merge the segments into `out`, and
/// drain the segment directory. Equivalent to [`run_distributed_with`]
/// with the plan's own retry/backoff knobs.
pub fn run_distributed(
    plan: &ShardPlan,
    segment_dir: &Path,
    out: &Path,
    worker_exe: &Path,
) -> Result<DistReport> {
    run_distributed_with(plan, segment_dir, out, worker_exe, &SuperviseOptions::from_plan(plan))
}

/// [`run_distributed`] with explicit supervision options (retry budget,
/// backoff, stall deadline, and the optional first-attempt fault
/// injection used by the crash tests and the CI smoke leg).
///
/// Worker stdout/stderr are inherited, so per-worker progress lines
/// interleave with the driver's. Workers always run with `--resume`:
/// the first attempt finds nothing to resume (the directory was cleaned
/// up front), and every restart skips ahead over whatever its crashed
/// predecessor completed. A worker exhausting its retry budget fails the
/// run; the supervisor kills and reaps the remaining children, and the
/// segments are left in place — rerunning the same command resumes from
/// them.
pub fn run_distributed_with(
    plan: &ShardPlan,
    segment_dir: &Path,
    out: &Path,
    worker_exe: &Path,
    opts: &SuperviseOptions,
) -> Result<DistReport> {
    run_distributed_telemetry(plan, segment_dir, out, worker_exe, opts, &DistTelemetry::default())
}

/// [`run_distributed_with`] plus telemetry outputs: when
/// [`DistTelemetry::trace`] is set, each worker runs with `--trace`, the
/// driver absorbs every worker's trace stream into its own (plus its
/// driver/merge lifecycle events) and writes the combined JSONL to that
/// path; when [`DistTelemetry::report`] is set, workers run with
/// `--report` and the driver composes their reports plus the merge
/// outcome into one `report.json` of kind `driver`. The worker telemetry
/// files are collected *before* the merge drains the segment directory.
/// Telemetry is write-only: the output file is byte-identical with it on
/// or off (the trace-sink lint makes that structural).
pub fn run_distributed_telemetry(
    plan: &ShardPlan,
    segment_dir: &Path,
    out: &Path,
    worker_exe: &Path,
    opts: &SuperviseOptions,
    telemetry: &DistTelemetry,
) -> Result<DistReport> {
    plan.validate()?;
    std::fs::create_dir_all(segment_dir)
        .with_context(|| format!("creating segment dir {}", segment_dir.display()))?;
    clean_stale_artifacts(segment_dir, plan)?;
    let plan_path = segment_dir.join(PLAN_FILE);
    plan.save(&plan_path)?;

    let hash = plan.hash_hex();
    let trace = if telemetry.trace.is_some() {
        TraceHandle::new(&hash, "driver", None)
    } else {
        TraceHandle::disabled()
    };
    trace.emit(
        "driver_start",
        &[
            ("workers", Fv::U(plan.num_workers() as u64)),
            ("shards", Fv::U(plan.num_shards as u64)),
        ],
    );
    let supervised =
        supervise_workers(plan.num_workers(), segment_dir, &hash, opts, |w, fault| {
            let mut cmd = Command::new(worker_exe);
            cmd.arg("shard-worker")
                .arg("--plan")
                .arg(&plan_path)
                .arg("--worker")
                .arg(w.to_string())
                .arg("--segment-dir")
                .arg(segment_dir)
                .arg("--resume")
                .stdin(Stdio::null());
            if let Some(artifact) = &opts.artifact {
                cmd.arg("--artifact").arg(artifact);
            }
            if telemetry.trace.is_some() {
                cmd.arg("--trace");
            }
            if telemetry.report.is_some() {
                cmd.arg("--report");
            }
            if let Some(spec) = fault {
                cmd.arg("--inject-fault").arg(spec);
            }
            cmd
        })?;
    trace.emit("workers_done", &[("restarts", Fv::U(supervised.restarts as u64))]);

    // Collect worker telemetry *before* the merge: remove_inputs drains
    // every same-plan meta file, telemetry included.
    let mut worker_reports = Vec::new();
    for w in 0..plan.num_workers() {
        if telemetry.trace.is_some() {
            let path = segment_dir.join(trace_file_name(&hash, w));
            if let Ok(text) = std::fs::read_to_string(&path) {
                trace.absorb_stream(&text);
            }
        }
        if telemetry.report.is_some() {
            let path = segment_dir.join(report_file_name(&hash, w));
            if let Ok(text) = std::fs::read_to_string(&path) {
                worker_reports.push(text.trim().to_string());
            }
        }
    }

    // All children are reaped (success or not), so leftover temps from
    // crashed attempts are provably dead and safe to sweep; the merge
    // would otherwise refuse to run over them.
    sweep_temp_files(segment_dir)?;

    let merge_opts = MergeOptions {
        merge_threads: plan.merge_threads,
        remove_inputs: true,
        trace: trace.clone(),
        ..Default::default()
    };
    let merge = merge_segments_with(segment_dir, plan, out, &merge_opts)?;
    std::fs::remove_file(&plan_path).ok();
    // Remove the directory if we own all of it (ignore failure: the user
    // may have pointed --segment-dir at a shared location, or the doctor
    // may have quarantined files there).
    std::fs::remove_dir(segment_dir).ok();
    let report = DistReport { workers: plan.num_workers(), restarts: supervised.restarts, merge };
    if let Some(path) = &telemetry.trace {
        trace.write_to(path)?;
    }
    if let Some(path) = &telemetry.report {
        let (dir, name) = crate::trace::split_dir_name(path)
            .with_context(|| format!("driver report path {} has no file name", path.display()))?;
        let body = driver_report_json(&hash, &report, worker_reports);
        crate::graph::write_atomic(&dir, &name, body.as_bytes())
            .with_context(|| format!("writing driver report {}", path.display()))?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_stale_artifacts_keeps_resume_state_and_guards_foreign_plans() {
        let dir = std::env::temp_dir().join("magquilt_dist_clean_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let plan = ShardPlan::new(
            &crate::config::ModelSpec::default_spec(),
            &crate::config::RunSpec::default_spec(),
            2,
        )
        .unwrap();
        let hash = plan.hash_hex();
        std::fs::write(dir.join(PLAN_FILE), "stale").unwrap();
        std::fs::write(dir.join(segment_file_name(&hash, 0, 0)), "resume me").unwrap();
        std::fs::write(dir.join(overflow_file_name(&hash, 1, 1)), "resume me").unwrap();
        std::fs::write(dir.join(marker_file_name(&hash, 0)), "resume me").unwrap();
        std::fs::write(dir.join(heartbeat_file_name(&hash, 1)), "").unwrap();
        std::fs::write(dir.join(trace_file_name(&hash, 0)), "stale telemetry").unwrap();
        std::fs::write(dir.join(report_file_name(&hash, 1)), "stale telemetry").unwrap();
        std::fs::write(dir.join("magquilt-tmp-1-x-0-seg.part"), "stale").unwrap();
        std::fs::write(dir.join("keep.txt"), "user data").unwrap();
        clean_stale_artifacts(&dir, &plan).unwrap();
        let mut left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        left.sort();
        // Resume state (segments, overflow, marker) survives; the temp,
        // the stale heartbeat, the stale telemetry, and the stale
        // manifest are gone.
        assert_eq!(
            left,
            vec![
                "keep.txt".to_string(),
                marker_file_name(&hash, 0),
                overflow_file_name(&hash, 1, 1),
                segment_file_name(&hash, 0, 0),
            ]
        );

        // Another plan's artifacts are sacred: the driver must refuse,
        // not silently destroy a different run's collected (unmerged)
        // work — whether segments or markers.
        let foreign = dir.join("seg-deadbeefdeadbeef-s00000-w0000.seg");
        std::fs::write(&foreign, "another run").unwrap();
        let err = clean_stale_artifacts(&dir, &plan).unwrap_err();
        assert!(err.to_string().contains("refusing to overwrite"), "{err}");
        assert!(foreign.exists(), "foreign segment must survive");
        std::fs::remove_file(&foreign).unwrap();
        let foreign_marker = dir.join("done-deadbeefdeadbeef-w0000.ok");
        std::fs::write(&foreign_marker, "another run").unwrap();
        let err = clean_stale_artifacts(&dir, &plan).unwrap_err();
        assert!(err.to_string().contains("refusing to overwrite"), "{err}");
        assert!(foreign_marker.exists(), "foreign marker must survive");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn driver_report_renders_and_validates() {
        let report = DistReport {
            workers: 3,
            restarts: 1,
            merge: MergeReport {
                shards: Vec::new(),
                total_edges: 42,
                merge_threads: 2,
                merge_ms: 1.5,
                deferred_shards: 0,
                spilled_shards: 0,
            },
        };
        let worker_report = r#"{"format":"MAGQRPT1","kind":"worker"}"#.to_string();
        let json = driver_report_json("00ff00ff00ff00ff", &report, vec![worker_report]);
        let kind = crate::trace::report::validate_report(&json).unwrap();
        assert_eq!(kind, "driver");
        assert!(json.contains("\"workers\":3"), "{json}");
        assert!(json.contains("\"restarts\":1"), "{json}");
        assert!(json.contains("\"total_edges\":42"), "{json}");
        assert!(json.contains("\"kind\":\"worker\""), "{json}");
    }

    #[test]
    fn sweep_temp_files_removes_only_temps() {
        let dir = std::env::temp_dir().join("magquilt_dist_sweep_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("magquilt-tmp-9-aa-0-seg.part"), "dead").unwrap();
        std::fs::write(dir.join("magquilt-tmp-9-aa-1-ovf.part"), "dead").unwrap();
        std::fs::write(dir.join("seg-0000000000000000-s00000-w0000.seg"), "keep").unwrap();
        assert_eq!(sweep_temp_files(&dir).unwrap(), 2);
        let left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(left, vec!["seg-0000000000000000-s00000-w0000.seg".to_string()]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
