//! `magquilt doctor`: classify, repair, or quarantine the contents of a
//! segment directory after a crash.
//!
//! A segment directory is an append-only ledger of atomic renames, so
//! after any crash its files fall into a small set of classes:
//!
//! * **complete** — a validly named segment/overflow file whose header
//!   checks out against the plan; kept.
//! * **truncated** — a final-named file that fails validation (short
//!   header, wrong magic, size/edge-count mismatch, wrong node count).
//!   Final names are only produced by renames of complete files, so this
//!   means external corruption; quarantined.
//! * **stale temp** — a `magquilt-tmp-*` leftover from a dead attempt
//!   (the crash-before-rename and mid-write windows leave these);
//!   removed.
//! * **foreign plan** — any artifact carrying a different plan hash;
//!   quarantined (it may be another run's unmerged work — never deleted).
//! * **orphaned / misplaced** — a file whose name contradicts the plan's
//!   topology (overflow from the shard's own owner, out-of-range shard
//!   or worker, owner segment from a non-owner); quarantined.
//! * **stale marker / heartbeat** — completion markers that disagree
//!   with the segments actually on disk, and leftover liveness beacons;
//!   removed (a marker is cheap to re-earn by re-running the worker).
//! * **setup artifact** — a shared `setup-*.art` prologue file
//!   ([`crate::setup`]); an input the workers read, not run state, and
//!   self-validating on load; kept.
//! * **telemetry** — a worker's `trc-*.trace.jsonl` / `rpt-*.report.json`
//!   ([`crate::trace`]); write-only observability that never feeds the
//!   merge, and evidence worth preserving after a crash; kept (foreign
//!   telemetry follows the foreign-plan rule like everything else).
//!
//! Quarantine moves files into a `quarantine/` subdirectory instead of
//! deleting them: the doctor's job is to make the directory mergeable
//! again without destroying evidence (or another plan's data). Without
//! `--fix`, the doctor only reports what it *would* do.
//!
//! When no plan manifest is available, the doctor falls back to a
//! majority vote over the hashes embedded in the file names (ties break
//! to the lexicographically smallest hash) and skips the plan-dependent
//! checks (node counts, ownership topology).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::graph::read_binary_header;

use super::plan::ShardPlan;
use super::worker::{
    parse_marker, parse_meta_file_name, parse_segment_file_name, MetaFileKind, SegmentKind,
};

/// Subdirectory quarantined files are moved into.
pub const QUARANTINE_DIR: &str = "quarantine";

/// What the doctor concluded about one file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FileStatus {
    /// A valid segment/overflow file (or trusted marker) of this plan.
    Complete,
    /// A final-named segment that fails validation.
    Truncated(String),
    /// A `magquilt-tmp-*` leftover from a dead attempt.
    StaleTemp,
    /// An artifact from a different plan (its hash).
    ForeignPlan(String),
    /// An overflow file contradicting the plan's topology.
    OrphanedOverflow(String),
    /// An owner segment contradicting the plan's topology.
    Misplaced(String),
    /// A completion marker that disagrees with the disk.
    StaleMarker(String),
    /// A leftover liveness beacon.
    StaleHeartbeat,
    /// A shared setup artifact (`setup-*.art`): an input, not run state.
    Artifact,
    /// This plan's trace/report telemetry: write-only observability,
    /// kept as post-crash evidence.
    Telemetry,
    /// A name the runtime never produces.
    Unrecognized,
}

impl FileStatus {
    /// Human-readable label (the reason travels separately).
    pub fn label(&self) -> &'static str {
        match self {
            FileStatus::Complete => "complete",
            FileStatus::Truncated(_) => "truncated",
            FileStatus::StaleTemp => "stale-temp",
            FileStatus::ForeignPlan(_) => "foreign-plan",
            FileStatus::OrphanedOverflow(_) => "orphaned-overflow",
            FileStatus::Misplaced(_) => "misplaced",
            FileStatus::StaleMarker(_) => "stale-marker",
            FileStatus::StaleHeartbeat => "stale-heartbeat",
            FileStatus::Artifact => "artifact",
            FileStatus::Telemetry => "telemetry",
            FileStatus::Unrecognized => "unrecognized",
        }
    }

    /// The repair this status calls for.
    fn remedy(&self) -> Remedy {
        match self {
            FileStatus::Complete | FileStatus::Artifact | FileStatus::Telemetry => Remedy::Keep,
            FileStatus::StaleTemp | FileStatus::StaleMarker(_) | FileStatus::StaleHeartbeat => {
                Remedy::Remove
            }
            FileStatus::Truncated(_)
            | FileStatus::ForeignPlan(_)
            | FileStatus::OrphanedOverflow(_)
            | FileStatus::Misplaced(_)
            | FileStatus::Unrecognized => Remedy::Quarantine,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Remedy {
    Keep,
    Remove,
    Quarantine,
}

/// What happened (or would happen) to one file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DoctorAction {
    /// Healthy; left in place.
    Kept,
    /// Deleted (`--fix`).
    Removed,
    /// Moved into `quarantine/` (`--fix`).
    Quarantined,
    /// Would be deleted without `--fix`.
    WouldRemove,
    /// Would be quarantined without `--fix`.
    WouldQuarantine,
}

/// One examined file.
#[derive(Debug, Clone)]
pub struct DoctorEntry {
    /// File name inside the segment directory.
    pub name: String,
    /// The diagnosis.
    pub status: FileStatus,
    /// What was (or would be) done about it.
    pub action: DoctorAction,
}

/// The doctor's full findings for one directory.
#[derive(Debug)]
pub struct DoctorReport {
    /// The reference plan hash the classification ran against (absent
    /// only for a directory with no recognizable artifacts at all).
    pub hash: Option<String>,
    /// Per-file rows, sorted by name.
    pub entries: Vec<DoctorEntry>,
    /// Files deleted (or that would be).
    pub removed: usize,
    /// Files quarantined (or that would be).
    pub quarantined: usize,
}

impl DoctorReport {
    /// Whether the directory needs (or needed) any repair at all.
    pub fn healthy(&self) -> bool {
        self.removed == 0 && self.quarantined == 0
    }
}

/// Pick the reference hash by majority vote over all hash-carrying file
/// names (ties break to the lexicographically smallest hash).
fn majority_hash(names: &[String]) -> Option<String> {
    let mut votes: BTreeMap<String, usize> = BTreeMap::new();
    for name in names {
        let hash = parse_segment_file_name(name)
            .map(|i| i.hash_hex)
            .or_else(|| parse_meta_file_name(name).map(|i| i.hash_hex));
        if let Some(h) = hash {
            *votes.entry(h).or_insert(0) += 1;
        }
    }
    // BTreeMap iterates in key order, so with `>` on the count the first
    // (lexicographically smallest) hash wins ties.
    let mut best: Option<(String, usize)> = None;
    for (h, n) in votes {
        if best.as_ref().map_or(true, |(_, bn)| n > *bn) {
            best = Some((h, n));
        }
    }
    best.map(|(h, _)| h)
}

/// Move `path` into `dir/quarantine/`, suffixing the name on collision.
fn quarantine(dir: &Path, name: &str) -> Result<()> {
    let qdir = dir.join(QUARANTINE_DIR);
    std::fs::create_dir_all(&qdir)
        .with_context(|| format!("creating {}", qdir.display()))?;
    let mut target = qdir.join(name);
    let mut suffix = 0;
    while target.exists() {
        suffix += 1;
        if suffix > 1000 {
            bail!("cannot find a free quarantine name for {name}");
        }
        target = qdir.join(format!("{name}.{suffix}"));
    }
    std::fs::rename(dir.join(name), &target)
        .with_context(|| format!("quarantining {name} into {}", target.display()))
}

/// Examine `dir` and classify every file; with `fix`, apply the
/// remedies (delete stale files, move damaged/foreign ones into
/// `quarantine/`). `plan` enables the plan-dependent checks; without it
/// the reference hash comes from a majority vote over the file names.
pub fn doctor(dir: &Path, plan: Option<&ShardPlan>, fix: bool) -> Result<DoctorReport> {
    let mut names: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("reading segment directory {}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name == super::PLAN_FILE || (name == QUARANTINE_DIR && entry.path().is_dir()) {
            continue;
        }
        names.push(name);
    }
    names.sort();
    let hash = match plan {
        Some(p) => Some(p.hash_hex()),
        None => majority_hash(&names),
    };

    // First pass: segments and overflow files (markers are judged
    // against the set of valid segments, so they need a second pass).
    let mut statuses: BTreeMap<String, FileStatus> = BTreeMap::new();
    // worker → (segments present and valid, their edge total).
    let mut valid_owned: BTreeMap<usize, (usize, u64)> = BTreeMap::new();
    for name in &names {
        if name.starts_with("magquilt-tmp-") {
            statuses.insert(name.clone(), FileStatus::StaleTemp);
            continue;
        }
        if crate::setup::is_artifact_file(name) {
            // A setup artifact is a run *input* (self-validating on load),
            // not crash residue; never remove or quarantine it.
            statuses.insert(name.clone(), FileStatus::Artifact);
            continue;
        }
        if parse_meta_file_name(name).is_some() {
            continue; // second pass
        }
        let Some(info) = parse_segment_file_name(name) else {
            statuses.insert(name.clone(), FileStatus::Unrecognized);
            continue;
        };
        if hash.as_deref() != Some(info.hash_hex.as_str()) {
            statuses.insert(name.clone(), FileStatus::ForeignPlan(info.hash_hex));
            continue;
        }
        if let Some(p) = plan {
            let topology = if info.shard >= p.num_shards {
                Some(format!("shard {} out of range (plan has {})", info.shard, p.num_shards))
            } else if info.worker >= p.num_workers() {
                Some(format!(
                    "worker {} out of range (plan has {})",
                    info.worker,
                    p.num_workers()
                ))
            } else {
                let owner = p.owner_of_shard(info.shard);
                match info.kind {
                    SegmentKind::Owned if info.worker != owner => {
                        Some(format!("shard {} is owned by worker {owner}", info.shard))
                    }
                    SegmentKind::Overflow if info.worker == owner => Some(format!(
                        "worker {owner} owns shard {} and cannot overflow into it",
                        info.shard
                    )),
                    _ => None,
                }
            };
            if let Some(reason) = topology {
                let status = match info.kind {
                    SegmentKind::Owned => FileStatus::Misplaced(reason),
                    SegmentKind::Overflow => FileStatus::OrphanedOverflow(reason),
                };
                statuses.insert(name.clone(), status);
                continue;
            }
        }
        let header = match read_binary_header(&dir.join(name)) {
            Ok(h) => h,
            Err(e) => {
                statuses.insert(name.clone(), FileStatus::Truncated(e.to_string()));
                continue;
            }
        };
        if let Some(p) = plan {
            if header.num_nodes != p.model.num_nodes() as u64 {
                statuses.insert(
                    name.clone(),
                    FileStatus::Truncated(format!(
                        "claims {} nodes but the plan's model has {}",
                        header.num_nodes,
                        p.model.num_nodes()
                    )),
                );
                continue;
            }
        }
        if info.kind == SegmentKind::Owned {
            let slot = valid_owned.entry(info.worker).or_insert((0, 0));
            slot.0 += 1;
            slot.1 += header.num_edges;
        }
        statuses.insert(name.clone(), FileStatus::Complete);
    }

    // Second pass: markers and heartbeats.
    for name in &names {
        let Some(meta) = parse_meta_file_name(name) else { continue };
        if hash.as_deref() != Some(meta.hash_hex.as_str()) {
            statuses.insert(name.clone(), FileStatus::ForeignPlan(meta.hash_hex));
            continue;
        }
        if meta.kind == MetaFileKind::Heartbeat {
            // Any heartbeat the doctor sees is a dead worker's: doctoring
            // a directory with live workers is already undefined.
            statuses.insert(name.clone(), FileStatus::StaleHeartbeat);
            continue;
        }
        if matches!(meta.kind, MetaFileKind::Trace | MetaFileKind::Report) {
            statuses.insert(name.clone(), FileStatus::Telemetry);
            continue;
        }
        let verdict = std::fs::read_to_string(dir.join(name))
            .ok()
            .and_then(|text| parse_marker(&text))
            .map_or(Some("unparseable contents".to_string()), |(h, w, s)| {
                if h != meta.hash_hex || w != meta.worker {
                    return Some("contents disagree with the file name".to_string());
                }
                let Some(p) = plan else { return None };
                let Ok(owned) = p.worker_range(w) else {
                    return Some(format!("worker {w} out of the plan's range"));
                };
                let width = owned.1 - owned.0;
                let (have, edges) = valid_owned.get(&w).copied().unwrap_or((0, 0));
                if s.owned_segments != width || have != width || s.owned_edges != edges {
                    return Some(format!(
                        "records {} segments / {} edges but {have} valid segments / {edges} \
                         edges are on disk",
                        s.owned_segments, s.owned_edges
                    ));
                }
                None
            });
        let status = match verdict {
            None => FileStatus::Complete,
            Some(reason) => FileStatus::StaleMarker(reason),
        };
        statuses.insert(name.clone(), status);
    }

    // Apply remedies.
    let mut report =
        DoctorReport { hash, entries: Vec::with_capacity(names.len()), removed: 0, quarantined: 0 };
    for name in &names {
        let status = statuses
            .remove(name)
            .unwrap_or(FileStatus::Unrecognized);
        let action = match status.remedy() {
            Remedy::Keep => DoctorAction::Kept,
            Remedy::Remove => {
                report.removed += 1;
                if fix {
                    std::fs::remove_file(dir.join(name))
                        .with_context(|| format!("removing {name}"))?;
                    DoctorAction::Removed
                } else {
                    DoctorAction::WouldRemove
                }
            }
            Remedy::Quarantine => {
                report.quarantined += 1;
                if fix {
                    quarantine(dir, name)?;
                    DoctorAction::Quarantined
                } else {
                    DoctorAction::WouldQuarantine
                }
            }
        };
        report.entries.push(DoctorEntry { name: name.clone(), status, action });
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelSpec, RunSpec};
    use crate::dist::worker::{
        heartbeat_file_name, marker_file_name, overflow_file_name, report_file_name,
        segment_file_name, trace_file_name, write_marker, SegmentSummary,
    };
    use crate::graph::{write_edge_list_binary, EdgeList};

    fn test_plan() -> ShardPlan {
        let mut model = ModelSpec::default_spec();
        model.log2_nodes = 4;
        model.attributes = 4;
        let mut run = RunSpec::default_spec();
        run.shards = 4;
        ShardPlan::new(&model, &run, 2).unwrap()
    }

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("magquilt_doctor_test").join(tag);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn status_of<'r>(report: &'r DoctorReport, name: &str) -> &'r DoctorEntry {
        report.entries.iter().find(|e| e.name == name).unwrap()
    }

    #[test]
    fn classifies_and_repairs_every_crash_residue() {
        let plan = test_plan();
        let hash = plan.hash_hex();
        let dir = fresh_dir("classify");
        let n = 16;
        // Worker 0 owns shards {0,1}; worker 1 owns {2,3}.
        let good_seg = segment_file_name(&hash, 0, 0);
        write_edge_list_binary(&EdgeList::from_edges(n, vec![(0, 1)]), &dir.join(&good_seg))
            .unwrap();
        let good_ovf = overflow_file_name(&hash, 2, 0);
        write_edge_list_binary(&EdgeList::from_edges(n, vec![(8, 0)]), &dir.join(&good_ovf))
            .unwrap();
        let truncated = segment_file_name(&hash, 1, 0);
        std::fs::write(dir.join(&truncated), b"MAGQ").unwrap();
        let foreign = segment_file_name("deadbeefdeadbeef", 0, 0);
        std::fs::write(dir.join(&foreign), b"other").unwrap();
        let temp = "magquilt-tmp-12-00ff-0-seg.part";
        std::fs::write(dir.join(temp), b"junk").unwrap();
        let self_ovf = overflow_file_name(&hash, 0, 0);
        write_edge_list_binary(&EdgeList::from_edges(n, vec![(0, 2)]), &dir.join(&self_ovf))
            .unwrap();
        let misplaced = segment_file_name(&hash, 2, 0);
        write_edge_list_binary(&EdgeList::from_edges(n, vec![(8, 1)]), &dir.join(&misplaced))
            .unwrap();
        let hb = heartbeat_file_name(&hash, 0);
        std::fs::write(dir.join(&hb), b"").unwrap();
        // Marker claiming worker 0 finished: stale (shard 1 is truncated).
        let summary = SegmentSummary {
            owned_segments: 2,
            owned_edges: 2,
            overflow_files: 1,
            overflow_edges: 1,
        };
        write_marker(&dir, &hash, 0, &summary).unwrap();
        let marker = marker_file_name(&hash, 0);
        std::fs::write(dir.join("notes.txt"), "?").unwrap();
        std::fs::write(dir.join(super::super::PLAN_FILE), "ignored").unwrap();
        let artifact = "setup-0011223344556677.art";
        std::fs::write(dir.join(artifact), b"opaque to the doctor").unwrap();
        let trace = trace_file_name(&hash, 0);
        std::fs::write(dir.join(&trace), "{\"format\":\"MAGQTRC1\"}\n").unwrap();
        let rpt = report_file_name(&hash, 1);
        std::fs::write(dir.join(&rpt), "{\"format\":\"MAGQRPT1\"}").unwrap();
        let foreign_trace = trace_file_name("deadbeefdeadbeef", 0);
        std::fs::write(dir.join(&foreign_trace), "other run's telemetry").unwrap();

        // Dry run: everything classified, nothing touched.
        let report = doctor(&dir, Some(&plan), false).unwrap();
        assert_eq!(report.hash.as_deref(), Some(hash.as_str()));
        assert!(!report.healthy());
        assert_eq!(status_of(&report, &good_seg).status, FileStatus::Complete);
        assert_eq!(status_of(&report, &good_ovf).status, FileStatus::Complete);
        assert!(matches!(status_of(&report, &truncated).status, FileStatus::Truncated(_)));
        assert!(matches!(status_of(&report, &foreign).status, FileStatus::ForeignPlan(_)));
        assert_eq!(status_of(&report, temp).status, FileStatus::StaleTemp);
        assert!(matches!(
            status_of(&report, &self_ovf).status,
            FileStatus::OrphanedOverflow(_)
        ));
        assert!(matches!(status_of(&report, &misplaced).status, FileStatus::Misplaced(_)));
        assert_eq!(status_of(&report, &hb).status, FileStatus::StaleHeartbeat);
        assert!(matches!(status_of(&report, &marker).status, FileStatus::StaleMarker(_)));
        assert_eq!(status_of(&report, "notes.txt").status, FileStatus::Unrecognized);
        assert_eq!(status_of(&report, artifact).status, FileStatus::Artifact);
        assert_eq!(status_of(&report, artifact).action, DoctorAction::Kept);
        assert_eq!(status_of(&report, &trace).status, FileStatus::Telemetry);
        assert_eq!(status_of(&report, &trace).action, DoctorAction::Kept);
        assert_eq!(status_of(&report, &rpt).status, FileStatus::Telemetry);
        assert!(matches!(
            status_of(&report, &foreign_trace).status,
            FileStatus::ForeignPlan(_)
        ));
        assert_eq!(status_of(&report, temp).action, DoctorAction::WouldRemove);
        assert_eq!(status_of(&report, &foreign).action, DoctorAction::WouldQuarantine);
        assert!(dir.join(&truncated).exists(), "dry run touches nothing");
        assert!(dir.join(temp).exists());

        // Fix: stale files removed, damaged/foreign quarantined.
        let report = doctor(&dir, Some(&plan), true).unwrap();
        assert_eq!(report.removed, 3, "temp + heartbeat + marker");
        assert_eq!(
            report.quarantined,
            6,
            "truncated + foreign seg + foreign trace + ovf + misplaced + notes"
        );
        assert!(dir.join(&good_seg).exists());
        assert!(dir.join(&good_ovf).exists());
        assert!(dir.join(artifact).exists(), "setup artifacts are inputs, never repaired away");
        assert!(dir.join(&trace).exists(), "this plan's telemetry is evidence, kept");
        assert!(dir.join(&rpt).exists());
        assert!(!dir.join(temp).exists());
        assert!(!dir.join(&hb).exists());
        assert!(!dir.join(&marker).exists());
        let q = dir.join(QUARANTINE_DIR);
        assert!(q.join(&truncated).exists());
        assert!(q.join(&foreign).exists());
        assert!(q.join(&self_ovf).exists());
        assert!(q.join(&misplaced).exists());
        assert!(q.join(&foreign_trace).exists());
        assert!(q.join("notes.txt").exists());

        // The directory is now healthy (the quarantine dir is ignored).
        let report = doctor(&dir, Some(&plan), false).unwrap();
        assert!(report.healthy(), "{report:?}");
    }

    #[test]
    fn trusted_marker_is_kept() {
        let plan = test_plan();
        let hash = plan.hash_hex();
        let dir = fresh_dir("marker_ok");
        let n = 16;
        write_edge_list_binary(
            &EdgeList::from_edges(n, vec![(0, 1), (2, 0)]),
            &dir.join(segment_file_name(&hash, 0, 0)),
        )
        .unwrap();
        write_edge_list_binary(
            &EdgeList::from_edges(n, vec![(4, 4)]),
            &dir.join(segment_file_name(&hash, 1, 0)),
        )
        .unwrap();
        let summary = SegmentSummary {
            owned_segments: 2,
            owned_edges: 3,
            overflow_files: 0,
            overflow_edges: 0,
        };
        write_marker(&dir, &hash, 0, &summary).unwrap();
        let report = doctor(&dir, Some(&plan), false).unwrap();
        assert!(report.healthy(), "{report:?}");
        assert_eq!(
            status_of(&report, &marker_file_name(&hash, 0)).status,
            FileStatus::Complete
        );
    }

    #[test]
    fn majority_hash_breaks_ties_lexicographically() {
        let names = vec![
            segment_file_name("bbbbbbbbbbbbbbbb", 0, 0),
            segment_file_name("aaaaaaaaaaaaaaaa", 0, 0),
            segment_file_name("bbbbbbbbbbbbbbbb", 1, 0),
            segment_file_name("aaaaaaaaaaaaaaaa", 1, 0),
            "notes.txt".to_string(),
        ];
        assert_eq!(majority_hash(&names).as_deref(), Some("aaaaaaaaaaaaaaaa"));
        let names = vec![
            segment_file_name("bbbbbbbbbbbbbbbb", 0, 0),
            segment_file_name("bbbbbbbbbbbbbbbb", 1, 0),
            segment_file_name("aaaaaaaaaaaaaaaa", 0, 0),
        ];
        assert_eq!(majority_hash(&names).as_deref(), Some("bbbbbbbbbbbbbbbb"));
        assert_eq!(majority_hash(&["x.txt".to_string()]), None);
    }

    #[test]
    fn planless_mode_still_classifies_by_name_and_header() {
        let dir = fresh_dir("planless");
        let n = 16;
        let hash = "aaaaaaaaaaaaaaaa";
        write_edge_list_binary(
            &EdgeList::from_edges(n, vec![(0, 1)]),
            &dir.join(segment_file_name(hash, 0, 0)),
        )
        .unwrap();
        write_edge_list_binary(
            &EdgeList::from_edges(n, vec![(1, 1)]),
            &dir.join(segment_file_name(hash, 1, 0)),
        )
        .unwrap();
        let foreign = segment_file_name("ffffffffffffffff", 0, 0);
        std::fs::write(dir.join(&foreign), b"other plan").unwrap();
        let truncated = segment_file_name(hash, 2, 1);
        std::fs::write(dir.join(&truncated), b"MAGQ").unwrap();
        let report = doctor(&dir, None, false).unwrap();
        assert_eq!(report.hash.as_deref(), Some(hash));
        assert!(matches!(status_of(&report, &foreign).status, FileStatus::ForeignPlan(_)));
        assert!(matches!(status_of(&report, &truncated).status, FileStatus::Truncated(_)));
        assert_eq!(
            status_of(&report, &segment_file_name(hash, 0, 0)).status,
            FileStatus::Complete
        );
    }
}
