//! Paper **Algorithm 2**: sample a MAGM graph by quilting `B²` KPGM
//! samples.
//!
//! For every pair of partition sets `(D_k, D_l)` we sample one KPGM graph
//! with Algorithm 1 and keep only edges `(x, y)` where `x` is the
//! configuration of some node in `D_k` and `y` of some node in `D_l`;
//! those edges are un-permuted (`x = λ_i → i`) and appended to the output.
//! Theorem 3: the quilted adjacency entries are independent
//! `Bernoulli(Q_ij)`.
//!
//! Piece modes
//! -----------
//! The paper's literal reading ([`PieceMode::Rejection`]) drops
//! `X ≈ |E_KPGM|` balls over the full `2^d × 2^d` space for **each** of
//! the `B²` pieces and filters against the `(D_k, D_l)` maps —
//! `O(B² · d · |E_KPGM|)` work for `O(|E|)` retained output, with the
//! acceptance rate collapsing as `B` grows.
//!
//! The default ([`PieceMode::Conditioned`]) is the rejection-free
//! *conditioned quadrisection descent*
//! ([`crate::kpgm::ConditionedBallDropSampler`]): the per-set prefix
//! tries restrict every level of the descent to quadrants with retained
//! cells below them, renormalized by downstream reachable mass, so each
//! ball lands on a retained cell of the block with probability 1 and per
//! cell `(x, y)` with probability exactly `P[x, y] / m_kl`. The per-piece
//! edge count is drawn from the *restricted* mass
//! `m_kl = Σ_{(x,y) ∈ C_k × C_l} P[x, y]` (aggregated bottom-up in the
//! shared product DAG, not by an `O(|C_k|·|C_l|)` cell scan at sample
//! time), clamped to the block's `|D_k|·|D_l|` cells. Total sampling work
//! drops from `O(B² · d · |E_KPGM|)` to `O(d · |E|)` plus the one-off
//! `O(d · n)`-ish trie/DAG setup.
//!
//! One pragmatic bound: a *dense* block (more cells than the full-space
//! ball count, e.g. `D_1 × D_1` at balanced μ) keeps the plain descent
//! even in conditioned mode — its product DAG would cost more to build
//! than the rejections it avoids, and the full-space acceptance rate
//! `cells / 4^d` is high exactly there. Sparse blocks, where acceptance
//! collapses, are always conditioned. See
//! [`crate::kpgm::ConditionedBallDropSampler`].
//!
//! Implementation notes
//! --------------------
//! * Pieces stream: ball drops are appended directly to the shared output;
//!   the raw KPGM sample (which in rejection mode covers the whole
//!   `2^d × 2^d` space) is never materialized.
//! * Duplicate semantics follow the Algorithm-1 *pseudo-code* (`E ← E ∪
//!   {(S,T)}`, i.e. set union): duplicates collapse. Because distinct
//!   pieces write disjoint `(D_k, D_l)` blocks of A, one global dedup at
//!   the end is equivalent to per-piece set semantics.
//! * Conditioned pieces drop i.i.d. balls and collapse duplicates (exact
//!   Poisson thinning per cell); the rejection path keeps Algorithm 1's
//!   full-space resample-on-duplicate, and balls it abandons after
//!   `MAX_ATTEMPTS` are counted and surfaced (they used to vanish
//!   silently); see
//!   [`crate::coordinator::SampleReport::dropped_resamples`].
//! * Each piece gets an RNG forked from the base seed by its piece id, so
//!   results are reproducible and pieces can run on any worker in any
//!   order (see [`crate::coordinator`]).

use crate::graph::EdgeList;
use crate::hashutil::{fast_set_with_capacity, FastSet};
use crate::kpgm::{BallDropSampler, ConditionedBallDropSampler, PieceSampler};
use crate::magm::{AttributeAssignment, MagmParams};
use crate::rng::Rng;

use super::Partition;

/// How quilt pieces place their balls.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PieceMode {
    /// Conditioned quadrisection descent: every drop lands on a retained
    /// cell (no filter-discard loop). The default.
    #[default]
    Conditioned,
    /// Full-space Algorithm 1 plus filtering (the paper's literal
    /// procedure); kept for A/B validation and ablations.
    Rejection,
}

impl PieceMode {
    /// Parse from the CLI / config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "conditioned" => Some(PieceMode::Conditioned),
            "rejection" => Some(PieceMode::Rejection),
            _ => None,
        }
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            PieceMode::Conditioned => "conditioned",
            PieceMode::Rejection => "rejection",
        }
    }
}

/// One quilt piece: KPGM-sample restricted (or filtered) to `(D_k, D_l)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PieceJob {
    /// Source partition set index (0-based).
    pub k: usize,
    /// Target partition set index (0-based).
    pub l: usize,
    /// RNG fork id for the piece (stable across schedules).
    pub fork_id: u64,
}

/// The quilting sampler (paper Algorithm 2).
#[derive(Debug, Clone)]
pub struct QuiltSampler {
    params: MagmParams,
    seed: u64,
    mode: PieceMode,
}

impl QuiltSampler {
    /// New sampler; d ≤ 32 (the KPGM index space is `2^d`).
    pub fn new(params: MagmParams) -> Self {
        assert!(params.depth() <= 32, "quilting needs d <= 32 (KPGM ids are u32)");
        QuiltSampler { params, seed: 0, mode: PieceMode::default() }
    }

    /// Set the seed (builder style).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the piece mode (builder style; defaults to
    /// [`PieceMode::Conditioned`]).
    pub fn piece_mode(mut self, mode: PieceMode) -> Self {
        self.mode = mode;
        self
    }

    /// Model parameters.
    pub fn params(&self) -> &MagmParams {
        &self.params
    }

    /// Sample attributes then the graph.
    pub fn sample(&self) -> EdgeList {
        let mut rng = Rng::new(self.seed);
        let attrs = AttributeAssignment::sample(&self.params, &mut rng);
        self.sample_with_attrs(&attrs)
    }

    /// Sample a graph for a fixed attribute assignment.
    pub fn sample_with_attrs(&self, attrs: &AttributeAssignment) -> EdgeList {
        self.sample_with_attrs_reporting(attrs).0
    }

    /// As [`Self::sample_with_attrs`], also returning the number of balls
    /// abandoned after exhausting duplicate resamples (previously lost
    /// silently).
    pub fn sample_with_attrs_reporting(&self, attrs: &AttributeAssignment) -> (EdgeList, u64) {
        let mut partition = Partition::build(attrs.configs());
        maybe_build_dense(&mut partition, self.params.depth());
        let jobs = self.plan(&partition);
        let base = Rng::new(self.seed).fork(crate::rngtags::QUILT_PIECE_STREAM);
        let mut out = EdgeList::new(self.params.num_nodes());
        let mut dropped = 0u64;
        let kpgm = BallDropSampler::new(self.params.thetas().clone());
        let conditioner = (self.mode == PieceMode::Conditioned)
            .then(|| partition.conditioned_sampler(self.params.thetas()));
        for job in jobs {
            let backend = match &conditioner {
                Some(cond) => PieceBackend::Conditioned { cond, kpgm: &kpgm },
                None => PieceBackend::Rejection(&kpgm),
            };
            let mut rng = base.fork(job.fork_id);
            dropped += sample_piece(backend, &partition, job, &mut rng, &mut out);
        }
        out.dedup();
        (out, dropped)
    }

    /// The `B²` piece jobs for a partition (the coordinator distributes
    /// these across workers).
    pub fn plan(&self, partition: &Partition) -> Vec<PieceJob> {
        let b = partition.size();
        let mut jobs = Vec::with_capacity(b * b);
        for k in 0..b {
            for l in 0..b {
                jobs.push(PieceJob { k, l, fork_id: (k * b + l) as u64 });
            }
        }
        jobs
    }
}

/// Above this many ball drops the full-space duplicate set would dominate
/// memory AND time (it inserts every drop, retained or not; at millions of
/// entries each insert is a cache miss); switch to tracking duplicates only
/// among *retained* edges. The two modes differ by the full-space duplicate
/// rate ≈ (Σθ²/Σθ)^d, which is < 1% for every X above this threshold
/// (e.g. θ1 at d = 15 — the smallest d with X ≳ 2^20 — gives 0.7%).
const FULL_DEDUP_MAX_DROPS: u64 = 1 << 20;

/// Resample budget per ball on the rejection path before it is abandoned
/// (and counted); the conditioned path collapses duplicates instead.
const MAX_ATTEMPTS: u32 = 64;

/// Build the dense config→node index when the configuration space is small
/// enough. Two gates: this one caps a single table at `2^22 · 4` = 16 MB,
/// and [`Partition::build_dense_index`] additionally skips sets that would
/// be under 1/64 full, so the total dense memory is bounded by `256·n`
/// bytes — not `B · 2^d · 4` — even when `B` is large.
pub(crate) fn maybe_build_dense(partition: &mut Partition, depth: usize) {
    if depth <= 22 {
        partition.build_dense_index(1usize << depth);
    }
}

/// The shared sampling machinery a piece runs against, dispatched by
/// [`PieceMode`]. Workers hold it by reference (both variants are `Sync`).
#[derive(Debug, Clone, Copy)]
pub(crate) enum PieceBackend<'a> {
    /// Full-space Algorithm 1 + filter.
    Rejection(&'a BallDropSampler),
    /// Conditioned product-DAG descent; `kpgm` handles the dense blocks
    /// the budgeted DAG excludes (full-space acceptance is high there).
    Conditioned { cond: &'a ConditionedBallDropSampler, kpgm: &'a BallDropSampler },
}

/// Run one piece with the given backend; returns the number of balls
/// abandoned after exhausting duplicate resamples.
pub(crate) fn sample_piece(
    backend: PieceBackend<'_>,
    partition: &Partition,
    job: PieceJob,
    rng: &mut Rng,
    out: &mut EdgeList,
) -> u64 {
    match backend {
        PieceBackend::Rejection(kpgm) => sample_piece_rejection(kpgm, partition, job, rng, out),
        PieceBackend::Conditioned { cond, kpgm } => match cond.piece(job.k, job.l) {
            Some(piece) => sample_piece_conditioned(&piece, partition, job, rng, out),
            None => sample_piece_rejection(kpgm, partition, job, rng, out),
        },
    }
}

/// Conditioned piece: draw the block edge count `x ~ Poisson(m_kl)`, drop
/// `x` i.i.d. conditioned balls, and **collapse** duplicates (the
/// Algorithm-1 pseudo-code's set union, which the global dedup already
/// implements for cross-piece edges).
///
/// Collapse — not resample — is load-bearing for A/B parity: with i.i.d.
/// `Poisson(m_kl)` drops, Poisson thinning makes every block cell receive
/// an independent `Poisson(P[x,y])` hit count, so each cell is included
/// independently with probability `1 − e^{−P}` — the same marginal the
/// rejection path realizes (its within-block duplicates re-drop over the
/// full space and almost surely leave the block). Resampling to a fresh
/// *block* cell would instead force-distinct the placements and
/// over-include cells of saturated blocks.
///
/// Never abandons a ball (duplicates merge by design), so the returned
/// `dropped_resamples` contribution is always 0.
pub(crate) fn sample_piece_conditioned(
    piece: &PieceSampler<'_>,
    partition: &Partition,
    job: PieceJob,
    rng: &mut Rng,
    out: &mut EdgeList,
) -> u64 {
    let x = piece.draw_edge_count(rng);
    if x == 0 {
        return 0;
    }
    let mut seen: FastSet<u64> = fast_set_with_capacity(x as usize * 2);
    for _ in 0..x {
        let (s, t) = piece.drop_one(rng);
        if seen.insert((s << 32) | t) {
            // Conditioning guarantees the cell is retained: the lookups
            // cannot miss.
            let i = partition.lookup(job.k, s).expect("conditioned drop outside D_k");
            let j = partition.lookup(job.l, t).expect("conditioned drop outside D_l");
            out.push(i, j);
        }
    }
    0
}

/// Rejection piece (the paper's literal Algorithm 2 step): draw the
/// full-space KPGM edge count, stream ball drops with Algorithm 1's
/// resample-on-duplicate semantics, filter against the `(D_k, D_l)` maps,
/// un-permute, append.
pub(crate) fn sample_piece_rejection(
    kpgm: &BallDropSampler,
    partition: &Partition,
    job: PieceJob,
    rng: &mut Rng,
    out: &mut EdgeList,
) -> u64 {
    let x = kpgm.draw_edge_count(rng);
    let mut dropped = 0u64;
    if x <= FULL_DEDUP_MAX_DROPS {
        // Faithful Algorithm 1: re-drop until the ball lands on a fresh
        // cell of the full 2^d × 2^d space.
        let mut seen: FastSet<u64> = fast_set_with_capacity(x as usize * 2);
        for _ in 0..x {
            let mut resolved = false;
            for _ in 0..MAX_ATTEMPTS {
                let (s, t) = kpgm.drop_one(rng);
                if seen.insert(((s as u64) << 32) | t as u64) {
                    if let (Some(i), Some(j)) = (
                        partition.lookup(job.k, s as u64),
                        partition.lookup(job.l, t as u64),
                    ) {
                        out.push(i, j);
                    }
                    resolved = true;
                    break;
                }
            }
            if !resolved {
                dropped += 1;
            }
        }
    } else {
        // Memory-bounded variant: only retained cells are tracked; a
        // duplicate retained cell triggers a re-drop, duplicates among
        // discarded cells collapse silently.
        let mut seen: FastSet<u64> = FastSet::default();
        for _ in 0..x {
            let mut resolved = false;
            for _ in 0..MAX_ATTEMPTS {
                let (s, t) = kpgm.drop_one(rng);
                match (
                    partition.lookup(job.k, s as u64),
                    partition.lookup(job.l, t as u64),
                ) {
                    (Some(i), Some(j)) => {
                        if seen.insert(((i as u64) << 32) | j as u64) {
                            out.push(i, j);
                            resolved = true;
                            break;
                        }
                        // retained duplicate: re-drop
                    }
                    _ => {
                        resolved = true; // discarded ball, consumed
                        break;
                    }
                }
            }
            if !resolved {
                dropped += 1;
            }
        }
    }
    dropped
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeId;
    use crate::kpgm::Initiator;
    use crate::magm;

    #[test]
    fn plan_covers_all_pieces() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 64, 6);
        let s = QuiltSampler::new(params);
        let configs = vec![1u64, 1, 2, 3, 3, 3];
        let p = Partition::build(&configs);
        assert_eq!(p.size(), 3);
        let jobs = s.plan(&p);
        assert_eq!(jobs.len(), 9);
        // all (k, l) pairs present, fork ids unique
        let mut ids: Vec<u64> = jobs.iter().map(|j| j.fork_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 9);
    }

    #[test]
    fn sample_is_deterministic_in_seed() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 256, 8);
        let g1 = QuiltSampler::new(params.clone()).seed(7).sample();
        let g2 = QuiltSampler::new(params.clone()).seed(7).sample();
        let g3 = QuiltSampler::new(params).seed(8).sample();
        assert_eq!(g1, g2);
        assert_ne!(g1, g3);
    }

    #[test]
    fn rejection_mode_deterministic_too() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 256, 8);
        let g1 = QuiltSampler::new(params.clone()).piece_mode(PieceMode::Rejection).seed(7).sample();
        let g2 = QuiltSampler::new(params).piece_mode(PieceMode::Rejection).seed(7).sample();
        assert_eq!(g1, g2);
        assert!(g1.validate().is_ok());
    }

    #[test]
    fn no_duplicate_edges_after_sample() {
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.5, 512, 9);
        let mut g = QuiltSampler::new(params).seed(3).sample();
        assert_eq!(g.dedup(), 0);
    }

    #[test]
    fn edge_ids_in_bounds() {
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.7, 300, 9);
        let g = QuiltSampler::new(params).seed(5).sample();
        assert!(g.validate().is_ok());
        assert_eq!(g.num_nodes(), 300);
    }

    #[test]
    fn piece_mode_parses() {
        assert_eq!(PieceMode::parse("conditioned"), Some(PieceMode::Conditioned));
        assert_eq!(PieceMode::parse("rejection"), Some(PieceMode::Rejection));
        assert_eq!(PieceMode::parse("bogus"), None);
        assert_eq!(PieceMode::default().name(), "conditioned");
    }

    #[test]
    fn quilted_edge_count_tracks_q_expectation() {
        // For a FIXED attribute draw, E|E| = sum_ij Q_ij. Average the
        // quilted sampler over many seeds and compare.
        let n = 64;
        let d = 6;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
        let mut rng = Rng::new(211);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let mut want = 0.0;
        for i in 0..n as NodeId {
            for j in 0..n as NodeId {
                want += magm::edge_probability(&params, &attrs, i, j);
            }
        }
        let trials = 200;
        let mut total = 0usize;
        for t in 0..trials {
            let g = QuiltSampler::new(params.clone()).seed(1000 + t).sample_with_attrs(&attrs);
            total += g.num_edges();
        }
        let mean = total as f64 / trials as f64;
        // Ball-dropping + set-collapse biases slightly low; allow 5%.
        assert!(
            (mean - want).abs() / want < 0.05,
            "mean={mean} want={want}"
        );
    }

    #[test]
    fn restricted_mass_sums_to_full_expectation() {
        // Σ_{k,l} m_kl over all B² pieces must equal Σ_{i,j} P[λ_i, λ_j]
        // exactly: the blocks tile the adjacency matrix.
        let n = 64;
        let d = 6;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.6, n, d);
        let mut rng = Rng::new(227);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let mut partition = Partition::build(attrs.configs());
        partition.build_tries(d as usize);
        let cond = partition.conditioned_sampler(params.thetas());
        let b = partition.size();
        let mut total_mass = 0.0;
        let mut total_cells = 0u64;
        for k in 0..b {
            for l in 0..b {
                let piece = cond.piece(k, l).expect("small blocks are all conditioned");
                total_mass += piece.restricted_mass();
                total_cells += piece.num_cells();
            }
        }
        let mut want = 0.0;
        for i in 0..n as NodeId {
            for j in 0..n as NodeId {
                want += magm::edge_probability(&params, &attrs, i, j);
            }
        }
        assert!(
            (total_mass - want).abs() / want < 1e-9,
            "sum m_kl = {total_mass}, full expectation = {want}"
        );
        assert_eq!(total_cells, (n * n) as u64, "blocks must tile all n² cells");
    }

    #[test]
    fn conditioned_marginals_match_rejection() {
        // The A/B parity claim behind deprecating the rejection path: for
        // fixed attributes the two modes must have identical per-cell
        // marginals (both equal P[λ_i, λ_j] to first order).
        let n = 16;
        let d = 4;
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.5, n, d);
        let mut rng = Rng::new(233);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let trials = 3000u64;
        let mut cond_counts = vec![vec![0u32; n]; n];
        let mut rej_counts = vec![vec![0u32; n]; n];
        for t in 0..trials {
            let g = QuiltSampler::new(params.clone()).seed(t).sample_with_attrs(&attrs);
            for &(s, tt) in g.edges() {
                cond_counts[s as usize][tt as usize] += 1;
            }
            let g = QuiltSampler::new(params.clone())
                .piece_mode(PieceMode::Rejection)
                .seed(t)
                .sample_with_attrs(&attrs);
            for &(s, tt) in g.edges() {
                rej_counts[s as usize][tt as usize] += 1;
            }
        }
        for i in 0..n {
            for j in 0..n {
                let c = cond_counts[i][j] as f64 / trials as f64;
                let r = rej_counts[i][j] as f64 / trials as f64;
                let p = r.clamp(1e-4, 1.0 - 1e-4);
                let sigma = (2.0 * p * (1.0 - p) / trials as f64).sqrt();
                assert!(
                    (c - r).abs() < 6.0 * sigma + 0.01,
                    "cell ({i},{j}): conditioned {c:.4} vs rejection {r:.4}"
                );
            }
        }
    }

    #[test]
    fn per_edge_frequency_matches_permuted_kpgm() {
        // The paper's actual claim (eq. 8 + Alg. 2): quilting samples cell
        // (i, j) exactly like Algorithm 1 samples KPGM cell (λ_i, λ_j).
        // Compare empirical marginals of the two samplers; this isolates
        // the quilting machinery from the (known, inherited) ball-drop
        // approximation of Algorithm 1 itself.
        let n = 16;
        let d = 4;
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.5, n, d);
        let mut rng = Rng::new(223);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let trials = 4000u64;

        // Reference: direct Algorithm-1 KPGM sampling over the 2^d space.
        let kpgm_n = 1usize << d;
        let kpgm = crate::kpgm::BallDropSampler::new(params.thetas().clone());
        let mut ref_counts = vec![vec![0u32; kpgm_n]; kpgm_n];
        let mut kpgm_rng = Rng::new(777);
        for _ in 0..trials {
            for &(s, t) in kpgm.sample(&mut kpgm_rng).edges() {
                ref_counts[s as usize][t as usize] += 1;
            }
        }

        // Quilted MAGM sampling with fixed attributes.
        let mut counts = vec![vec![0u32; n]; n];
        for t in 0..trials {
            let g = QuiltSampler::new(params.clone()).seed(t).sample_with_attrs(&attrs);
            for &(s, tt) in g.edges() {
                counts[s as usize][tt as usize] += 1;
            }
        }

        for i in 0..n as NodeId {
            for j in 0..n as NodeId {
                let (li, lj) = (attrs.config(i) as usize, attrs.config(j) as usize);
                let want = ref_counts[li][lj] as f64 / trials as f64;
                let got = counts[i as usize][j as usize] as f64 / trials as f64;
                let sigma =
                    (want.max(1e-4) * (1.0 - want).max(1e-4) / trials as f64).sqrt();
                assert!(
                    (got - want).abs() < 6.0 * sigma * 1.5 + 0.01,
                    "cell ({i},{j}) ~ kpgm ({li},{lj}): got {got:.4}, want {want:.4}"
                );
            }
        }
    }

    #[test]
    fn works_when_d_less_than_log2n() {
        // n = 64 nodes but only d = 3 attributes (8 configs): B ~ n/8.
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 64, 3);
        let g = QuiltSampler::new(params).seed(2).sample();
        assert_eq!(g.num_nodes(), 64);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn works_when_d_greater_than_log2n() {
        // n = 16 nodes, d = 6 attributes: KPGM space is 64x64.
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 16, 6);
        let g = QuiltSampler::new(params).seed(2).sample();
        assert_eq!(g.num_nodes(), 16);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn both_modes_work_in_saturated_blocks() {
        // θ near 1 saturates blocks; the conditioned clamp to |D_k|·|D_l|
        // plus the resample budget must terminate and report drops.
        let params = MagmParams::homogeneous(
            Initiator::new([0.95, 0.95, 0.95, 0.95]),
            0.5,
            16,
            4,
        );
        for mode in [PieceMode::Conditioned, PieceMode::Rejection] {
            let sampler = QuiltSampler::new(params.clone()).piece_mode(mode).seed(11);
            let mut rng = Rng::new(11);
            let attrs = AttributeAssignment::sample(sampler.params(), &mut rng);
            let (mut g, _dropped) = sampler.sample_with_attrs_reporting(&attrs);
            assert!(g.num_edges() <= 16 * 16);
            assert_eq!(g.dedup(), 0);
        }
    }
}
