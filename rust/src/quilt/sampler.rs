//! Paper **Algorithm 2**: sample a MAGM graph by quilting `B²` KPGM
//! samples.
//!
//! For every pair of partition sets `(D_k, D_l)` we sample one KPGM graph
//! with Algorithm 1 and keep only edges `(x, y)` where `x` is the
//! configuration of some node in `D_k` and `y` of some node in `D_l`;
//! those edges are un-permuted (`x = λ_i → i`) and appended to the output.
//! Theorem 3: the quilted adjacency entries are independent
//! `Bernoulli(Q_ij)`.
//!
//! Implementation notes
//! --------------------
//! * Pieces stream: each ball drop is filtered immediately against the two
//!   `config → node` maps, so the raw KPGM sample (which covers the whole
//!   `2^d × 2^d` space) is never materialized.
//! * Duplicate semantics follow the Algorithm-1 *pseudo-code* (`E ← E ∪
//!   {(S,T)}`, i.e. set union): duplicates collapse. Because distinct
//!   pieces write disjoint `(D_k, D_l)` blocks of A, one global dedup at
//!   the end is equivalent to per-piece set semantics.
//! * Each piece gets an RNG forked from the base seed by its piece id, so
//!   results are reproducible and pieces can run on any worker in any
//!   order (see [`crate::coordinator`]).

use crate::graph::EdgeList;
use crate::hashutil::{fast_set_with_capacity, FastSet};
use crate::kpgm::BallDropSampler;
use crate::magm::{AttributeAssignment, MagmParams};
use crate::rng::Rng;

use super::Partition;

/// One quilt piece: KPGM-sample then filter to `(D_k, D_l)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PieceJob {
    /// Source partition set index (0-based).
    pub k: usize,
    /// Target partition set index (0-based).
    pub l: usize,
    /// RNG fork id for the piece (stable across schedules).
    pub fork_id: u64,
}

/// The quilting sampler (paper Algorithm 2).
#[derive(Debug, Clone)]
pub struct QuiltSampler {
    params: MagmParams,
    seed: u64,
}

impl QuiltSampler {
    /// New sampler; d ≤ 32 (the KPGM index space is `2^d`).
    pub fn new(params: MagmParams) -> Self {
        assert!(params.depth() <= 32, "quilting needs d <= 32 (KPGM ids are u32)");
        QuiltSampler { params, seed: 0 }
    }

    /// Set the seed (builder style).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Model parameters.
    pub fn params(&self) -> &MagmParams {
        &self.params
    }

    /// Sample attributes then the graph.
    pub fn sample(&self) -> EdgeList {
        let mut rng = Rng::new(self.seed);
        let attrs = AttributeAssignment::sample(&self.params, &mut rng);
        self.sample_with_attrs(&attrs)
    }

    /// Sample a graph for a fixed attribute assignment.
    pub fn sample_with_attrs(&self, attrs: &AttributeAssignment) -> EdgeList {
        let mut partition = Partition::build(attrs.configs());
        maybe_build_dense(&mut partition, self.params.depth());
        let jobs = self.plan(&partition);
        let base = Rng::new(self.seed).fork(0x9011_7ed);
        let kpgm = BallDropSampler::new(self.params.thetas().clone());
        let mut out = EdgeList::new(self.params.num_nodes());
        for job in jobs {
            let mut rng = base.fork(job.fork_id);
            sample_piece(&kpgm, &partition, job, &mut rng, &mut out);
        }
        out.dedup();
        out
    }

    /// The `B²` piece jobs for a partition (the coordinator distributes
    /// these across workers).
    pub fn plan(&self, partition: &Partition) -> Vec<PieceJob> {
        let b = partition.size();
        let mut jobs = Vec::with_capacity(b * b);
        for k in 0..b {
            for l in 0..b {
                jobs.push(PieceJob { k, l, fork_id: (k * b + l) as u64 });
            }
        }
        jobs
    }
}

/// Above this many ball drops the full-space duplicate set would dominate
/// memory AND time (it inserts every drop, retained or not; at millions of
/// entries each insert is a cache miss); switch to tracking duplicates only
/// among *retained* edges. The two modes differ by the full-space duplicate
/// rate ≈ (Σθ²/Σθ)^d, which is < 1% for every X above this threshold
/// (e.g. θ1 at d = 15 — the smallest d with X ≳ 2^20 — gives 0.7%).
const FULL_DEDUP_MAX_DROPS: u64 = 1 << 20;

/// Build the dense config→node index when the configuration space is small
/// enough (`B · 2^d · 4` bytes; gate at 2^22 configs ≈ 16 MB per set).
pub(crate) fn maybe_build_dense(partition: &mut Partition, depth: usize) {
    if depth <= 22 {
        partition.build_dense_index(1usize << depth);
    }
}

/// Run one piece: draw the KPGM edge count, stream ball drops with
/// Algorithm 1's resample-on-duplicate semantics, filter against the
/// `(D_k, D_l)` maps, un-permute, append.
pub(crate) fn sample_piece(
    kpgm: &BallDropSampler,
    partition: &Partition,
    job: PieceJob,
    rng: &mut Rng,
    out: &mut EdgeList,
) {
    let x = kpgm.draw_edge_count(rng);
    const MAX_ATTEMPTS: u32 = 64;
    if x <= FULL_DEDUP_MAX_DROPS {
        // Faithful Algorithm 1: re-drop until the ball lands on a fresh
        // cell of the full 2^d × 2^d space.
        let mut seen: FastSet<u64> = fast_set_with_capacity(x as usize * 2);
        for _ in 0..x {
            for _ in 0..MAX_ATTEMPTS {
                let (s, t) = kpgm.drop_one(rng);
                if seen.insert(((s as u64) << 32) | t as u64) {
                    if let (Some(i), Some(j)) = (
                        partition.lookup(job.k, s as u64),
                        partition.lookup(job.l, t as u64),
                    ) {
                        out.push(i, j);
                    }
                    break;
                }
            }
        }
    } else {
        // Memory-bounded variant: only retained cells are tracked; a
        // duplicate retained cell triggers a re-drop, duplicates among
        // discarded cells collapse silently.
        let mut seen: FastSet<u64> = FastSet::default();
        for _ in 0..x {
            for _ in 0..MAX_ATTEMPTS {
                let (s, t) = kpgm.drop_one(rng);
                match (
                    partition.lookup(job.k, s as u64),
                    partition.lookup(job.l, t as u64),
                ) {
                    (Some(i), Some(j)) => {
                        if seen.insert(((i as u64) << 32) | j as u64) {
                            out.push(i, j);
                            break;
                        }
                        // retained duplicate: re-drop
                    }
                    _ => break, // discarded ball, consumed
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeId;
    use crate::kpgm::Initiator;
    use crate::magm;

    #[test]
    fn plan_covers_all_pieces() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 64, 6);
        let s = QuiltSampler::new(params);
        let configs = vec![1u64, 1, 2, 3, 3, 3];
        let p = Partition::build(&configs);
        assert_eq!(p.size(), 3);
        let jobs = s.plan(&p);
        assert_eq!(jobs.len(), 9);
        // all (k, l) pairs present, fork ids unique
        let mut ids: Vec<u64> = jobs.iter().map(|j| j.fork_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 9);
    }

    #[test]
    fn sample_is_deterministic_in_seed() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 256, 8);
        let g1 = QuiltSampler::new(params.clone()).seed(7).sample();
        let g2 = QuiltSampler::new(params.clone()).seed(7).sample();
        let g3 = QuiltSampler::new(params).seed(8).sample();
        assert_eq!(g1, g2);
        assert_ne!(g1, g3);
    }

    #[test]
    fn no_duplicate_edges_after_sample() {
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.5, 512, 9);
        let mut g = QuiltSampler::new(params).seed(3).sample();
        assert_eq!(g.dedup(), 0);
    }

    #[test]
    fn edge_ids_in_bounds() {
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.7, 300, 9);
        let g = QuiltSampler::new(params).seed(5).sample();
        assert!(g.validate().is_ok());
        assert_eq!(g.num_nodes(), 300);
    }

    #[test]
    fn quilted_edge_count_tracks_q_expectation() {
        // For a FIXED attribute draw, E|E| = sum_ij Q_ij. Average the
        // quilted sampler over many seeds and compare.
        let n = 64;
        let d = 6;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
        let mut rng = Rng::new(211);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let mut want = 0.0;
        for i in 0..n as NodeId {
            for j in 0..n as NodeId {
                want += magm::edge_probability(&params, &attrs, i, j);
            }
        }
        let trials = 200;
        let mut total = 0usize;
        for t in 0..trials {
            let g = QuiltSampler::new(params.clone()).seed(1000 + t).sample_with_attrs(&attrs);
            total += g.num_edges();
        }
        let mean = total as f64 / trials as f64;
        // Ball-dropping + set-collapse biases slightly low; allow 5%.
        assert!(
            (mean - want).abs() / want < 0.05,
            "mean={mean} want={want}"
        );
    }

    #[test]
    fn per_edge_frequency_matches_permuted_kpgm() {
        // The paper's actual claim (eq. 8 + Alg. 2): quilting samples cell
        // (i, j) exactly like Algorithm 1 samples KPGM cell (λ_i, λ_j).
        // Compare empirical marginals of the two samplers; this isolates
        // the quilting machinery from the (known, inherited) ball-drop
        // approximation of Algorithm 1 itself.
        let n = 16;
        let d = 4;
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.5, n, d);
        let mut rng = Rng::new(223);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let trials = 4000u64;

        // Reference: direct Algorithm-1 KPGM sampling over the 2^d space.
        let kpgm_n = 1usize << d;
        let kpgm = crate::kpgm::BallDropSampler::new(params.thetas().clone());
        let mut ref_counts = vec![vec![0u32; kpgm_n]; kpgm_n];
        let mut kpgm_rng = Rng::new(777);
        for _ in 0..trials {
            for &(s, t) in kpgm.sample(&mut kpgm_rng).edges() {
                ref_counts[s as usize][t as usize] += 1;
            }
        }

        // Quilted MAGM sampling with fixed attributes.
        let mut counts = vec![vec![0u32; n]; n];
        for t in 0..trials {
            let g = QuiltSampler::new(params.clone()).seed(t).sample_with_attrs(&attrs);
            for &(s, tt) in g.edges() {
                counts[s as usize][tt as usize] += 1;
            }
        }

        for i in 0..n as NodeId {
            for j in 0..n as NodeId {
                let (li, lj) = (attrs.config(i) as usize, attrs.config(j) as usize);
                let want = ref_counts[li][lj] as f64 / trials as f64;
                let got = counts[i as usize][j as usize] as f64 / trials as f64;
                let sigma =
                    (want.max(1e-4) * (1.0 - want).max(1e-4) / trials as f64).sqrt();
                assert!(
                    (got - want).abs() < 6.0 * sigma * 1.5 + 0.01,
                    "cell ({i},{j}) ~ kpgm ({li},{lj}): got {got:.4}, want {want:.4}"
                );
            }
        }
    }

    #[test]
    fn works_when_d_less_than_log2n() {
        // n = 64 nodes but only d = 3 attributes (8 configs): B ~ n/8.
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 64, 3);
        let g = QuiltSampler::new(params).seed(2).sample();
        assert_eq!(g.num_nodes(), 64);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn works_when_d_greater_than_log2n() {
        // n = 16 nodes, d = 6 attributes: KPGM space is 64x64.
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 16, 6);
        let g = QuiltSampler::new(params).seed(2).sample();
        assert_eq!(g.num_nodes(), 16);
        assert!(g.validate().is_ok());
    }
}
