//! §5 hybrid speedup for unbalanced attribute distributions.
//!
//! When `μ` drifts from 0.5 a few attribute configurations occur very
//! often (Fig. 7), blowing up the partition size B and hence the `B²`
//! piece count of plain Algorithm 2. The fix:
//!
//! * configurations occurring **more than `B'` times** form groups
//!   `D̂_1 … D̂_R`; every block of Q between two groups (including a group
//!   with itself, and between a group and any other node) is *uniform*,
//!   because Q_ij depends only on the endpoint configurations — so those
//!   blocks are Erdős–Rényi and sampled by geometric skipping,
//! * the remaining nodes `W` (every configuration ≤ B' occurrences) go
//!   through Algorithm 2, whose partition size is now ≤ B'.
//!
//! `B'` is chosen by minimizing the paper's cost model
//! `T(B') = B'² log2(n) |E| + (|W| + d) R + d R²` over the O(n) distinct
//! candidate values.

use crate::graph::{EdgeList, NodeId};
use crate::kpgm::{self, BallDropSampler};
use crate::magm::{AttributeAssignment, Config, MagmParams};
use crate::rng::Rng;

use super::sampler::{sample_piece, PieceBackend, PieceMode};
use super::{sample_er_block, Partition, QuiltSampler};

/// The hybrid split for one attribute assignment.
#[derive(Debug, Clone)]
pub struct HybridPlan {
    /// The chosen threshold.
    pub b_prime: u32,
    /// Light configurations (≤ B' occurrences): `(config, nodes)`.
    pub light: Vec<(Config, Vec<NodeId>)>,
    /// Heavy configurations (> B' occurrences): `(config, nodes)` — the
    /// groups `D̂_1 … D̂_R`.
    pub heavy: Vec<(Config, Vec<NodeId>)>,
    /// The cost model value T(B') at the chosen threshold.
    pub predicted_cost: f64,
}

impl HybridPlan {
    /// All nodes in light configurations (the W set), in id order.
    pub fn w_nodes(&self) -> Vec<NodeId> {
        let mut w: Vec<NodeId> =
            self.light.iter().flat_map(|(_, nodes)| nodes.iter().copied()).collect();
        w.sort_unstable();
        w
    }

    /// R, the number of heavy groups.
    pub fn num_heavy(&self) -> usize {
        self.heavy.len()
    }
}

/// The paper's abstract cost model `T(B')` (§5), kept for reference and
/// ablations; the planner minimizes [`cost_model_wall`] instead.
pub fn cost_model_paper(
    b_prime: f64,
    w_size: f64,
    r: f64,
    log2n: f64,
    d: f64,
    e_edges: f64,
) -> f64 {
    b_prime * b_prime * log2n * e_edges + (w_size + d) * r + d * r * r
}

/// Calibrated wall-time estimate (seconds) of one hybrid split.
///
/// The paper's `T(B')` adds ball-drop counts and block counts as if each
/// unit cost the same; on this implementation a ball drop costs
/// `d · ~2.2 ns + ~10 ns` while spawning one ER block costs ~200 ns (RNG
/// fork + setup), so the abstract model over-penalizes quilting and picks
/// a too-small `B'` at balanced μ (measured 2.3× slowdown at n = 2^16,
/// see EXPERIMENTS.md §Perf). Same three terms, measured constants:
///
/// * quilting: `B'²` pieces × `balls` drops each,
/// * light×heavy strips: `2 · C_light · R` blocks,
/// * heavy×heavy: `R²` blocks.
fn cost_model_wall(b_prime: f64, c_light: f64, r: f64, d: f64, balls: f64) -> f64 {
    const DROP_SEC_PER_LEVEL: f64 = 2.2e-9;
    const DROP_SEC_BASE: f64 = 1.0e-8;
    const BLOCK_SEC: f64 = 2.0e-7;
    let c_ball = d * DROP_SEC_PER_LEVEL + DROP_SEC_BASE;
    b_prime * b_prime * balls * c_ball + (2.0 * c_light * r + r * r) * BLOCK_SEC
}

/// Choose `B'` minimizing the calibrated cost over the distinct
/// multiplicity values (plus the degenerate all-heavy candidate B' = 0).
///
/// `expected_edges` should be the **KPGM ball count** `Π_k Σθ^(k)` rather
/// than the MAGM edge count: the quilting term pays one Algorithm-1 sample
/// per piece over the full `2^d × 2^d` space, so for `d > log2 n` the ball
/// count (which grows as `(Σθ)^d`) is what actually blows up — the paper's
/// §4.2 `Ω(4^{d-d''})` observation. With `d = log2 n` the two coincide in
/// expectation, so this refinement is conservative, not a deviation.
pub fn choose_b_prime(
    counts: &[(Config, u32)],
    _num_nodes: usize,
    depth: usize,
    expected_edges: f64,
) -> (u32, f64) {
    let d = depth as f64;
    // Sort multiplicities ascending; prefix counts give C_light/R cheaply.
    let mut mults: Vec<u32> = counts.iter().map(|&(_, m)| m).collect();
    mults.sort_unstable();
    let total_configs = mults.len();

    let mut candidates: Vec<u32> = mults.clone();
    candidates.dedup();
    candidates.push(0); // everything heavy

    let mut best = (u32::MAX, f64::INFINITY);
    for &bp in &candidates {
        // C_light = #configs with mult <= bp; R = #configs with mult > bp.
        let split = mults.partition_point(|&m| m <= bp);
        let c_light = split as f64;
        let r = (total_configs - split) as f64;
        let t = cost_model_wall(bp as f64, c_light, r, d, expected_edges);
        if t < best.1 {
            best = (bp, t);
        }
    }
    best
}

/// The §5 hybrid sampler.
#[derive(Debug, Clone)]
pub struct HybridSampler {
    params: MagmParams,
    seed: u64,
    b_prime_override: Option<u32>,
    mode: PieceMode,
}

impl HybridSampler {
    /// New sampler; d ≤ 32 as for [`QuiltSampler`].
    pub fn new(params: MagmParams) -> Self {
        assert!(params.depth() <= 32, "hybrid sampling needs d <= 32");
        HybridSampler { params, seed: 0, b_prime_override: None, mode: PieceMode::default() }
    }

    /// Set the seed (builder style).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the quilt-piece mode for the W×W part (builder style).
    pub fn piece_mode(mut self, mode: PieceMode) -> Self {
        self.mode = mode;
        self
    }

    /// Pin `B'` instead of optimizing `T(B')` (ablations/tests).
    pub fn b_prime(mut self, b_prime: u32) -> Self {
        self.b_prime_override = Some(b_prime);
        self
    }

    /// Model parameters.
    pub fn params(&self) -> &MagmParams {
        &self.params
    }

    /// Build the hybrid plan for an attribute assignment.
    pub fn plan(&self, attrs: &AttributeAssignment) -> HybridPlan {
        let counts = attrs.config_counts();
        let (b_prime, predicted_cost) = match self.b_prime_override {
            Some(bp) => (bp, f64::NAN),
            None => choose_b_prime(
                &counts,
                self.params.num_nodes(),
                self.params.depth(),
                // KPGM ball count per piece — see choose_b_prime docs.
                self.params.thetas().expected_edges(),
            ),
        };
        // Group nodes per config. counts is sorted by config; gather nodes
        // in one pass over the assignment.
        let mut nodes_per_config: crate::hashutil::FastMap<Config, Vec<NodeId>> =
            crate::hashutil::fast_map_with_capacity(counts.len());
        for (i, &c) in attrs.configs().iter().enumerate() {
            nodes_per_config.entry(c).or_default().push(i as NodeId);
        }
        let mut light = Vec::new();
        let mut heavy = Vec::new();
        for &(c, m) in &counts {
            let nodes = nodes_per_config.remove(&c).expect("config seen in counts");
            if m > b_prime {
                heavy.push((c, nodes));
            } else {
                light.push((c, nodes));
            }
        }
        HybridPlan { b_prime, light, heavy, predicted_cost }
    }

    /// Sample attributes then the graph.
    pub fn sample(&self) -> EdgeList {
        let mut rng = Rng::new(self.seed);
        let attrs = AttributeAssignment::sample(&self.params, &mut rng);
        self.sample_with_attrs(&attrs)
    }

    /// Sample for a fixed attribute assignment.
    pub fn sample_with_attrs(&self, attrs: &AttributeAssignment) -> EdgeList {
        let plan = self.plan(attrs);
        self.sample_with_plan(attrs, &plan)
    }

    /// Sample for a fixed plan (exposed for the coordinator and tests).
    pub fn sample_with_plan(&self, attrs: &AttributeAssignment, plan: &HybridPlan) -> EdgeList {
        self.sample_with_plan_reporting(attrs, plan).0
    }

    /// As [`Self::sample_with_plan`], also returning the number of balls
    /// the W×W quilting abandoned after exhausting duplicate resamples.
    /// Conditioned pieces collapse duplicates and abandon nothing, but
    /// over-budget dense blocks fall back to the rejection descent, so
    /// the count can be non-zero even in conditioned mode.
    pub fn sample_with_plan_reporting(
        &self,
        attrs: &AttributeAssignment,
        plan: &HybridPlan,
    ) -> (EdgeList, u64) {
        let n = self.params.num_nodes();
        let thetas = self.params.thetas();
        let mut out = EdgeList::new(n);
        let mut dropped = 0u64;
        let base = Rng::new(self.seed).fork(crate::rngtags::HYBRID_PIECE_STREAM);

        // --- 1. W × W by Algorithm 2 on the light subset. --------------
        let w_nodes = plan.w_nodes();
        if !w_nodes.is_empty() {
            let mut partition = Partition::build_subset(attrs.configs(), &w_nodes);
            super::sampler::maybe_build_dense(&mut partition, self.params.depth());
            let conditioner = (self.mode == PieceMode::Conditioned)
                .then(|| partition.conditioned_sampler(thetas));
            let kpgm = BallDropSampler::new(thetas.clone());
            let quilt = QuiltSampler::new(self.params.clone());
            for job in quilt.plan(&partition) {
                let backend = match &conditioner {
                    Some(cond) => PieceBackend::Conditioned { cond, kpgm: &kpgm },
                    None => PieceBackend::Rejection(&kpgm),
                };
                let mut rng = base.fork(job.fork_id);
                dropped += sample_piece(backend, &partition, job, &mut rng, &mut out);
            }
        }

        // --- 2. heavy × heavy ER blocks. --------------------------------
        // ER_STREAM is deliberately the same constant coordinator::pool
        // forks, so the parallel runner reads these exact streams; it is
        // distinct from HYBRID_PIECE_STREAM so ER-block ids can never
        // collide with W-piece ids under the same seed.
        let er_base = Rng::new(self.seed).fork(crate::rngtags::ER_STREAM);
        let mut er_id = 0u64;
        for (ci, nodes_i) in &plan.heavy {
            for (cj, nodes_j) in &plan.heavy {
                let p = kpgm::edge_probability(thetas, *ci as NodeId, *cj as NodeId);
                let mut rng = er_base.fork(er_id);
                er_id += 1;
                sample_er_block(nodes_i, nodes_j, p, &mut rng, &mut out);
            }
        }

        // --- 3. light × heavy and heavy × light ER strips. --------------
        for (ci, nodes_i) in &plan.light {
            for (cj, nodes_j) in &plan.heavy {
                let p_ij = kpgm::edge_probability(thetas, *ci as NodeId, *cj as NodeId);
                let mut rng = er_base.fork(er_id);
                er_id += 1;
                sample_er_block(nodes_i, nodes_j, p_ij, &mut rng, &mut out);
                let p_ji = kpgm::edge_probability(thetas, *cj as NodeId, *ci as NodeId);
                let mut rng = er_base.fork(er_id);
                er_id += 1;
                sample_er_block(nodes_j, nodes_i, p_ji, &mut rng, &mut out);
            }
        }

        out.dedup();
        (out, dropped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::Initiator;
    use crate::magm;

    #[test]
    fn choose_b_prime_all_unique_prefers_quilting() {
        // Every config unique: B' = 1 covers everything with B = 1.
        let counts: Vec<(Config, u32)> = (0..100u64).map(|c| (c, 1)).collect();
        let (bp, _) = choose_b_prime(&counts, 100, 7, 500.0);
        assert_eq!(bp, 1);
    }

    #[test]
    fn choose_b_prime_one_giant_config_goes_heavy() {
        // One config holds almost all nodes; quilting it would need B ~ n.
        let mut counts: Vec<(Config, u32)> = vec![(0, 10_000)];
        counts.extend((1..50u64).map(|c| (c, 1)));
        let (bp, _) = choose_b_prime(&counts, 10_049, 14, 1e6);
        assert!(bp < 10_000, "giant config must be heavy, bp={bp}");
    }

    #[test]
    fn plan_splits_by_threshold() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 10, 3);
        let attrs =
            AttributeAssignment::from_configs(vec![0, 0, 0, 0, 1, 1, 2, 3, 4, 5], 3);
        let s = HybridSampler::new(params).b_prime(2);
        let plan = s.plan(&attrs);
        assert_eq!(plan.b_prime, 2);
        assert_eq!(plan.num_heavy(), 1); // config 0 occurs 4 > 2 times
        assert_eq!(plan.heavy[0].0, 0);
        assert_eq!(plan.heavy[0].1.len(), 4);
        assert_eq!(plan.w_nodes().len(), 6);
    }

    #[test]
    fn hybrid_deterministic_in_seed() {
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.8, 256, 8);
        let g1 = HybridSampler::new(params.clone()).seed(11).sample();
        let g2 = HybridSampler::new(params.clone()).seed(11).sample();
        assert_eq!(g1, g2);
    }

    #[test]
    fn hybrid_no_duplicates_and_valid() {
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.9, 400, 9);
        let mut g = HybridSampler::new(params).seed(13).sample();
        assert!(g.validate().is_ok());
        assert_eq!(g.dedup(), 0);
    }

    #[test]
    fn hybrid_per_edge_frequency_matches_q() {
        // The Theorem-3-style statistical check, now with skewed mu so the
        // heavy/light machinery actually engages.
        let n = 16;
        let d = 4;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.85, n, d);
        let mut rng = Rng::new(239);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let sampler = HybridSampler::new(params.clone());
        let plan = sampler.plan(&attrs);
        assert!(plan.num_heavy() > 0, "skewed mu should produce heavy groups");
        let trials = 3000u64;
        let mut counts = vec![vec![0u32; n]; n];
        for t in 0..trials {
            let g = HybridSampler::new(params.clone())
                .seed(t)
                .sample_with_attrs(&attrs);
            for &(s, tt) in g.edges() {
                counts[s as usize][tt as usize] += 1;
            }
        }
        for i in 0..n as NodeId {
            for j in 0..n as NodeId {
                let q = magm::edge_probability(&params, &attrs, i, j);
                let got = counts[i as usize][j as usize] as f64 / trials as f64;
                let sigma = (q * (1.0 - q) / trials as f64).sqrt();
                assert!(
                    (got - q).abs() < 5.0 * sigma + 0.02,
                    "cell ({i},{j}): got {got:.4}, want {q:.4}"
                );
            }
        }
    }

    #[test]
    fn hybrid_agrees_with_quilt_in_distribution() {
        // Same fixed attrs, mu = 0.5: hybrid (which may pick all-light)
        // and plain quilting should produce statistically similar |E|.
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 128, 7);
        let mut rng = Rng::new(241);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let trials = 60;
        let mut quilt_total = 0usize;
        let mut hybrid_total = 0usize;
        for t in 0..trials {
            quilt_total += QuiltSampler::new(params.clone())
                .seed(t)
                .sample_with_attrs(&attrs)
                .num_edges();
            hybrid_total += HybridSampler::new(params.clone())
                .seed(10_000 + t)
                .sample_with_attrs(&attrs)
                .num_edges();
        }
        let qm = quilt_total as f64 / trials as f64;
        let hm = hybrid_total as f64 / trials as f64;
        assert!((qm - hm).abs() / qm < 0.1, "quilt={qm} hybrid={hm}");
    }
}
