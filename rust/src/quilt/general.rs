//! Quilting for the generalized (K×K, categorical-attribute) MAGM.
//!
//! The quilting machinery is representation-agnostic: the partition
//! minimality (Theorem 2) and correctness (Theorem 3) arguments only use
//! `Q_ij = P_{λ_i λ_j}`, which holds for base-K configuration packing just
//! as for binary. This sampler reuses [`Partition`] verbatim and the
//! generalized Algorithm 1 from [`crate::kpgm::general`].

use crate::graph::EdgeList;
use crate::kpgm::general::GenBallDropSampler;
use crate::magm::{Config, GenMagmParams};
use crate::rng::Rng;

use super::Partition;

/// Quilting sampler for the categorical MAGM.
#[derive(Debug, Clone)]
pub struct GeneralQuiltSampler {
    params: GenMagmParams,
    seed: u64,
}

impl GeneralQuiltSampler {
    /// New sampler; `K^d` must fit the u32 node-id space.
    pub fn new(params: GenMagmParams) -> Self {
        assert!(
            params.thetas().num_nodes() <= u32::MAX as u64 + 1,
            "K^d must fit u32 ids for quilting"
        );
        GeneralQuiltSampler { params, seed: 0 }
    }

    /// Set the seed (builder style).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Sample configurations then the graph.
    pub fn sample(&self) -> EdgeList {
        let mut rng = Rng::new(self.seed);
        let configs = self.params.sample_configs(&mut rng);
        self.sample_with_configs(&configs)
    }

    /// Sample for fixed configurations.
    pub fn sample_with_configs(&self, configs: &[Config]) -> EdgeList {
        assert_eq!(configs.len(), self.params.num_nodes());
        let mut partition = Partition::build(configs);
        let space = self.params.thetas().num_nodes();
        if space <= 1 << 22 {
            partition.build_dense_index(space as usize);
        }
        let b = partition.size();
        let kpgm = GenBallDropSampler::new(self.params.thetas().clone());
        let base = Rng::new(self.seed).fork(crate::rngtags::GENERAL_QUILT_STREAM);
        let mut out = EdgeList::new(self.params.num_nodes());
        for k in 0..b {
            for l in 0..b {
                let mut rng = base.fork((k * b + l) as u64);
                let x = kpgm.draw_edge_count(&mut rng);
                let mut seen = crate::hashutil::FastSet::default();
                for _ in 0..x {
                    for _ in 0..64 {
                        let (s, t) = kpgm.drop_one(&mut rng);
                        match (partition.lookup(k, s), partition.lookup(l, t)) {
                            (Some(i), Some(j)) => {
                                if seen.insert(((i as u64) << 32) | j as u64) {
                                    out.push(i, j);
                                    break;
                                }
                            }
                            _ => break,
                        }
                    }
                }
            }
        }
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::general::{GenInitiator, GenThetaSeq};

    fn params(n: usize, d: u32) -> GenMagmParams {
        let theta = GenInitiator::new(vec![0.8, 0.4, 0.2, 0.4, 0.6, 0.3, 0.2, 0.3, 0.7]);
        GenMagmParams::new(
            GenThetaSeq::homogeneous(theta, d),
            vec![vec![0.4, 0.35, 0.25]; d as usize],
            n,
        )
    }

    #[test]
    fn deterministic_and_valid() {
        let p = params(200, 5);
        let g1 = GeneralQuiltSampler::new(p.clone()).seed(7).sample();
        let g2 = GeneralQuiltSampler::new(p).seed(7).sample();
        assert_eq!(g1, g2);
        assert!(g1.validate().is_ok());
    }

    #[test]
    fn mean_edges_matches_naive() {
        // The general quilting sampler must agree with the exact naive
        // sampler on mean edge count for fixed configs.
        let p = params(48, 3);
        let mut rng = Rng::new(307);
        let configs = p.sample_configs(&mut rng);
        let trials = 60;
        let quilt: usize = (0..trials)
            .map(|t| {
                GeneralQuiltSampler::new(p.clone())
                    .seed(t)
                    .sample_with_configs(&configs)
                    .num_edges()
            })
            .sum();
        let naive: usize =
            (0..trials).map(|_| p.naive_sample(&configs, &mut rng).num_edges()).sum();
        let (qm, nm) = (quilt as f64 / trials as f64, naive as f64 / trials as f64);
        assert!((qm - nm).abs() / nm < 0.1, "quilt {qm} vs naive {nm}");
    }

    #[test]
    fn per_cell_rate_matches_q() {
        // Cell-level correctness on a tiny instance (probabilities small
        // enough that ball-drop saturation is negligible).
        let theta = GenInitiator::new(vec![0.3, 0.2, 0.1, 0.2, 0.25, 0.15, 0.1, 0.15, 0.3]);
        let p = GenMagmParams::new(
            GenThetaSeq::homogeneous(theta, 3),
            vec![vec![1.0 / 3.0; 3]; 3],
            12,
        );
        let mut rng = Rng::new(311);
        let configs = p.sample_configs(&mut rng);
        let trials = 4000u64;
        let mut counts = vec![vec![0u32; 12]; 12];
        for t in 0..trials {
            let g = GeneralQuiltSampler::new(p.clone()).seed(t).sample_with_configs(&configs);
            for &(s, d) in g.edges() {
                counts[s as usize][d as usize] += 1;
            }
        }
        for i in 0..12 {
            for j in 0..12 {
                let q = p.edge_probability(configs[i], configs[j]);
                let got = counts[i][j] as f64 / trials as f64;
                let sigma = (q * (1.0 - q) / trials as f64).sqrt();
                assert!(
                    (got - q).abs() < 5.0 * sigma + 0.015,
                    "cell ({i},{j}): {got:.4} vs {q:.4}"
                );
            }
        }
    }
}
