//! The paper's contribution: sub-quadratic MAGM sampling by quilting KPGM
//! samples (Algorithm 2), plus the §5 hybrid speedup for unbalanced μ.
//!
//! Pipeline:
//! 1. [`Partition`] the nodes into `D_1 … D_B` so that no two nodes in a
//!    set share an attribute configuration (minimal by Theorem 2),
//! 2. for each of the `B²` pieces `(D_k, D_l)`, sample the block's edges —
//!    by default with the rejection-free **conditioned** quadrisection
//!    descent restricted to the configurations present in `D_k` resp.
//!    `D_l` ([`PieceMode::Conditioned`]), or with the paper's literal
//!    sample-then-filter Algorithm 1 ([`PieceMode::Rejection`]),
//! 3. un-permute (`λ_i → i`) and **quilt** the pieces into one edge list
//!    (Theorem 3: the result samples `A_ij ~ Bernoulli(Q_ij)`
//!    independently).
//!
//! The [`HybridSampler`] additionally splits off configurations occurring
//! more than `B'` times; blocks involving those are uniform Erdős–Rényi
//! sub-graphs sampled in `O(1 + p·cells)` by geometric skipping
//! ([`er_block`]), and only the leftover `W` goes through Algorithm 2.

mod er_block;
mod general;
mod hybrid;
mod partition;
mod sampler;

pub use er_block::sample_er_block;
pub use general::GeneralQuiltSampler;
pub use hybrid::{choose_b_prime, cost_model_paper, HybridPlan, HybridSampler};
pub use partition::Partition;
pub use sampler::{PieceJob, PieceMode, QuiltSampler};

pub(crate) use sampler::sample_piece as sample_piece_for_coordinator;
pub(crate) use sampler::maybe_build_dense as maybe_build_dense_index;
pub(crate) use sampler::PieceBackend;
