//! The node partition `D_1 … D_B` (paper §4, Theorem 2) and its
//! parallel, deterministic construction.
//!
//! `Z_i = {j ≤ i : λ_j = λ_i}`; node `i` goes to set `D_{|Z_i|}`. Within a
//! set every attribute configuration appears at most once, and the number
//! of non-empty sets `B = max_c (multiplicity of c)` is minimal by the
//! pigeon-hole argument of Theorem 2.
//!
//! The partition is the first half of the run's **setup pipeline** (the
//! second is piece sampling, see [`crate::coordinator`]). Two builds are
//! provided, with asserted-identical output:
//!
//! * [`Partition::build`] — the textbook single left-to-right scan with a
//!   multiplicity counter (`O(n)` expected, serial);
//! * [`Partition::build_parallel`] — a prefix-sum reformulation for the
//!   worker pool: nodes split into fixed [`PARTITION_CHUNK`]-sized chunks,
//!   each chunk histograms its configs in parallel, an exclusive
//!   prefix-sum across chunk histograms recovers the rank each config
//!   starts at in each chunk, and a second parallel pass turns that into
//!   every node's occurrence rank `|Z_i| − 1` — exactly the value the
//!   sequential counter would have produced. Set membership, set order,
//!   and the per-set maps are therefore **bit-for-bit identical** to the
//!   sequential scan for every thread count.
//!
//! Neither half keeps a serial wall: the exclusive prefix-sum across
//! chunk histograms runs as a two-pass tree reduction (up-sweep of merged
//! counts, key-filtered down-sweep of offsets — both parallel per level),
//! and the per-shard [`ConfigForest`] arenas of the sharded trie build
//! ([`Partition::build_tries_parallel`]) fold together by a deterministic
//! pairwise tree-merge of hash-consing passes
//! ([`ConfigForest::adopt_trie`]) that lands on the *serial* arena —
//! class ids included — for every thread count.
//! [`Partition::conditioned_sampler_threaded`] parallelizes the product
//! DAG's bottom-up mass aggregation per level.

use anyhow::{bail, Result};

use crate::hashutil::{fast_map_with_capacity, FastMap};

use crate::graph::NodeId;
use crate::kpgm::{AdoptMemo, ConditionedBallDropSampler, ConfigForest, ConfigTrie, ThetaSeq};
use crate::magm::Config;
use crate::setup::wire::{Reader, Writer};

/// Nodes per chunk in [`Partition::build_parallel`]. Fixed — never
/// derived from the thread count — so chunk histograms and prefix sums
/// are a pure function of the input (the chunking is invisible in the
/// output either way, but a fixed size also keeps the *work split*
/// reproducible run to run).
const PARTITION_CHUNK: usize = 8192;

/// A set only gets a dense `config → node + 1` table when it would be at
/// least `1/DENSE_MIN_LOAD_DIV` full. The old all-sets rule allocated
/// `B · 2^d · 4` bytes — 16 MB *per set* at the d = 22 gate, even for
/// singleton sets; gating per set bounds total dense memory by
/// `DENSE_MIN_LOAD_DIV · 4 · Σ_c |D_c| = 256·n` bytes while the big
/// early sets, which absorb almost all lookups, stay dense.
const DENSE_MIN_LOAD_DIV: usize = 64;

/// The partition plus, per set, the `config → node` lookup used when
/// filtering KPGM samples (the permutation `λ_i → i` of Figure 3).
#[derive(Debug, Clone)]
pub struct Partition {
    /// `sets[c]` holds the nodes with `|Z_i| = c + 1`.
    sets: Vec<Vec<NodeId>>,
    /// `maps[c]`: configuration → node for set c.
    maps: Vec<FastMap<Config, NodeId>>,
    /// Optional dense lookup (`dense[c][config] = node + 1`, 0 = absent):
    /// the filter runs once per ball drop, and a direct index is ~5× faster
    /// than the hash probe. Built by [`Partition::build_dense_index`] when
    /// the configuration space is small enough to afford it.
    dense: Vec<Vec<NodeId>>,
    /// Optional hash-consed prefix-trie arena over the sets' configs (one
    /// [`ConfigTrie`] per set), built by [`Partition::build_tries`]. The
    /// trie classes power the rejection-free conditioned piece sampler;
    /// the per-level reachability bitmasks each trie carries are a
    /// diagnostic surface (tests/tooling), not consulted by the descent.
    forest: Option<ConfigForest>,
    tries: Vec<ConfigTrie>,
    /// Wall-clock of the trie build's shard-merge phase (0 when the build
    /// ran serially). Timing only — never consulted by the sampling path.
    trie_merge_ms: f64,
}

/// One shard's private trie arena plus its registered tries tagged with
/// their **global** set index (ascending — shard `s` of `S` holds sets
/// `s, s + S, …`). The unit the pairwise tree-merge folds over.
struct ShardForest {
    forest: ConfigForest,
    tries: Vec<(usize, ConfigTrie)>,
}

/// Combine two shard forests into one by re-interning every trie of both
/// into a fresh arena in **ascending global set order** (a two-pointer
/// merge of the two sorted lists), with one pre-sized [`AdoptMemo`] per
/// source so shared suffix structure is re-interned once.
///
/// Adoption creates classes in the target in first-visit DFS post-order —
/// exactly the order [`ConfigForest::register_set`] creates them — so the
/// combined arena is *the* canonical arena of the merged set list. The
/// pairwise tree over shards therefore converges to the serial build's
/// arena (class ids included) regardless of the pairing shape, which is
/// what keeps the output bit-for-bit identical for every thread count.
fn merge_shard_forests(depth: usize, a: ShardForest, b: ShardForest) -> ShardForest {
    let mut forest = ConfigForest::new(depth);
    let mut memo_a = AdoptMemo::for_source(&a.forest);
    let mut memo_b = AdoptMemo::for_source(&b.forest);
    let mut tries = Vec::with_capacity(a.tries.len() + b.tries.len());
    let (mut i, mut j) = (0, 0);
    while i < a.tries.len() || j < b.tries.len() {
        let from_a = match (a.tries.get(i), b.tries.get(j)) {
            (Some((ia, _)), Some((jb, _))) => ia < jb,
            (Some(_), None) => true,
            (None, _) => false,
        };
        if from_a {
            let (idx, trie) = &a.tries[i];
            tries.push((*idx, forest.adopt_trie(&a.forest, trie, &mut memo_a)));
            i += 1;
        } else {
            let (idx, trie) = &b.tries[j];
            tries.push((*idx, forest.adopt_trie(&b.forest, trie, &mut memo_b)));
            j += 1;
        }
    }
    ShardForest { forest, tries }
}

/// The exclusive prefix-sum across per-chunk config histograms, as a
/// two-pass tree reduction (Blelloch-style up/down-sweep over maps).
/// Returns `(total, starts)`: the global `config → multiplicity` map and,
/// per chunk, the rank its first occurrence of each config starts at.
///
/// * **Up-sweep** (parallel per level): node `j` of level `k + 1` merges
///   the histograms of children `2j` and `2j + 1` of level `k`; the root
///   is the global multiplicity map. Every level is kept.
/// * **Down-sweep** (parallel per level): a node's offset map holds, for
///   each config, how many occurrences precede its subtree — the left
///   child inherits the parent's offsets, the right child adds the left
///   sibling's counts. Crucially each offset map is **key-filtered to
///   its own subtree's configs**: that keeps memory `O(total histogram
///   entries)` per level instead of `O(chunks × unique)`, and it makes
///   the leaf maps carry exactly their chunk's key set — a config first
///   appearing in chunk `i` maps to 0 there — which is precisely the
///   serial fold's `starts[i]` contents that phase 3 indexes into.
///
/// Counts are exact integer sums, so the result is identical to the
/// serial left-to-right fold for every thread count; `threads <= 1` runs
/// the serial fold directly.
fn exclusive_chunk_offsets(
    histograms: Vec<FastMap<Config, u32>>,
    threads: usize,
) -> (FastMap<Config, u32>, Vec<FastMap<Config, u32>>) {
    if threads <= 1 || histograms.len() <= 2 {
        let entries: usize = histograms.iter().map(|h| h.len()).sum();
        let mut total: FastMap<Config, u32> = fast_map_with_capacity(entries);
        let mut starts: Vec<FastMap<Config, u32>> = Vec::with_capacity(histograms.len());
        for h in &histograms {
            let mut s: FastMap<Config, u32> = fast_map_with_capacity(h.len());
            for (&c, &cnt) in h {
                let t = total.entry(c).or_insert(0);
                s.insert(c, *t);
                *t += cnt;
            }
            starts.push(s);
        }
        return (total, starts);
    }

    // Up-sweep.
    let mut levels: Vec<Vec<FastMap<Config, u32>>> = vec![histograms];
    while levels.last().is_some_and(|l| l.len() > 1) {
        let src = levels.last().expect("non-empty by construction");
        let pair_ids: Vec<usize> = (0..src.len().div_ceil(2)).collect();
        let next: Vec<FastMap<Config, u32>> =
            crate::parallel::map_indexed(pair_ids, threads, |_, j| {
                let mut m = src[2 * j].clone();
                if let Some(right) = src.get(2 * j + 1) {
                    for (&c, &cnt) in right {
                        *m.entry(c).or_insert(0) += cnt;
                    }
                }
                m
            });
        levels.push(next);
    }

    // Down-sweep.
    let top = levels.len() - 1;
    let root = &levels[top][0];
    let mut root_off: FastMap<Config, u32> = fast_map_with_capacity(root.len());
    for &c in root.keys() { // lint: order-ok(builds a keyed map; insertion order never observed)
        root_off.insert(c, 0);
    }
    let mut offs = vec![root_off];
    for k in (0..top).rev() {
        let parents = offs;
        let src = &levels[k];
        let ids: Vec<usize> = (0..src.len()).collect();
        offs = crate::parallel::map_indexed(ids, threads, |_, j| {
            let p = &parents[j / 2];
            let mut m: FastMap<Config, u32> = fast_map_with_capacity(src[j].len());
            if j % 2 == 0 {
                for &c in src[j].keys() { // lint: order-ok(builds a keyed map; insertion order never observed)
                    m.insert(c, p.get(&c).copied().unwrap_or(0));
                }
            } else {
                let left = &src[j - 1];
                for &c in src[j].keys() { // lint: order-ok(builds a keyed map; insertion order never observed)
                    let before =
                        p.get(&c).copied().unwrap_or(0) + left.get(&c).copied().unwrap_or(0);
                    m.insert(c, before);
                }
            }
            m
        });
    }
    let total = levels.pop().expect("root level").pop().expect("root node");
    (total, offs)
}

impl Partition {
    /// Build the partition by a single left-to-right scan with a
    /// multiplicity counter (O(n) expected).
    pub fn build(configs: &[Config]) -> Self {
        let mut multiplicity: FastMap<Config, u32> = crate::hashutil::fast_map_with_capacity(configs.len());
        let mut sets: Vec<Vec<NodeId>> = Vec::new();
        let mut maps: Vec<FastMap<Config, NodeId>> = Vec::new();
        for (i, &c) in configs.iter().enumerate() {
            let m = multiplicity.entry(c).or_insert(0);
            *m += 1;
            let idx = (*m - 1) as usize;
            if idx == sets.len() {
                sets.push(Vec::new());
                maps.push(FastMap::default());
            }
            sets[idx].push(i as NodeId);
            maps[idx].insert(c, i as NodeId);
        }
        Partition { sets, maps, dense: Vec::new(), forest: None, tries: Vec::new(), trie_merge_ms: 0.0 }
    }

    /// Parallel [`Partition::build`] over `threads` setup threads.
    ///
    /// Three passes replace the sequential multiplicity scan: per-chunk
    /// config histograms (parallel), an exclusive prefix-sum across the
    /// chunk histograms (a two-pass tree reduction, parallel per level —
    /// `O(log chunks)` sweeps instead of a serial fold), and a per-chunk
    /// rank assignment (parallel) whose chunk-start offsets come from the
    /// prefix sums — node `i`'s rank equals the number of earlier nodes
    /// with its config, exactly as in the sequential scan. Output is
    /// identical for every `threads`; `threads <= 1` or small inputs
    /// delegate to the sequential build.
    pub fn build_parallel(configs: &[Config], threads: usize) -> Self {
        if threads <= 1 || configs.len() < 2 * PARTITION_CHUNK {
            return Self::build(configs);
        }
        Self::build_ranked(configs, configs.len(), |i| i as NodeId, threads)
    }

    /// Parallel [`Partition::build_subset`] (same prefix-sum pipeline over
    /// the subset's node list; nodes keep their original ids).
    pub fn build_subset_parallel(configs: &[Config], nodes: &[NodeId], threads: usize) -> Self {
        if threads <= 1 || nodes.len() < 2 * PARTITION_CHUNK {
            return Self::build_subset(configs, nodes);
        }
        Self::build_ranked(configs, nodes.len(), |i| nodes[i], threads)
    }

    /// The prefix-sum pipeline shared by [`Partition::build_parallel`] and
    /// [`Partition::build_subset_parallel`]: logical index `i ∈ 0..len`
    /// names node `node_at(i)`, scanned in logical order.
    fn build_ranked<F>(configs: &[Config], len: usize, node_at: F, threads: usize) -> Self
    where
        F: Fn(usize) -> NodeId + Sync,
    {
        let node_at = &node_at;
        let num_chunks = len.div_ceil(PARTITION_CHUNK);

        // Phase 1 (parallel): per-chunk config histograms.
        let chunk_ids: Vec<usize> = (0..num_chunks).collect();
        let histograms: Vec<FastMap<Config, u32>> =
            crate::parallel::map_indexed(chunk_ids, threads, |_, ci| {
                let lo = ci * PARTITION_CHUNK;
                let hi = (lo + PARTITION_CHUNK).min(len);
                let mut h: FastMap<Config, u32> = fast_map_with_capacity(hi - lo);
                for i in lo..hi {
                    *h.entry(configs[node_at(i) as usize]).or_insert(0) += 1;
                }
                h
            });

        // Phase 2 (two-pass tree reduction, parallel per level): exclusive
        // prefix sums — the occurrence rank each config starts at in each
        // chunk — plus the global multiplicity map.
        let (total, starts) = exclusive_chunk_offsets(histograms, threads);
        let b = total.values().copied().max().unwrap_or(0) as usize; // lint: order-ok(max is order-independent)
        // |D_r| = number of configs with multiplicity > r (exact
        // capacities for phase 4's pushes).
        let mut set_sizes = vec![0usize; b];
        for &m in total.values() { // lint: order-ok(integer increments commute; counts are order-independent)
            for size in set_sizes.iter_mut().take(m as usize) {
                *size += 1;
            }
        }

        // Phase 3 (parallel): every node's occurrence rank = its chunk's
        // start for the config plus the within-chunk running count.
        let rank_jobs: Vec<FastMap<Config, u32>> = starts;
        let chunk_ranks: Vec<Vec<u32>> =
            crate::parallel::map_indexed(rank_jobs, threads, |ci, mut next| {
                let lo = ci * PARTITION_CHUNK;
                let hi = (lo + PARTITION_CHUNK).min(len);
                let mut ranks = Vec::with_capacity(hi - lo);
                for i in lo..hi {
                    let r = next
                        .get_mut(&configs[node_at(i) as usize])
                        .expect("config counted in phase 1");
                    ranks.push(*r);
                    *r += 1;
                }
                ranks
            });

        // Phase 4 (serial, pure pushes): fill the sets in logical order —
        // the same node order the sequential scan produces.
        let mut sets: Vec<Vec<NodeId>> =
            set_sizes.iter().map(|&s| Vec::with_capacity(s)).collect();
        let mut i = 0usize;
        for ranks in &chunk_ranks {
            for &r in ranks {
                sets[r as usize].push(node_at(i));
                i += 1;
            }
        }

        // Phase 5 (parallel over sets): the config → node lookup maps.
        let set_refs: Vec<&Vec<NodeId>> = sets.iter().collect();
        let maps: Vec<FastMap<Config, NodeId>> =
            crate::parallel::map_indexed(set_refs, threads, |_, set| {
                let mut m: FastMap<Config, NodeId> = fast_map_with_capacity(set.len());
                for &node in set.iter() {
                    m.insert(configs[node as usize], node);
                }
                m
            });

        Partition { sets, maps, dense: Vec::new(), forest: None, tries: Vec::new(), trie_merge_ms: 0.0 }
    }

    /// Build restricted to a subset of nodes (used by the hybrid sampler's
    /// W set). Nodes keep their original ids.
    pub fn build_subset(configs: &[Config], nodes: &[NodeId]) -> Self {
        let mut multiplicity: FastMap<Config, u32> = crate::hashutil::fast_map_with_capacity(nodes.len());
        let mut sets: Vec<Vec<NodeId>> = Vec::new();
        let mut maps: Vec<FastMap<Config, NodeId>> = Vec::new();
        for &i in nodes {
            let c = configs[i as usize];
            let m = multiplicity.entry(c).or_insert(0);
            *m += 1;
            let idx = (*m - 1) as usize;
            if idx == sets.len() {
                sets.push(Vec::new());
                maps.push(FastMap::default());
            }
            sets[idx].push(i);
            maps[idx].insert(c, i);
        }
        Partition { sets, maps, dense: Vec::new(), forest: None, tries: Vec::new(), trie_merge_ms: 0.0 }
    }

    /// Build the per-set prefix tries (and per-level reachability masks)
    /// over the `depth`-bit configuration space. Idempotent
    /// ([`Partition::conditioned_sampler`] calls it automatically). Cost
    /// `O(d · n)`, with hash-consing sharing suffix structure across the
    /// nested sets.
    pub fn build_tries(&mut self, depth: usize) {
        self.build_tries_parallel(depth, 1);
    }

    /// Parallel [`Partition::build_tries`]: set `c` is registered into the
    /// private forest of shard `c % shards` (shards build concurrently),
    /// then the shard forests fold together by a deterministic pairwise
    /// tree-merge ([`merge_shard_forests`] via
    /// [`crate::parallel::tree_reduce`]) whose every combine re-interns
    /// tries in ascending set order. Adoption creates classes in exactly
    /// the order serial registration would have, so the merged forest —
    /// class ids included — and the tries are bit-for-bit the serial
    /// build's for every thread count; the merge itself takes `O(log
    /// shards)` parallel levels instead of one serial re-interning loop.
    /// Idempotent.
    pub fn build_tries_parallel(&mut self, depth: usize, threads: usize) {
        if let Some(forest) = &self.forest {
            debug_assert_eq!(
                forest.depth(),
                depth,
                "build_tries called again with a different depth"
            );
            return;
        }
        // Sorted config list per set (parallel; the sort is per set).
        let map_refs: Vec<&FastMap<Config, NodeId>> = self.maps.iter().collect();
        let cfg_lists: Vec<Vec<Config>> =
            crate::parallel::map_indexed(map_refs, threads, |_, m| {
                let mut cfgs: Vec<Config> = m.keys().copied().collect(); // lint: order-ok(sorted on the next line)
                cfgs.sort_unstable();
                cfgs
            });
        let shards = threads.max(1).min(cfg_lists.len().max(1));
        if shards <= 1 {
            let mut forest = ConfigForest::new(depth);
            self.tries = cfg_lists.iter().map(|cfgs| forest.register_set(cfgs)).collect();
            self.forest = Some(forest);
            self.trie_merge_ms = 0.0;
            return;
        }
        // Shard build (parallel): shard s registers sets s, s+shards, …
        let cfg_ref = &cfg_lists;
        let shard_ids: Vec<usize> = (0..shards).collect();
        let shard_forests: Vec<ShardForest> =
            crate::parallel::map_indexed(shard_ids, threads, |_, s| {
                let mut forest = ConfigForest::new(depth);
                let tries = cfg_ref
                    .iter()
                    .enumerate()
                    .skip(s)
                    .step_by(shards)
                    .map(|(idx, cfgs)| (idx, forest.register_set(cfgs)))
                    .collect();
                ShardForest { forest, tries }
            });
        // Merge (pairwise tree of hash-consing passes, parallel per level).
        let merge_start = std::time::Instant::now(); // lint: time-ok(setup timing stat, never output-determining)
        let merged = crate::parallel::tree_reduce(shard_forests, threads, |a, b| {
            merge_shard_forests(depth, a, b)
        })
        .expect("shards >= 1");
        self.trie_merge_ms = merge_start.elapsed().as_secs_f64() * 1e3;
        debug_assert!(
            merged.tries.iter().enumerate().all(|(k, (idx, _))| k == *idx),
            "tree-merge must yield every set's trie in global order"
        );
        self.tries = merged.tries.into_iter().map(|(_, t)| t).collect();
        self.forest = Some(merged.forest);
    }

    /// Wall-clock milliseconds the last [`Partition::build_tries_parallel`]
    /// spent in its shard-merge phase (0 for serial builds).
    pub fn trie_merge_ms(&self) -> f64 {
        self.trie_merge_ms
    }

    /// Whether [`Partition::build_tries`] has run.
    pub fn has_tries(&self) -> bool {
        self.forest.is_some()
    }

    /// The shared trie arena (if built).
    pub fn config_forest(&self) -> Option<&ConfigForest> {
        self.forest.as_ref()
    }

    /// The prefix trie of set `c` (panics if tries are not built).
    pub fn trie(&self, c: usize) -> &ConfigTrie {
        assert!(self.forest.is_some(), "call build_tries first");
        &self.tries[c]
    }

    /// Build the rejection-free conditioned ball dropper for the pieces of
    /// this partition (builds the tries first if needed).
    ///
    /// Dense blocks — more cells than the expected full-space ball count —
    /// are excluded from the product DAG (their conditioning setup would
    /// outweigh the drops it saves, and the plain descent's acceptance
    /// rate is high exactly there); callers fall back to Algorithm 1 for
    /// those. The split depends only on the partition and `thetas`, so
    /// seeded runs stay reproducible.
    pub fn conditioned_sampler(&mut self, thetas: &ThetaSeq) -> ConditionedBallDropSampler {
        self.conditioned_sampler_threaded(thetas, 1)
    }

    /// As [`Partition::conditioned_sampler`] with `threads` setup threads
    /// for the trie build and the DAG's per-level bottom-up mass
    /// aggregation. The sampler is identical for every thread count.
    pub fn conditioned_sampler_threaded(
        &mut self,
        thetas: &ThetaSeq,
        threads: usize,
    ) -> ConditionedBallDropSampler {
        self.build_tries_parallel(thetas.depth(), threads);
        let forest = self.forest.as_ref().expect("tries built above");
        // Floor keeps small blocks conditioned even for sparse θ; ceiling
        // guards the f64 → u64 cast for huge d.
        let budget = thetas.expected_edges().clamp(65536.0, 1e18) as u64;
        ConditionedBallDropSampler::build_budgeted_threaded(
            thetas,
            forest,
            &self.tries,
            budget,
            threads,
        )
    }

    /// Build the dense `config → node + 1` index for the sets that can
    /// afford it.
    ///
    /// `num_configs` is the configuration-space size `2^d`. Each set gets
    /// a table only when it would be at least `1/64` full
    /// ([`DENSE_MIN_LOAD_DIV`]); sparser sets — the long tail of small
    /// `D_c` when `B` is large — keep their hash map, bounding total
    /// dense memory by `256·n` bytes instead of `B · 2^d · 4` (which at
    /// the d = 22 gate was 16 MB per set, singletons included).
    pub fn build_dense_index(&mut self, num_configs: usize) {
        self.dense = self
            .maps
            .iter()
            .map(|m| {
                if m.len().saturating_mul(DENSE_MIN_LOAD_DIV) < num_configs {
                    return Vec::new(); // sparse set: keep the hash map
                }
                let mut table = vec![0 as NodeId; num_configs];
                for (&cfg, &node) in m {
                    table[cfg as usize] = node + 1;
                }
                table
            })
            .collect();
    }

    /// Whether the dense index is built (individual sets may still answer
    /// from their hash map — see [`Partition::build_dense_index`]).
    pub fn has_dense_index(&self) -> bool {
        !self.dense.is_empty()
    }

    /// Number of sets with a materialized dense table (diagnostics).
    pub fn num_dense_sets(&self) -> usize {
        self.dense.iter().filter(|t| !t.is_empty()).count()
    }

    /// `config → node` lookup for set `c`, using the set's dense table if
    /// one was built.
    #[inline]
    pub fn lookup(&self, c: usize, config: Config) -> Option<NodeId> {
        if let Some(table) = self.dense.get(c) {
            if !table.is_empty() {
                let v = table[config as usize];
                return if v == 0 { None } else { Some(v - 1) };
            }
        }
        self.maps[c].get(&config).copied()
    }

    /// The partition size B.
    #[inline]
    pub fn size(&self) -> usize {
        self.sets.len()
    }

    /// Nodes of set `c` (0-based).
    #[inline]
    pub fn set(&self, c: usize) -> &[NodeId] {
        &self.sets[c]
    }

    /// Configuration → node lookup for set `c`.
    #[inline]
    pub fn map(&self, c: usize) -> &FastMap<Config, NodeId> {
        &self.maps[c]
    }

    /// Total number of nodes across all sets.
    pub fn num_nodes(&self) -> usize {
        self.sets.iter().map(|s| s.len()).sum()
    }

    /// Serialize into a setup-artifact body (`crate::setup`): the sets,
    /// the per-set maps (entries in ascending config order, so the byte
    /// stream is canonical), the trie forest, and the per-set tries.
    /// Derived state is *not* written — the dense index is rebuilt on
    /// hydration and `trie_merge_ms` is build provenance.
    pub(crate) fn encode(&self, w: &mut Writer) {
        w.put_u64(self.sets.len() as u64);
        for set in &self.sets {
            w.put_u64(set.len() as u64);
            for &node in set {
                w.put_u32(node);
            }
        }
        for m in &self.maps {
            w.put_u64(m.len() as u64);
            let mut pairs: Vec<(Config, NodeId)> =
                m.iter().map(|(&c, &n)| (c, n)).collect(); // lint: order-ok(sorted on the next line)
            pairs.sort_unstable();
            for (c, n) in pairs {
                w.put_u64(c);
                w.put_u32(n);
            }
        }
        match &self.forest {
            None => w.put_u8(0),
            Some(f) => {
                w.put_u8(1);
                f.encode(w);
            }
        }
        w.put_u64(self.tries.len() as u64);
        for t in &self.tries {
            t.encode(w);
        }
    }

    /// Decode the counterpart of [`Partition::encode`] from untrusted
    /// bytes, with structural validation (map/set cardinality agreement,
    /// no repeated configs, tries iff forest). The dense index comes back
    /// empty — hydration rebuilds it — and `trie_merge_ms` is 0.
    pub(crate) fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let num_sets = r.take_len(8, "partition sets")?;
        let mut sets = Vec::with_capacity(num_sets);
        for _ in 0..num_sets {
            let len = r.take_len(4, "partition set nodes")?;
            let mut set = Vec::with_capacity(len);
            for _ in 0..len {
                set.push(r.take_u32("partition node")?);
            }
            sets.push(set);
        }
        let mut maps = Vec::with_capacity(num_sets);
        for (c, set) in sets.iter().enumerate() {
            let len = r.take_len(12, "partition map entries")?;
            if len != set.len() {
                bail!(
                    "artifact body corrupt: set {c} holds {} nodes but its map claims {len} \
                     entries",
                    set.len()
                );
            }
            let mut m: FastMap<Config, NodeId> = fast_map_with_capacity(len);
            for _ in 0..len {
                let cfg = r.take_u64("map config")?;
                let node = r.take_u32("map node")?;
                if m.insert(cfg, node).is_some() {
                    bail!("artifact body corrupt: config {cfg:#x} repeated in set {c}'s map");
                }
            }
            maps.push(m);
        }
        let forest = match r.take_u8("forest flag")? {
            0 => None,
            1 => Some(ConfigForest::decode(r)?),
            b => bail!("artifact body corrupt: forest flag byte {b}"),
        };
        let num_tries = r.take_len(4, "tries")?;
        match &forest {
            None if num_tries != 0 => {
                bail!("artifact body corrupt: {num_tries} tries without a forest")
            }
            Some(_) if num_tries != num_sets => bail!(
                "artifact body corrupt: {num_tries} tries for {num_sets} partition sets"
            ),
            _ => {}
        }
        let mut tries = Vec::with_capacity(num_tries);
        for _ in 0..num_tries {
            tries.push(ConfigTrie::decode(r)?);
        }
        if let Some(f) = &forest {
            for (c, t) in tries.iter().enumerate() {
                if (t.root() as usize) >= f.num_root_classes() {
                    bail!(
                        "artifact body corrupt: trie {c} root {} outside the forest's level 0",
                        t.root()
                    );
                }
            }
        }
        Ok(Partition { sets, maps, dense: Vec::new(), forest, tries, trie_merge_ms: 0.0 })
    }
}

/// Equality over the partition *content*: sets, maps, forest, tries.
/// Deliberately manual — the dense index is a derived cache (identical
/// lookups either way) and `trie_merge_ms` is build provenance, so
/// neither may distinguish a hydrated partition from a fresh one.
impl PartialEq for Partition {
    fn eq(&self, other: &Self) -> bool {
        self.sets == other.sets
            && self.maps == other.maps
            && self.forest == other.forest
            && self.tries == other.tries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{forall, Config as PropConfig};

    #[test]
    fn simple_partition() {
        // configs: a a b a b -> D_1 = {0 (a), 2 (b)}, D_2 = {1, 4}, D_3 = {3}
        let configs = vec![7u64, 7, 3, 7, 3];
        let p = Partition::build(&configs);
        assert_eq!(p.size(), 3);
        assert_eq!(p.set(0), &[0, 2]);
        assert_eq!(p.set(1), &[1, 4]);
        assert_eq!(p.set(2), &[3]);
        assert_eq!(p.map(1)[&7], 1);
        assert_eq!(p.map(1)[&3], 4);
    }

    #[test]
    fn all_unique_gives_b_one() {
        let configs: Vec<u64> = (0..100).collect();
        let p = Partition::build(&configs);
        assert_eq!(p.size(), 1);
        assert_eq!(p.set(0).len(), 100);
    }

    #[test]
    fn all_same_gives_b_n() {
        let configs = vec![5u64; 40];
        let p = Partition::build(&configs);
        assert_eq!(p.size(), 40);
        for c in 0..40 {
            assert_eq!(p.set(c), &[c as u32]);
        }
    }

    #[test]
    fn property_partition_invariants() {
        // For random configs: (1) sets partition the nodes, (2) no config
        // repeats inside a set, (3) B equals the max multiplicity
        // (Theorem 2 minimality), (4) maps agree with sets.
        forall(PropConfig::cases(200), |rng| {
            let n = 1 + rng.below(200) as usize;
            let k = 1 + rng.below(20); // distinct configs
            let configs: Vec<u64> = (0..n).map(|_| rng.below(k)).collect();
            let p = Partition::build(&configs);

            let mut seen = vec![false; n];
            for c in 0..p.size() {
                let mut cfgs_in_set = std::collections::HashSet::new();
                for &i in p.set(c) {
                    if seen[i as usize] {
                        return Err(format!("node {i} in two sets"));
                    }
                    seen[i as usize] = true;
                    if !cfgs_in_set.insert(configs[i as usize]) {
                        return Err(format!("config repeated in set {c}"));
                    }
                    if p.map(c).get(&configs[i as usize]) != Some(&i) {
                        return Err(format!("map mismatch for node {i} in set {c}"));
                    }
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("some node missing from partition".into());
            }

            let mut mult: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
            for &c in &configs {
                *mult.entry(c).or_default() += 1;
            }
            let max_mult = mult.values().copied().max().unwrap_or(0);
            if p.size() != max_mult {
                return Err(format!("B = {} != max multiplicity {max_mult}", p.size()));
            }
            Ok(())
        });
    }

    #[test]
    fn tries_cover_set_configs() {
        // configs: a a b a b -> set sizes 2, 2, 1 (see simple_partition).
        let configs = vec![0b111u64, 0b111, 0b011, 0b111, 0b011];
        let mut p = Partition::build(&configs);
        assert!(!p.has_tries());
        p.build_tries(3);
        assert!(p.has_tries());
        assert_eq!(p.trie(0).num_configs(), 2);
        assert_eq!(p.trie(1).num_configs(), 2);
        assert_eq!(p.trie(2).num_configs(), 1);
        // Sets 0 and 1 hold the same config set {0b011, 0b111}: hash
        // consing must give them the same root class.
        assert_eq!(p.trie(0).root(), p.trie(1).root());
        // Reachability for {0b011, 0b111}: length-2 prefixes 01 and 11
        // are live, 00 and 10 dead.
        assert_eq!(p.trie(0).is_live(2, 0b01), Some(true));
        assert_eq!(p.trie(0).is_live(2, 0b11), Some(true));
        assert_eq!(p.trie(0).is_live(2, 0b00), Some(false));
        assert_eq!(p.trie(0).is_live(2, 0b10), Some(false));
        assert_eq!(p.trie(2).is_live(3, 0b111), Some(true));
        assert_eq!(p.trie(2).is_live(3, 0b011), Some(false));
        // Idempotent.
        p.build_tries(3);
        assert_eq!(p.config_forest().unwrap().depth(), 3);
    }

    /// The full equality the parallel builds promise: same sets (same
    /// node order), same maps.
    fn assert_same_partition(a: &Partition, b: &Partition) {
        assert_eq!(a.size(), b.size());
        for c in 0..a.size() {
            assert_eq!(a.set(c), b.set(c), "set {c} differs");
            assert_eq!(a.map(c), b.map(c), "map {c} differs");
        }
    }

    /// Random configs big enough to span several [`PARTITION_CHUNK`]s,
    /// with skew so multiplicities (and hence B) are non-trivial.
    fn chunky_configs(n: usize, distinct: u64, seed: u64) -> Vec<u64> {
        let mut rng = crate::rng::Rng::new(seed);
        (0..n).map(|_| rng.below(distinct) * rng.below(distinct) % distinct).collect()
    }

    #[test]
    fn parallel_build_matches_sequential_across_thread_counts() {
        let configs = chunky_configs(3 * PARTITION_CHUNK + 111, 5000, 41);
        let serial = Partition::build(&configs);
        for threads in [1usize, 2, 8] {
            let par = Partition::build_parallel(&configs, threads);
            assert_same_partition(&par, &serial);
        }
    }

    #[test]
    fn parallel_subset_build_matches_sequential() {
        let configs = chunky_configs(5 * PARTITION_CHUNK, 3000, 43);
        let nodes: Vec<NodeId> =
            (0..configs.len() as NodeId).filter(|i| i % 7 != 0).collect();
        let serial = Partition::build_subset(&configs, &nodes);
        for threads in [2usize, 8] {
            let par = Partition::build_subset_parallel(&configs, &nodes, threads);
            assert_same_partition(&par, &serial);
        }
    }

    #[test]
    fn parallel_tries_match_serial_forest_exactly() {
        // The sharded build + adopt merge must reproduce the serial arena
        // bit-for-bit: same forest (levels AND class ids), same tries.
        let configs = chunky_configs(2 * PARTITION_CHUNK, 600, 47);
        let depth = 13;
        let mut serial = Partition::build(&configs);
        serial.build_tries(depth);
        assert_eq!(serial.trie_merge_ms(), 0.0, "serial build has no merge phase");
        for threads in [1usize, 2, 3, 8] {
            let mut par = Partition::build_parallel(&configs, threads);
            par.build_tries_parallel(depth, threads);
            assert_eq!(
                par.config_forest().unwrap(),
                serial.config_forest().unwrap(),
                "forest differs at threads={threads}"
            );
            for c in 0..serial.size() {
                assert_eq!(par.trie(c), serial.trie(c), "trie {c} at threads={threads}");
            }
        }
    }

    #[test]
    fn tree_prefix_sum_matches_serial_fold() {
        // The up/down-sweep must reproduce the serial fold's exact maps:
        // same total multiplicities, and per chunk exactly that chunk's
        // key set (first appearances at 0) with the serial start ranks.
        let mut rng = crate::rng::Rng::new(59);
        for num_chunks in [3usize, 4, 7, 16, 33] {
            let histograms: Vec<FastMap<Config, u32>> = (0..num_chunks)
                .map(|_| {
                    let mut h: FastMap<Config, u32> = FastMap::default();
                    for _ in 0..rng.below(50) {
                        *h.entry(rng.below(30)).or_insert(0) += 1 + rng.below(4) as u32;
                    }
                    h
                })
                .collect();
            let (serial_total, serial_starts) = exclusive_chunk_offsets(histograms.clone(), 1);
            for threads in [2usize, 3, 8] {
                let (total, starts) = exclusive_chunk_offsets(histograms.clone(), threads);
                assert_eq!(total, serial_total, "chunks={num_chunks} threads={threads}");
                assert_eq!(starts, serial_starts, "chunks={num_chunks} threads={threads}");
            }
        }
    }

    #[test]
    fn threaded_conditioned_sampler_matches_serial() {
        let configs = chunky_configs(2 * PARTITION_CHUNK, 400, 53);
        let thetas = ThetaSeq::homogeneous(crate::kpgm::Initiator::THETA1, 12);
        let serial = Partition::build(&configs).conditioned_sampler(&thetas);
        let threaded =
            Partition::build_parallel(&configs, 4).conditioned_sampler_threaded(&thetas, 4);
        assert_eq!(serial, threaded);
    }

    #[test]
    fn dense_index_gates_per_set() {
        // One big set (every config once) and a long tail of tiny sets
        // (config 0 repeated): only the big set affords a dense table.
        let num_configs = 1usize << 12;
        let mut configs: Vec<u64> = (0..num_configs as u64).collect();
        configs.extend(std::iter::repeat(0u64).take(40));
        let mut p = Partition::build(&configs);
        assert_eq!(p.size(), 41);
        p.build_dense_index(num_configs);
        assert!(p.has_dense_index());
        // Set 0 holds 2^12 configs (dense); sets 1..41 hold one config
        // each (1 · 64 < 4096: hash map).
        assert_eq!(p.num_dense_sets(), 1);
        // Lookups agree with the maps on every set either way.
        assert_eq!(p.lookup(0, 77), Some(77));
        assert_eq!(p.lookup(1, 0), Some(num_configs as NodeId));
        assert_eq!(p.lookup(1, 77), None);
        assert_eq!(p.lookup(40, 0), Some((num_configs + 39) as NodeId));
    }

    #[test]
    fn wire_round_trip_with_and_without_tries() {
        let configs = chunky_configs(PARTITION_CHUNK, 700, 61);
        // Bare partition (rejection-mode artifacts carry no tries).
        let bare = Partition::build(&configs);
        let mut w = Writer::new();
        bare.encode(&mut w);
        let bytes = w.into_bytes();
        let back = Partition::decode(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(back, bare);
        assert!(!back.has_tries());
        // With forest + tries (conditioned-mode artifacts).
        let mut full = Partition::build(&configs);
        full.build_tries(12);
        let mut w = Writer::new();
        full.encode(&mut w);
        let bytes = w.into_bytes();
        let mut back = Partition::decode(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(back, full);
        assert_eq!(back.config_forest(), full.config_forest());
        for c in 0..full.size() {
            assert_eq!(back.trie(c), full.trie(c), "trie {c}");
        }
        // The decoded forest's interners were rebuilt from the arena:
        // registering the same sets again must dedupe onto the existing
        // classes, and the trie rebuild short-circuits (idempotence).
        back.build_tries(12);
        assert_eq!(back.config_forest(), full.config_forest());
        // The dense index is rebuilt, not deserialized, and equality is
        // blind to it (derived cache).
        back.build_dense_index(1 << 12);
        assert_eq!(back, full);
        for c in 0..full.size() {
            for &node in full.set(c) {
                assert_eq!(back.lookup(c, configs[node as usize]), Some(node));
            }
        }
    }

    #[test]
    fn decode_rejects_inconsistent_bodies() {
        let configs = vec![1u64, 1, 2];
        let p = Partition::build(&configs);
        let mut w = Writer::new();
        p.encode(&mut w);
        let good = w.into_bytes();
        assert!(Partition::decode(&mut Reader::new(&good)).is_ok());
        // Truncated anywhere → structured error.
        for cut in [0, 4, good.len() / 2, good.len() - 1] {
            assert!(Partition::decode(&mut Reader::new(&good[..cut])).is_err(), "cut {cut}");
        }
        // A map claiming more entries than its set holds nodes.
        let mut w = Writer::new();
        w.put_u64(1); // one set
        w.put_u64(1); // with one node
        w.put_u32(0);
        w.put_u64(2); // but a two-entry map
        w.put_u64(1);
        w.put_u32(0);
        w.put_u64(2);
        w.put_u32(0);
        w.put_u8(0);
        w.put_u64(0);
        let bytes = w.into_bytes();
        let err = Partition::decode(&mut Reader::new(&bytes)).unwrap_err().to_string();
        assert!(err.contains("map claims"), "{err}");
        // A repeated config inside one set's map.
        let mut w = Writer::new();
        w.put_u64(1);
        w.put_u64(2);
        w.put_u32(0);
        w.put_u32(1);
        w.put_u64(2);
        w.put_u64(5);
        w.put_u32(0);
        w.put_u64(5);
        w.put_u32(1);
        w.put_u8(0);
        w.put_u64(0);
        let bytes = w.into_bytes();
        let err = Partition::decode(&mut Reader::new(&bytes)).unwrap_err().to_string();
        assert!(err.contains("repeated"), "{err}");
        // Tries without a forest.
        let mut w = Writer::new();
        w.put_u64(0); // no sets
        w.put_u8(0); // no forest
        w.put_u64(3); // but three tries
        w.put_u32(0); // (payload present so the length check passes and
        w.put_u32(0); //  the structural tries-without-forest check fires)
        w.put_u32(0);
        let bytes = w.into_bytes();
        let err = Partition::decode(&mut Reader::new(&bytes)).unwrap_err().to_string();
        assert!(err.contains("without a forest"), "{err}");
    }

    #[test]
    fn subset_partition_restricts() {
        let configs = vec![1u64, 1, 2, 1, 2, 3];
        let nodes = vec![0u32, 2, 3, 4];
        let p = Partition::build_subset(&configs, &nodes);
        assert_eq!(p.size(), 2);
        assert_eq!(p.num_nodes(), 4);
        assert_eq!(p.set(0), &[0, 2]); // first occurrence of config 1 and 2
        assert_eq!(p.set(1), &[3, 4]);
    }
}
