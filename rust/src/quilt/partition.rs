//! The node partition `D_1 … D_B` (paper §4, Theorem 2).
//!
//! `Z_i = {j ≤ i : λ_j = λ_i}`; node `i` goes to set `D_{|Z_i|}`. Within a
//! set every attribute configuration appears at most once, and the number
//! of non-empty sets `B = max_c (multiplicity of c)` is minimal by the
//! pigeon-hole argument of Theorem 2.

use crate::hashutil::FastMap;

use crate::graph::NodeId;
use crate::kpgm::{ConditionedBallDropSampler, ConfigForest, ConfigTrie, ThetaSeq};
use crate::magm::Config;

/// The partition plus, per set, the `config → node` lookup used when
/// filtering KPGM samples (the permutation `λ_i → i` of Figure 3).
#[derive(Debug, Clone)]
pub struct Partition {
    /// `sets[c]` holds the nodes with `|Z_i| = c + 1`.
    sets: Vec<Vec<NodeId>>,
    /// `maps[c]`: configuration → node for set c.
    maps: Vec<FastMap<Config, NodeId>>,
    /// Optional dense lookup (`dense[c][config] = node + 1`, 0 = absent):
    /// the filter runs once per ball drop, and a direct index is ~5× faster
    /// than the hash probe. Built by [`Partition::build_dense_index`] when
    /// the configuration space is small enough to afford it.
    dense: Vec<Vec<NodeId>>,
    /// Optional hash-consed prefix-trie arena over the sets' configs (one
    /// [`ConfigTrie`] per set), built by [`Partition::build_tries`]. The
    /// trie classes power the rejection-free conditioned piece sampler;
    /// the per-level reachability bitmasks each trie carries are a
    /// diagnostic surface (tests/tooling), not consulted by the descent.
    forest: Option<ConfigForest>,
    tries: Vec<ConfigTrie>,
}

impl Partition {
    /// Build the partition by a single left-to-right scan with a
    /// multiplicity counter (O(n) expected).
    pub fn build(configs: &[Config]) -> Self {
        let mut multiplicity: FastMap<Config, u32> = crate::hashutil::fast_map_with_capacity(configs.len());
        let mut sets: Vec<Vec<NodeId>> = Vec::new();
        let mut maps: Vec<FastMap<Config, NodeId>> = Vec::new();
        for (i, &c) in configs.iter().enumerate() {
            let m = multiplicity.entry(c).or_insert(0);
            *m += 1;
            let idx = (*m - 1) as usize;
            if idx == sets.len() {
                sets.push(Vec::new());
                maps.push(FastMap::default());
            }
            sets[idx].push(i as NodeId);
            maps[idx].insert(c, i as NodeId);
        }
        Partition { sets, maps, dense: Vec::new(), forest: None, tries: Vec::new() }
    }

    /// Build restricted to a subset of nodes (used by the hybrid sampler's
    /// W set). Nodes keep their original ids.
    pub fn build_subset(configs: &[Config], nodes: &[NodeId]) -> Self {
        let mut multiplicity: FastMap<Config, u32> = crate::hashutil::fast_map_with_capacity(nodes.len());
        let mut sets: Vec<Vec<NodeId>> = Vec::new();
        let mut maps: Vec<FastMap<Config, NodeId>> = Vec::new();
        for &i in nodes {
            let c = configs[i as usize];
            let m = multiplicity.entry(c).or_insert(0);
            *m += 1;
            let idx = (*m - 1) as usize;
            if idx == sets.len() {
                sets.push(Vec::new());
                maps.push(FastMap::default());
            }
            sets[idx].push(i);
            maps[idx].insert(c, i);
        }
        Partition { sets, maps, dense: Vec::new(), forest: None, tries: Vec::new() }
    }

    /// Build the per-set prefix tries (and per-level reachability masks)
    /// over the `depth`-bit configuration space. Idempotent
    /// ([`Partition::conditioned_sampler`] calls it automatically). Cost
    /// `O(d · n)`, with hash-consing sharing suffix structure across the
    /// nested sets.
    pub fn build_tries(&mut self, depth: usize) {
        if let Some(forest) = &self.forest {
            debug_assert_eq!(
                forest.depth(),
                depth,
                "build_tries called again with a different depth"
            );
            return;
        }
        let mut forest = ConfigForest::new(depth);
        self.tries = self
            .maps
            .iter()
            .map(|m| {
                let mut cfgs: Vec<Config> = m.keys().copied().collect();
                cfgs.sort_unstable();
                forest.register_set(&cfgs)
            })
            .collect();
        self.forest = Some(forest);
    }

    /// Whether [`Partition::build_tries`] has run.
    pub fn has_tries(&self) -> bool {
        self.forest.is_some()
    }

    /// The shared trie arena (if built).
    pub fn config_forest(&self) -> Option<&ConfigForest> {
        self.forest.as_ref()
    }

    /// The prefix trie of set `c` (panics if tries are not built).
    pub fn trie(&self, c: usize) -> &ConfigTrie {
        assert!(self.forest.is_some(), "call build_tries first");
        &self.tries[c]
    }

    /// Build the rejection-free conditioned ball dropper for the pieces of
    /// this partition (builds the tries first if needed).
    ///
    /// Dense blocks — more cells than the expected full-space ball count —
    /// are excluded from the product DAG (their conditioning setup would
    /// outweigh the drops it saves, and the plain descent's acceptance
    /// rate is high exactly there); callers fall back to Algorithm 1 for
    /// those. The split depends only on the partition and `thetas`, so
    /// seeded runs stay reproducible.
    pub fn conditioned_sampler(&mut self, thetas: &ThetaSeq) -> ConditionedBallDropSampler {
        self.build_tries(thetas.depth());
        let forest = self.forest.as_ref().expect("tries built above");
        // Floor keeps small blocks conditioned even for sparse θ; ceiling
        // guards the f64 → u64 cast for huge d.
        let budget = thetas.expected_edges().clamp(65536.0, 1e18) as u64;
        ConditionedBallDropSampler::build_budgeted(thetas, forest, &self.tries, budget)
    }

    /// Build the dense `config → node + 1` index for every set.
    ///
    /// `num_configs` is the configuration-space size `2^d`; call only when
    /// `B · 2^d · 4` bytes is affordable (the quilting sampler gates at
    /// `2^d ≤ 2^22`).
    pub fn build_dense_index(&mut self, num_configs: usize) {
        self.dense = self
            .maps
            .iter()
            .map(|m| {
                let mut table = vec![0 as NodeId; num_configs];
                for (&cfg, &node) in m {
                    table[cfg as usize] = node + 1;
                }
                table
            })
            .collect();
    }

    /// Whether the dense index is built.
    pub fn has_dense_index(&self) -> bool {
        !self.dense.is_empty()
    }

    /// `config → node` lookup for set `c`, using the dense index if built.
    #[inline]
    pub fn lookup(&self, c: usize, config: Config) -> Option<NodeId> {
        if let Some(table) = self.dense.get(c) {
            let v = table[config as usize];
            if v == 0 { None } else { Some(v - 1) }
        } else {
            self.maps[c].get(&config).copied()
        }
    }

    /// The partition size B.
    #[inline]
    pub fn size(&self) -> usize {
        self.sets.len()
    }

    /// Nodes of set `c` (0-based).
    #[inline]
    pub fn set(&self, c: usize) -> &[NodeId] {
        &self.sets[c]
    }

    /// Configuration → node lookup for set `c`.
    #[inline]
    pub fn map(&self, c: usize) -> &FastMap<Config, NodeId> {
        &self.maps[c]
    }

    /// Total number of nodes across all sets.
    pub fn num_nodes(&self) -> usize {
        self.sets.iter().map(|s| s.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{forall, Config as PropConfig};

    #[test]
    fn simple_partition() {
        // configs: a a b a b -> D_1 = {0 (a), 2 (b)}, D_2 = {1, 4}, D_3 = {3}
        let configs = vec![7u64, 7, 3, 7, 3];
        let p = Partition::build(&configs);
        assert_eq!(p.size(), 3);
        assert_eq!(p.set(0), &[0, 2]);
        assert_eq!(p.set(1), &[1, 4]);
        assert_eq!(p.set(2), &[3]);
        assert_eq!(p.map(1)[&7], 1);
        assert_eq!(p.map(1)[&3], 4);
    }

    #[test]
    fn all_unique_gives_b_one() {
        let configs: Vec<u64> = (0..100).collect();
        let p = Partition::build(&configs);
        assert_eq!(p.size(), 1);
        assert_eq!(p.set(0).len(), 100);
    }

    #[test]
    fn all_same_gives_b_n() {
        let configs = vec![5u64; 40];
        let p = Partition::build(&configs);
        assert_eq!(p.size(), 40);
        for c in 0..40 {
            assert_eq!(p.set(c), &[c as u32]);
        }
    }

    #[test]
    fn property_partition_invariants() {
        // For random configs: (1) sets partition the nodes, (2) no config
        // repeats inside a set, (3) B equals the max multiplicity
        // (Theorem 2 minimality), (4) maps agree with sets.
        forall(PropConfig::cases(200), |rng| {
            let n = 1 + rng.below(200) as usize;
            let k = 1 + rng.below(20); // distinct configs
            let configs: Vec<u64> = (0..n).map(|_| rng.below(k)).collect();
            let p = Partition::build(&configs);

            let mut seen = vec![false; n];
            for c in 0..p.size() {
                let mut cfgs_in_set = std::collections::HashSet::new();
                for &i in p.set(c) {
                    if seen[i as usize] {
                        return Err(format!("node {i} in two sets"));
                    }
                    seen[i as usize] = true;
                    if !cfgs_in_set.insert(configs[i as usize]) {
                        return Err(format!("config repeated in set {c}"));
                    }
                    if p.map(c).get(&configs[i as usize]) != Some(&i) {
                        return Err(format!("map mismatch for node {i} in set {c}"));
                    }
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("some node missing from partition".into());
            }

            let mut mult: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
            for &c in &configs {
                *mult.entry(c).or_default() += 1;
            }
            let max_mult = mult.values().copied().max().unwrap_or(0);
            if p.size() != max_mult {
                return Err(format!("B = {} != max multiplicity {max_mult}", p.size()));
            }
            Ok(())
        });
    }

    #[test]
    fn tries_cover_set_configs() {
        // configs: a a b a b -> set sizes 2, 2, 1 (see simple_partition).
        let configs = vec![0b111u64, 0b111, 0b011, 0b111, 0b011];
        let mut p = Partition::build(&configs);
        assert!(!p.has_tries());
        p.build_tries(3);
        assert!(p.has_tries());
        assert_eq!(p.trie(0).num_configs(), 2);
        assert_eq!(p.trie(1).num_configs(), 2);
        assert_eq!(p.trie(2).num_configs(), 1);
        // Sets 0 and 1 hold the same config set {0b011, 0b111}: hash
        // consing must give them the same root class.
        assert_eq!(p.trie(0).root(), p.trie(1).root());
        // Reachability for {0b011, 0b111}: length-2 prefixes 01 and 11
        // are live, 00 and 10 dead.
        assert_eq!(p.trie(0).is_live(2, 0b01), Some(true));
        assert_eq!(p.trie(0).is_live(2, 0b11), Some(true));
        assert_eq!(p.trie(0).is_live(2, 0b00), Some(false));
        assert_eq!(p.trie(0).is_live(2, 0b10), Some(false));
        assert_eq!(p.trie(2).is_live(3, 0b111), Some(true));
        assert_eq!(p.trie(2).is_live(3, 0b011), Some(false));
        // Idempotent.
        p.build_tries(3);
        assert_eq!(p.config_forest().unwrap().depth(), 3);
    }

    #[test]
    fn subset_partition_restricts() {
        let configs = vec![1u64, 1, 2, 1, 2, 3];
        let nodes = vec![0u32, 2, 3, 4];
        let p = Partition::build_subset(&configs, &nodes);
        assert_eq!(p.size(), 2);
        assert_eq!(p.num_nodes(), 4);
        assert_eq!(p.set(0), &[0, 2]); // first occurrence of config 1 and 2
        assert_eq!(p.set(1), &[3, 4]);
    }
}
