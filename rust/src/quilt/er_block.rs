//! Uniform (Erdős–Rényi) block sampling by geometric skipping.
//!
//! The §5 footnote's trick: instead of `k` i.i.d. Bernoulli(p) trials over
//! the cells of a block, draw geometric gaps and jump straight to the next
//! success. Cost is `O(1 + p · cells)` instead of `O(cells)`.

use crate::graph::{EdgeList, NodeId};
use crate::rng::Rng;

/// Sample a uniform block: every (row, col) pair becomes an edge
/// independently with probability `p`. Rows and cols are node-id slices
/// (the block is the sub-matrix `rows × cols` of the adjacency matrix).
pub fn sample_er_block(
    rows: &[NodeId],
    cols: &[NodeId],
    p: f64,
    rng: &mut Rng,
    out: &mut EdgeList,
) {
    if rows.is_empty() || cols.is_empty() || p <= 0.0 {
        return;
    }
    let cells = rows.len() as u64 * cols.len() as u64;
    if p >= 1.0 {
        for &r in rows {
            for &c in cols {
                out.push(r, c);
            }
        }
        return;
    }
    let ncols = cols.len() as u64;
    // Position of the next success in the linearized cell order.
    let mut pos = rng.geometric(p);
    while pos < cells {
        let r = rows[(pos / ncols) as usize];
        let c = cols[(pos % ncols) as usize];
        out.push(r, c);
        let gap = rng.geometric(p);
        pos = match next_success(pos, gap) {
            Some(next) => next,
            None => break,
        };
    }
}

/// Advance from success position `pos` by a geometric `gap`:
/// `pos + 1 + gap`, or `None` past the end of the index space.
///
/// Both additions must be checked: `geometric` returns `u64::MAX` as its
/// improper-distribution sentinel for vanishingly small `p`, so the naive
/// `pos.checked_add(1 + gap)` computes `1 + gap` *unchecked* first — it
/// panics in debug builds and wraps to 0 in release, leaving `pos`
/// unchanged and re-emitting the same cell forever.
#[inline]
fn next_success(pos: u64, gap: u64) -> Option<u64> {
    gap.checked_add(1).and_then(|g| pos.checked_add(g))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{forall, Config as PropConfig};

    #[test]
    fn zero_probability_empty() {
        let mut out = EdgeList::new(10);
        let mut rng = Rng::new(1);
        sample_er_block(&[0, 1, 2], &[3, 4], 0.0, &mut rng, &mut out);
        assert_eq!(out.num_edges(), 0);
    }

    #[test]
    fn one_probability_full() {
        let mut out = EdgeList::new(10);
        let mut rng = Rng::new(1);
        sample_er_block(&[0, 1], &[2, 3, 4], 1.0, &mut rng, &mut out);
        assert_eq!(out.num_edges(), 6);
        let mut dedup = out.clone();
        assert_eq!(dedup.dedup(), 0);
    }

    #[test]
    fn density_matches_p() {
        let rows: Vec<NodeId> = (0..50).collect();
        let cols: Vec<NodeId> = (50..150).collect();
        let p = 0.07;
        let mut rng = Rng::new(229);
        let trials = 400;
        let mut total = 0usize;
        for _ in 0..trials {
            let mut out = EdgeList::new(150);
            sample_er_block(&rows, &cols, p, &mut rng, &mut out);
            total += out.num_edges();
        }
        let mean = total as f64 / trials as f64;
        let want = 50.0 * 100.0 * p; // 350
        let sigma = (50.0 * 100.0 * p * (1.0 - p) / trials as f64).sqrt();
        assert!((mean - want).abs() < 5.0 * sigma, "mean={mean} want={want}");
    }

    #[test]
    fn per_cell_rate_uniform() {
        // Each individual cell must fire at rate p (no positional bias).
        let rows: Vec<NodeId> = vec![0, 1, 2];
        let cols: Vec<NodeId> = vec![3, 4];
        let p = 0.3;
        let mut rng = Rng::new(233);
        let trials = 30_000;
        let mut counts = std::collections::HashMap::new();
        for _ in 0..trials {
            let mut out = EdgeList::new(5);
            sample_er_block(&rows, &cols, p, &mut rng, &mut out);
            for &e in out.edges() {
                *counts.entry(e).or_insert(0u32) += 1;
            }
        }
        for &r in &rows {
            for &c in &cols {
                let got = *counts.get(&(r, c)).unwrap_or(&0) as f64 / trials as f64;
                let sigma = (p * (1.0 - p) / trials as f64).sqrt();
                assert!((got - p).abs() < 5.0 * sigma, "cell ({r},{c}): {got}");
            }
        }
    }

    #[test]
    fn next_success_overflow_regression() {
        // The geometric sentinel for improper p: gap = u64::MAX must stop
        // the walk, not wrap `1 + gap` to 0 and duplicate the last cell.
        assert_eq!(next_success(5, u64::MAX), None);
        assert_eq!(next_success(u64::MAX - 1, u64::MAX), None);
        // Position overflow with a small gap also stops.
        assert_eq!(next_success(u64::MAX - 1, 1), None);
        assert_eq!(next_success(u64::MAX, 0), None);
        // Normal stepping is pos + 1 + gap.
        assert_eq!(next_success(5, 0), Some(6));
        assert_eq!(next_success(5, 3), Some(9));
        assert_eq!(next_success(u64::MAX - 2, 1), Some(u64::MAX));
    }

    #[test]
    fn tiny_probability_terminates() {
        // p > 0 but so small every geometric draw hits the u64::MAX
        // sentinel: the sampler must return (almost surely empty), not
        // spin on a wrapped position.
        let rows: Vec<NodeId> = (0..64).collect();
        let cols: Vec<NodeId> = (64..128).collect();
        let mut rng = Rng::new(5);
        let mut out = EdgeList::new(128);
        sample_er_block(&rows, &cols, f64::MIN_POSITIVE, &mut rng, &mut out);
        assert_eq!(out.num_edges(), 0);
    }

    #[test]
    fn property_no_duplicates_and_in_block() {
        forall(PropConfig::cases(100), |rng| {
            let nr = 1 + rng.below(20) as usize;
            let nc = 1 + rng.below(20) as usize;
            let p = rng.uniform();
            let rows: Vec<NodeId> = (0..nr as u32).collect();
            let cols: Vec<NodeId> = (100..(100 + nc as u32)).collect();
            let mut out = EdgeList::new(200);
            sample_er_block(&rows, &cols, p, rng, &mut out);
            let mut seen = std::collections::HashSet::new();
            for &(r, c) in out.edges() {
                if !(r < nr as u32 && (100..100 + nc as u32).contains(&c)) {
                    return Err(format!("edge ({r},{c}) outside block"));
                }
                if !seen.insert((r, c)) {
                    return Err(format!("duplicate edge ({r},{c})"));
                }
            }
            Ok(())
        });
    }
}
