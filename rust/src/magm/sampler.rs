//! Naive `O(n²)` MAGM sampler — the paper's baseline (§6.2, Fig. 10/11).
//!
//! One Bernoulli trial per adjacency entry, with each `Q_ij` evaluated as
//! the d-way product of paper eq. 7. This is intentionally the
//! straightforward scheme the paper benchmarks against; the accelerated
//! XLA-block variant lives in [`crate::runtime::naive_xla_sample`] and the
//! sub-quadratic sampler in [`crate::quilt`].

use crate::graph::{EdgeList, NodeId};
use crate::rng::Rng;

use super::{edge_probability, AttributeAssignment, MagmParams};

/// Sample a MAGM graph by `n²` independent Bernoulli trials.
pub fn naive_sample(
    params: &MagmParams,
    attrs: &AttributeAssignment,
    rng: &mut Rng,
) -> EdgeList {
    let n = params.num_nodes();
    assert_eq!(attrs.num_nodes(), n);
    let mut g = EdgeList::new(n);
    for i in 0..n as NodeId {
        for j in 0..n as NodeId {
            let q = edge_probability(params, attrs, i, j);
            if rng.bernoulli(q) {
                g.push(i, j);
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::Initiator;

    #[test]
    fn edge_rate_matches_q_aggregate() {
        let params = MagmParams::homogeneous(Initiator::THETA2, 0.6, 32, 5);
        let mut rng = Rng::new(127);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        // Expected |E| for the FIXED attribute draw:
        let mut want = 0.0;
        for i in 0..32u32 {
            for j in 0..32u32 {
                want += edge_probability(&params, &attrs, i, j);
            }
        }
        let trials = 300;
        let mut total = 0usize;
        for _ in 0..trials {
            total += naive_sample(&params, &attrs, &mut rng).num_edges();
        }
        let mean = total as f64 / trials as f64;
        assert!(
            (mean - want).abs() < 4.0 * (want / trials as f64).sqrt() + 1.0,
            "mean={mean} want={want}"
        );
    }

    #[test]
    fn per_entry_rate_matches_q() {
        // Two nodes with known configs; check a single cell's frequency.
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 2, 3);
        let attrs = AttributeAssignment::from_configs(vec![0b101, 0b010], 3);
        let q01 = edge_probability(&params, &attrs, 0, 1);
        let mut rng = Rng::new(131);
        let trials = 40_000;
        let mut hits = 0;
        for _ in 0..trials {
            let g = naive_sample(&params, &attrs, &mut rng);
            if g.edges().contains(&(0, 1)) {
                hits += 1;
            }
        }
        let got = hits as f64 / trials as f64;
        assert!(
            (got - q01).abs() < 5.0 * (q01 * (1.0 - q01) / trials as f64).sqrt(),
            "got={got} want={q01}"
        );
    }
}
