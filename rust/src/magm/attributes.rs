//! Attribute matrices and configurations.

use crate::graph::NodeId;
use crate::rng::Rng;

use super::MagmParams;

/// An attribute configuration λ: the d attribute bits of a node packed into
/// a u64, most significant bit = attribute 1 (matching the KPGM bit
/// convention so `Q_ij = P_{λ_i λ_j}` holds literally).
pub type Config = u64;

/// The sampled attribute assignment `F = (f(1), …, f(n))`, stored as packed
/// configurations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AttributeAssignment {
    configs: Vec<Config>,
    depth: u32,
}

impl AttributeAssignment {
    /// Sample `F` from the model: `f_k(i) ~ Bernoulli(μ_k)` independently.
    pub fn sample(params: &MagmParams, rng: &mut Rng) -> Self {
        let d = params.depth() as u32;
        let mus = params.mus();
        let configs = (0..params.num_nodes())
            .map(|_| {
                let mut c: Config = 0;
                for &mu in mus {
                    c = (c << 1) | rng.bernoulli(mu) as u64;
                }
                c
            })
            .collect();
        AttributeAssignment { configs, depth: d }
    }

    /// Wrap pre-drawn configurations (tests / deterministic experiments).
    pub fn from_configs(configs: Vec<Config>, depth: u32) -> Self {
        assert!(depth <= 63);
        debug_assert!(configs.iter().all(|&c| c < (1u64 << depth)));
        AttributeAssignment { configs, depth }
    }

    /// Configuration λ_i.
    #[inline]
    pub fn config(&self, node: NodeId) -> Config {
        self.configs[node as usize]
    }

    /// All configurations, indexed by node.
    #[inline]
    pub fn configs(&self) -> &[Config] {
        &self.configs
    }

    /// Attribute bit `f_k(i)` (k is 0-based level, 0 = most significant).
    #[inline]
    pub fn bit(&self, node: NodeId, k: u32) -> u8 {
        debug_assert!(k < self.depth);
        ((self.configs[node as usize] >> (self.depth - 1 - k)) & 1) as u8
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.configs.len()
    }

    /// Number of attribute levels d.
    #[inline]
    pub fn depth(&self) -> u32 {
        self.depth
    }

    /// Histogram of configuration frequencies: sorted `(config, count)`
    /// pairs. Powers Fig. 7 and the §5 hybrid split.
    pub fn config_counts(&self) -> Vec<(Config, u32)> {
        let mut sorted = self.configs.clone();
        sorted.sort_unstable();
        let mut out: Vec<(Config, u32)> = Vec::new();
        for &c in &sorted {
            match out.last_mut() {
                Some((prev, count)) if *prev == c => *count += 1,
                _ => out.push((c, 1)),
            }
        }
        out
    }

    /// Expand node `i`'s bits into an f32 row (for the XLA runtime path).
    pub fn bits_f32_row(&self, node: NodeId, out: &mut [f32]) {
        let d = self.depth as usize;
        assert!(out.len() >= d);
        for k in 0..d {
            out[k] = self.bit(node, k as u32) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::Initiator;

    #[test]
    fn bit_extraction_msb_first() {
        let a = AttributeAssignment::from_configs(vec![0b101], 3);
        assert_eq!(a.bit(0, 0), 1);
        assert_eq!(a.bit(0, 1), 0);
        assert_eq!(a.bit(0, 2), 1);
    }

    #[test]
    fn sample_respects_mu() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.8, 20_000, 4);
        let mut rng = Rng::new(107);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        // Fraction of 1-bits at each level ≈ 0.8.
        for k in 0..4 {
            let ones: u64 =
                (0..attrs.num_nodes()).map(|i| attrs.bit(i as NodeId, k) as u64).sum();
            let frac = ones as f64 / attrs.num_nodes() as f64;
            assert!((frac - 0.8).abs() < 0.02, "level {k}: {frac}");
        }
    }

    #[test]
    fn heterogeneous_mus() {
        let params = MagmParams::new(
            crate::kpgm::ThetaSeq::homogeneous(Initiator::THETA1, 2),
            vec![1.0, 0.0],
            1000,
        );
        let mut rng = Rng::new(109);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        for i in 0..1000u32 {
            assert_eq!(attrs.config(i), 0b10);
        }
    }

    #[test]
    fn config_counts_sum_to_n() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 4096, 6);
        let mut rng = Rng::new(113);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let counts = attrs.config_counts();
        let total: u64 = counts.iter().map(|&(_, c)| c as u64).sum();
        assert_eq!(total, 4096);
        // sorted and unique configs
        for w in counts.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn bits_f32_row_roundtrip() {
        let a = AttributeAssignment::from_configs(vec![0b0110], 4);
        let mut row = [0f32; 4];
        a.bits_f32_row(0, &mut row);
        assert_eq!(row, [0.0, 1.0, 1.0, 0.0]);
    }
}
