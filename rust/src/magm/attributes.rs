//! Attribute matrices and configurations.

use crate::graph::NodeId;
use crate::hashutil::{fast_map_with_capacity, FastMap};
use crate::rng::Rng;

use super::MagmParams;

/// An attribute configuration λ: the d attribute bits of a node packed into
/// a u64, most significant bit = attribute 1 (matching the KPGM bit
/// convention so `Q_ij = P_{λ_i λ_j}` holds literally).
pub type Config = u64;

/// How attribute sampling consumes randomness.
///
/// The MAGM definition makes `f(i)` i.i.d. per node, so any stream layout
/// yields the model; the layout only decides which *specific* assignment
/// a seed maps to, and whether sampling can parallelize.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AttrSampleMode {
    /// One left-to-right stream drawn from the caller's RNG — the legacy
    /// layout, seed-compatible with goldens recorded before the chunked
    /// pipeline existed. Inherently single-threaded.
    #[default]
    Sequential,
    /// Fixed-size node chunks ([`ATTR_CHUNK`]), chunk `c` drawn from a
    /// stable fork `rng.fork(tag).fork(c)`. The assignment is a pure
    /// function of the seed — bit-for-bit identical for every thread
    /// count — and chunks sample in parallel. Draws a *different*
    /// (equally distributed) assignment than `Sequential` for the same
    /// seed.
    Chunked,
}

impl AttrSampleMode {
    /// Parse from the CLI / config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sequential" => Some(AttrSampleMode::Sequential),
            "chunked" => Some(AttrSampleMode::Chunked),
            _ => None,
        }
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            AttrSampleMode::Sequential => "sequential",
            AttrSampleMode::Chunked => "chunked",
        }
    }
}

/// Nodes per chunk in [`AttrSampleMode::Chunked`]. Fixed — never derived
/// from the thread count — so the RNG stream layout (and hence the
/// assignment) depends only on the seed.
pub const ATTR_CHUNK: usize = 4096;

/// Fork tag separating the chunked attribute streams from every other
/// consumer of the base seed (named in the [`crate::rngtags`] registry).
const ATTR_FORK_TAG: u64 = crate::rngtags::ATTR_STREAM;

/// The sampled attribute assignment `F = (f(1), …, f(n))`, stored as packed
/// configurations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AttributeAssignment {
    configs: Vec<Config>,
    depth: u32,
}

impl AttributeAssignment {
    /// Sample `F` from the model: `f_k(i) ~ Bernoulli(μ_k)` independently.
    pub fn sample(params: &MagmParams, rng: &mut Rng) -> Self {
        let d = params.depth() as u32;
        let mus = params.mus();
        let configs = (0..params.num_nodes())
            .map(|_| {
                let mut c: Config = 0;
                for &mu in mus {
                    c = (c << 1) | rng.bernoulli(mu) as u64;
                }
                c
            })
            .collect();
        AttributeAssignment { configs, depth: d }
    }

    /// Sample `F` with the given mode. `threads` only affects wall-clock
    /// — never the result — and is ignored by the sequential mode.
    pub fn sample_with_mode(
        params: &MagmParams,
        rng: &mut Rng,
        mode: AttrSampleMode,
        threads: usize,
    ) -> Self {
        match mode {
            AttrSampleMode::Sequential => Self::sample(params, rng),
            AttrSampleMode::Chunked => Self::sample_chunked(params, rng, threads),
        }
    }

    /// Chunked sampling ([`AttrSampleMode::Chunked`]): nodes split into
    /// fixed [`ATTR_CHUNK`]-sized chunks, chunk `c` drawn from
    /// `rng.fork(tag).fork(c)`. Forking never advances `rng`, so the
    /// parent stream is untouched, and chunk streams are independent of
    /// how chunks are distributed over threads — the assignment is
    /// bit-for-bit reproducible for any `threads`.
    pub fn sample_chunked(params: &MagmParams, rng: &Rng, threads: usize) -> Self {
        let d = params.depth() as u32;
        let mus = params.mus();
        let base = rng.fork(ATTR_FORK_TAG);
        let mut configs = vec![0 as Config; params.num_nodes()];
        let chunks: Vec<&mut [Config]> = configs.chunks_mut(ATTR_CHUNK).collect();
        crate::parallel::map_indexed(chunks, threads, |ci, chunk| {
            let mut rng = base.fork(ci as u64);
            for slot in chunk {
                let mut c: Config = 0;
                for &mu in mus {
                    c = (c << 1) | rng.bernoulli(mu) as u64;
                }
                *slot = c;
            }
        });
        AttributeAssignment { configs, depth: d }
    }

    /// Wrap pre-drawn configurations (tests / deterministic experiments).
    pub fn from_configs(configs: Vec<Config>, depth: u32) -> Self {
        assert!(depth <= 63);
        debug_assert!(configs.iter().all(|&c| c < (1u64 << depth)));
        AttributeAssignment { configs, depth }
    }

    /// Configuration λ_i.
    #[inline]
    pub fn config(&self, node: NodeId) -> Config {
        self.configs[node as usize]
    }

    /// All configurations, indexed by node.
    #[inline]
    pub fn configs(&self) -> &[Config] {
        &self.configs
    }

    /// Attribute bit `f_k(i)` (k is 0-based level, 0 = most significant).
    #[inline]
    pub fn bit(&self, node: NodeId, k: u32) -> u8 {
        debug_assert!(k < self.depth);
        ((self.configs[node as usize] >> (self.depth - 1 - k)) & 1) as u8
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.configs.len()
    }

    /// Number of attribute levels d.
    #[inline]
    pub fn depth(&self) -> u32 {
        self.depth
    }

    /// Histogram of configuration frequencies: sorted `(config, count)`
    /// pairs. Powers Fig. 7 and the §5 hybrid split.
    ///
    /// Single hash pass plus a sort of the **unique** configs only — the
    /// number of distinct configurations is typically far below `n`, so
    /// this avoids the `O(n log n)` sort (and the 8·n-byte clone) of all
    /// `n` configs.
    pub fn config_counts(&self) -> Vec<(Config, u32)> {
        let mut counts: FastMap<Config, u32> = fast_map_with_capacity(self.configs.len().min(1024));
        for &c in &self.configs {
            *counts.entry(c).or_insert(0) += 1;
        }
        let mut out: Vec<(Config, u32)> = counts.into_iter().collect(); // lint: order-ok(sorted on the next line)
        out.sort_unstable();
        out
    }

    /// Expand node `i`'s bits into an f32 row (for the XLA runtime path).
    pub fn bits_f32_row(&self, node: NodeId, out: &mut [f32]) {
        let d = self.depth as usize;
        assert!(out.len() >= d);
        for k in 0..d {
            out[k] = self.bit(node, k as u32) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::Initiator;

    #[test]
    fn bit_extraction_msb_first() {
        let a = AttributeAssignment::from_configs(vec![0b101], 3);
        assert_eq!(a.bit(0, 0), 1);
        assert_eq!(a.bit(0, 1), 0);
        assert_eq!(a.bit(0, 2), 1);
    }

    #[test]
    fn sample_respects_mu() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.8, 20_000, 4);
        let mut rng = Rng::new(107);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        // Fraction of 1-bits at each level ≈ 0.8.
        for k in 0..4 {
            let ones: u64 =
                (0..attrs.num_nodes()).map(|i| attrs.bit(i as NodeId, k) as u64).sum();
            let frac = ones as f64 / attrs.num_nodes() as f64;
            assert!((frac - 0.8).abs() < 0.02, "level {k}: {frac}");
        }
    }

    #[test]
    fn heterogeneous_mus() {
        let params = MagmParams::new(
            crate::kpgm::ThetaSeq::homogeneous(Initiator::THETA1, 2),
            vec![1.0, 0.0],
            1000,
        );
        let mut rng = Rng::new(109);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        for i in 0..1000u32 {
            assert_eq!(attrs.config(i), 0b10);
        }
    }

    #[test]
    fn config_counts_sum_to_n() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 4096, 6);
        let mut rng = Rng::new(113);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let counts = attrs.config_counts();
        let total: u64 = counts.iter().map(|&(_, c)| c as u64).sum();
        assert_eq!(total, 4096);
        // sorted and unique configs
        for w in counts.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn chunked_identical_across_thread_counts() {
        // Several full chunks plus a ragged tail, so the test covers both
        // the interior chunks and the boundary.
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.6, 3 * ATTR_CHUNK + 17, 8);
        let t1 = AttributeAssignment::sample_chunked(&params, &Rng::new(5), 1);
        let t2 = AttributeAssignment::sample_chunked(&params, &Rng::new(5), 2);
        let t8 = AttributeAssignment::sample_chunked(&params, &Rng::new(5), 8);
        assert_eq!(t1, t2);
        assert_eq!(t1, t8);
    }

    #[test]
    fn chunked_respects_mu() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.8, 20_000, 4);
        let attrs = AttributeAssignment::sample_chunked(&params, &Rng::new(107), 4);
        for k in 0..4 {
            let ones: u64 =
                (0..attrs.num_nodes()).map(|i| attrs.bit(i as NodeId, k) as u64).sum();
            let frac = ones as f64 / attrs.num_nodes() as f64;
            assert!((frac - 0.8).abs() < 0.02, "level {k}: {frac}");
        }
    }

    #[test]
    fn sample_with_mode_dispatches() {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 1000, 6);
        // Sequential mode is exactly the legacy stream.
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let legacy = AttributeAssignment::sample(&params, &mut r1);
        let seq =
            AttributeAssignment::sample_with_mode(&params, &mut r2, AttrSampleMode::Sequential, 8);
        assert_eq!(legacy, seq);
        // Both modes left their RNGs in the same state...
        assert_eq!(r1.next_u64(), r2.next_u64());
        // ...and chunked mode never advances the parent at all (forks only).
        let mut r3 = Rng::new(3);
        let chunked =
            AttributeAssignment::sample_with_mode(&params, &mut r3, AttrSampleMode::Chunked, 2);
        assert_eq!(r3.next_u64(), Rng::new(3).next_u64());
        assert_ne!(legacy, chunked, "modes draw different assignments for the same seed");
    }

    #[test]
    fn attr_mode_parses() {
        assert_eq!(AttrSampleMode::parse("sequential"), Some(AttrSampleMode::Sequential));
        assert_eq!(AttrSampleMode::parse("chunked"), Some(AttrSampleMode::Chunked));
        assert_eq!(AttrSampleMode::parse("bogus"), None);
        assert_eq!(AttrSampleMode::default().name(), "sequential");
        assert_eq!(AttrSampleMode::Chunked.name(), "chunked");
    }

    #[test]
    fn bits_f32_row_roundtrip() {
        let a = AttributeAssignment::from_configs(vec![0b0110], 4);
        let mut row = [0f32; 4];
        a.bits_f32_row(0, &mut row);
        assert_eq!(row, [0.0, 1.0, 1.0, 0.0]);
    }
}
