//! MAGM parameter bundle: `(Θ̃, μ̃, n)`.

use crate::kpgm::{Initiator, ThetaSeq};

/// Parameters of a Multiplicative Attribute Graph Model.
#[derive(Debug, Clone, PartialEq)]
pub struct MagmParams {
    thetas: ThetaSeq,
    mus: Vec<f64>,
    num_nodes: usize,
}

impl MagmParams {
    /// Fully heterogeneous parameters. `thetas.depth()` defines d and must
    /// equal `mus.len()`.
    pub fn new(thetas: ThetaSeq, mus: Vec<f64>, num_nodes: usize) -> Self {
        assert_eq!(thetas.depth(), mus.len(), "need one mu per attribute level");
        assert!(num_nodes > 0);
        for (k, &mu) in mus.iter().enumerate() {
            assert!((0.0..=1.0).contains(&mu), "mu[{k}] = {mu} outside [0, 1]");
        }
        MagmParams { thetas, mus, num_nodes }
    }

    /// The paper's experimental setup: one `theta` and one `mu` at every of
    /// the `d` levels, `num_nodes` nodes.
    pub fn homogeneous(theta: Initiator, mu: f64, num_nodes: usize, d: u32) -> Self {
        Self::new(ThetaSeq::homogeneous(theta, d), vec![mu; d as usize], num_nodes)
    }

    /// Per-level initiator matrices.
    #[inline]
    pub fn thetas(&self) -> &ThetaSeq {
        &self.thetas
    }

    /// Per-level attribute probabilities μ̃.
    #[inline]
    pub fn mus(&self) -> &[f64] {
        &self.mus
    }

    /// Number of nodes n.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    /// Number of attributes d.
    #[inline]
    pub fn depth(&self) -> usize {
        self.thetas.depth()
    }

    /// Number of possible attribute configurations, `2^d`.
    #[inline]
    pub fn num_configs(&self) -> u64 {
        1u64 << self.depth()
    }

    /// Probability that a node receives configuration `c`:
    /// `Π_k μ_k^{b_k(c)} (1 − μ_k)^{1 − b_k(c)}`.
    pub fn config_probability(&self, c: u64) -> f64 {
        let d = self.depth();
        let mut p = 1.0;
        for k in 0..d {
            let bit = (c >> (d - 1 - k)) & 1;
            p *= if bit == 1 { self.mus[k] } else { 1.0 - self.mus[k] };
        }
        p
    }

    /// Expected number of edges `E|E| = Σ_{c,c'} n_c n_{c'} P_{c c'}` is
    /// quadratic in the number of distinct configs; this returns the exact
    /// expectation over attribute draws instead:
    /// `E|E| = Π_k (μ_k² θ11 + μ_k(1−μ_k)(θ01 + θ10) + (1−μ_k)² θ00) · n²`.
    pub fn expected_edges(&self) -> f64 {
        let mut per_pair = 1.0;
        for (k, level) in self.thetas.levels().iter().enumerate() {
            let mu = self.mus[k];
            per_pair *= mu * mu * level.get(1, 1)
                + mu * (1.0 - mu) * (level.get(0, 1) + level.get(1, 0))
                + (1.0 - mu) * (1.0 - mu) * level.get(0, 0);
        }
        per_pair * (self.num_nodes as f64) * (self.num_nodes as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_probability_balanced() {
        let p = MagmParams::homogeneous(Initiator::THETA1, 0.5, 16, 4);
        for c in 0..16 {
            assert!((p.config_probability(c) - 1.0 / 16.0).abs() < 1e-12);
        }
    }

    #[test]
    fn config_probability_unbalanced() {
        let p = MagmParams::homogeneous(Initiator::THETA1, 0.9, 4, 2);
        // c = 3 = 0b11 -> 0.81, c = 0 -> 0.01, c = 1 = 0b01 -> 0.09
        assert!((p.config_probability(3) - 0.81).abs() < 1e-12);
        assert!((p.config_probability(0) - 0.01).abs() < 1e-12);
        assert!((p.config_probability(1) - 0.09).abs() < 1e-12);
        // probabilities sum to 1
        let total: f64 = (0..4).map(|c| p.config_probability(c)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn expected_edges_balanced_mu() {
        // mu = 0.5: per-pair prob = (mean of theta)^d.
        let p = MagmParams::homogeneous(Initiator::THETA1, 0.5, 8, 3);
        let mean_theta: f64 = (0.15 + 0.7 + 0.7 + 0.85) / 4.0; // 0.6
        let want = mean_theta.powi(3) * 64.0;
        assert!((p.expected_edges() - want).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "one mu per attribute level")]
    fn mismatched_mu_length_panics() {
        MagmParams::new(ThetaSeq::homogeneous(Initiator::THETA1, 3), vec![0.5; 2], 8);
    }
}
