//! Categorical-attribute MAGM (the full Kim & Leskovec model): attribute
//! `k` of node `i` takes a value in `{0, …, K−1}` with per-level
//! probability vector `π^(k)`, and
//! `Q_ij = Π_k Θ^(k)[f_k(i), f_k(j)]` with K×K initiators.
//!
//! The binary model in the parent module is the K = 2 special case the
//! paper evaluates; this module provides the generalization the paper
//! mentions in §2, reusing the base-K configuration packing from
//! [`crate::kpgm::general`].

use crate::graph::NodeId;
use crate::kpgm::general::GenThetaSeq;
use crate::rng::Rng;

use super::Config;

/// Parameters of a categorical MAGM.
#[derive(Debug, Clone, PartialEq)]
pub struct GenMagmParams {
    thetas: GenThetaSeq,
    /// Per-level categorical distributions, each of length K, summing to 1.
    pis: Vec<Vec<f64>>,
    num_nodes: usize,
}

impl GenMagmParams {
    /// New parameters; `pis[k]` must be a length-K probability vector.
    pub fn new(thetas: GenThetaSeq, pis: Vec<Vec<f64>>, num_nodes: usize) -> Self {
        assert_eq!(thetas.depth(), pis.len(), "one pi vector per level");
        for (k, pi) in pis.iter().enumerate() {
            assert_eq!(pi.len(), thetas.k(), "pi[{k}] must have K entries");
            let total: f64 = pi.iter().sum();
            assert!((total - 1.0).abs() < 1e-9, "pi[{k}] must sum to 1, got {total}");
            assert!(pi.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        assert!(num_nodes > 0);
        GenMagmParams { thetas, pis, num_nodes }
    }

    /// Uniform category distribution at every level.
    pub fn uniform(thetas: GenThetaSeq, num_nodes: usize) -> Self {
        let k = thetas.k();
        let d = thetas.depth();
        Self::new(thetas, vec![vec![1.0 / k as f64; k]; d], num_nodes)
    }

    /// Initiator sequence.
    pub fn thetas(&self) -> &GenThetaSeq {
        &self.thetas
    }

    /// Category probabilities.
    pub fn pis(&self) -> &[Vec<f64>] {
        &self.pis
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    /// Number of levels d.
    pub fn depth(&self) -> usize {
        self.thetas.depth()
    }

    /// Sample the categorical attribute configurations for all nodes,
    /// packed base-K (most significant digit = level 0).
    pub fn sample_configs(&self, rng: &mut Rng) -> Vec<Config> {
        let k = self.thetas.k() as u64;
        (0..self.num_nodes)
            .map(|_| {
                let mut c = 0u64;
                for pi in &self.pis {
                    let u = rng.uniform();
                    let mut cum = 0.0;
                    let mut digit = (pi.len() - 1) as u64;
                    for (v, &p) in pi.iter().enumerate() {
                        cum += p;
                        if u < cum {
                            digit = v as u64;
                            break;
                        }
                    }
                    c = c * k + digit;
                }
                c
            })
            .collect()
    }

    /// Edge probability between two packed configurations.
    pub fn edge_probability(&self, ci: Config, cj: Config) -> f64 {
        self.thetas.edge_probability(ci, cj)
    }

    /// Naive O(n²) sampler over fixed configurations (the exact baseline
    /// for correctness tests).
    pub fn naive_sample(&self, configs: &[Config], rng: &mut Rng) -> crate::graph::EdgeList {
        let n = self.num_nodes;
        assert_eq!(configs.len(), n);
        let mut g = crate::graph::EdgeList::new(n);
        for i in 0..n {
            for j in 0..n {
                if rng.bernoulli(self.edge_probability(configs[i], configs[j])) {
                    g.push(i as NodeId, j as NodeId);
                }
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::general::GenInitiator;

    fn params3(n: usize, d: u32) -> GenMagmParams {
        let theta = GenInitiator::new(vec![0.9, 0.4, 0.2, 0.4, 0.7, 0.3, 0.2, 0.3, 0.8]);
        GenMagmParams::new(
            GenThetaSeq::homogeneous(theta, d),
            vec![vec![0.5, 0.3, 0.2]; d as usize],
            n,
        )
    }

    #[test]
    fn config_sampling_respects_pi() {
        let p = params3(60_000, 1);
        let mut rng = Rng::new(281);
        let configs = p.sample_configs(&mut rng);
        let mut counts = [0u32; 3];
        for &c in &configs {
            counts[c as usize] += 1;
        }
        for (v, &want) in [0.5, 0.3, 0.2].iter().enumerate() {
            let got = counts[v] as f64 / 60_000.0;
            assert!((got - want).abs() < 0.01, "digit {v}: {got}");
        }
    }

    #[test]
    fn multi_level_packing_msb_first() {
        // pi puts all mass on digit 2 at level 0 and digit 1 at level 1.
        let theta = GenInitiator::new(vec![0.5; 9]);
        let p = GenMagmParams::new(
            GenThetaSeq::homogeneous(theta, 2),
            vec![vec![0.0, 0.0, 1.0], vec![0.0, 1.0, 0.0]],
            10,
        );
        let mut rng = Rng::new(283);
        let configs = p.sample_configs(&mut rng);
        for &c in &configs {
            assert_eq!(c, 2 * 3 + 1);
        }
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn invalid_pi_rejected() {
        let theta = GenInitiator::new(vec![0.5; 9]);
        GenMagmParams::new(GenThetaSeq::homogeneous(theta, 1), vec![vec![0.5, 0.2, 0.2]], 4);
    }

    #[test]
    fn naive_sampler_rate() {
        let p = params3(24, 2);
        let mut rng = Rng::new(293);
        let configs = p.sample_configs(&mut rng);
        let want: f64 = (0..24)
            .flat_map(|i| (0..24).map(move |j| (i, j)))
            .map(|(i, j)| p.edge_probability(configs[i], configs[j]))
            .sum();
        let trials = 300;
        let total: usize =
            (0..trials).map(|_| p.naive_sample(&configs, &mut rng).num_edges()).sum();
        let mean = total as f64 / trials as f64;
        assert!(
            (mean - want).abs() < 5.0 * (want / trials as f64).sqrt(),
            "mean={mean} want={want}"
        );
    }
}
