//! Multiplicative Attribute Graph Model (Kim & Leskovec 2010).
//!
//! Each node `i` carries a bit vector `f(i) ∈ {0,1}^d` with
//! `P(f_k(i) = 1) = μ^(k)`; the edge probability is
//! `Q_ij = Π_k θ^(k)[f_k(i), f_k(j)]` (paper eq. 7). Packing `f(i)` into an
//! integer gives the *attribute configuration* `λ_i` with
//! `Q_ij = P_{λ_i λ_j}` (eq. 8) — the identity the quilting sampler in
//! [`crate::quilt`] exploits.

mod attributes;
pub mod general;
mod params;
mod sampler;

pub use attributes::{AttrSampleMode, AttributeAssignment, Config, ATTR_CHUNK};
pub use general::GenMagmParams;
pub use params::MagmParams;
pub use sampler::naive_sample;

use crate::graph::NodeId;
use crate::kpgm;

/// Edge probability `Q_ij` given the attribute assignment.
#[inline]
pub fn edge_probability(
    params: &MagmParams,
    attrs: &AttributeAssignment,
    i: NodeId,
    j: NodeId,
) -> f64 {
    kpgm::edge_probability(params.thetas(), attrs.config(i) as NodeId, attrs.config(j) as NodeId)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::Initiator;

    #[test]
    fn q_equals_p_of_lambda() {
        // Paper eq. 8: Q_ij = P_{λ_i λ_j}.
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 8, 3);
        let attrs = AttributeAssignment::from_configs(vec![5, 0, 7, 3, 2, 2, 1, 6], 3);
        for i in 0..8u32 {
            for j in 0..8u32 {
                let want = kpgm::edge_probability(
                    params.thetas(),
                    attrs.config(i) as NodeId,
                    attrs.config(j) as NodeId,
                );
                let got = edge_probability(&params, &attrs, i, j);
                assert_eq!(got, want);
            }
        }
    }
}
