//! Per-graph summary used by `magquilt stats` and the examples.

use crate::graph::{clustering_coefficient, largest_scc_size, largest_wcc_size, Csr, EdgeList};

use super::{mean, powerlaw_alpha_mle, LogHistogram};

/// Aggregate statistics of one sampled graph.
#[derive(Debug, Clone)]
pub struct GraphSummary {
    /// Node count.
    pub num_nodes: usize,
    /// Directed edge count after dedup.
    pub num_edges: usize,
    /// Self-loop count.
    pub self_loops: usize,
    /// Mean out-degree.
    pub mean_degree: f64,
    /// Maximum out-degree (`u64`: degree accumulation must not wrap at
    /// multi-billion-edge scale).
    pub max_out_degree: u64,
    /// Maximum in-degree.
    pub max_in_degree: u64,
    /// Fraction of nodes in the largest strongly connected component
    /// (paper Fig. 9's quantity).
    pub scc_fraction: f64,
    /// Fraction of nodes in the largest weakly connected component.
    pub wcc_fraction: f64,
    /// Sampled average local clustering coefficient.
    pub clustering: f64,
    /// Power-law MLE exponent of the out-degree tail (x_min = 4), if the
    /// tail is large enough.
    pub powerlaw_alpha: Option<f64>,
    /// Log-binned (base 2) out-degree histogram: (lower bound, count).
    pub degree_histogram: Vec<(u64, u64)>,
}

/// Compute the summary. `clustering_sample` nodes are sampled for the
/// clustering estimate (it is the only super-linear statistic here).
pub fn summarize(g: &EdgeList, clustering_sample: usize, seed: u64) -> GraphSummary {
    let csr = Csr::from_edge_list(g);
    let n = g.num_nodes();
    let out = g.out_degrees();
    let inn = g.in_degrees();
    let mut hist = LogHistogram::new(2.0);
    for &d in &out {
        hist.add(d);
    }
    GraphSummary {
        num_nodes: n,
        num_edges: csr.num_edges(),
        self_loops: g.num_self_loops(),
        mean_degree: mean(&out.iter().map(|&d| d as f64).collect::<Vec<_>>()),
        max_out_degree: out.iter().copied().max().unwrap_or(0),
        max_in_degree: inn.iter().copied().max().unwrap_or(0),
        scc_fraction: if n == 0 { 0.0 } else { largest_scc_size(&csr) as f64 / n as f64 },
        wcc_fraction: if n == 0 { 0.0 } else { largest_wcc_size(&csr) as f64 / n as f64 },
        clustering: clustering_coefficient(&csr, clustering_sample, seed),
        powerlaw_alpha: powerlaw_alpha_mle(&out, 4, 50).map(|f| f.alpha),
        degree_histogram: hist.nonzero_bins(),
    }
}

impl GraphSummary {
    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("nodes             {}\n", self.num_nodes));
        s.push_str(&format!("edges             {}\n", self.num_edges));
        s.push_str(&format!("self-loops        {}\n", self.self_loops));
        s.push_str(&format!("mean out-degree   {:.3}\n", self.mean_degree));
        s.push_str(&format!("max out/in degree {} / {}\n", self.max_out_degree, self.max_in_degree));
        s.push_str(&format!("largest SCC       {:.4} of nodes\n", self.scc_fraction));
        s.push_str(&format!("largest WCC       {:.4} of nodes\n", self.wcc_fraction));
        s.push_str(&format!("clustering (est)  {:.4}\n", self.clustering));
        match self.powerlaw_alpha {
            Some(a) => s.push_str(&format!("power-law alpha   {a:.3}\n")),
            None => s.push_str("power-law alpha   (tail too small)\n"),
        }
        s.push_str("degree histogram  ");
        for (lo, c) in &self.degree_histogram {
            s.push_str(&format!("[{lo}+]:{c} "));
        }
        s.push('\n');
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_cycle() {
        let n = 10;
        let edges: Vec<(u32, u32)> = (0..n as u32).map(|i| (i, (i + 1) % n as u32)).collect();
        let g = EdgeList::from_edges(n, edges);
        let s = summarize(&g, n, 7);
        assert_eq!(s.num_nodes, n);
        assert_eq!(s.num_edges, n);
        assert_eq!(s.scc_fraction, 1.0);
        assert_eq!(s.wcc_fraction, 1.0);
        assert_eq!(s.max_out_degree, 1);
        assert!((s.mean_degree - 1.0).abs() < 1e-12);
        assert!(s.report().contains("nodes"));
    }

    #[test]
    fn summary_of_empty() {
        let g = EdgeList::new(5);
        let s = summarize(&g, 5, 7);
        assert_eq!(s.num_edges, 0);
        assert_eq!(s.scc_fraction, 1.0 / 5.0); // singletons
    }
}
