//! Graph statistics: histograms, degree distributions, power-law fits,
//! and the per-sample summary used by the experiments and examples.

mod histogram;
mod powerlaw;
mod summary;

pub use histogram::{Histogram, LogHistogram};
pub use powerlaw::{powerlaw_alpha_mle, PowerLawFit};
pub use summary::{GraphSummary, summarize};

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Least-squares slope of log(y) on log(x), used to estimate the growth
/// exponent c in |E| = n^c (paper Fig. 8). Points with non-positive x or y
/// are skipped.
pub fn loglog_slope(points: &[(f64, f64)]) -> f64 {
    let logs: Vec<(f64, f64)> = points
        .iter()
        .filter(|&&(x, y)| x > 0.0 && y > 0.0)
        .map(|&(x, y)| (x.ln(), y.ln()))
        .collect();
    if logs.len() < 2 {
        return f64::NAN;
    }
    let n = logs.len() as f64;
    let sx: f64 = logs.iter().map(|p| p.0).sum();
    let sy: f64 = logs.iter().map(|p| p.1).sum();
    let sxx: f64 = logs.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = logs.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[5.0]), 0.0);
    }

    #[test]
    fn loglog_slope_recovers_exponent() {
        // y = 3 * x^1.7
        let pts: Vec<(f64, f64)> =
            (1..20).map(|i| (i as f64, 3.0 * (i as f64).powf(1.7))).collect();
        assert!((loglog_slope(&pts) - 1.7).abs() < 1e-9);
    }

    #[test]
    fn loglog_slope_skips_nonpositive() {
        let pts = vec![(0.0, 1.0), (1.0, 2.0), (2.0, 4.0), (4.0, 8.0)];
        assert!((loglog_slope(&pts) - 1.0).abs() < 1e-9);
    }
}
