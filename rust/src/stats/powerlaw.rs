//! Power-law exponent estimation (Clauset–Shalizi–Newman discrete MLE).
//!
//! MAGM can provably produce power-law degree distributions (Kim &
//! Leskovec 2010) — the fit here lets the examples report the exponent of
//! generated graphs.

/// Result of a power-law fit on a degree sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerLawFit {
    /// Estimated exponent alpha ( > 1 for proper distributions).
    pub alpha: f64,
    /// The cutoff x_min used in the fit.
    pub x_min: u64,
    /// Number of observations at or above x_min.
    pub tail_n: usize,
}

/// Discrete power-law MLE with the standard continuous approximation
/// `alpha ≈ 1 + n / sum(ln(x_i / (x_min - 0.5)))` (CSN 2009, eq. 3.7).
///
/// Returns None when fewer than `min_tail` observations lie at/above
/// `x_min`.
pub fn powerlaw_alpha_mle(degrees: &[u64], x_min: u64, min_tail: usize) -> Option<PowerLawFit> {
    assert!(x_min >= 1);
    let tail: Vec<u64> = degrees.iter().copied().filter(|&d| d >= x_min).collect();
    if tail.len() < min_tail {
        return None;
    }
    let denom: f64 = tail
        .iter()
        .map(|&d| (d as f64 / (x_min as f64 - 0.5)).ln())
        .sum();
    if denom <= 0.0 {
        return None;
    }
    Some(PowerLawFit {
        alpha: 1.0 + tail.len() as f64 / denom,
        x_min,
        tail_n: tail.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Sample a discrete power law by inverse-CDF on the continuous
    /// approximation: x = floor(x_min * u^(-1/(alpha-1))).
    fn sample_powerlaw(rng: &mut Rng, alpha: f64, x_min: u64, n: usize) -> Vec<u64> {
        (0..n)
            .map(|_| {
                let u = rng.uniform_open();
                ((x_min as f64 - 0.5) * u.powf(-1.0 / (alpha - 1.0)) + 0.5) as u64
            })
            .collect()
    }

    #[test]
    fn recovers_known_alpha() {
        // The continuous-approximation MLE has a known O(1/x_min)
        // discretization bias; with x_min = 8 it is well under the
        // tolerance used here.
        let mut rng = Rng::new(61);
        for &alpha in &[2.0, 2.5, 3.0] {
            let xs = sample_powerlaw(&mut rng, alpha, 8, 200_000);
            let fit = powerlaw_alpha_mle(&xs, 8, 100).unwrap();
            assert!(
                (fit.alpha - alpha).abs() < 0.05,
                "alpha={alpha} got={}",
                fit.alpha
            );
        }
    }

    #[test]
    fn too_small_tail_returns_none() {
        let xs = vec![1u64, 1, 1, 2];
        assert!(powerlaw_alpha_mle(&xs, 10, 5).is_none());
    }
}
