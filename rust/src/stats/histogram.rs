//! Linear and logarithmic histograms for degree distributions.

/// Fixed-width linear histogram over `[0, max)`.
#[derive(Debug, Clone)]
pub struct Histogram {
    bins: Vec<u64>,
    width: f64,
    max: f64,
    overflow: u64,
    count: u64,
}

impl Histogram {
    /// `nbins` equal-width bins covering `[0, max)`.
    pub fn new(nbins: usize, max: f64) -> Self {
        assert!(nbins > 0 && max > 0.0);
        Histogram { bins: vec![0; nbins], width: max / nbins as f64, max, overflow: 0, count: 0 }
    }

    /// Record one observation.
    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if x >= self.max || x < 0.0 {
            self.overflow += 1;
            return;
        }
        let idx = ((x / self.width) as usize).min(self.bins.len() - 1);
        self.bins[idx] += 1;
    }

    /// Bin counts.
    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    /// Observations outside `[0, max)`.
    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }
}

/// Logarithmically-binned histogram for heavy-tailed data (degree
/// distributions): bin k covers `[base^k, base^(k+1))`, bin 0 also takes
/// the value 0.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    base: f64,
    bins: Vec<u64>,
    count: u64,
}

impl LogHistogram {
    /// Base-`base` log bins (base > 1), e.g. 2.0 for doubling bins.
    pub fn new(base: f64) -> Self {
        assert!(base > 1.0);
        LogHistogram { base, bins: Vec::new(), count: 0 }
    }

    /// Record a non-negative integer observation.
    pub fn add(&mut self, x: u64) {
        self.count += 1;
        let idx = if x <= 1 { 0 } else { (x as f64).log(self.base).floor() as usize };
        if idx >= self.bins.len() {
            self.bins.resize(idx + 1, 0);
        }
        self.bins[idx] += 1;
    }

    /// (bin lower bound, count) pairs for non-empty bins.
    pub fn nonzero_bins(&self) -> Vec<(u64, u64)> {
        self.bins
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(k, &c)| (self.base.powi(k as i32) as u64, c))
            .collect()
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_binning() {
        let mut h = Histogram::new(4, 8.0);
        for x in [0.0, 1.9, 2.0, 7.9, 8.0, -1.0] {
            h.add(x);
        }
        assert_eq!(h.bins(), &[2, 1, 0, 1]);
        assert_eq!(h.overflow(), 2);
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn log_binning() {
        let mut h = LogHistogram::new(2.0);
        for x in [0u64, 1, 2, 3, 4, 7, 8, 100] {
            h.add(x);
        }
        let bins = h.nonzero_bins();
        // bin 0 (x<=1): {0,1}; bin 1 [2,4): {2,3}; bin 2 [4,8): {4,7};
        // bin 3 [8,16): {8}; bin 6 [64,128): {100}
        assert_eq!(bins, vec![(1, 2), (2, 2), (4, 2), (8, 1), (64, 1)]);
        assert_eq!(h.count(), 8);
    }
}
