//! Minimal stand-in for the `xla` crate's PJRT surface.
//!
//! The accelerated path in [`super::client`] drives compiled HLO modules
//! through a PJRT CPU client. The `xla` crate that provides that client is
//! a heavyweight native dependency that is not vendored in every build
//! environment, and nothing in the paper pipeline *requires* it — the
//! pure-Rust samplers cover every workload. This module mirrors exactly
//! the slice of the `xla` API that `client.rs` touches, with every entry
//! point reporting the backend as unavailable. `client.rs` imports it as
//! `use super::xla_stub as xla;`, so swapping in the real crate is a
//! one-line change (replace the alias with `use xla;`) and no call site
//! moves.
//!
//! Because [`PjRtClient::cpu`] is the sole constructor and it always
//! fails, the remaining methods are unreachable at runtime; they exist so
//! the call sites type-check against the same shapes the real crate has.

use std::path::Path;

use anyhow::{bail, Result};

/// Error returned by every fallible entry point of the stub.
const UNAVAILABLE: &str = "the XLA/PJRT backend is not available in this build \
     (the `xla` crate is not vendored); the pure-Rust samplers cover every \
     workload — rebuild with the real `xla` crate wired into \
     `runtime::client` to use compiled HLO kernels";

/// Stub for `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// The real call constructs a PJRT CPU client; the stub always fails.
    pub fn cpu() -> Result<PjRtClient> {
        bail!(UNAVAILABLE)
    }

    /// Platform name of the backing device.
    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    /// Compile an [`XlaComputation`] into an executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(UNAVAILABLE)
    }
}

/// Stub for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO module from its text-format dump.
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        bail!(UNAVAILABLE)
    }
}

/// Stub for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module as a computation.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals; the outer `Vec` is one
    /// entry per device, the inner one per output.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(UNAVAILABLE)
    }
}

/// Stub for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the device buffer back into a host [`Literal`].
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }
}

/// Stub for `xla::Literal` (host-side tensor value).
pub struct Literal;

impl Literal {
    /// Build a rank-1 `f32` literal from a slice.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }

    /// Split a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        bail!(UNAVAILABLE)
    }

    /// Copy the literal's elements out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"), "{err}");
    }

    #[test]
    fn literal_constructors_are_infallible_but_inert() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_tuple().is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
