//! AOT artifact manifest: the shape contract between `python/compile/aot.py`
//! and the Rust runtime — plus the setup-artifact side of the cache: the
//! same directory that holds the lowered HLO can hold content-addressed
//! [`crate::setup::SetupArtifact`] files, so an accelerated run reuses the
//! deterministic prologue exactly like a distributed one does.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::setup::{artifact_file_name, ArtifactHeader, SetupArtifact};

use super::json::Json;

/// One input or output tensor spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimensions (row-major).
    pub shape: Vec<usize>,
    /// dtype string ("f32").
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|d| d.as_u64().map(|v| v as usize).ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    /// Entry name (e.g. "edge_prob_block").
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Input tensor specs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs.
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest plus the artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory containing the HLO files.
    pub dir: PathBuf,
    /// Padded attribute depth every entry was lowered at.
    pub d_pad: usize,
    /// Block sizes (source rows, destination rows, pair batch).
    pub bm: usize,
    /// Destination block rows.
    pub bn: usize,
    /// Pair batch size.
    pub bp: usize,
    /// Entries by name.
    pub entries: Vec<EntrySpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version = j.get("version").and_then(Json::as_u64).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let get_dim = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_u64)
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
            .iter()
            .map(|e| {
                Ok(EntrySpec {
                    name: e
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("entry missing name"))?
                        .to_string(),
                    file: e
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("entry missing file"))?
                        .to_string(),
                    inputs: e
                        .get("inputs")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("entry missing inputs"))?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: e
                        .get("outputs")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            d_pad: get_dim("d_pad")?,
            bm: get_dim("bm")?,
            bn: get_dim("bn")?,
            bp: get_dim("bp")?,
            entries,
        })
    }

    /// Find an entry by name.
    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("entry {name:?} not in manifest (re-run `make artifacts`)"))
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &EntrySpec) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

/// Default artifacts directory: `$MAGQUILT_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("MAGQUILT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Canonical location of a setup artifact with this identity hash inside
/// an artifacts directory.
pub fn setup_artifact_path(dir: &Path, hash_hex: &str) -> PathBuf {
    dir.join(artifact_file_name(hash_hex))
}

/// Look up a cached setup artifact by its content address. `Ok(None)`
/// means a cache miss (build and [`store_setup_artifact`] it); a file
/// that exists but is corrupt or belongs to a different prologue is an
/// error, never a silent miss.
pub fn load_setup_artifact(dir: &Path, expected: &ArtifactHeader) -> Result<Option<SetupArtifact>> {
    let path = setup_artifact_path(dir, &expected.hash_hex());
    if !path.exists() {
        return Ok(None);
    }
    let artifact = SetupArtifact::load(&path)?;
    artifact.check_matches(expected)?;
    Ok(Some(artifact))
}

/// Persist a setup artifact into the cache under its canonical
/// content-addressed name (atomic rename; see [`SetupArtifact::save`]).
pub fn store_setup_artifact(dir: &Path, artifact: &SetupArtifact) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating artifacts directory {}", dir.display()))?;
    let path = setup_artifact_path(dir, &artifact.hash_hex());
    artifact.save(&path)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("magquilt_manifest_ok");
        write_manifest(
            &dir,
            r#"{"version": 1, "d_pad": 32, "bm": 512, "bn": 512, "bp": 8192,
               "entries": [{"name": "e", "file": "e.hlo.txt",
                            "inputs": [{"shape": [512, 32], "dtype": "f32"}],
                            "outputs": [{"shape": [512], "dtype": "f32"}]}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.d_pad, 32);
        let e = m.entry("e").unwrap();
        assert_eq!(e.inputs[0].shape, vec![512, 32]);
        assert_eq!(e.inputs[0].elements(), 512 * 32);
        assert!(m.entry("nope").is_err());
        assert!(m.hlo_path(e).ends_with("e.hlo.txt"));
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("magquilt_manifest_badver");
        write_manifest(&dir, r#"{"version": 9, "entries": []}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_file_is_context_error() {
        let dir = std::env::temp_dir().join("magquilt_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn setup_artifact_cache_round_trips() {
        use crate::config::{ModelSpec, SamplerKind};
        use crate::coordinator::Coordinator;
        use crate::magm::AttrSampleMode;
        use crate::quilt::PieceMode;

        let dir = std::env::temp_dir().join("magquilt_setup_cache");
        let _ = std::fs::remove_dir_all(&dir);
        let mut model = ModelSpec::default_spec();
        model.log2_nodes = 6;
        model.attributes = 6;
        let expected = ArtifactHeader::from_model(
            &model,
            7,
            SamplerKind::Quilt,
            PieceMode::Conditioned,
            AttrSampleMode::Sequential,
        );
        // Miss on an absent cache directory: not an error.
        assert!(load_setup_artifact(&dir, &expected).unwrap().is_none());
        let art = Coordinator::new().build_setup(&model, 7, SamplerKind::Quilt).unwrap();
        let path = store_setup_artifact(&dir, &art).unwrap();
        assert_eq!(path, setup_artifact_path(&dir, &art.hash_hex()));
        let cached = load_setup_artifact(&dir, &expected).unwrap().expect("cache hit");
        assert_eq!(cached.hash64(), art.hash64());
        assert_eq!(cached.attrs(), art.attrs());
        // A different prologue identity misses even with a populated cache.
        let other = ArtifactHeader { seed: 8, ..expected };
        assert!(load_setup_artifact(&dir, &other).unwrap().is_none());
        // Corruption under the canonical name is an error, not a miss.
        std::fs::write(&path, b"MAGQART1 but mangled").unwrap();
        assert!(load_setup_artifact(&dir, &expected).is_err());
    }
}
