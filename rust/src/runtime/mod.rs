//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas kernels.
//!
//! Python runs only at build time (`make artifacts`); this module gives the
//! Rust hot path access to the lowered HLO:
//!
//! * [`Manifest`] — the shape contract written by `python/compile/aot.py`,
//! * [`XlaRuntime`] — PJRT CPU client + compiled executables,
//! * [`MagmKernels`] — model-bound wrappers (coefficient transform,
//!   padding, block iteration),
//! * [`naive_xla_sample`] — the accelerated `O(n²)` baseline sampler,
//! * [`expected_out_degrees`] — analysis helper used by examples/stats,
//! * [`load_setup_artifact`] / [`store_setup_artifact`] — the setup-artifact
//!   side of the cache: the artifacts directory also holds content-addressed
//!   [`crate::setup::SetupArtifact`] files, and
//!   [`naive_xla_sample_from_artifact`] runs the baseline over a hydrated
//!   artifact's attribute assignment (same world as the quilt run, no
//!   separate setup pass).
//!
//! Everything degrades gracefully when `artifacts/` is missing: loading
//! returns an error telling the user to run `make artifacts`; nothing else
//! in the crate requires the runtime.

mod artifacts;
mod client;
pub mod json;
mod kernels;
mod xla_stub;

pub use artifacts::{
    default_artifacts_dir, load_setup_artifact, setup_artifact_path, store_setup_artifact,
    EntrySpec, Manifest, TensorSpec,
};
pub use client::XlaRuntime;
pub use kernels::{
    expected_out_degrees, naive_xla_sample, naive_xla_sample_from_artifact, theta_to_coef,
    MagmKernels,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::{Initiator, ThetaSeq};

    #[test]
    fn coef_transform_reconstructs_log_theta() {
        let thetas = ThetaSeq::homogeneous(Initiator::THETA1, 3);
        let d_pad = 8;
        let coef = theta_to_coef(&thetas, d_pad);
        for k in 0..3 {
            let c0 = coef[k] as f64;
            let c1 = coef[d_pad + k] as f64;
            let c2 = coef[2 * d_pad + k] as f64;
            let c3 = coef[3 * d_pad + k] as f64;
            for a in 0..2 {
                for b in 0..2 {
                    let want = Initiator::THETA1.get(a, b).ln();
                    let got = c0 + c1 * a as f64 + c2 * b as f64 + c3 * (a * b) as f64;
                    assert!((got - want).abs() < 1e-6, "({a},{b}): {got} vs {want}");
                }
            }
        }
        // padding columns are exactly zero
        for k in 3..d_pad {
            for row in 0..4 {
                assert_eq!(coef[row * d_pad + k], 0.0);
            }
        }
    }

    #[test]
    fn coef_transform_handles_zero_theta() {
        let t = Initiator::new([0.0, 0.5, 0.5, 1.0]);
        let coef = theta_to_coef(&ThetaSeq::homogeneous(t, 1), 1);
        assert!(coef[0].is_finite());
        // exp(c0) must underflow to 0 in f32 once multiplied out
        assert!(coef[0] < -60.0);
    }
}
