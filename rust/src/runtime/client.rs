//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate's PJRT CPU client. One [`XlaRuntime`] owns the
//! client plus every compiled executable from the manifest; executables are
//! compiled once at load and reused for every call (loading + compiling is
//! the slow part, execution is the hot path).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::hashutil::FastMap;

use super::artifacts::{default_artifacts_dir, EntrySpec, Manifest};
// The real `xla` crate is not vendored in this build; `xla_stub` mirrors
// the exact API slice used below so this module compiles and reports the
// backend as unavailable. Swap this alias for the real crate to enable it.
use super::xla_stub as xla;

/// A loaded PJRT runtime with compiled entry points.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: FastMap<String, xla::PjRtLoadedExecutable>,
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut entries: Vec<&String> = self.executables.keys().collect(); // lint: order-ok(sorted on the next line)
        entries.sort();
        f.debug_struct("XlaRuntime")
            .field("platform", &self.client.platform_name())
            .field("entries", &entries)
            .finish()
    }
}

impl XlaRuntime {
    /// Load from the default artifacts directory (`$MAGQUILT_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<Self> {
        Self::load(&default_artifacts_dir())
    }

    /// Load the manifest, compile every entry on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = FastMap::default();
        for entry in &manifest.entries {
            let path = manifest.hlo_path(entry);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?;
            executables.insert(entry.name.clone(), exe);
        }
        Ok(XlaRuntime { client, manifest, executables })
    }

    /// The manifest (shape contract).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute an entry with f32 inputs; returns the flattened f32 outputs
    /// (one Vec per output tensor).
    ///
    /// Inputs must match the manifest shapes exactly — the caller pads
    /// (see [`super::kernels`]).
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let entry = self.manifest.entry(name)?;
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("entry {name} not compiled"))?;
        let literals = build_literals(entry, inputs)?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .context("executable returned no outputs")?;
        let literal = first.to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the output is a tuple.
        let parts = literal.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            out.push(part.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// Build input literals, validating lengths against the manifest.
fn build_literals(entry: &EntrySpec, inputs: &[&[f32]]) -> Result<Vec<xla::Literal>> {
    if inputs.len() != entry.inputs.len() {
        bail!(
            "{}: expected {} inputs, got {}",
            entry.name,
            entry.inputs.len(),
            inputs.len()
        );
    }
    let mut literals = Vec::with_capacity(inputs.len());
    for (spec, data) in entry.inputs.iter().zip(inputs) {
        if data.len() != spec.elements() {
            bail!(
                "{}: input shape {:?} needs {} elements, got {}",
                entry.name,
                spec.shape,
                spec.elements(),
                data.len()
            );
        }
        let lit = xla::Literal::vec1(data);
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        literals.push(if dims.len() == 1 { lit } else { lit.reshape(&dims)? });
    }
    Ok(literals)
}
