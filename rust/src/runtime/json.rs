//! Minimal JSON parser for `artifacts/manifest.json`.
//!
//! The vendored crate set has no serde, so this is a small recursive-
//! descent parser covering the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, booleans, null). Only used at startup
//! to read the AOT manifest — not on any hot path.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true / false
    Bool(bool),
    /// Any number (f64 precision suffices for the manifest).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (order-insensitive).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// As u64 (integral numbers).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as u64),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else { bail!("unterminated string") };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else { bail!("bad escape") };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // Continue multi-byte UTF-8 sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated utf8");
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{"version": 1, "d_pad": 32, "entries": [
            {"name": "edge_prob_block", "file": "edge_prob_block.hlo.txt",
             "inputs": [{"shape": [512, 32], "dtype": "f32"}]}]}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_u64(), Some(1));
        let entries = j.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries[0].get("name").unwrap().as_str(), Some("edge_prob_block"));
        let shape = entries[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_u64(), Some(512));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"\\u00e9 café""#.trim()).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\u00e9 café");
    }

    #[test]
    fn parses_numbers() {
        let j = Json::parse("[-1.5e3, 0, 42, 0.25]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[2].as_u64(), Some(42));
        assert_eq!(a[3].as_f64(), Some(0.25));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("true false").is_err());
    }

    #[test]
    fn nested_structures() {
        let j = Json::parse(r#"{"a": {"b": [1, {"c": null}]}, "d": true}"#).unwrap();
        assert_eq!(
            j.get("a").unwrap().get("b").unwrap().as_arr().unwrap()[1].get("c"),
            Some(&Json::Null)
        );
    }
}
