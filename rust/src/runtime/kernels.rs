//! High-level MAGM kernels on top of the raw runtime: coefficient
//! transform, padding to the artifact shape contract, block iteration.
//!
//! Mirrors `python/compile/model.py`: theta is converted once per model to
//! the `[4, d_pad]` bilinear coefficients (`log θ[a,b] = c0 + c1·a + c2·b +
//! c3·ab`), bits/counts are zero-padded to the lowered shapes, outputs are
//! sliced back.

use anyhow::Result;

use crate::graph::{EdgeList, NodeId};
use crate::kpgm::ThetaSeq;
use crate::magm::{AttributeAssignment, MagmParams};
use crate::rng::Rng;

use super::XlaRuntime;

/// Matches model.THETA_FLOOR: keeps log finite; exp underflows to 0 anyway.
const THETA_FLOOR: f64 = 1e-30;

/// Bilinear log-space coefficients for a theta sequence, padded to `d_pad`
/// (padding columns are zero = neutral levels).
pub fn theta_to_coef(thetas: &ThetaSeq, d_pad: usize) -> Vec<f32> {
    let d = thetas.depth();
    assert!(d <= d_pad, "model depth {d} exceeds artifact d_pad {d_pad}");
    let mut coef = vec![0f32; 4 * d_pad];
    for (k, level) in thetas.levels().iter().enumerate() {
        let l00 = level.get(0, 0).max(THETA_FLOOR).ln();
        let l01 = level.get(0, 1).max(THETA_FLOOR).ln();
        let l10 = level.get(1, 0).max(THETA_FLOOR).ln();
        let l11 = level.get(1, 1).max(THETA_FLOOR).ln();
        coef[k] = l00 as f32;
        coef[d_pad + k] = (l10 - l00) as f32;
        coef[2 * d_pad + k] = (l01 - l00) as f32;
        coef[3 * d_pad + k] = (l11 - l10 - l01 + l00) as f32;
    }
    coef
}

/// MAGM kernels bound to one runtime + one model.
pub struct MagmKernels<'rt> {
    runtime: &'rt XlaRuntime,
    coef: Vec<f32>,
    depth: usize,
}

impl<'rt> MagmKernels<'rt> {
    /// Bind a model's theta sequence to the runtime.
    pub fn new(runtime: &'rt XlaRuntime, thetas: &ThetaSeq) -> Self {
        let d_pad = runtime.manifest().d_pad;
        MagmKernels { runtime, coef: theta_to_coef(thetas, d_pad), depth: thetas.depth() }
    }

    /// The artifact block size (rows per block call).
    pub fn block_rows(&self) -> usize {
        self.runtime.manifest().bm
    }

    /// Pack attribute bits of `nodes` into a zero-padded `[rows, d_pad]`
    /// f32 buffer.
    fn pack_bits(&self, attrs: &AttributeAssignment, nodes: &[NodeId], rows: usize) -> Vec<f32> {
        let d_pad = self.runtime.manifest().d_pad;
        assert!(nodes.len() <= rows);
        let mut out = vec![0f32; rows * d_pad];
        for (r, &node) in nodes.iter().enumerate() {
            attrs.bits_f32_row(node, &mut out[r * d_pad..r * d_pad + self.depth]);
        }
        out
    }

    /// Edge-probability block `Q[src × dst]` via the AOT Pallas kernel.
    /// `src.len() ≤ bm`, `dst.len() ≤ bn`; returns row-major
    /// `src.len() × dst.len()`.
    pub fn edge_prob_block(
        &self,
        attrs: &AttributeAssignment,
        src: &[NodeId],
        dst: &[NodeId],
    ) -> Result<Vec<f32>> {
        let m = self.runtime.manifest();
        let fs = self.pack_bits(attrs, src, m.bm);
        let fd = self.pack_bits(attrs, dst, m.bn);
        let outs = self.runtime.execute_f32("edge_prob_block", &[&fs, &fd, &self.coef])?;
        let full = &outs[0];
        let mut q = Vec::with_capacity(src.len() * dst.len());
        for r in 0..src.len() {
            q.extend_from_slice(&full[r * m.bn..r * m.bn + dst.len()]);
        }
        Ok(q)
    }

    /// Elementwise probabilities for up to `bp` aligned (src, dst) pairs.
    pub fn edge_prob_pairs(
        &self,
        attrs: &AttributeAssignment,
        pairs: &[(NodeId, NodeId)],
    ) -> Result<Vec<f32>> {
        let m = self.runtime.manifest();
        assert!(pairs.len() <= m.bp, "at most {} pairs per call", m.bp);
        let srcs: Vec<NodeId> = pairs.iter().map(|&(s, _)| s).collect();
        let dsts: Vec<NodeId> = pairs.iter().map(|&(_, t)| t).collect();
        let fs = self.pack_bits(attrs, &srcs, m.bp);
        let fd = self.pack_bits(attrs, &dsts, m.bp);
        let outs = self.runtime.execute_f32("edge_prob_pairs", &[&fs, &fd, &self.coef])?;
        Ok(outs[0][..pairs.len()].to_vec())
    }

    /// Expected out-degree contributions of a destination block:
    /// `sum_j counts[j] Q[src_i, dst_j]` for each src row.
    pub fn expected_degree_contrib(
        &self,
        attrs: &AttributeAssignment,
        src: &[NodeId],
        dst: &[NodeId],
        counts_dst: &[f32],
    ) -> Result<Vec<f32>> {
        let m = self.runtime.manifest();
        assert_eq!(dst.len(), counts_dst.len());
        let fs = self.pack_bits(attrs, src, m.bm);
        let fd = self.pack_bits(attrs, dst, m.bn);
        let mut cnt = vec![0f32; m.bn];
        cnt[..counts_dst.len()].copy_from_slice(counts_dst);
        let outs = self
            .runtime
            .execute_f32("expected_degree_contrib", &[&fs, &fd, &self.coef, &cnt])?;
        Ok(outs[0][..src.len()].to_vec())
    }

    /// Bernoulli log-likelihood of an adjacency block. `adj` is row-major
    /// `src.len() × dst.len()`; the mask excludes padding automatically.
    pub fn loglik_block(
        &self,
        attrs: &AttributeAssignment,
        src: &[NodeId],
        dst: &[NodeId],
        adj: &[f32],
    ) -> Result<f64> {
        let m = self.runtime.manifest();
        assert_eq!(adj.len(), src.len() * dst.len());
        let fs = self.pack_bits(attrs, src, m.bm);
        let fd = self.pack_bits(attrs, dst, m.bn);
        let mut adj_pad = vec![0f32; m.bm * m.bn];
        let mut mask = vec![0f32; m.bm * m.bn];
        for r in 0..src.len() {
            adj_pad[r * m.bn..r * m.bn + dst.len()]
                .copy_from_slice(&adj[r * dst.len()..(r + 1) * dst.len()]);
            mask[r * m.bn..r * m.bn + dst.len()].fill(1.0);
        }
        let outs = self
            .runtime
            .execute_f32("loglik_block", &[&fs, &fd, &self.coef, &adj_pad, &mask])?;
        Ok(outs[0][0] as f64)
    }
}

/// The accelerated `O(n²)` baseline: naive MAGM sampling with the Q blocks
/// computed by the AOT XLA kernel and the Bernoulli trials done in Rust.
///
/// Still quadratic (it must be — it is the *baseline*), but the per-entry
/// probability evaluation is vectorized through the MXU-shaped kernel
/// instead of a d-way scalar product.
pub fn naive_xla_sample(
    runtime: &XlaRuntime,
    params: &MagmParams,
    attrs: &AttributeAssignment,
    rng: &mut Rng,
) -> Result<EdgeList> {
    let kernels = MagmKernels::new(runtime, params.thetas());
    let n = params.num_nodes();
    let bm = runtime.manifest().bm;
    let bn = runtime.manifest().bn;
    let mut g = EdgeList::new(n);
    let all: Vec<NodeId> = (0..n as NodeId).collect();
    for src_chunk in all.chunks(bm) {
        for dst_chunk in all.chunks(bn) {
            let q = kernels.edge_prob_block(attrs, src_chunk, dst_chunk)?;
            for (r, &i) in src_chunk.iter().enumerate() {
                let row = &q[r * dst_chunk.len()..(r + 1) * dst_chunk.len()];
                for (c, &j) in dst_chunk.iter().enumerate() {
                    if rng.bernoulli(row[c] as f64) {
                        g.push(i, j);
                    }
                }
            }
        }
    }
    Ok(g)
}

/// Run the accelerated baseline over a hydrated setup artifact instead of
/// re-running the attribute draw: the artifact pins the exact per-node
/// configurations (and the model identity in its header), so the XLA
/// baseline samples the same world a quilt/hybrid run of that artifact
/// did — the cross-sampler comparison needs no separate setup pass.
pub fn naive_xla_sample_from_artifact(
    runtime: &XlaRuntime,
    artifact: &crate::setup::SetupArtifact,
    rng: &mut Rng,
) -> Result<EdgeList> {
    let h = artifact.header();
    let params = MagmParams::homogeneous(
        crate::kpgm::Initiator::new(h.theta),
        h.mu,
        h.num_nodes(),
        h.attributes,
    );
    naive_xla_sample(runtime, &params, artifact.attrs(), rng)
}

/// Expected out-degrees for every node, computed block-wise through the
/// `expected_degree_contrib` kernel over the distinct-configuration
/// representation (cost `O((#configs / b)² )` kernel calls).
pub fn expected_out_degrees(
    runtime: &XlaRuntime,
    params: &MagmParams,
    attrs: &AttributeAssignment,
) -> Result<Vec<f64>> {
    let kernels = MagmKernels::new(runtime, params.thetas());
    let bm = runtime.manifest().bm;
    let bn = runtime.manifest().bn;
    // Distinct configurations with counts; one representative node each.
    let counts = attrs.config_counts();
    let mut rep: crate::hashutil::FastMap<u64, NodeId> = crate::hashutil::FastMap::default();
    for (i, &c) in attrs.configs().iter().enumerate() {
        rep.entry(c).or_insert(i as NodeId);
    }
    let reps: Vec<NodeId> = counts.iter().map(|&(c, _)| rep[&c]).collect();
    let cnts: Vec<f32> = counts.iter().map(|&(_, m)| m as f32).collect();

    // deg(config r) = sum over dst blocks of contrib.
    let mut per_config = vec![0f64; reps.len()];
    for (si, src_chunk) in reps.chunks(bm).enumerate() {
        for (di, dst_chunk) in reps.chunks(bn).enumerate() {
            let c = &cnts[di * bn..(di * bn + dst_chunk.len()).min(cnts.len())];
            let contrib = kernels.expected_degree_contrib(attrs, src_chunk, dst_chunk, c)?;
            for (r, v) in contrib.iter().enumerate() {
                per_config[si * bm + r] += *v as f64;
            }
        }
    }
    // Broadcast back to nodes via their configuration.
    let mut cfg_index: crate::hashutil::FastMap<u64, usize> = crate::hashutil::FastMap::default();
    for (idx, &(c, _)) in counts.iter().enumerate() {
        cfg_index.insert(c, idx);
    }
    Ok(attrs.configs().iter().map(|c| per_config[cfg_index[c]]).collect())
}
