//! Job coordinator: a **two-phase** engine — a parallel, deterministic
//! *setup pipeline* followed by pooled *piece sampling* with a sharded
//! streaming merge into any [`crate::graph::EdgeSink`].
//!
//! # Phase 1 — setup pipeline
//!
//! Before the first ball drops, the run needs the attribute assignment,
//! the partition `D_1 … D_B`, the per-set prefix tries, and (in
//! conditioned mode) the shared product DAG. Naively these are a serial
//! `O(d · n)` prologue on the leader while every worker idles — the
//! dominant wall-clock cost at paper scale. The coordinator instead runs
//! each phase on `--setup-threads` scoped threads (0 = auto, matching the
//! worker count), and every phase is **bit-for-bit deterministic in the
//! seed** for any thread count:
//!
//! * **attributes** — [`crate::magm::AttrSampleMode::Chunked`] draws
//!   fixed-size node chunks from stable per-chunk RNG forks (the legacy
//!   sequential stream stays available — and default — for
//!   seed-compatibility with existing goldens),
//! * **partition** — [`crate::quilt::Partition::build_parallel`] replaces
//!   the left-to-right multiplicity scan with per-chunk histograms + an
//!   exclusive prefix-sum, reproducing every node's occurrence rank
//!   `|Z_i|` exactly,
//! * **tries** — [`crate::quilt::Partition::build_tries_parallel`] builds
//!   per-set tries into sharded [`crate::kpgm::ConfigForest`] arenas and
//!   merges them with a final hash-consing pass into the serial arena,
//! * **product DAG** — the bottom-up restricted-mass aggregation of
//!   [`crate::kpgm::ConditionedBallDropSampler`] parallelizes per level.
//!
//! Per-phase wall-clock lands in [`SetupStats`] (on [`RunStats`] /
//! [`SampleReport`]), surfacing where setup time goes.
//!
//! The whole prologue can also be **built once and reused**:
//! [`Coordinator::build_setup`] packages it as a content-addressed
//! [`crate::setup::SetupArtifact`] file, and
//! [`Coordinator::plan_from_artifact`] hydrates a plan from one —
//! skipping every setup phase while producing byte-identical output
//! ([`SetupStats::artifact_hash`] is the non-zero witness that the
//! pipeline was skipped). See the [`crate::setup`] module docs for the
//! format and the cross-check contract.
//!
//! # Phase 2 — piece sampling and merge
//!
//! The quilting algorithm is embarrassingly parallel at the piece level —
//! each of the `B²` KPGM samples (and each ER block of the §5 hybrid) is
//! independent given its RNG fork — so sampling is a classic
//! leader/worker design:
//!
//! * the **leader** builds a [`JobPlan`] (piece jobs + block jobs),
//!   ordered by estimated cost — for conditioned plans the per-piece
//!   **restricted mass** `m_kl`, not the uniform full-space ball count —
//!   so the heaviest pieces start first and the pool drains evenly,
//! * **workers** (std threads) pull jobs from a shared queue and route
//!   each job's edges *by source-node range* to one of `S` **shard
//!   mergers** over bounded channels (backpressure: workers block when a
//!   merger falls behind),
//! * each **shard merger** ([`crate::graph::ShardMerger`]) folds arriving
//!   batches into one sorted, deduplicated run incrementally, so the
//!   pre-dedup edge multiset is never materialized in a single buffer:
//!   per-shard residency is bounded by the post-dedup shard size plus
//!   batch-sized merge overhead (at most two batches),
//! * each shard counts its **contributing jobs** (a job's sources are
//!   confined to its `D_k` / block node list, a contiguous shard span),
//!   and a merger is closed — delivering its finished run mid-run — the
//!   moment its last contributing job completes,
//! * finished shards are handed to the **sink** in **completion order**
//!   through the shard-addressable protocol
//!   (`begin_shard`/`accept_shard`/`finalize`): an early-finishing late
//!   shard is consumed — and its merger's memory released — immediately,
//!   never buffered waiting for its turn; since shards partition the
//!   source range, stitching them at their index slots yields the
//!   globally sorted, deduplicated edge list — there is no final sort.
//!
//! Sinks ([`crate::graph::EdgeSink`]) decouple merging from destination:
//! collect in memory ([`crate::graph::CollectSink`], the default used by
//! [`Coordinator::run`]), accumulate degrees only
//! ([`crate::graph::CountingSink`]), or stream straight to the binary
//! edge-list format ([`crate::graph::BinaryFileSink`]) for samples larger
//! than RAM — the binary sink defers out-of-order shards within a memory
//! budget and spills them to temp files (`--spill-dir`, `--spill-budget`)
//! past it, keeping sink-side residency bounded under any completion
//! skew.
//!
//! Determinism: every job carries a stable RNG fork id derived from the
//! plan, so the *set* of sampled edges is independent of worker count,
//! shard count, setup-thread count, and scheduling order; the delivered
//! edge list is bit-for-bit the sequential samplers' (sorted,
//! deduplicated) output for the same seed and attribute mode.

mod pool;

pub use pool::{Coordinator, JobPlan, RunStats, SampleReport, SetupStats, MAX_SHARDS};
