//! Job coordinator: plans the quilt pieces (and the hybrid's ER blocks),
//! routes them across a bounded worker pool, and merges the edge streams
//! into one quilted sample.
//!
//! The quilting algorithm is embarrassingly parallel at the piece level —
//! each of the `B²` KPGM samples (and each ER block of the §5 hybrid) is
//! independent given its RNG fork — so the coordinator is a classic
//! leader/worker design:
//!
//! * the **leader** builds a [`JobPlan`] (piece jobs + block jobs),
//!   ordered by estimated cost — for conditioned plans the per-piece
//!   **restricted mass** `m_kl`, not the uniform full-space ball count —
//!   so the heaviest pieces start first and the pool drains evenly,
//! * **workers** (std threads) pull jobs from a shared queue and emit
//!   per-job edge batches into a bounded channel (backpressure: workers
//!   block when the merger falls behind),
//! * the **merger** (the calling thread) absorbs batches into the output
//!   edge list, then dedups (the quilting step).
//!
//! Determinism: every job carries a stable RNG fork id derived from the
//! plan, so the *set* of sampled edges is independent of worker count and
//! scheduling order; [`SampleReport::graph`] is canonicalized (sorted) by
//! the final dedup.

mod pool;

pub use pool::{Coordinator, JobPlan, SampleReport};
