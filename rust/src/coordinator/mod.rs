//! Job coordinator: plans the quilt pieces (and the hybrid's ER blocks),
//! routes them across a bounded worker pool, and merges the edge streams
//! with a **sharded streaming merge** into any [`crate::graph::EdgeSink`].
//!
//! The quilting algorithm is embarrassingly parallel at the piece level —
//! each of the `B²` KPGM samples (and each ER block of the §5 hybrid) is
//! independent given its RNG fork — so the coordinator is a classic
//! leader/worker design:
//!
//! * the **leader** builds a [`JobPlan`] (piece jobs + block jobs),
//!   ordered by estimated cost — for conditioned plans the per-piece
//!   **restricted mass** `m_kl`, not the uniform full-space ball count —
//!   so the heaviest pieces start first and the pool drains evenly,
//! * **workers** (std threads) pull jobs from a shared queue and route
//!   each job's edges *by source-node range* to one of `S` **shard
//!   mergers** over bounded channels (backpressure: workers block when a
//!   merger falls behind),
//! * each **shard merger** ([`crate::graph::ShardMerger`]) folds arriving
//!   batches into one sorted, deduplicated run incrementally, so the
//!   pre-dedup edge multiset is never materialized in a single buffer:
//!   per-shard residency is bounded by the post-dedup shard size plus
//!   batch-sized merge overhead (at most two batches),
//! * finished shards are handed to the **sink** in ascending index order;
//!   since shards partition the source range, their concatenation is the
//!   globally sorted, deduplicated edge list — there is no final sort.
//!
//! Sinks ([`crate::graph::EdgeSink`]) decouple merging from destination:
//! collect in memory ([`crate::graph::CollectSink`], the default used by
//! [`Coordinator::run`]), accumulate degrees only
//! ([`crate::graph::CountingSink`]), or stream straight to the binary
//! edge-list format ([`crate::graph::BinaryFileSink`]) for samples larger
//! than RAM.
//!
//! Determinism: every job carries a stable RNG fork id derived from the
//! plan, so the *set* of sampled edges is independent of worker count,
//! shard count, and scheduling order; the delivered edge list is
//! bit-for-bit the sequential samplers' (sorted, deduplicated) output
//! for the same seed.

mod pool;

pub use pool::{Coordinator, JobPlan, RunStats, SampleReport};
