//! The worker pool and job plan.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::graph::{EdgeList, NodeId};
use crate::kpgm::{BallDropSampler, ConditionedBallDropSampler};
use crate::magm::{AttributeAssignment, MagmParams};
use crate::quilt::{sample_er_block, HybridPlan, HybridSampler, Partition, PieceBackend,
                   PieceJob, PieceMode, QuiltSampler};
use crate::rng::Rng;

/// Reference to a node block in a hybrid plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BlockRef {
    /// Index into `HybridPlan::light`.
    Light(usize),
    /// Index into `HybridPlan::heavy`.
    Heavy(usize),
}

/// One unit of work.
#[derive(Debug, Clone, Copy)]
enum Job {
    /// A quilt piece (KPGM sample filtered to `(D_k, D_l)`).
    Piece(PieceJob),
    /// A uniform block `src × dst` with the configs' edge probability.
    ErBlock { src: BlockRef, dst: BlockRef, fork_id: u64 },
}

/// The full set of jobs for one sample, plus the shared inputs workers
/// need. Built once by the leader.
pub struct JobPlan {
    jobs: Vec<Job>,
    partition: Partition,
    hybrid: Option<HybridPlan>,
    params: MagmParams,
    seed: u64,
    mode: PieceMode,
    /// The shared product DAG for [`PieceMode::Conditioned`] plans.
    conditioner: Option<ConditionedBallDropSampler>,
}

impl JobPlan {
    /// Number of jobs.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// Whether the plan is empty.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Partition size B of the quilting part.
    pub fn partition_size(&self) -> usize {
        self.partition.size()
    }

    /// The piece mode this plan was built for.
    pub fn piece_mode(&self) -> PieceMode {
        self.mode
    }

    /// Expected work of one job, used to order the queue (largest first)
    /// so the pool keeps all workers busy to the end.
    ///
    /// * Conditioned pieces cost their **restricted mass** `m_kl` (the
    ///   balls actually dropped) — not the full-space `X`, which would
    ///   treat every piece as equally heavy.
    /// * Rejection pieces all drop the same full-space `X`.
    /// * ER blocks cost their expected success count `p · cells`.
    fn estimated_cost(&self, job: &Job) -> f64 {
        match *job {
            Job::Piece(p) => match self.conditioner.as_ref().and_then(|c| c.piece(p.k, p.l)) {
                Some(piece) => 1.0 + piece.restricted_mass(),
                // Rejection pieces (and dense over-budget blocks) all
                // drop the same full-space X.
                None => 1.0 + self.params.thetas().expected_edges(),
            },
            Job::ErBlock { src, dst, .. } => {
                let Some(hybrid) = self.hybrid.as_ref() else { return 1.0 };
                let (ci, nodes_i) = block(hybrid, src);
                let (cj, nodes_j) = block(hybrid, dst);
                let p = crate::kpgm::edge_probability(
                    self.params.thetas(),
                    ci as NodeId,
                    cj as NodeId,
                );
                1.0 + p * nodes_i.len() as f64 * nodes_j.len() as f64
            }
        }
    }

    /// Sort jobs by descending estimated cost (stable: ties keep plan
    /// order). Fork ids travel with their jobs, so the sampled edge set
    /// is unchanged — only the schedule improves.
    fn order_by_cost(&mut self) {
        let costs: Vec<f64> = self.jobs.iter().map(|j| self.estimated_cost(j)).collect();
        let mut order: Vec<usize> = (0..self.jobs.len()).collect();
        order.sort_by(|&a, &b| {
            costs[b].partial_cmp(&costs[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        self.jobs = order.into_iter().map(|i| self.jobs[i]).collect();
    }
}

/// Result of a coordinated sampling run.
#[derive(Debug)]
pub struct SampleReport {
    /// The sampled graph (deduplicated, canonical order).
    pub graph: EdgeList,
    /// Partition size B (of the quilted part).
    pub partition_size: usize,
    /// Total jobs executed.
    pub num_jobs: usize,
    /// Worker threads used.
    pub workers: usize,
    /// Wall-clock milliseconds.
    pub wall_ms: f64,
    /// Edges per second of wall time (post-dedup edges).
    pub edges_per_sec: f64,
    /// Balls abandoned after exhausting duplicate resamples (previously
    /// lost silently; 0 in healthy runs, non-zero signals saturation).
    pub dropped_resamples: u64,
}

/// The leader/worker coordinator.
#[derive(Debug, Clone)]
pub struct Coordinator {
    workers: usize,
    channel_capacity: usize,
    piece_mode: PieceMode,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    /// Workers = available parallelism (capped at 16; the merger is one
    /// more thread).
    pub fn new() -> Self {
        let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(16);
        Coordinator { workers, channel_capacity: 64, piece_mode: PieceMode::default() }
    }

    /// Set the worker count (0 = auto).
    pub fn workers(mut self, workers: usize) -> Self {
        if workers > 0 {
            self.workers = workers;
        }
        self
    }

    /// Bound on in-flight edge batches (backpressure knob).
    pub fn channel_capacity(mut self, cap: usize) -> Self {
        self.channel_capacity = cap.max(1);
        self
    }

    /// Set the quilt-piece mode (defaults to [`PieceMode::Conditioned`],
    /// matching the sequential samplers).
    pub fn piece_mode(mut self, mode: PieceMode) -> Self {
        self.piece_mode = mode;
        self
    }

    /// Plan the quilting jobs (Algorithm 2 pieces only).
    pub fn plan_quilt(
        &self,
        params: &MagmParams,
        attrs: &AttributeAssignment,
        seed: u64,
    ) -> JobPlan {
        let mut partition = Partition::build(attrs.configs());
        crate::quilt::maybe_build_dense_index(&mut partition, params.depth());
        let conditioner = self.build_conditioner(&mut partition, params);
        let sampler = QuiltSampler::new(params.clone());
        let jobs = sampler.plan(&partition).into_iter().map(Job::Piece).collect();
        let mut plan = JobPlan {
            jobs,
            partition,
            hybrid: None,
            params: params.clone(),
            seed,
            mode: self.piece_mode,
            conditioner,
        };
        plan.order_by_cost();
        plan
    }

    /// Build tries + the shared product DAG when running conditioned.
    fn build_conditioner(
        &self,
        partition: &mut Partition,
        params: &MagmParams,
    ) -> Option<ConditionedBallDropSampler> {
        (self.piece_mode == PieceMode::Conditioned)
            .then(|| partition.conditioned_sampler(params.thetas()))
    }

    /// Plan the §5 hybrid jobs: W-subset pieces + ER blocks.
    pub fn plan_hybrid(
        &self,
        params: &MagmParams,
        attrs: &AttributeAssignment,
        seed: u64,
    ) -> JobPlan {
        let hybrid = HybridSampler::new(params.clone()).seed(seed);
        let plan = hybrid.plan(attrs);
        let w_nodes = plan.w_nodes();
        let mut partition = Partition::build_subset(attrs.configs(), &w_nodes);
        crate::quilt::maybe_build_dense_index(&mut partition, params.depth());
        let conditioner = self.build_conditioner(&mut partition, params);
        let mut jobs: Vec<Job> = QuiltSampler::new(params.clone())
            .plan(&partition)
            .into_iter()
            .map(Job::Piece)
            .collect();
        let mut er_id = 0u64;
        for hi in 0..plan.heavy.len() {
            for hj in 0..plan.heavy.len() {
                jobs.push(Job::ErBlock {
                    src: BlockRef::Heavy(hi),
                    dst: BlockRef::Heavy(hj),
                    fork_id: er_id,
                });
                er_id += 1;
            }
        }
        for li in 0..plan.light.len() {
            for hj in 0..plan.heavy.len() {
                jobs.push(Job::ErBlock {
                    src: BlockRef::Light(li),
                    dst: BlockRef::Heavy(hj),
                    fork_id: er_id,
                });
                er_id += 1;
                jobs.push(Job::ErBlock {
                    src: BlockRef::Heavy(hj),
                    dst: BlockRef::Light(li),
                    fork_id: er_id,
                });
                er_id += 1;
            }
        }
        let mut job_plan = JobPlan {
            jobs,
            partition,
            hybrid: Some(plan),
            params: params.clone(),
            seed,
            mode: self.piece_mode,
            conditioner,
        };
        job_plan.order_by_cost();
        job_plan
    }

    /// Sample a MAGM graph with Algorithm 2 across the pool.
    pub fn sample_quilt(&self, params: &MagmParams, seed: u64) -> SampleReport {
        let mut rng = Rng::new(seed);
        let attrs = AttributeAssignment::sample(params, &mut rng);
        let plan = self.plan_quilt(params, &attrs, seed);
        self.run(plan)
    }

    /// Sample a MAGM graph with the §5 hybrid across the pool.
    pub fn sample_hybrid(&self, params: &MagmParams, seed: u64) -> SampleReport {
        let mut rng = Rng::new(seed);
        let attrs = AttributeAssignment::sample(params, &mut rng);
        let plan = self.plan_hybrid(params, &attrs, seed);
        self.run(plan)
    }

    /// Execute a plan on the pool and merge the result.
    pub fn run(&self, plan: JobPlan) -> SampleReport {
        let start = Instant::now();
        let n = plan.params.num_nodes();
        let partition_size = plan.partition.size();
        let num_jobs = plan.jobs.len();
        let workers = self.workers.max(1);

        let kpgm = BallDropSampler::new(plan.params.thetas().clone());
        // Matches the single-threaded samplers' fork tags so coordinated
        // and sequential sampling agree for the same seed.
        let piece_base = Rng::new(plan.seed).fork(if plan.hybrid.is_some() {
            0x4b1d
        } else {
            0x9011_7ed
        });
        let er_base = Rng::new(plan.seed).fork(0xe4b10c);

        let next_job = AtomicUsize::new(0);
        let dropped_total = AtomicU64::new(0);
        let (tx, rx) = mpsc::sync_channel::<Vec<(NodeId, NodeId)>>(self.channel_capacity);

        let mut graph = EdgeList::new(n);
        std::thread::scope(|scope| {
            let plan_ref = &plan;
            let kpgm_ref = &kpgm;
            let next = &next_job;
            let dropped_ref = &dropped_total;
            let piece_base_ref = &piece_base;
            let er_base_ref = &er_base;
            for _ in 0..workers {
                let tx = tx.clone();
                scope.spawn(move || {
                    loop {
                        let idx = next.fetch_add(1, Ordering::Relaxed);
                        let Some(job) = plan_ref.jobs.get(idx) else { break };
                        let mut local = EdgeList::new(n);
                        match *job {
                            Job::Piece(piece) => {
                                let backend = match plan_ref.conditioner.as_ref() {
                                    Some(cond) => {
                                        PieceBackend::Conditioned { cond, kpgm: kpgm_ref }
                                    }
                                    None => PieceBackend::Rejection(kpgm_ref),
                                };
                                let mut rng = piece_base_ref.fork(piece.fork_id);
                                let dropped = crate::quilt::sample_piece_for_coordinator(
                                    backend,
                                    &plan_ref.partition,
                                    piece,
                                    &mut rng,
                                    &mut local,
                                );
                                if dropped > 0 {
                                    dropped_ref.fetch_add(dropped, Ordering::Relaxed);
                                }
                            }
                            Job::ErBlock { src, dst, fork_id } => {
                                let hybrid =
                                    plan_ref.hybrid.as_ref().expect("ER block without plan");
                                let (ci, nodes_i) = block(hybrid, src);
                                let (cj, nodes_j) = block(hybrid, dst);
                                let p = crate::kpgm::edge_probability(
                                    plan_ref.params.thetas(),
                                    ci as NodeId,
                                    cj as NodeId,
                                );
                                let mut rng = er_base_ref.fork(fork_id);
                                sample_er_block(nodes_i, nodes_j, p, &mut rng, &mut local);
                            }
                        }
                        if tx.send(local.into_edges()).is_err() {
                            break; // merger gone
                        }
                    }
                });
            }
            drop(tx);
            // Merger: absorb batches as they arrive (bounded channel gives
            // backpressure against slow merging).
            while let Ok(batch) = rx.recv() {
                graph.extend(batch);
            }
        });

        graph.dedup();
        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        let edges_per_sec = graph.num_edges() as f64 / (wall_ms / 1e3).max(1e-9);
        SampleReport {
            graph,
            partition_size,
            num_jobs,
            workers,
            wall_ms,
            edges_per_sec,
            dropped_resamples: dropped_total.into_inner(),
        }
    }
}

fn block(plan: &HybridPlan, r: BlockRef) -> (u64, &[NodeId]) {
    match r {
        BlockRef::Light(i) => (plan.light[i].0, &plan.light[i].1),
        BlockRef::Heavy(i) => (plan.heavy[i].0, &plan.heavy[i].1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::Initiator;

    fn params(n: usize, d: u32, mu: f64) -> MagmParams {
        MagmParams::homogeneous(Initiator::THETA1, mu, n, d)
    }

    #[test]
    fn coordinated_equals_sequential_quilt() {
        // Same seed: the coordinator must produce exactly the edge set of
        // the single-threaded QuiltSampler.
        let p = params(256, 8, 0.5);
        let seq = QuiltSampler::new(p.clone()).seed(31).sample();
        let rep = Coordinator::new().workers(4).sample_quilt(&p, 31);
        let mut a = seq.into_edges();
        let mut b = rep.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn coordinated_equals_sequential_hybrid() {
        let p = params(300, 9, 0.85);
        let seq = HybridSampler::new(p.clone()).seed(37).sample();
        let rep = Coordinator::new().workers(3).sample_hybrid(&p, 37);
        let mut a = seq.into_edges();
        let mut b = rep.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn worker_count_does_not_change_result() {
        let p = params(128, 7, 0.7);
        let r1 = Coordinator::new().workers(1).sample_hybrid(&p, 5);
        let r8 = Coordinator::new().workers(8).sample_hybrid(&p, 5);
        let mut a = r1.graph.into_edges();
        let mut b = r8.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn report_metrics_populated() {
        let p = params(128, 7, 0.5);
        let rep = Coordinator::new().sample_quilt(&p, 1);
        assert!(rep.wall_ms > 0.0);
        assert!(rep.num_jobs >= rep.partition_size * rep.partition_size);
        assert!(rep.edges_per_sec > 0.0);
        assert!(rep.graph.validate().is_ok());
        // Healthy (unsaturated) runs abandon essentially no balls.
        assert!(rep.dropped_resamples <= 2, "dropped {}", rep.dropped_resamples);
    }

    #[test]
    fn rejection_mode_coordinated_equals_sequential() {
        let p = params(256, 8, 0.5);
        let seq =
            QuiltSampler::new(p.clone()).piece_mode(PieceMode::Rejection).seed(41).sample();
        let rep =
            Coordinator::new().workers(4).piece_mode(PieceMode::Rejection).sample_quilt(&p, 41);
        let mut a = seq.into_edges();
        let mut b = rep.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn cost_ordering_keeps_edge_set() {
        // The plan sorts pieces by restricted mass; the sampled edges must
        // be schedule-independent regardless.
        let p = params(200, 8, 0.7);
        let mut rng = Rng::new(3);
        let attrs = AttributeAssignment::sample(&p, &mut rng);
        let coord = Coordinator::new().workers(2);
        let plan = coord.plan_quilt(&p, &attrs, 3);
        assert_eq!(plan.piece_mode(), PieceMode::Conditioned);
        assert!(!plan.is_empty());
        // Costs must be non-increasing along the job queue.
        let costs: Vec<f64> = plan.jobs.iter().map(|j| plan.estimated_cost(j)).collect();
        assert!(costs.windows(2).all(|w| w[0] >= w[1]), "jobs not cost-ordered: {costs:?}");
        let rep = coord.run(plan);
        let seq = QuiltSampler::new(p).seed(3).sample_with_attrs(&attrs);
        let mut a = seq.into_edges();
        let mut b = rep.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn tiny_channel_capacity_still_completes() {
        // Backpressure path: capacity 1 forces workers to block on send.
        let p = params(256, 8, 0.5);
        let rep = Coordinator::new().workers(4).channel_capacity(1).sample_quilt(&p, 9);
        assert!(rep.graph.num_edges() > 0);
    }
}
