//! The worker pool and job plan.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::graph::{EdgeList, NodeId};
use crate::kpgm::BallDropSampler;
use crate::magm::{AttributeAssignment, MagmParams};
use crate::quilt::{sample_er_block, HybridPlan, HybridSampler, Partition, PieceJob, QuiltSampler};
use crate::rng::Rng;

/// Reference to a node block in a hybrid plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BlockRef {
    /// Index into `HybridPlan::light`.
    Light(usize),
    /// Index into `HybridPlan::heavy`.
    Heavy(usize),
}

/// One unit of work.
#[derive(Debug, Clone, Copy)]
enum Job {
    /// A quilt piece (KPGM sample filtered to `(D_k, D_l)`).
    Piece(PieceJob),
    /// A uniform block `src × dst` with the configs' edge probability.
    ErBlock { src: BlockRef, dst: BlockRef, fork_id: u64 },
}

/// The full set of jobs for one sample, plus the shared inputs workers
/// need. Built once by the leader.
pub struct JobPlan {
    jobs: Vec<Job>,
    partition: Partition,
    hybrid: Option<HybridPlan>,
    params: MagmParams,
    seed: u64,
}

impl JobPlan {
    /// Number of jobs.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// Whether the plan is empty.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Partition size B of the quilting part.
    pub fn partition_size(&self) -> usize {
        self.partition.size()
    }
}

/// Result of a coordinated sampling run.
#[derive(Debug)]
pub struct SampleReport {
    /// The sampled graph (deduplicated, canonical order).
    pub graph: EdgeList,
    /// Partition size B (of the quilted part).
    pub partition_size: usize,
    /// Total jobs executed.
    pub num_jobs: usize,
    /// Worker threads used.
    pub workers: usize,
    /// Wall-clock milliseconds.
    pub wall_ms: f64,
    /// Edges per second of wall time (post-dedup edges).
    pub edges_per_sec: f64,
}

/// The leader/worker coordinator.
#[derive(Debug, Clone)]
pub struct Coordinator {
    workers: usize,
    channel_capacity: usize,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    /// Workers = available parallelism (capped at 16; the merger is one
    /// more thread).
    pub fn new() -> Self {
        let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(16);
        Coordinator { workers, channel_capacity: 64 }
    }

    /// Set the worker count (0 = auto).
    pub fn workers(mut self, workers: usize) -> Self {
        if workers > 0 {
            self.workers = workers;
        }
        self
    }

    /// Bound on in-flight edge batches (backpressure knob).
    pub fn channel_capacity(mut self, cap: usize) -> Self {
        self.channel_capacity = cap.max(1);
        self
    }

    /// Plan the quilting jobs (Algorithm 2 pieces only).
    pub fn plan_quilt(
        &self,
        params: &MagmParams,
        attrs: &AttributeAssignment,
        seed: u64,
    ) -> JobPlan {
        let mut partition = Partition::build(attrs.configs());
        crate::quilt::maybe_build_dense_index(&mut partition, params.depth());
        let sampler = QuiltSampler::new(params.clone());
        let jobs = sampler.plan(&partition).into_iter().map(Job::Piece).collect();
        JobPlan { jobs, partition, hybrid: None, params: params.clone(), seed }
    }

    /// Plan the §5 hybrid jobs: W-subset pieces + ER blocks.
    pub fn plan_hybrid(
        &self,
        params: &MagmParams,
        attrs: &AttributeAssignment,
        seed: u64,
    ) -> JobPlan {
        let hybrid = HybridSampler::new(params.clone()).seed(seed);
        let plan = hybrid.plan(attrs);
        let w_nodes = plan.w_nodes();
        let mut partition = Partition::build_subset(attrs.configs(), &w_nodes);
        crate::quilt::maybe_build_dense_index(&mut partition, params.depth());
        let mut jobs: Vec<Job> = QuiltSampler::new(params.clone())
            .plan(&partition)
            .into_iter()
            .map(Job::Piece)
            .collect();
        let mut er_id = 0u64;
        for hi in 0..plan.heavy.len() {
            for hj in 0..plan.heavy.len() {
                jobs.push(Job::ErBlock {
                    src: BlockRef::Heavy(hi),
                    dst: BlockRef::Heavy(hj),
                    fork_id: er_id,
                });
                er_id += 1;
            }
        }
        for li in 0..plan.light.len() {
            for hj in 0..plan.heavy.len() {
                jobs.push(Job::ErBlock {
                    src: BlockRef::Light(li),
                    dst: BlockRef::Heavy(hj),
                    fork_id: er_id,
                });
                er_id += 1;
                jobs.push(Job::ErBlock {
                    src: BlockRef::Heavy(hj),
                    dst: BlockRef::Light(li),
                    fork_id: er_id,
                });
                er_id += 1;
            }
        }
        JobPlan { jobs, partition, hybrid: Some(plan), params: params.clone(), seed }
    }

    /// Sample a MAGM graph with Algorithm 2 across the pool.
    pub fn sample_quilt(&self, params: &MagmParams, seed: u64) -> SampleReport {
        let mut rng = Rng::new(seed);
        let attrs = AttributeAssignment::sample(params, &mut rng);
        let plan = self.plan_quilt(params, &attrs, seed);
        self.run(plan)
    }

    /// Sample a MAGM graph with the §5 hybrid across the pool.
    pub fn sample_hybrid(&self, params: &MagmParams, seed: u64) -> SampleReport {
        let mut rng = Rng::new(seed);
        let attrs = AttributeAssignment::sample(params, &mut rng);
        let plan = self.plan_hybrid(params, &attrs, seed);
        self.run(plan)
    }

    /// Execute a plan on the pool and merge the result.
    pub fn run(&self, plan: JobPlan) -> SampleReport {
        let start = Instant::now();
        let n = plan.params.num_nodes();
        let partition_size = plan.partition.size();
        let num_jobs = plan.jobs.len();
        let workers = self.workers.max(1);

        let kpgm = BallDropSampler::new(plan.params.thetas().clone());
        // Matches the single-threaded samplers' fork tags so coordinated
        // and sequential sampling agree for the same seed.
        let piece_base = Rng::new(plan.seed).fork(if plan.hybrid.is_some() {
            0x4b1d
        } else {
            0x9011_7ed
        });
        let er_base = Rng::new(plan.seed).fork(0xe4b10c);

        let next_job = AtomicUsize::new(0);
        let (tx, rx) = mpsc::sync_channel::<Vec<(NodeId, NodeId)>>(self.channel_capacity);

        let mut graph = EdgeList::new(n);
        std::thread::scope(|scope| {
            let plan_ref = &plan;
            let kpgm_ref = &kpgm;
            let next = &next_job;
            let piece_base_ref = &piece_base;
            let er_base_ref = &er_base;
            for _ in 0..workers {
                let tx = tx.clone();
                scope.spawn(move || {
                    loop {
                        let idx = next.fetch_add(1, Ordering::Relaxed);
                        let Some(job) = plan_ref.jobs.get(idx) else { break };
                        let mut local = EdgeList::new(n);
                        match *job {
                            Job::Piece(piece) => {
                                let mut rng = piece_base_ref.fork(piece.fork_id);
                                crate::quilt::sample_piece_for_coordinator(
                                    kpgm_ref,
                                    &plan_ref.partition,
                                    piece,
                                    &mut rng,
                                    &mut local,
                                );
                            }
                            Job::ErBlock { src, dst, fork_id } => {
                                let hybrid =
                                    plan_ref.hybrid.as_ref().expect("ER block without plan");
                                let (ci, nodes_i) = block(hybrid, src);
                                let (cj, nodes_j) = block(hybrid, dst);
                                let p = crate::kpgm::edge_probability(
                                    plan_ref.params.thetas(),
                                    ci as NodeId,
                                    cj as NodeId,
                                );
                                let mut rng = er_base_ref.fork(fork_id);
                                sample_er_block(nodes_i, nodes_j, p, &mut rng, &mut local);
                            }
                        }
                        if tx.send(local.into_edges()).is_err() {
                            break; // merger gone
                        }
                    }
                });
            }
            drop(tx);
            // Merger: absorb batches as they arrive (bounded channel gives
            // backpressure against slow merging).
            while let Ok(batch) = rx.recv() {
                graph.extend(batch);
            }
        });

        graph.dedup();
        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        let edges_per_sec = graph.num_edges() as f64 / (wall_ms / 1e3).max(1e-9);
        SampleReport {
            graph,
            partition_size,
            num_jobs,
            workers,
            wall_ms,
            edges_per_sec,
        }
    }
}

fn block(plan: &HybridPlan, r: BlockRef) -> (u64, &[NodeId]) {
    match r {
        BlockRef::Light(i) => (plan.light[i].0, &plan.light[i].1),
        BlockRef::Heavy(i) => (plan.heavy[i].0, &plan.heavy[i].1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::Initiator;

    fn params(n: usize, d: u32, mu: f64) -> MagmParams {
        MagmParams::homogeneous(Initiator::THETA1, mu, n, d)
    }

    #[test]
    fn coordinated_equals_sequential_quilt() {
        // Same seed: the coordinator must produce exactly the edge set of
        // the single-threaded QuiltSampler.
        let p = params(256, 8, 0.5);
        let seq = QuiltSampler::new(p.clone()).seed(31).sample();
        let rep = Coordinator::new().workers(4).sample_quilt(&p, 31);
        let mut a = seq.into_edges();
        let mut b = rep.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn coordinated_equals_sequential_hybrid() {
        let p = params(300, 9, 0.85);
        let seq = HybridSampler::new(p.clone()).seed(37).sample();
        let rep = Coordinator::new().workers(3).sample_hybrid(&p, 37);
        let mut a = seq.into_edges();
        let mut b = rep.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn worker_count_does_not_change_result() {
        let p = params(128, 7, 0.7);
        let r1 = Coordinator::new().workers(1).sample_hybrid(&p, 5);
        let r8 = Coordinator::new().workers(8).sample_hybrid(&p, 5);
        let mut a = r1.graph.into_edges();
        let mut b = r8.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn report_metrics_populated() {
        let p = params(128, 7, 0.5);
        let rep = Coordinator::new().sample_quilt(&p, 1);
        assert!(rep.wall_ms > 0.0);
        assert!(rep.num_jobs >= rep.partition_size * rep.partition_size);
        assert!(rep.edges_per_sec > 0.0);
        assert!(rep.graph.validate().is_ok());
    }

    #[test]
    fn tiny_channel_capacity_still_completes() {
        // Backpressure path: capacity 1 forces workers to block on send.
        let p = params(256, 8, 0.5);
        let rep = Coordinator::new().workers(4).channel_capacity(1).sample_quilt(&p, 9);
        assert!(rep.graph.num_edges() > 0);
    }
}
