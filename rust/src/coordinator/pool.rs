//! The worker pool, job plan, and sharded streaming merge.

use std::io;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::{ModelSpec, SamplerKind};
use crate::graph::{summarize_spill, CollectSink, Edge, EdgeList, EdgeSink, NodeId,
                   ShardMergeStats, ShardMerger, ShardSpec, SpillSummary};
use crate::kpgm::{BallDropSampler, ConditionedBallDropSampler, Initiator};
use crate::magm::{AttrSampleMode, AttributeAssignment, MagmParams};
use crate::quilt::{sample_er_block, HybridPlan, HybridSampler, Partition, PieceBackend,
                   PieceJob, PieceMode, QuiltSampler};
use crate::rng::Rng;
use crate::setup::{ArtifactHeader, SetupArtifact};
use crate::trace::{progress::ProgressState, Fv, TraceHandle};

/// Reference to a node block in a hybrid plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BlockRef {
    /// Index into `HybridPlan::light`.
    Light(usize),
    /// Index into `HybridPlan::heavy`.
    Heavy(usize),
}

/// One unit of work.
#[derive(Debug, Clone, Copy)]
enum Job {
    /// A quilt piece (KPGM sample filtered to `(D_k, D_l)`).
    Piece(PieceJob),
    /// A uniform block `src × dst` with the configs' edge probability.
    ErBlock { src: BlockRef, dst: BlockRef, fork_id: u64 },
}

/// Message to a shard merger: an edge batch, or proof that no further
/// batch can arrive because the shard's last contributing job finished —
/// which lets the merger deliver its run mid-run instead of waiting for
/// every worker to exit.
enum ShardMsg {
    /// One job's edges for this shard.
    Batch(Vec<Edge>),
    /// No job that can route to this shard remains; finish now.
    Close,
}

/// Wall-clock timings and knobs of the leader's **setup pipeline** — the
/// phases that run before the first piece job is dispatched (attribute
/// sampling, partition build, trie build, product-DAG build). Every phase
/// is deterministic in the seed: the thread count changes only these
/// timings, never the plan or the sampled graph.
#[derive(Debug, Clone, Copy)]
pub struct SetupStats {
    /// Attribute sampling milliseconds.
    pub attrs_ms: f64,
    /// Partition build milliseconds (includes the dense index and, for
    /// hybrid plans, the §5 light/heavy split).
    pub partition_ms: f64,
    /// Per-set prefix-trie build (+ shard merge) milliseconds.
    pub trie_ms: f64,
    /// Of [`SetupStats::trie_ms`], the shard-merge phase alone: the
    /// pairwise tree-merge folding per-shard arenas into the serial one
    /// (0 for serial builds and non-conditioned plans).
    pub trie_merge_ms: f64,
    /// Conditioned product-DAG build milliseconds.
    pub dag_ms: f64,
    /// Setup threads used (resolved; never 0).
    pub setup_threads: usize,
    /// How the attribute assignment consumed randomness.
    pub attr_mode: AttrSampleMode,
    /// Identity hash of the [`crate::setup::SetupArtifact`] this plan was
    /// hydrated from, or 0 for a fresh setup run. Non-zero proves the
    /// setup pipeline was *skipped*: the phase timings above are then the
    /// original build's provenance-free zeros, not re-run phases.
    pub artifact_hash: u64,
    /// Wall-clock spent loading + validating the artifact (0 for fresh
    /// runs) — the replacement cost for the skipped pipeline.
    pub artifact_load_ms: f64,
}

impl Default for SetupStats {
    fn default() -> Self {
        SetupStats {
            attrs_ms: 0.0,
            partition_ms: 0.0,
            trie_ms: 0.0,
            trie_merge_ms: 0.0,
            dag_ms: 0.0,
            setup_threads: 1,
            attr_mode: AttrSampleMode::Sequential,
            artifact_hash: 0,
            artifact_load_ms: 0.0,
        }
    }
}

/// The full set of jobs for one sample, plus the shared inputs workers
/// need. Built once by the leader.
pub struct JobPlan {
    jobs: Vec<Job>,
    partition: Partition,
    hybrid: Option<HybridPlan>,
    params: MagmParams,
    seed: u64,
    mode: PieceMode,
    /// The shared product DAG for [`PieceMode::Conditioned`] plans.
    conditioner: Option<ConditionedBallDropSampler>,
    /// Setup-pipeline timings recorded while building the plan
    /// (`attrs_ms` is filled by the `sample_*` entry points, which own
    /// attribute sampling).
    setup: SetupStats,
}

impl JobPlan {
    /// Number of jobs.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// Whether the plan is empty.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Partition size B of the quilting part.
    pub fn partition_size(&self) -> usize {
        self.partition.size()
    }

    /// The piece mode this plan was built for.
    pub fn piece_mode(&self) -> PieceMode {
        self.mode
    }

    /// Setup-pipeline timings recorded while building this plan.
    pub fn setup(&self) -> &SetupStats {
        &self.setup
    }

    /// Expected work of one job, used to order the queue (largest first)
    /// so the pool keeps all workers busy to the end.
    ///
    /// * Conditioned pieces cost their **restricted mass** `m_kl` (the
    ///   balls actually dropped) — not the full-space `X`, which would
    ///   treat every piece as equally heavy.
    /// * Rejection pieces all drop the same full-space `X`.
    /// * ER blocks cost their expected success count `p · cells`.
    fn estimated_cost(&self, job: &Job) -> f64 {
        match *job {
            Job::Piece(p) => match self.conditioner.as_ref().and_then(|c| c.piece(p.k, p.l)) {
                Some(piece) => 1.0 + piece.restricted_mass(),
                // Rejection pieces (and dense over-budget blocks) all
                // drop the same full-space X.
                None => 1.0 + self.params.thetas().expected_edges(),
            },
            Job::ErBlock { src, dst, .. } => {
                let Some(hybrid) = self.hybrid.as_ref() else { return 1.0 };
                let (ci, nodes_i) = block(hybrid, src);
                let (cj, nodes_j) = block(hybrid, dst);
                let p = crate::kpgm::edge_probability(
                    self.params.thetas(),
                    ci as NodeId,
                    cj as NodeId,
                );
                1.0 + p * nodes_i.len() as f64 * nodes_j.len() as f64
            }
        }
    }

    /// Sort jobs by descending estimated cost (stable: ties keep plan
    /// order). Fork ids travel with their jobs, so the sampled edge set
    /// is unchanged — only the schedule improves.
    fn order_by_cost(&mut self) {
        let costs: Vec<f64> = self.jobs.iter().map(|j| self.estimated_cost(j)).collect();
        let mut order: Vec<usize> = (0..self.jobs.len()).collect();
        order.sort_by(|&a, &b| {
            costs[b].partial_cmp(&costs[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        self.jobs = order.into_iter().map(|i| self.jobs[i]).collect();
    }

    /// Per-job *source span*: the contiguous shard range job `i`'s edges
    /// can route to under `spec`, or `None` for a job with no source
    /// nodes. Piece `(k, l)` sources come from `D_k` and ER-block sources
    /// from the block's node list, and `shard_of` is monotone in the node
    /// id, so `[shard_of(min), shard_of(max)]` over the source set covers
    /// every edge the job can emit.
    ///
    /// This is the contract the distributed runtime's job-ownership rule
    /// is built on: every process recomputes the same spans from the same
    /// plan, so span-based assignment needs no communication.
    pub fn job_source_spans(&self, spec: &ShardSpec) -> Vec<Option<(usize, usize)>> {
        let source_span = |nodes: &[NodeId]| -> Option<(usize, usize)> {
            let lo = *nodes.iter().min()?;
            let hi = *nodes.iter().max().expect("non-empty after min");
            Some((spec.shard_of(lo), spec.shard_of(hi)))
        };
        let piece_spans: Vec<Option<(usize, usize)>> =
            (0..self.partition.size()).map(|k| source_span(self.partition.set(k))).collect();
        let (light_spans, heavy_spans): (Vec<_>, Vec<_>) = match self.hybrid.as_ref() {
            Some(h) => (
                h.light.iter().map(|(_, nodes)| source_span(nodes)).collect(),
                h.heavy.iter().map(|(_, nodes)| source_span(nodes)).collect(),
            ),
            None => (Vec::new(), Vec::new()),
        };
        self.jobs
            .iter()
            .map(|job| match *job {
                Job::Piece(p) => piece_spans[p.k],
                Job::ErBlock { src, .. } => match src {
                    BlockRef::Light(i) => light_spans[i],
                    BlockRef::Heavy(i) => heavy_spans[i],
                },
            })
            .collect()
    }

    /// Keep only the jobs whose index satisfies `keep` (indices refer to
    /// the current job order, matching [`Self::job_source_spans`]).
    /// Fork ids travel with their jobs, so the retained jobs sample
    /// exactly the edges they would have in the full plan — the
    /// distributed runtime uses this to carve one deterministic plan into
    /// per-process slices whose union is the whole sample.
    pub fn retain_jobs(&mut self, mut keep: impl FnMut(usize) -> bool) {
        let mut i = 0;
        self.jobs.retain(|_| {
            let k = keep(i);
            i += 1;
            k
        });
    }
}

/// Sink-agnostic statistics of one coordinated sampling run.
#[derive(Debug, Clone)]
pub struct RunStats {
    /// Partition size B (of the quilted part).
    pub partition_size: usize,
    /// Total jobs executed.
    pub num_jobs: usize,
    /// Worker threads used.
    pub workers: usize,
    /// Shard mergers used — the *effective* count after clamping the
    /// request to the merger cap and the node count.
    pub num_shards: usize,
    /// Post-dedup edge count delivered to the sink.
    pub num_edges: u64,
    /// Wall-clock milliseconds.
    pub wall_ms: f64,
    /// Edges per second of wall time (post-dedup edges).
    pub edges_per_sec: f64,
    /// Balls abandoned after exhausting duplicate resamples (previously
    /// lost silently; 0 in healthy runs, non-zero signals saturation).
    pub dropped_resamples: u64,
    /// Per-shard merge statistics (one entry per shard, in index order),
    /// including the sink-side deferral/spill columns.
    pub shard_stats: Vec<ShardMergeStats>,
    /// Aggregate out-of-order deferral/spill picture across shards.
    pub spill: SpillSummary,
    /// Setup-pipeline phase timings (leader-side, before job dispatch).
    pub setup: SetupStats,
}

/// Result of a coordinated sampling run collected in memory.
#[derive(Debug)]
pub struct SampleReport {
    /// The sampled graph (deduplicated, canonical order).
    pub graph: EdgeList,
    /// Partition size B (of the quilted part).
    pub partition_size: usize,
    /// Total jobs executed.
    pub num_jobs: usize,
    /// Worker threads used.
    pub workers: usize,
    /// Shard mergers used (effective count, see [`RunStats::num_shards`]).
    pub num_shards: usize,
    /// Wall-clock milliseconds.
    pub wall_ms: f64,
    /// Edges per second of wall time (post-dedup edges).
    pub edges_per_sec: f64,
    /// Balls abandoned after exhausting duplicate resamples (previously
    /// lost silently; 0 in healthy runs, non-zero signals saturation).
    pub dropped_resamples: u64,
    /// Per-shard merge statistics (one entry per shard, in index order).
    pub shard_stats: Vec<ShardMergeStats>,
    /// Aggregate out-of-order deferral/spill picture across shards.
    pub spill: SpillSummary,
    /// Setup-pipeline phase timings (leader-side, before job dispatch).
    pub setup: SetupStats,
}

impl SampleReport {
    /// The run in [`RunStats`] form — what `report.json` serializes.
    /// `num_edges` comes from the collected graph.
    pub fn stats(&self) -> RunStats {
        RunStats {
            partition_size: self.partition_size,
            num_jobs: self.num_jobs,
            workers: self.workers,
            num_shards: self.num_shards,
            num_edges: self.graph.num_edges() as u64,
            wall_ms: self.wall_ms,
            edges_per_sec: self.edges_per_sec,
            dropped_resamples: self.dropped_resamples,
            shard_stats: self.shard_stats.clone(),
            spill: self.spill,
            setup: self.setup,
        }
    }
}

/// Upper bound on shard mergers (each is a thread). Public because the
/// distributed planner must clamp its shard count the same way every
/// worker process will.
pub const MAX_SHARDS: usize = 256;

/// The leader/worker coordinator.
#[derive(Debug, Clone)]
pub struct Coordinator {
    workers: usize,
    channel_capacity: usize,
    piece_mode: PieceMode,
    /// Shard-merger count; 0 = auto (match the worker count).
    shards: usize,
    /// Setup-pipeline thread count; 0 = auto (match the worker count).
    setup_threads: usize,
    /// How attribute sampling consumes randomness.
    attr_mode: AttrSampleMode,
    /// Write-only telemetry stream (disabled by default; the sampled
    /// output is byte-identical either way — the trace-sink lint keeps
    /// telemetry out of every output-determining module).
    trace: TraceHandle,
    /// Live progress counters, bumped as jobs complete and shards seal
    /// (None = no live progress).
    progress: Option<Arc<ProgressState>>,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    /// Workers = available parallelism (capped at 16; shard mergers are
    /// additional threads, one per shard).
    pub fn new() -> Self {
        let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(16);
        Coordinator {
            workers,
            channel_capacity: 64,
            piece_mode: PieceMode::default(),
            shards: 0,
            setup_threads: 0,
            attr_mode: AttrSampleMode::default(),
            trace: TraceHandle::disabled(),
            progress: None,
        }
    }

    /// Set the worker count (0 = auto).
    pub fn workers(mut self, workers: usize) -> Self {
        if workers > 0 {
            self.workers = workers;
        }
        self
    }

    /// Set the shard-merger count (0 = auto, matching the worker count).
    /// The sampled edge set is identical for every shard count; only the
    /// merge parallelism and per-shard memory change. Values beyond the
    /// merger cap (256) or the node count are clamped at run time — with
    /// a warning, and the effective count reported in
    /// [`RunStats::num_shards`] — since extra mergers would only be empty
    /// threads.
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Bound on in-flight edge batches **per shard** (backpressure knob).
    pub fn channel_capacity(mut self, cap: usize) -> Self {
        self.channel_capacity = cap.max(1);
        self
    }

    /// Set the quilt-piece mode (defaults to [`PieceMode::Conditioned`],
    /// matching the sequential samplers).
    pub fn piece_mode(mut self, mode: PieceMode) -> Self {
        self.piece_mode = mode;
        self
    }

    /// Set the setup-pipeline thread count (0 = auto, matching the worker
    /// count). Every setup phase is bit-for-bit deterministic in the
    /// seed, so this knob changes only wall-clock — never the plan or the
    /// sampled graph.
    pub fn setup_threads(mut self, threads: usize) -> Self {
        self.setup_threads = threads;
        self
    }

    /// Set the attribute sampling mode. Defaults to
    /// [`AttrSampleMode::Sequential`] for seed-compatibility with goldens
    /// recorded before the chunked pipeline; [`AttrSampleMode::Chunked`]
    /// is required for the attribute phase to parallelize.
    pub fn attr_mode(mut self, mode: AttrSampleMode) -> Self {
        self.attr_mode = mode;
        self
    }

    /// Attach a telemetry stream. Events (setup, job plan, per-job and
    /// per-shard completions, run summary) are emitted as the run
    /// progresses; the sampled output is byte-identical with tracing on
    /// or off.
    pub fn trace(mut self, trace: TraceHandle) -> Self {
        self.trace = trace;
        self
    }

    /// Attach live progress counters (shared with a heartbeat thread or
    /// a status printer). Observability only — never read back into the
    /// run.
    pub fn progress(mut self, progress: Arc<ProgressState>) -> Self {
        self.progress = Some(progress);
        self
    }

    /// Resolved setup-thread count (0 = auto → worker count).
    fn effective_setup_threads(&self) -> usize {
        if self.setup_threads == 0 { self.workers.max(1) } else { self.setup_threads }
    }

    /// Sample the attribute assignment per the configured mode, returning
    /// it with the phase's wall-clock milliseconds.
    fn sample_attrs(&self, params: &MagmParams, seed: u64) -> (AttributeAssignment, f64) {
        let start = Instant::now();
        let mut rng = Rng::new(seed);
        let attrs = AttributeAssignment::sample_with_mode(
            params,
            &mut rng,
            self.attr_mode,
            self.effective_setup_threads(),
        );
        (attrs, start.elapsed().as_secs_f64() * 1e3)
    }

    /// Plan the quilting jobs (Algorithm 2 pieces only).
    ///
    /// Runs the setup pipeline on the configured setup threads: parallel
    /// prefix-sum partition build, sharded trie build, and per-level
    /// parallel DAG aggregation — each phase timed into
    /// [`JobPlan::setup`], each bit-for-bit identical to its serial
    /// counterpart.
    pub fn plan_quilt(
        &self,
        params: &MagmParams,
        attrs: &AttributeAssignment,
        seed: u64,
    ) -> JobPlan {
        let st = self.effective_setup_threads();
        let start = Instant::now();
        let mut partition = Partition::build_parallel(attrs.configs(), st);
        crate::quilt::maybe_build_dense_index(&mut partition, params.depth());
        let partition_ms = start.elapsed().as_secs_f64() * 1e3;
        let (conditioner, trie_ms, trie_merge_ms, dag_ms) =
            self.build_conditioner(&mut partition, params, st);
        let sampler = QuiltSampler::new(params.clone());
        let jobs = sampler.plan(&partition).into_iter().map(Job::Piece).collect();
        let mut plan = JobPlan {
            jobs,
            partition,
            hybrid: None,
            params: params.clone(),
            seed,
            mode: self.piece_mode,
            conditioner,
            setup: SetupStats {
                attrs_ms: 0.0,
                partition_ms,
                trie_ms,
                trie_merge_ms,
                dag_ms,
                setup_threads: st,
                attr_mode: self.attr_mode,
                artifact_hash: 0,
                artifact_load_ms: 0.0,
            },
        };
        plan.order_by_cost();
        plan
    }

    /// Build tries + the shared product DAG when running conditioned,
    /// timing the phases separately. Returns
    /// `(dag, trie_ms, trie_merge_ms, dag_ms)` — `trie_merge_ms` is the
    /// shard-merge slice of `trie_ms`.
    fn build_conditioner(
        &self,
        partition: &mut Partition,
        params: &MagmParams,
        setup_threads: usize,
    ) -> (Option<ConditionedBallDropSampler>, f64, f64, f64) {
        if self.piece_mode != PieceMode::Conditioned {
            return (None, 0.0, 0.0, 0.0);
        }
        let start = Instant::now();
        partition.build_tries_parallel(params.depth(), setup_threads);
        let trie_ms = start.elapsed().as_secs_f64() * 1e3;
        let start = Instant::now();
        let dag = partition.conditioned_sampler_threaded(params.thetas(), setup_threads);
        let dag_ms = start.elapsed().as_secs_f64() * 1e3;
        (Some(dag), trie_ms, partition.trie_merge_ms(), dag_ms)
    }

    /// Plan the §5 hybrid jobs: W-subset pieces + ER blocks.
    pub fn plan_hybrid(
        &self,
        params: &MagmParams,
        attrs: &AttributeAssignment,
        seed: u64,
    ) -> JobPlan {
        let st = self.effective_setup_threads();
        let start = Instant::now();
        let hybrid = HybridSampler::new(params.clone()).seed(seed);
        let plan = hybrid.plan(attrs);
        let w_nodes = plan.w_nodes();
        let mut partition = Partition::build_subset_parallel(attrs.configs(), &w_nodes, st);
        crate::quilt::maybe_build_dense_index(&mut partition, params.depth());
        let partition_ms = start.elapsed().as_secs_f64() * 1e3;
        let (conditioner, trie_ms, trie_merge_ms, dag_ms) =
            self.build_conditioner(&mut partition, params, st);
        let jobs = assemble_hybrid_jobs(params, &partition, &plan);
        let mut job_plan = JobPlan {
            jobs,
            partition,
            hybrid: Some(plan),
            params: params.clone(),
            seed,
            mode: self.piece_mode,
            conditioner,
            setup: SetupStats {
                attrs_ms: 0.0,
                partition_ms,
                trie_ms,
                trie_merge_ms,
                dag_ms,
                setup_threads: st,
                attr_mode: self.attr_mode,
                artifact_hash: 0,
                artifact_load_ms: 0.0,
            },
        };
        job_plan.order_by_cost();
        job_plan
    }

    /// Sample a MAGM graph with Algorithm 2 across the pool.
    pub fn sample_quilt(&self, params: &MagmParams, seed: u64) -> SampleReport {
        let (attrs, attrs_ms) = self.sample_attrs(params, seed);
        let mut plan = self.plan_quilt(params, &attrs, seed);
        plan.setup.attrs_ms = attrs_ms;
        self.run(plan)
    }

    /// Sample a MAGM graph with the §5 hybrid across the pool.
    pub fn sample_hybrid(&self, params: &MagmParams, seed: u64) -> SampleReport {
        let (attrs, attrs_ms) = self.sample_attrs(params, seed);
        let mut plan = self.plan_hybrid(params, &attrs, seed);
        plan.setup.attrs_ms = attrs_ms;
        self.run(plan)
    }

    /// As [`Self::sample_quilt`], delivering the edges to `sink` instead
    /// of collecting them in memory.
    pub fn sample_quilt_with_sink<K: EdgeSink>(
        &self,
        params: &MagmParams,
        seed: u64,
        sink: K,
    ) -> io::Result<(K::Output, RunStats)> {
        let (attrs, attrs_ms) = self.sample_attrs(params, seed);
        let mut plan = self.plan_quilt(params, &attrs, seed);
        plan.setup.attrs_ms = attrs_ms;
        self.run_with_sink(plan, sink)
    }

    /// As [`Self::sample_hybrid`], delivering the edges to `sink`.
    pub fn sample_hybrid_with_sink<K: EdgeSink>(
        &self,
        params: &MagmParams,
        seed: u64,
        sink: K,
    ) -> io::Result<(K::Output, RunStats)> {
        let (attrs, attrs_ms) = self.sample_attrs(params, seed);
        let mut plan = self.plan_hybrid(params, &attrs, seed);
        plan.setup.attrs_ms = attrs_ms;
        self.run_with_sink(plan, sink)
    }

    /// Run the full deterministic setup prologue — attributes, partition
    /// (full for quilt, the §5 W subset for hybrid), and in conditioned
    /// mode the tries + product DAG — and package it as a
    /// [`SetupArtifact`] ready to [`SetupArtifact::save`].
    ///
    /// Only the homogeneous MAGM of the CLI config surface is supported
    /// (the artifact header stores the [`ModelSpec`] fields; a
    /// heterogeneous [`MagmParams`] has no such compact identity). The
    /// dense config→set index is deliberately **not** built here: it is
    /// a derived cache the hydration path rebuilds, so the artifact stays
    /// smaller and the build faster.
    pub fn build_setup(
        &self,
        model: &ModelSpec,
        seed: u64,
        sampler: SamplerKind,
    ) -> Result<SetupArtifact> {
        let start = Instant::now();
        let params = MagmParams::homogeneous(
            Initiator::new(model.theta),
            model.mu,
            model.num_nodes(),
            model.attributes,
        );
        let st = self.effective_setup_threads();
        let (attrs, _attrs_ms) = self.sample_attrs(&params, seed);
        let mut partition = match sampler {
            SamplerKind::Quilt => Partition::build_parallel(attrs.configs(), st),
            SamplerKind::Hybrid => {
                // The hybrid split is a pure function of the attrs, so
                // only its W-subset partition needs to be persisted; the
                // hydration path re-derives the split itself.
                let plan = HybridSampler::new(params.clone()).seed(seed).plan(&attrs);
                Partition::build_subset_parallel(attrs.configs(), &plan.w_nodes(), st)
            }
            other => bail!(
                "setup artifacts cover the quilt and hybrid samplers; `{}` has no \
                 partition prologue to persist",
                other.name()
            ),
        };
        let (conditioner, _trie_ms, _trie_merge_ms, _dag_ms) =
            self.build_conditioner(&mut partition, &params, st);
        let mut header =
            ArtifactHeader::from_model(model, seed, sampler, self.piece_mode, self.attr_mode);
        header.setup_threads = st;
        header.setup_ms = start.elapsed().as_secs_f64() * 1e3;
        Ok(SetupArtifact::new(header, attrs, partition, conditioner))
    }

    /// Hydrate a [`JobPlan`] from a setup artifact, **skipping the whole
    /// setup pipeline**: attrs, partition, tries, and DAG come straight
    /// from the artifact; only the derived pieces are recomputed (the
    /// dense index, the job list, and — for hybrid — the split, a pure
    /// function of the attrs). The resulting plan samples byte-identical
    /// output to one built fresh under the same model/seed/modes.
    ///
    /// `load_ms` is the wall-clock the caller spent loading + validating
    /// the artifact; it lands in [`SetupStats::artifact_load_ms`], and
    /// [`SetupStats::artifact_hash`] is set to the artifact's identity
    /// hash (non-zero is the "setup was skipped" witness).
    ///
    /// The artifact's piece and attr modes must match this coordinator's
    /// — a conditioned run cannot borrow a rejection artifact's partition
    /// (no DAG), and an attr-mode mismatch means a different assignment
    /// than the seed would sample here.
    pub fn plan_from_artifact(
        &self,
        artifact: SetupArtifact,
        load_ms: f64,
    ) -> Result<JobPlan> {
        let (header, attrs, mut partition, conditioner) = artifact.into_parts();
        if header.piece_mode != self.piece_mode {
            bail!(
                "setup artifact was built for piece mode `{}`, this run wants `{}` — \
                 regenerate with `magquilt setup`",
                header.piece_mode.name(),
                self.piece_mode.name()
            );
        }
        if header.attr_mode != self.attr_mode {
            bail!(
                "setup artifact was built for attr mode `{}`, this run wants `{}` — \
                 regenerate with `magquilt setup`",
                header.attr_mode.name(),
                self.attr_mode.name()
            );
        }
        if header.piece_mode == PieceMode::Conditioned && conditioner.is_none() {
            bail!("conditioned setup artifact is missing its product DAG");
        }
        let params = MagmParams::homogeneous(
            Initiator::new(header.theta),
            header.mu,
            header.num_nodes(),
            header.attributes,
        );
        crate::quilt::maybe_build_dense_index(&mut partition, params.depth());
        let setup = SetupStats {
            attrs_ms: 0.0,
            partition_ms: 0.0,
            trie_ms: 0.0,
            trie_merge_ms: 0.0,
            dag_ms: 0.0,
            setup_threads: header.setup_threads.max(1),
            attr_mode: header.attr_mode,
            artifact_hash: header.hash64(),
            artifact_load_ms: load_ms,
        };
        let seed = header.seed;
        let mut plan = match header.sampler {
            SamplerKind::Quilt => {
                let jobs = QuiltSampler::new(params.clone())
                    .plan(&partition)
                    .into_iter()
                    .map(Job::Piece)
                    .collect();
                JobPlan {
                    jobs,
                    partition,
                    hybrid: None,
                    params,
                    seed,
                    mode: self.piece_mode,
                    conditioner,
                    setup,
                }
            }
            SamplerKind::Hybrid => {
                let hybrid = HybridSampler::new(params.clone()).seed(seed).plan(&attrs);
                let jobs = assemble_hybrid_jobs(&params, &partition, &hybrid);
                JobPlan {
                    jobs,
                    partition,
                    hybrid: Some(hybrid),
                    params,
                    seed,
                    mode: self.piece_mode,
                    conditioner,
                    setup,
                }
            }
            other => bail!(
                "setup artifact names sampler `{}`, which has no artifact-backed plan",
                other.name()
            ),
        };
        plan.order_by_cost();
        Ok(plan)
    }

    /// Sample from a hydrated artifact, collecting the graph in memory.
    /// See [`Self::plan_from_artifact`] for the equivalence contract.
    pub fn sample_with_artifact(
        &self,
        artifact: SetupArtifact,
        load_ms: f64,
    ) -> Result<SampleReport> {
        let plan = self.plan_from_artifact(artifact, load_ms)?;
        Ok(self.run(plan))
    }

    /// Sample from a hydrated artifact, delivering edges to `sink`.
    pub fn sample_with_artifact_sink<K: EdgeSink>(
        &self,
        artifact: SetupArtifact,
        load_ms: f64,
        sink: K,
    ) -> Result<(K::Output, RunStats)> {
        let plan = self.plan_from_artifact(artifact, load_ms)?;
        Ok(self.run_with_sink(plan, sink)?)
    }

    /// Execute a plan on the pool, collecting the merged graph in memory.
    pub fn run(&self, plan: JobPlan) -> SampleReport {
        let (graph, stats) = self
            .run_with_sink(plan, CollectSink::new())
            .expect("in-memory collect sink cannot fail");
        SampleReport {
            graph,
            partition_size: stats.partition_size,
            num_jobs: stats.num_jobs,
            workers: stats.workers,
            num_shards: stats.num_shards,
            wall_ms: stats.wall_ms,
            edges_per_sec: stats.edges_per_sec,
            dropped_resamples: stats.dropped_resamples,
            shard_stats: stats.shard_stats,
            spill: stats.spill,
            setup: stats.setup,
        }
    }

    /// Execute a plan with the sharded streaming merge, delivering the
    /// finished shards to `sink`.
    ///
    /// Data flow: workers pull jobs from the shared queue, sample each
    /// job's edges, and route them **by source-node range** to `S` shard
    /// mergers over bounded channels (backpressure per shard). Each
    /// [`ShardMerger`] folds arriving batches into one sorted,
    /// deduplicated run, so no thread ever holds the pre-dedup edge
    /// multiset: per-shard residency is bounded by the post-dedup shard
    /// size plus batch-sized merge overhead (at most two batches inside
    /// the merger, see [`crate::graph::ShardMergeStats::peak_resident`],
    /// plus up to `channel_capacity` batches queued in the shard's
    /// bounded channel). Every job's *source span* — the contiguous
    /// shard range its sources can route to (piece sources come from
    /// `D_k`, ER-block sources from the block's node list) — is counted
    /// per shard up front, and a shard's merger is **closed as soon as
    /// its last contributing job completes**: it delivers its finished
    /// run mid-run, while other workers are still sampling. Finished
    /// shards are handed to the sink **in completion order** through the
    /// shard-addressable protocol (`begin_shard`/`accept_shard`) — an
    /// early-finishing shard is consumed (and its merger's memory
    /// released) immediately instead of sitting buffered until every
    /// earlier shard catches up; sinks that need index order
    /// ([`crate::graph::BinaryFileSink`]) defer or spill per their
    /// budget and stitch at the file frontier, so the output is still
    /// the globally sorted edge list with no final sort or dedup pass.
    ///
    /// Determinism: jobs carry the same RNG fork ids as the sequential
    /// samplers, and routing/merging only rearranges edges, so the
    /// delivered edge list is bit-for-bit the sequential samplers'
    /// (sorted, deduplicated) output for the same seed — for every
    /// shard count, worker count, and completion order.
    ///
    /// A sampled edge whose source id falls outside the node range is an
    /// upstream sampler bug; the routing path fails the run with
    /// [`io::ErrorKind::InvalidData`] rather than absorbing the id into
    /// the last shard.
    pub fn run_with_sink<K: EdgeSink>(
        &self,
        plan: JobPlan,
        mut sink: K,
    ) -> io::Result<(K::Output, RunStats)> {
        let start = Instant::now();
        let n = plan.params.num_nodes();
        let partition_size = plan.partition.size();
        let num_jobs = plan.jobs.len();
        let workers = self.workers.max(1);
        // Each shard is a merger thread; cap so a pathological --shards
        // cannot spawn unbounded threads — and say so, instead of
        // silently running with fewer mergers than asked for.
        let requested = if self.shards == 0 { workers } else { self.shards };
        if requested > MAX_SHARDS {
            eprintln!(
                "warning: {requested} shards requested but the merger cap is {MAX_SHARDS}; \
                 running with {MAX_SHARDS}"
            );
        }
        let spec = ShardSpec::new(n, requested.min(MAX_SHARDS));
        let num_shards = spec.num_shards();
        if self.shards != 0 && num_shards < requested.min(MAX_SHARDS) {
            eprintln!(
                "warning: {requested} shards requested for {n} nodes; running with \
                 {num_shards} (shards beyond the node count would stay empty)"
            );
        }
        sink.begin(n, num_shards)?;
        let n64 = n as u64;
        self.trace.emit(
            "setup",
            &[
                ("threads", Fv::U(plan.setup.setup_threads as u64)),
                ("attr_mode", Fv::S(plan.setup.attr_mode.name().to_string())),
                ("artifact", Fv::S(format!("{:016x}", plan.setup.artifact_hash))),
                ("attrs_ms", Fv::F(plan.setup.attrs_ms)),
                ("partition_ms", Fv::F(plan.setup.partition_ms)),
                ("trie_ms", Fv::F(plan.setup.trie_ms)),
                ("trie_merge_ms", Fv::F(plan.setup.trie_merge_ms)),
                ("dag_ms", Fv::F(plan.setup.dag_ms)),
                ("artifact_load_ms", Fv::F(plan.setup.artifact_load_ms)),
            ],
        );
        self.trace.emit(
            "job_plan",
            &[
                ("jobs", Fv::U(num_jobs as u64)),
                ("partition", Fv::U(partition_size as u64)),
                ("shards", Fv::U(num_shards as u64)),
                ("workers", Fv::U(workers as u64)),
            ],
        );
        if let Some(progress) = self.progress.as_deref() {
            progress.jobs_total.fetch_add(num_jobs as u64, Ordering::Relaxed);
        }

        // Per-job *source span* ([`JobPlan::job_source_spans`]): shards
        // count their contributing jobs; when a shard's count hits zero
        // its merger is closed and delivers immediately — mid-run —
        // instead of holding its finished run until the last worker
        // exits.
        let job_spans = plan.job_source_spans(&spec);
        let mut span_counts = vec![0usize; num_shards];
        for span in &job_spans {
            if let Some((lo, hi)) = *span {
                for s in lo..=hi {
                    span_counts[s] += 1;
                }
            }
        }
        let remaining: Vec<AtomicUsize> =
            span_counts.iter().map(|&c| AtomicUsize::new(c)).collect();

        let kpgm = BallDropSampler::new(plan.params.thetas().clone());
        // The registry constants are the same ones the single-threaded
        // samplers fork, so coordinated and sequential sampling read
        // identical streams for the same seed.
        let piece_base = Rng::new(plan.seed).fork(if plan.hybrid.is_some() {
            crate::rngtags::HYBRID_PIECE_STREAM
        } else {
            crate::rngtags::QUILT_PIECE_STREAM
        });
        let er_base = Rng::new(plan.seed).fork(crate::rngtags::ER_STREAM);

        let next_job = AtomicUsize::new(0);
        let dropped_total = AtomicU64::new(0);
        let mut txs = Vec::with_capacity(num_shards);
        let mut rxs = Vec::with_capacity(num_shards);
        for _ in 0..num_shards {
            let (tx, rx) = mpsc::sync_channel::<ShardMsg>(self.channel_capacity);
            txs.push(tx);
            rxs.push(rx);
        }
        // A shard no planned job can reach delivers (empty) right away.
        for (s, count) in span_counts.iter().enumerate() {
            if *count == 0 {
                let _ = txs[s].send(ShardMsg::Close);
            }
        }

        let mut shard_stats: Vec<ShardMergeStats> = Vec::with_capacity(num_shards);
        let mut sink_result: io::Result<()> = Ok(());
        // First out-of-range source id a worker caught while routing
        // (an upstream sampler bug — fails the run instead of being
        // absorbed into the last shard). `aborted` is the cancellation
        // signal the other workers poll between jobs, so a
        // guaranteed-to-fail run stops sampling instead of burning the
        // rest of the job queue before reporting.
        let route_error: Mutex<Option<String>> = Mutex::new(None);
        let aborted = std::sync::atomic::AtomicBool::new(false);
        // Finished shards arrive here in completion order.
        let (done_tx, done_rx) = mpsc::channel::<(Vec<Edge>, ShardMergeStats)>();
        std::thread::scope(|scope| {
            let plan_ref = &plan;
            let kpgm_ref = &kpgm;
            let next = &next_job;
            let dropped_ref = &dropped_total;
            let piece_base_ref = &piece_base;
            let er_base_ref = &er_base;
            let route_error_ref = &route_error;
            let aborted_ref = &aborted;
            let spans_ref = &job_spans;
            let remaining_ref = &remaining;
            let trace_ref = &self.trace;
            let progress_ref = self.progress.as_deref();

            // Shard mergers: each drains its own channel, folding batches
            // into a sorted, deduplicated run as they arrive, and reports
            // its finished run the moment it is closed (its last
            // contributing job completed) or its channel hangs up.
            let merger_handles: Vec<_> = rxs
                .into_iter()
                .enumerate()
                .map(|(si, rx)| {
                    let done_tx = done_tx.clone();
                    scope.spawn(move || {
                        let mut merger = ShardMerger::new(si);
                        loop {
                            match rx.recv() {
                                Ok(ShardMsg::Batch(batch)) => merger.absorb(batch),
                                Ok(ShardMsg::Close) | Err(_) => break,
                            }
                        }
                        let _ = done_tx.send(merger.finish());
                    })
                })
                .collect();
            drop(done_tx);

            for _ in 0..workers {
                let txs = txs.clone();
                scope.spawn(move || {
                    loop {
                        if aborted_ref.load(Ordering::Relaxed) {
                            break;
                        }
                        let idx = next.fetch_add(1, Ordering::Relaxed);
                        let Some(job) = plan_ref.jobs.get(idx) else { break };
                        let mut local = EdgeList::new(n);
                        match *job {
                            Job::Piece(piece) => {
                                let backend = match plan_ref.conditioner.as_ref() {
                                    Some(cond) => {
                                        PieceBackend::Conditioned { cond, kpgm: kpgm_ref }
                                    }
                                    None => PieceBackend::Rejection(kpgm_ref),
                                };
                                let mut rng = piece_base_ref.fork(piece.fork_id);
                                let dropped = crate::quilt::sample_piece_for_coordinator(
                                    backend,
                                    &plan_ref.partition,
                                    piece,
                                    &mut rng,
                                    &mut local,
                                );
                                if dropped > 0 {
                                    dropped_ref.fetch_add(dropped, Ordering::Relaxed);
                                }
                            }
                            Job::ErBlock { src, dst, fork_id } => {
                                // A planner bug, not a data error — but a
                                // panic here would poison the run with a
                                // hung merger; surface it through the
                                // abort path like any other worker error.
                                let Some(hybrid) = plan_ref.hybrid.as_ref() else {
                                    route_error_ref
                                        .lock()
                                        .unwrap_or_else(|p| p.into_inner())
                                        .get_or_insert_with(|| {
                                            "planner emitted an ER block without a hybrid \
                                             plan"
                                                .to_string()
                                        });
                                    aborted_ref.store(true, Ordering::Relaxed);
                                    break;
                                };
                                let (ci, nodes_i) = block(hybrid, src);
                                let (cj, nodes_j) = block(hybrid, dst);
                                let p = crate::kpgm::edge_probability(
                                    plan_ref.params.thetas(),
                                    ci as NodeId,
                                    cj as NodeId,
                                );
                                let mut rng = er_base_ref.fork(fork_id);
                                sample_er_block(nodes_i, nodes_j, p, &mut rng, &mut local);
                            }
                        }
                        // Route the job's edges to their shards in one
                        // pass (bounded channels give backpressure
                        // against slow merging), validating both ids as
                        // they are routed: a sampler emitting an
                        // out-of-range id must fail the run, not have
                        // the source clamped into the last shard.
                        let run = local.into_edges();
                        let job_edges = run.len() as u64;
                        let mut bad: Option<Edge> = None;
                        let mut closed_shard: Option<usize> = None;
                        if num_shards == 1 {
                            bad = run
                                .iter()
                                .find(|&&(s, t)| s as u64 >= n64 || t as u64 >= n64)
                                .copied();
                            if bad.is_none() && txs[0].send(ShardMsg::Batch(run)).is_err() {
                                closed_shard = Some(0);
                            }
                        } else {
                            let mut parts: Vec<Vec<Edge>> = vec![Vec::new(); num_shards];
                            for e in run {
                                match spec.checked_shard_of(e.0) {
                                    Some(si) if (e.1 as u64) < n64 => {
                                        debug_assert!(
                                            spans_ref[idx]
                                                .is_some_and(|(lo, hi)| (lo..=hi)
                                                    .contains(&si)),
                                            "edge {e:?} routed outside job {idx}'s span"
                                        );
                                        parts[si].push(e);
                                    }
                                    _ => {
                                        bad = Some(e);
                                        break;
                                    }
                                }
                            }
                            if bad.is_none() {
                                for (si, part) in parts.into_iter().enumerate() {
                                    if !part.is_empty()
                                        && txs[si].send(ShardMsg::Batch(part)).is_err()
                                    {
                                        closed_shard = Some(si);
                                        break;
                                    }
                                }
                            }
                        }
                        // A send can only fail if that merger already got
                        // its Close — i.e. the span accounting thought no
                        // contributing job remained. Silently dropping
                        // the batch would truncate the output; fail loud.
                        let error = match (bad, closed_shard) {
                            (Some((s, t)), _) => Some(format!(
                                "sampler emitted edge ({s}, {t}) with an id out of \
                                 range for {n} nodes"
                            )),
                            (None, Some(si)) => Some(format!(
                                "edge batch for shard {si} arrived after its merger \
                                 closed (job span accounting violated)"
                            )),
                            (None, None) => None,
                        };
                        if let Some(error) = error {
                            // A poisoned lock means a sibling panicked
                            // mid-report; recover the inner value — the
                            // first recorded error still wins.
                            route_error_ref
                                .lock()
                                .unwrap_or_else(|p| p.into_inner())
                                .get_or_insert(error);
                            aborted_ref.store(true, Ordering::Relaxed);
                            break;
                        }
                        // Every edge of this job is delivered: release
                        // its claim on the shards its sources can touch.
                        // The thread whose decrement empties a shard's
                        // count closes that merger — all contributing
                        // sends happened-before the close.
                        if let Some((lo, hi)) = spans_ref[idx] {
                            for s in lo..=hi {
                                if remaining_ref[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                                    let _ = txs[s].send(ShardMsg::Close);
                                }
                            }
                        }
                        trace_ref.emit(
                            "job_done",
                            &[("job", Fv::U(idx as u64)), ("edges", Fv::U(job_edges))],
                        );
                        if let Some(progress) = progress_ref {
                            progress.jobs_done.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
            drop(txs);

            // Consume finished shards the moment they finish — completion
            // order, not index order. The sink places each run at its
            // slot (or defers/spills it per its budget), so an
            // early-finishing late shard releases its memory immediately
            // instead of sitting in its merger until its turn.
            while let Ok((run, mut stats)) = done_rx.recv() {
                let index = stats.shard;
                if sink_result.is_ok() {
                    sink_result = sink
                        .begin_shard(index, run.len())
                        .and_then(|()| sink.accept_shard(index, run))
                        .map(|disposition| stats.record_disposition(disposition));
                    if sink_result.is_err() {
                        // The run is doomed (e.g. the output disk filled):
                        // stop the workers instead of sampling the rest of
                        // the job queue before reporting.
                        aborted.store(true, Ordering::Relaxed);
                    }
                }
                self.trace.emit(
                    "shard_seal",
                    &[
                        ("shard", Fv::U(index as u64)),
                        ("edges", Fv::U(stats.edges as u64)),
                        ("deferred", Fv::B(stats.deferred)),
                        ("spill_runs", Fv::U(stats.spill_runs)),
                        ("spill_bytes", Fv::U(stats.spill_bytes)),
                    ],
                );
                if let Some(progress) = self.progress.as_deref() {
                    progress.edges.fetch_add(stats.edges as u64, Ordering::Relaxed);
                    progress.shards_sealed.fetch_add(1, Ordering::Relaxed);
                    progress.bytes_written.fetch_add(stats.edges as u64 * 8, Ordering::Relaxed);
                }
                shard_stats.push(stats);
            }
            for handle in merger_handles {
                if handle.join().is_err() {
                    // Don't re-panic on the coordinator thread: record the
                    // failure so it surfaces as an error through the same
                    // path as routing errors, with the sink result intact.
                    route_error
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .get_or_insert_with(|| "a shard merger thread panicked".to_string());
                    aborted.store(true, Ordering::Relaxed);
                }
            }
        });
        let route_error = route_error.into_inner().unwrap_or_else(|p| p.into_inner());
        if let Some(msg) = route_error {
            return Err(io::Error::new(io::ErrorKind::InvalidData, msg));
        }
        sink_result?;
        // Stats were pushed in completion order; report them per shard.
        shard_stats.sort_by_key(|s| s.shard);

        let num_edges: u64 = shard_stats.iter().map(|s| s.edges as u64).sum();
        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        let stats = RunStats {
            partition_size,
            num_jobs,
            workers,
            num_shards,
            num_edges,
            wall_ms,
            edges_per_sec: num_edges as f64 / (wall_ms / 1e3).max(1e-9),
            dropped_resamples: dropped_total.into_inner(),
            spill: summarize_spill(&shard_stats),
            shard_stats,
            setup: plan.setup,
        };
        self.trace.emit(
            "run_done",
            &[
                ("edges", Fv::U(stats.num_edges)),
                ("shards", Fv::U(stats.num_shards as u64)),
                ("jobs", Fv::U(stats.num_jobs as u64)),
                ("dropped_resamples", Fv::U(stats.dropped_resamples)),
                ("wall_ms", Fv::F(stats.wall_ms)),
            ],
        );
        Ok((sink.finalize()?, stats))
    }
}

fn block(plan: &HybridPlan, r: BlockRef) -> (u64, &[NodeId]) {
    match r {
        BlockRef::Light(i) => (plan.light[i].0, &plan.light[i].1),
        BlockRef::Heavy(i) => (plan.heavy[i].0, &plan.heavy[i].1),
    }
}

/// Assemble the §5 hybrid job list: W-subset quilt pieces first, then the
/// ER blocks (heavy×heavy, then light↔heavy both directions) with
/// sequential fork ids. Shared by [`Coordinator::plan_hybrid`] and the
/// artifact hydration path so both derive bit-identical job streams.
fn assemble_hybrid_jobs(
    params: &MagmParams,
    partition: &Partition,
    plan: &HybridPlan,
) -> Vec<Job> {
    let mut jobs: Vec<Job> = QuiltSampler::new(params.clone())
        .plan(partition)
        .into_iter()
        .map(Job::Piece)
        .collect();
    let mut er_id = 0u64;
    for hi in 0..plan.heavy.len() {
        for hj in 0..plan.heavy.len() {
            jobs.push(Job::ErBlock {
                src: BlockRef::Heavy(hi),
                dst: BlockRef::Heavy(hj),
                fork_id: er_id,
            });
            er_id += 1;
        }
    }
    for li in 0..plan.light.len() {
        for hj in 0..plan.heavy.len() {
            jobs.push(Job::ErBlock {
                src: BlockRef::Light(li),
                dst: BlockRef::Heavy(hj),
                fork_id: er_id,
            });
            er_id += 1;
            jobs.push(Job::ErBlock {
                src: BlockRef::Heavy(hj),
                dst: BlockRef::Light(li),
                fork_id: er_id,
            });
            er_id += 1;
        }
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{BinaryFileSink, CountingSink};
    use crate::kpgm::Initiator;

    fn params(n: usize, d: u32, mu: f64) -> MagmParams {
        MagmParams::homogeneous(Initiator::THETA1, mu, n, d)
    }

    #[test]
    fn coordinated_equals_sequential_quilt() {
        // Same seed: the coordinator must produce exactly the edge set of
        // the single-threaded QuiltSampler.
        let p = params(256, 8, 0.5);
        let seq = QuiltSampler::new(p.clone()).seed(31).sample();
        let rep = Coordinator::new().workers(4).sample_quilt(&p, 31);
        let mut a = seq.into_edges();
        let mut b = rep.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn coordinated_equals_sequential_hybrid() {
        let p = params(300, 9, 0.85);
        let seq = HybridSampler::new(p.clone()).seed(37).sample();
        let rep = Coordinator::new().workers(3).sample_hybrid(&p, 37);
        let mut a = seq.into_edges();
        let mut b = rep.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn worker_count_does_not_change_result() {
        let p = params(128, 7, 0.7);
        let r1 = Coordinator::new().workers(1).sample_hybrid(&p, 5);
        let r8 = Coordinator::new().workers(8).sample_hybrid(&p, 5);
        let mut a = r1.graph.into_edges();
        let mut b = r8.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn report_metrics_populated() {
        let p = params(128, 7, 0.5);
        let rep = Coordinator::new().sample_quilt(&p, 1);
        assert!(rep.wall_ms > 0.0);
        assert!(rep.num_jobs >= rep.partition_size * rep.partition_size);
        assert!(rep.edges_per_sec > 0.0);
        assert!(rep.graph.validate().is_ok());
        // Healthy (unsaturated) runs abandon essentially no balls.
        assert!(rep.dropped_resamples <= 2, "dropped {}", rep.dropped_resamples);
    }

    #[test]
    fn rejection_mode_coordinated_equals_sequential() {
        let p = params(256, 8, 0.5);
        let seq =
            QuiltSampler::new(p.clone()).piece_mode(PieceMode::Rejection).seed(41).sample();
        let rep =
            Coordinator::new().workers(4).piece_mode(PieceMode::Rejection).sample_quilt(&p, 41);
        let mut a = seq.into_edges();
        let mut b = rep.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn cost_ordering_keeps_edge_set() {
        // The plan sorts pieces by restricted mass; the sampled edges must
        // be schedule-independent regardless.
        let p = params(200, 8, 0.7);
        let mut rng = Rng::new(3);
        let attrs = AttributeAssignment::sample(&p, &mut rng);
        let coord = Coordinator::new().workers(2);
        let plan = coord.plan_quilt(&p, &attrs, 3);
        assert_eq!(plan.piece_mode(), PieceMode::Conditioned);
        assert!(!plan.is_empty());
        // Costs must be non-increasing along the job queue.
        let costs: Vec<f64> = plan.jobs.iter().map(|j| plan.estimated_cost(j)).collect();
        assert!(costs.windows(2).all(|w| w[0] >= w[1]), "jobs not cost-ordered: {costs:?}");
        let rep = coord.run(plan);
        let seq = QuiltSampler::new(p).seed(3).sample_with_attrs(&attrs);
        let mut a = seq.into_edges();
        let mut b = rep.graph.into_edges();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn tiny_channel_capacity_still_completes() {
        // Backpressure path: capacity 1 forces workers to block on send.
        let p = params(256, 8, 0.5);
        let rep = Coordinator::new().workers(4).channel_capacity(1).sample_quilt(&p, 9);
        assert!(rep.graph.num_edges() > 0);
    }

    #[test]
    fn shard_worker_sweep_equals_sequential() {
        // The equivalence matrix: S ∈ {1, 3, 8} × workers ∈ {1, 4} must
        // reproduce the sequential samplers' edge lists bit-for-bit —
        // including order, since concatenated disjoint sorted shards are
        // the globally sorted list the sequential dedup produces.
        let pq = params(256, 8, 0.5);
        let seq_quilt = QuiltSampler::new(pq.clone()).seed(17).sample();
        let ph = params(300, 9, 0.85);
        let seq_hybrid = HybridSampler::new(ph.clone()).seed(23).sample();
        for shards in [1usize, 3, 8] {
            for workers in [1usize, 4] {
                let coord = Coordinator::new().workers(workers).shards(shards);
                let rep = coord.sample_quilt(&pq, 17);
                assert_eq!(rep.num_shards, shards);
                assert_eq!(rep.graph, seq_quilt, "quilt S={shards} workers={workers}");
                let rep = coord.sample_hybrid(&ph, 23);
                assert_eq!(rep.graph, seq_hybrid, "hybrid S={shards} workers={workers}");
            }
        }
    }

    #[test]
    fn sharded_output_is_sorted_without_final_pass() {
        let p = params(512, 9, 0.5);
        let rep = Coordinator::new().workers(4).shards(6).sample_quilt(&p, 13);
        assert!(
            rep.graph.edges().windows(2).all(|w| w[0] < w[1]),
            "concatenated shards must be strictly sorted"
        );
    }

    #[test]
    fn shard_stats_respect_streaming_memory_bound() {
        // The acceptance claim: no shard ever holds more than its
        // post-dedup size plus batch-sized merge overhead (the in-flight
        // batch and the merge's resize-by-batch scratch) — the pre-dedup
        // edge multiset is never materialized in a single buffer.
        let p = params(512, 9, 0.5);
        let rep = Coordinator::new().workers(4).shards(4).sample_quilt(&p, 13);
        assert_eq!(rep.shard_stats.len(), 4);
        let total: usize = rep.shard_stats.iter().map(|s| s.edges).sum();
        assert_eq!(total, rep.graph.num_edges());
        for s in &rep.shard_stats {
            assert!(
                s.peak_resident <= s.edges + 2 * s.max_batch,
                "shard {}: peak {} > {} + 2 * {}",
                s.shard,
                s.peak_resident,
                s.edges,
                s.max_batch
            );
        }
    }

    #[test]
    fn counting_sink_matches_collected_graph() {
        let p = params(256, 8, 0.6);
        let coord = Coordinator::new().workers(3).shards(3);
        let rep = coord.sample_quilt(&p, 29);
        let (counts, stats) =
            coord.sample_quilt_with_sink(&p, 29, CountingSink::new()).unwrap();
        assert_eq!(counts.num_edges, rep.graph.num_edges() as u64);
        assert_eq!(counts.self_loops, rep.graph.num_self_loops() as u64);
        assert_eq!(counts.out_degrees, rep.graph.out_degrees());
        assert_eq!(counts.in_degrees, rep.graph.in_degrees());
        assert_eq!(stats.num_edges, counts.num_edges);
    }

    #[test]
    fn binary_file_sink_matches_collect_sink() {
        let dir = std::env::temp_dir().join("magquilt_pool_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("coordinated.bin");
        let p = params(300, 9, 0.85);
        let coord = Coordinator::new().workers(4).shards(5);
        let rep = coord.sample_hybrid(&p, 41);
        let (written, _) = coord
            .sample_hybrid_with_sink(&p, 41, BinaryFileSink::create(&path))
            .unwrap();
        assert_eq!(written, rep.graph.num_edges() as u64);
        let back = crate::graph::read_edge_list_binary(&path).unwrap();
        assert_eq!(back, rep.graph);
    }

    #[test]
    fn setup_threads_do_not_change_result() {
        // The whole setup pipeline is deterministic in the seed: any
        // setup-thread count must yield the exact same graph.
        let p = params(256, 8, 0.5);
        let base = Coordinator::new().workers(2).sample_quilt(&p, 19);
        for st in [1usize, 2, 8] {
            let rep = Coordinator::new().workers(2).setup_threads(st).sample_quilt(&p, 19);
            assert_eq!(rep.graph, base.graph, "setup_threads={st}");
            assert_eq!(rep.setup.setup_threads, st);
            let rep = Coordinator::new().workers(2).setup_threads(st).sample_hybrid(&p, 19);
            let bh = Coordinator::new().workers(2).sample_hybrid(&p, 19);
            assert_eq!(rep.graph, bh.graph, "hybrid setup_threads={st}");
        }
    }

    #[test]
    fn chunked_setup_pipeline_equivalence_sweep() {
        // n above 2 × the partition chunk so the prefix-sum build and the
        // sharded trie merge actually engage; a sparse theta keeps piece
        // sampling near-empty so the test isolates the setup pipeline.
        let theta = Initiator::new([0.05, 0.15, 0.15, 0.25]);
        let p = MagmParams::homogeneous(theta, 0.5, 20_000, 14);
        let mut graphs = Vec::new();
        for st in [1usize, 2, 8] {
            let rep = Coordinator::new()
                .workers(2)
                .setup_threads(st)
                .attr_mode(AttrSampleMode::Chunked)
                .sample_quilt(&p, 11);
            assert_eq!(rep.setup.attr_mode, AttrSampleMode::Chunked);
            graphs.push(rep.graph);
        }
        assert_eq!(graphs[0], graphs[1]);
        assert_eq!(graphs[0], graphs[2]);
        // And the coordinated result equals the sequential sampler fed
        // the same chunked assignment.
        let attrs = AttributeAssignment::sample_chunked(&p, &Rng::new(11), 1);
        let seq = QuiltSampler::new(p).seed(11).sample_with_attrs(&attrs);
        assert_eq!(graphs[0], seq);
    }

    #[test]
    fn chunked_hybrid_matches_sequential() {
        let p = params(300, 9, 0.85);
        let coord =
            Coordinator::new().workers(3).setup_threads(4).attr_mode(AttrSampleMode::Chunked);
        let rep = coord.sample_hybrid(&p, 23);
        let attrs = AttributeAssignment::sample_chunked(&p, &Rng::new(23), 1);
        let seq = HybridSampler::new(p).seed(23).sample_with_attrs(&attrs);
        assert_eq!(rep.graph, seq);
    }

    #[test]
    fn setup_stats_populated() {
        let p = params(256, 8, 0.5);
        let rep = Coordinator::new().workers(3).sample_quilt(&p, 7);
        // Conditioned mode builds tries + DAG; every phase was timed.
        assert!(rep.setup.attrs_ms > 0.0);
        assert!(rep.setup.partition_ms > 0.0);
        assert!(rep.setup.trie_ms > 0.0);
        assert!(rep.setup.dag_ms > 0.0);
        assert_eq!(rep.setup.setup_threads, 3, "auto setup threads follow workers");
        assert_eq!(rep.setup.attr_mode, AttrSampleMode::Sequential);
        // Rejection mode skips the conditioner entirely.
        let rep = Coordinator::new()
            .workers(2)
            .piece_mode(PieceMode::Rejection)
            .sample_quilt(&p, 7);
        assert_eq!(rep.setup.trie_ms, 0.0);
        assert_eq!(rep.setup.dag_ms, 0.0);
    }

    #[test]
    fn auto_shards_defaults_to_workers() {
        let p = params(128, 7, 0.5);
        let rep = Coordinator::new().workers(3).sample_quilt(&p, 1);
        assert_eq!(rep.num_shards, 3);
        let rep = Coordinator::new().workers(3).shards(2).sample_quilt(&p, 1);
        assert_eq!(rep.num_shards, 2);
    }

    #[test]
    fn tiny_graph_clamps_effective_shards() {
        // More shards than nodes used to run (and report stats for)
        // empty trailing mergers; the effective count is min(S, n) and
        // the sampled graph is unchanged.
        let p = params(4, 3, 0.5);
        let rep = Coordinator::new().workers(2).shards(8).sample_quilt(&p, 3);
        assert_eq!(rep.num_shards, 4);
        assert_eq!(rep.shard_stats.len(), 4);
        let seq = QuiltSampler::new(p).seed(3).sample();
        assert_eq!(rep.graph, seq);
    }

    #[test]
    fn collect_runs_report_zero_spill() {
        // The in-memory sink may defer out-of-order shards (held in
        // `pending` until the frontier reaches them) but never touches
        // disk: the spill columns must stay zero.
        let p = params(256, 8, 0.5);
        let rep = Coordinator::new().workers(4).shards(4).sample_quilt(&p, 9);
        assert_eq!(rep.spill.spilled_shards, 0);
        assert_eq!(rep.spill.spill_runs, 0);
        assert_eq!(rep.spill.spill_bytes, 0);
        assert!(rep.shard_stats.iter().all(|s| s.spill_runs == 0 && s.spill_bytes == 0));
    }

    fn spec(log2_nodes: u32, attributes: u32, mu: f64) -> ModelSpec {
        let mut m = ModelSpec::default_spec();
        m.log2_nodes = log2_nodes;
        m.attributes = attributes;
        m.mu = mu;
        m
    }

    fn spec_params(m: &ModelSpec) -> MagmParams {
        MagmParams::homogeneous(Initiator::new(m.theta), m.mu, m.num_nodes(), m.attributes)
    }

    #[test]
    fn artifact_hydrated_equals_fresh_setup_sweep() {
        // The tentpole guarantee: a coordinator hydrated from a (wire
        // round-tripped) setup artifact produces bit-for-bit the output
        // of one that ran fresh setup — for both samplers, both piece
        // modes, and every shard/worker combination.
        for sampler in [SamplerKind::Quilt, SamplerKind::Hybrid] {
            for mode in [PieceMode::Conditioned, PieceMode::Rejection] {
                let m = spec(8, 8, if sampler == SamplerKind::Hybrid { 0.85 } else { 0.5 });
                let p = spec_params(&m);
                let art =
                    Coordinator::new().piece_mode(mode).build_setup(&m, 51, sampler).unwrap();
                // Hydrate from decoded bytes so the sweep exercises the
                // wire format end to end, not just the in-memory struct.
                let art = SetupArtifact::from_bytes(&art.to_bytes()).unwrap();
                for shards in [1usize, 2, 4] {
                    for workers in [1usize, 2, 4] {
                        let tag = format!("{sampler:?}/{mode:?} S={shards} W={workers}");
                        let coord =
                            Coordinator::new().workers(workers).shards(shards).piece_mode(mode);
                        let fresh = match sampler {
                            SamplerKind::Quilt => coord.sample_quilt(&p, 51),
                            _ => coord.sample_hybrid(&p, 51),
                        };
                        assert_eq!(fresh.setup.artifact_hash, 0, "fresh run, no hash ({tag})");
                        let rep = coord.sample_with_artifact(art.clone(), 1.5).unwrap();
                        assert_eq!(rep.graph, fresh.graph, "{tag}");
                        // Hydration skipped the pipeline and says so.
                        assert_eq!(rep.setup.artifact_hash, art.hash64(), "{tag}");
                        assert_eq!(rep.setup.artifact_load_ms, 1.5, "{tag}");
                        assert_eq!(rep.setup.partition_ms, 0.0, "{tag}");
                        assert_eq!(rep.setup.dag_ms, 0.0, "{tag}");
                    }
                }
            }
        }
    }

    #[test]
    fn artifact_mode_mismatches_are_rejected() {
        let m = spec(7, 6, 0.5);
        let art = Coordinator::new().build_setup(&m, 5, SamplerKind::Quilt).unwrap();
        let err = Coordinator::new()
            .piece_mode(PieceMode::Rejection)
            .plan_from_artifact(art.clone(), 0.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("piece mode"), "{err}");
        let err = Coordinator::new()
            .attr_mode(AttrSampleMode::Chunked)
            .plan_from_artifact(art, 0.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("attr mode"), "{err}");
        let err =
            Coordinator::new().build_setup(&m, 5, SamplerKind::Naive).unwrap_err().to_string();
        assert!(err.contains("naive"), "{err}");
    }

    #[test]
    fn artifact_sink_run_matches_collected() {
        let m = spec(8, 8, 0.5);
        let art = Coordinator::new().build_setup(&m, 9, SamplerKind::Quilt).unwrap();
        let coord = Coordinator::new().workers(2).shards(2);
        let rep = coord.sample_with_artifact(art.clone(), 0.0).unwrap();
        let (counts, stats) =
            coord.sample_with_artifact_sink(art, 0.0, CountingSink::new()).unwrap();
        assert_eq!(counts.num_edges, rep.graph.num_edges() as u64);
        assert_eq!(stats.setup.artifact_hash, rep.setup.artifact_hash);
    }

    #[test]
    fn forced_spill_out_of_order_equivalence_sweep() {
        // The acceptance matrix for the out-of-order/spill path: with a
        // zero in-memory budget every shard that finishes ahead of the
        // file frontier goes through a spill file, and the binary,
        // collect, and counting outputs must still be bit-for-bit the
        // sequential sampler's — for S × workers × both piece modes.
        let dir = std::env::temp_dir().join("magquilt_pool_spill_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = params(256, 8, 0.5);
        for mode in [PieceMode::Conditioned, PieceMode::Rejection] {
            let seq = QuiltSampler::new(p.clone()).piece_mode(mode).seed(61).sample();
            for shards in [1usize, 3, 8] {
                for workers in [1usize, 4] {
                    let tag = format!("{mode:?} S={shards} workers={workers}");
                    let coord =
                        Coordinator::new().workers(workers).shards(shards).piece_mode(mode);
                    let rep = coord.sample_quilt(&p, 61);
                    assert_eq!(rep.graph, seq, "collect {tag}");
                    // Merger residency bound is unchanged by delivery order.
                    for s in &rep.shard_stats {
                        assert!(
                            s.peak_resident <= s.edges + 2 * s.max_batch,
                            "residency {tag} shard {}",
                            s.shard
                        );
                    }
                    let path = dir.join(format!(
                        "sweep_{}_{shards}_{workers}.bin",
                        if mode == PieceMode::Conditioned { "cond" } else { "rej" }
                    ));
                    let sink =
                        BinaryFileSink::create(&path).spill_dir(&dir).spill_budget(0);
                    let (written, stats) = coord.sample_quilt_with_sink(&p, 61, sink).unwrap();
                    assert_eq!(written, seq.num_edges() as u64, "binary count {tag}");
                    let back = crate::graph::read_edge_list_binary(&path).unwrap();
                    assert_eq!(back, seq, "binary re-read {tag}");
                    // Spill accounting is consistent between the summary
                    // and the per-shard columns.
                    assert_eq!(
                        stats.spill.spilled_shards,
                        stats.shard_stats.iter().filter(|s| s.spill_runs > 0).count(),
                        "spill summary {tag}"
                    );
                    let (counts, _) =
                        coord.sample_quilt_with_sink(&p, 61, CountingSink::new()).unwrap();
                    assert_eq!(counts.num_edges, seq.num_edges() as u64, "counting {tag}");
                    assert_eq!(counts.out_degrees, seq.out_degrees(), "out-degrees {tag}");
                    assert_eq!(counts.in_degrees, seq.in_degrees(), "in-degrees {tag}");
                }
            }
        }
    }

    #[test]
    fn tracing_does_not_change_output_sweep() {
        // The telemetry acceptance matrix: with a live trace stream and
        // progress counters attached, the sampled graph must stay
        // byte-identical to the untraced run — for both samplers, both
        // piece modes, and several shard/worker shapes.
        let pq = params(256, 8, 0.5);
        let ph = params(300, 9, 0.85);
        for mode in [PieceMode::Conditioned, PieceMode::Rejection] {
            for (shards, workers) in [(1usize, 1usize), (3, 4), (8, 2)] {
                let tag = format!("{mode:?} S={shards} W={workers}");
                let plain = Coordinator::new().workers(workers).shards(shards).piece_mode(mode);
                let traced = plain
                    .clone()
                    .trace(TraceHandle::new("equiv", "run", None))
                    .progress(Arc::new(ProgressState::new()));
                assert_eq!(
                    plain.sample_quilt(&pq, 71).graph,
                    traced.sample_quilt(&pq, 71).graph,
                    "quilt {tag}"
                );
                assert_eq!(
                    plain.sample_hybrid(&ph, 73).graph,
                    traced.sample_hybrid(&ph, 73).graph,
                    "hybrid {tag}"
                );
            }
        }
    }

    #[test]
    fn trace_streams_are_deterministic_across_runs() {
        // Two same-seed runs race their workers differently, but after
        // stripping the hash-exempt fields (seq, timings, ...) the
        // canonicalized streams must be identical.
        let p = params(256, 8, 0.5);
        let mut canonical = Vec::new();
        for _ in 0..2 {
            let trace = TraceHandle::new("det", "run", None);
            let coord = Coordinator::new().workers(4).shards(3).trace(trace.clone());
            let rep = coord.sample_quilt(&p, 83);
            assert!(rep.graph.num_edges() > 0);
            let lines = trace.lines();
            for name in [
                "\"event\":\"setup\"",
                "\"event\":\"job_plan\"",
                "\"event\":\"shard_seal\"",
                "\"event\":\"run_done\"",
            ] {
                assert!(lines.iter().any(|l| l.contains(name)), "missing {name}");
            }
            canonical.push(crate::trace::canonical_stream(&lines));
        }
        assert_eq!(canonical[0], canonical[1], "canonical trace streams diverged");
    }

    #[test]
    fn progress_counters_track_the_run() {
        let p = params(256, 8, 0.5);
        let progress = Arc::new(ProgressState::new());
        let coord = Coordinator::new().workers(3).shards(3).progress(progress.clone());
        let rep = coord.sample_quilt(&p, 91);
        let snap = progress.snapshot();
        assert_eq!(snap.jobs_total, rep.num_jobs as u64);
        assert_eq!(snap.jobs_done, rep.num_jobs as u64);
        assert_eq!(snap.edges, rep.graph.num_edges() as u64);
        assert_eq!(snap.shards_sealed, rep.num_shards as u64);
        assert_eq!(snap.bytes_written, rep.graph.num_edges() as u64 * 8);
    }
}
