//! Command-line interface (hand-rolled; the vendored crate set has no
//! clap).
//!
//! ```text
//! magquilt generate [--config F] [--log2-nodes N] [--attributes D]
//!                   [--mu MU] [--theta a,b,c,d] [--sampler KIND]
//!                   [--piece-mode MODE] [--seed S] [--workers W]
//!                   [--shards S] [--setup-threads T] [--attr-mode MODE]
//!                   [--sink KIND] [--output PATH] [--spill-dir DIR]
//!                   [--spill-budget BYTES] [--binary] [--stats]
//! magquilt sample …         (alias of generate; accepts --out for --output;
//!                   add --dist-workers W for a multi-process run with
//!                   [--worker-retries R] [--worker-backoff-ms MS];
//!                   add --artifact F to reuse — or build and persist —
//!                   the setup prologue)
//! magquilt setup [model/run flags | --plan F] [--out F]
//! magquilt artifact info <file>
//! magquilt shard-plan [model/run flags] --dist-workers W [--plan-out F]
//! magquilt shard-worker --plan F --worker I [--segment-dir DIR]
//!                   [--resume] [--artifact F] [--inject-fault SPEC]
//! magquilt merge-segments --segments DIR [--plan F] --out PATH
//!                   [--merge-threads T] [--spill-budget BYTES]
//!                   [--remove-segments]
//! magquilt doctor <segment dir> [--plan F] [--fix]
//! magquilt stats <edge-list file | segment dir | setup artifact>
//! magquilt top <segment dir> [--plan F]
//! magquilt report <report.json> [--compare OTHER]
//! magquilt experiment <fig1|fig5|...|fig14|all> [--max-log2n N]
//!                   [--naive-max-log2n N] [--trials T] [--seed S]
//!                   [--out DIR]
//! magquilt artifacts-check [--dir DIR]
//! magquilt info
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{load_config, parse_attr_mode, parse_piece_mode, ModelSpec, RunSpec,
                    SamplerKind};
use crate::coordinator::{Coordinator, RunStats};
use crate::dist::{self, ShardPlan};
use crate::experiments::{run_experiment, Scale, ALL_EXPERIMENTS};
use crate::graph::{read_edge_list_binary, read_edge_list_text, write_edge_list_binary,
                   write_edge_list_text, BinaryFileSink, CountingSink, EdgeList, BINARY_MAGIC};
use crate::kpgm::Initiator;
use crate::magm::{AttributeAssignment, MagmParams};
use crate::rng::Rng;
use crate::stats::summarize;
use crate::trace::console;
use crate::trace::progress::ProgressState;
use crate::trace::report::{compare, pretty, sample_report, validate_report};
use crate::trace::TraceHandle;

/// Parsed flags: positional args plus `--key value` / `--flag` options.
#[derive(Debug, Default)]
pub struct Args {
    positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from raw argv (excluding the program and subcommand names).
    /// `bool_flags` lists options that take no value.
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if bool_flags.contains(&key) {
                    args.flags.push(key.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{key} needs a value"))?;
                    args.options.insert(key.to_string(), v.clone());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    /// Positional argument by index.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// Option value as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option parsed to a type.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    /// Whether a boolean flag was passed.
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Usage text.
pub const USAGE: &str = "\
magquilt — quilting sampler for Multiplicative Attribute Graphs
           (Yun & Vishwanathan, AISTATS 2012)

USAGE:
    magquilt generate [--config F] [--log2-nodes N] [--attributes D]
                      [--mu MU] [--theta a,b,c,d] [--sampler KIND]
                      [--piece-mode MODE] [--seed S] [--workers W]
                      [--shards S] [--setup-threads T] [--attr-mode MODE]
                      [--sink KIND] [--output PATH] [--spill-dir DIR]
                      [--spill-budget BYTES] [--binary] [--stats]
                      [--trace F] [--report F]
    magquilt sample   … (alias of generate; --out is accepted for --output)
    magquilt sample   --dist-workers W --out PATH [--segment-dir DIR]
                      [--worker-retries R] [--worker-backoff-ms MS] …
                      (distributed: spawn W supervised local worker
                      processes, restart crashed/stalled ones in place,
                      merge — bit-for-bit the single-process file)
    magquilt setup    [model/run flags | --plan F] [--out F]
                      (build the deterministic setup prologue once and
                      persist it as a content-addressed .art file)
    magquilt artifact info <file>
    magquilt shard-plan [model/run flags] --dist-workers W [--plan-out F]
    magquilt shard-worker --plan F --worker I [--segment-dir DIR]
                      [--resume] [--artifact F] [--inject-fault SPEC]
                      [--trace] [--report]
    magquilt merge-segments --segments DIR [--plan F] --out PATH
                      [--merge-threads T] [--spill-budget BYTES]
                      [--remove-segments] [--trace F] [--report F]
    magquilt doctor <segment dir> [--plan F] [--fix]
    magquilt stats <edge-list file | segment dir | setup artifact>
    magquilt top <segment dir> [--plan F]
    magquilt report <report.json> [--compare OTHER]
    magquilt experiment <id|all> [--max-log2n N] [--naive-max-log2n N]
                      [--trials T] [--seed S] [--out DIR]
    magquilt artifacts-check [--dir DIR]
    magquilt info

SAMPLERS: quilt (Algorithm 2) | hybrid (§5) | naive | naive-xla
PIECE MODES: conditioned (rejection-free, default) | rejection (paper-literal)
ATTR MODES: sequential (legacy stream; the single-process default)
       | chunked (parallel setup, bit-for-bit stable across any
         --setup-threads count; the default inside --dist-workers runs)
SINKS: collect (in-memory, default) | counting (degrees only, no graph)
       | binary (stream shards straight to the binary file at --output;
         a shard finishing ahead of the file frontier is held within
         --spill-budget BYTES of memory [default 256 MiB] then spilled to
         temp files in --spill-dir [default: next to the output] and
         concatenated into its slot when the frontier catches up)
DISTRIBUTED: one plan manifest seals the run (`shard-plan`); each worker
       process owns a contiguous shard range and writes per-shard MAGQEDG1
       segment files plus overflow runs for foreign shards
       (`shard-worker`, safe to run on separate hosts against a shared or
       collected --segment-dir); `merge-segments` folds them into one
       output identical to the single-process sampler, merging shards on
       --merge-threads T worker threads (0 = auto; byte-identical for
       every count); `stats <dir>` inspects a segment directory before
       merging. `sample --dist-workers W` runs plan → workers → merge
       locally, supervised: a crashed or stalled worker is restarted with
       --resume (up to --worker-retries R times, backoff doubling from
       --worker-backoff-ms MS), and a restarted worker skips every shard
       whose output is already durable — the merged file is byte-identical
       either way. `doctor <dir> [--fix]` classifies every file in a
       segment directory (complete / truncated / stale temp / foreign
       plan / orphaned overflow / stale marker) and repairs or
       quarantines; `shard-worker --inject-fault SPEC` (or
       `sample --inject-fault SPEC@wN`) deterministically crashes a
       chosen write window for testing — see docs/fault-tolerance.md.
SETUP ARTIFACTS: the deterministic prologue (attributes, partition,
       tries, product DAG) can be built once (`setup`) into a
       content-addressed MAGQART1 file and reused: `sample --artifact F`
       loads it (building and persisting on first use) and skips every
       setup phase; `sample --dist-workers W --artifact F` hands it to
       all workers; `shard-worker --artifact F` hydrates instead of
       re-running setup; `artifact info F` (and `stats F`) describe a
       file. Artifacts are cross-checked by identity hash before use —
       a stale or mismatched file is an error, never silent drift — and
       hydrated runs are bit-for-bit identical to fresh ones. See
       docs/setup-artifact.md.
TELEMETRY: every run kind can leave machine-readable telemetry, all of it
       write-only (the lint's trace-sink invariant): `--trace F` lands a
       structured MAGQTRC1 JSONL event stream, `--report F` a MAGQRPT1
       report.json; output bytes are identical with telemetry on or off.
       `sample --dist-workers W --trace F --report F` makes every worker
       write its own stream, absorbs them into one driver trace, and
       composes worker reports + the merge outcome into one driver
       report; the driver also prints a throttled live `progress:` line
       aggregated from the workers' heartbeats. `top <segment dir>`
       renders that same fleet view on demand from any host that sees
       the directory; `report <file> [--compare OTHER]` pretty-prints or
       field-diffs report.json files. See docs/observability.md.
EXPERIMENTS: fig1 fig5 fig6 fig7 fig8 fig9 fig10 fig11 fig12 fig13 fig14 | all
";

/// Entry point called by main; returns the process exit code.
pub fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd {
        "generate" | "sample" => cmd_generate(rest),
        "setup" => cmd_setup(rest),
        "artifact" => cmd_artifact(rest),
        "shard-plan" => cmd_shard_plan(rest),
        "shard-worker" => cmd_shard_worker(rest),
        "merge-segments" => cmd_merge_segments(rest),
        "doctor" => cmd_doctor(rest),
        "stats" => cmd_stats(rest),
        "top" => cmd_top(rest),
        "report" => cmd_report(rest),
        "experiment" => cmd_experiment(rest),
        "artifacts-check" => cmd_artifacts_check(rest),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Build model/run specs from a config file and/or CLI overrides.
fn specs_from_args(args: &Args) -> Result<(ModelSpec, RunSpec)> {
    let (mut model, mut run) = match args.get("config") {
        Some(path) => load_config(Path::new(path))?,
        None => (ModelSpec::default_spec(), RunSpec::default_spec()),
    };
    if let Some(v) = args.get_parsed::<u32>("log2-nodes")? {
        model.log2_nodes = v;
        if args.get("attributes").is_none() {
            model.attributes = v;
        }
    }
    if let Some(v) = args.get_parsed::<u32>("attributes")? {
        model.attributes = v;
    }
    if let Some(v) = args.get_parsed::<f64>("mu")? {
        model.mu = v;
    }
    if let Some(t) = args.get("theta") {
        let parts: Vec<f64> = t
            .split(',')
            .map(|x| x.trim().parse::<f64>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|e| anyhow!("--theta: {e}"))?;
        if parts.len() != 4 {
            bail!("--theta needs 4 comma-separated entries (row-major 2x2)");
        }
        model.theta = [parts[0], parts[1], parts[2], parts[3]];
    }
    if let Some(v) = args.get_parsed::<u64>("seed")? {
        run.seed = v;
    }
    if let Some(v) = args.get_parsed::<usize>("workers")? {
        run.workers = v;
    }
    if let Some(v) = args.get_parsed::<usize>("shards")? {
        run.shards = v;
    }
    if let Some(v) = args.get_parsed::<usize>("setup-threads")? {
        run.setup_threads = v;
    }
    if let Some(s) = args.get("attr-mode") {
        run.attr_mode = Some(parse_attr_mode(s)?);
    }
    if let Some(s) = args.get("sampler") {
        run.sampler = SamplerKind::parse(s)?;
    }
    if let Some(s) = args.get("piece-mode") {
        run.piece_mode = parse_piece_mode(s)?;
    }
    if let Some(o) = args.get("output").or_else(|| args.get("out")) {
        run.output = Some(o.to_string());
    }
    if let Some(d) = args.get("spill-dir") {
        run.spill_dir = Some(d.to_string());
    }
    if let Some(b) = args.get_parsed::<u64>("spill-budget")? {
        run.spill_budget = Some(b);
    }
    if let Some(w) = args.get_parsed::<usize>("dist-workers")? {
        run.dist_workers = w;
    }
    if let Some(d) = args.get("segment-dir") {
        run.segment_dir = Some(d.to_string());
    }
    if let Some(t) = args.get_parsed::<usize>("merge-threads")? {
        run.merge_threads = t;
    }
    if let Some(r) = args.get_parsed::<usize>("worker-retries")? {
        run.worker_retries = r;
    }
    if let Some(b) = args.get_parsed::<u64>("worker-backoff-ms")? {
        run.worker_backoff_ms = b;
    }
    if let Some(a) = args.get("artifact") {
        run.artifact = Some(a.to_string());
    }
    if let Some(t) = args.get("trace") {
        run.trace = Some(t.to_string());
    }
    if let Some(r) = args.get("report") {
        run.report = Some(r.to_string());
    }
    model.validate()?;
    Ok((model, run))
}

/// Telemetry outputs of one single-process run: the trace handle the
/// coordinator writes through, plus where the files land at the end.
/// Both default off; the sampled output is byte-identical either way.
struct RunTelemetry {
    trace: TraceHandle,
    trace_path: Option<PathBuf>,
    report_path: Option<PathBuf>,
    run_id: String,
}

impl RunTelemetry {
    /// Deterministic run id — descriptive and stable across reruns (no
    /// clocks, no pids), so traces of identical runs compare equal.
    fn new(model: &ModelSpec, run: &RunSpec) -> RunTelemetry {
        let run_id = format!(
            "sample-n{}-d{}-seed{}-{}",
            model.log2_nodes,
            model.attributes,
            run.seed,
            run.sampler.name()
        );
        let trace_path = run.trace.as_ref().map(PathBuf::from);
        let trace = if trace_path.is_some() {
            TraceHandle::new(&run_id, "sample", None)
        } else {
            TraceHandle::disabled()
        };
        let report_path = run.report.as_ref().map(PathBuf::from);
        RunTelemetry { trace, trace_path, report_path, run_id }
    }

    fn enabled(&self) -> bool {
        self.trace_path.is_some() || self.report_path.is_some()
    }

    /// Land the trace stream and `report.json` (whichever were asked
    /// for) now that the run's statistics exist.
    fn finish(&self, stats: &RunStats) -> Result<()> {
        if let Some(path) = &self.trace_path {
            ensure_parent_dir(path)?;
            self.trace.write_to(path)?;
            eprintln!("trace: wrote {}", path.display());
        }
        if let Some(path) = &self.report_path {
            write_report_file(path, &sample_report(&self.run_id, stats))?;
        }
        Ok(())
    }
}

/// Atomically write one rendered `report.json`.
fn write_report_file(path: &Path, body: &str) -> Result<()> {
    ensure_parent_dir(path)?;
    let (dir, name) = crate::trace::split_dir_name(path)
        .ok_or_else(|| anyhow!("report path {} has no file name", path.display()))?;
    crate::graph::write_atomic(&dir, &name, body.as_bytes())
        .with_context(|| format!("writing report {}", path.display()))?;
    eprintln!("report: wrote {}", path.display());
    Ok(())
}

/// Convert a ModelSpec into library parameters.
pub fn model_params(model: &ModelSpec) -> MagmParams {
    MagmParams::homogeneous(
        Initiator::new(model.theta),
        model.mu,
        model.num_nodes(),
        model.attributes,
    )
}

fn cmd_generate(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["binary", "stats"])?;
    let (model, run) = specs_from_args(&args)?;
    let params = model_params(&model);
    let sink = args.get("sink").unwrap_or("collect");
    eprintln!(
        "model: n=2^{} d={} mu={} theta={:?} | sampler={} pieces={} attrs={} seed={} sink={}",
        model.log2_nodes,
        model.attributes,
        model.mu,
        model.theta,
        run.sampler.name(),
        run.piece_mode.name(),
        run.attr_mode.map_or("auto", |m| m.name()),
        run.seed,
        if run.dist_workers > 0 { "dist-segments" } else { sink },
    );
    if run.dist_workers > 0 {
        return cmd_generate_dist(&args, &model, &run);
    }
    match sink {
        "collect" => cmd_generate_collect(&args, &model, &params, &run),
        "counting" => cmd_generate_counting(&model, &params, &run),
        "binary" => cmd_generate_binary(&args, &model, &params, &run),
        other => bail!("unknown sink {other:?} (expected collect|counting|binary)"),
    }
}

/// Distributed driver: build the plan, spawn one local `shard-worker`
/// process per worker, supervise them (bounded retries with backoff,
/// stall detection, resume-on-restart), merge their segments into the
/// output, and drain the segment directory. The result is bit-for-bit
/// the single-process binary sink's file for the same plan.
fn cmd_generate_dist(args: &Args, model: &ModelSpec, run: &RunSpec) -> Result<()> {
    if let Some(sink) = args.get("sink") {
        if sink != "binary" {
            bail!("--dist-workers writes the binary format; --sink {sink} is not supported");
        }
    }
    if args.has_flag("stats") {
        bail!("--stats needs the collect sink; run `magquilt stats <file>` on the output");
    }
    let out = run
        .output
        .as_deref()
        .ok_or_else(|| anyhow!("--dist-workers needs --output (or --out) <path>"))?;
    let out = Path::new(out);
    ensure_parent_dir(out)?;
    let plan = ShardPlan::new(model, run, run.dist_workers)?;
    let segment_dir = match &run.segment_dir {
        Some(d) => PathBuf::from(d),
        None => {
            let mut os = out.as_os_str().to_os_string();
            os.push(".segments");
            PathBuf::from(os)
        }
    };
    let exe =
        std::env::current_exe().context("locating the magquilt binary to spawn workers")?;
    let mut opts = dist::SuperviseOptions::from_plan(&plan);
    if let Some(p) = &run.artifact {
        let path = PathBuf::from(p);
        if path.exists() {
            // Validate once in the driver: one clear error beats W
            // identical worker failures.
            let artifact = crate::setup::SetupArtifact::load(&path)?;
            artifact.check_matches(&crate::setup::ArtifactHeader::from_plan(&plan))?;
            eprintln!("artifact: workers will load {} ({})", path.display(), artifact.hash_hex());
        } else {
            let artifact = dist::build_plan_artifact(&plan)?;
            ensure_parent_dir(&path)?;
            artifact.save(&path)?;
            eprintln!(
                "artifact: built and wrote {} ({}) — workers will load it",
                path.display(),
                artifact.hash_hex()
            );
        }
        opts.artifact = Some(path);
    }
    if let Some(spec) = args.get("inject-fault") {
        let (fault, target) = dist::parse_driver_fault(spec)?;
        let target = target.ok_or_else(|| {
            anyhow!("driver-level --inject-fault needs a target worker: {spec}@wN")
        })?;
        opts.fault = Some((target, fault.spec().to_string()));
    }
    eprintln!(
        "dist: plan {} | {} worker process(es) x {} shard(s), segments in {} \
         (retries {}, backoff {} ms)",
        plan.hash_hex(),
        plan.num_workers(),
        plan.num_shards,
        segment_dir.display(),
        opts.retries,
        opts.backoff_ms,
    );
    // Live fleet progress: the supervisor aggregates the workers'
    // heartbeat payloads into a throttled `progress:` line. Telemetry
    // files are opt-in; the merged output is byte-identical either way.
    opts.live_progress = true;
    let telemetry = dist::DistTelemetry {
        trace: run.trace.as_ref().map(PathBuf::from),
        report: run.report.as_ref().map(PathBuf::from),
    };
    if let Some(p) = &telemetry.trace {
        ensure_parent_dir(p)?;
    }
    if let Some(p) = &telemetry.report {
        ensure_parent_dir(p)?;
    }
    let start = std::time::Instant::now();
    let report =
        dist::run_distributed_telemetry(&plan, &segment_dir, out, &exe, &opts, &telemetry)?;
    let ms = start.elapsed().as_secs_f64() * 1e3;
    if report.restarts > 0 {
        println!("{}", console::dist_restart_line(report.restarts));
    }
    println!(
        "{}",
        console::dist_merged_line(
            report.merge.shards.len(),
            report.workers,
            report.merge.overflow_runs() as u64,
            report.merge.duplicates_dropped(),
        )
    );
    println!(
        "{}",
        console::merge_line(
            report.merge.merge_ms,
            report.merge.merge_threads,
            report.merge.deferred_shards,
            report.merge.spilled_shards,
        )
    );
    println!(
        "wrote {} ({} edges, {:.1} ms total)",
        out.display(),
        report.merge.total_edges,
        ms
    );
    if let Some(p) = &telemetry.trace {
        eprintln!("trace: wrote {}", p.display());
    }
    if let Some(p) = &telemetry.report {
        eprintln!("report: wrote {}", p.display());
    }
    Ok(())
}

/// Generate (and print) a plan manifest for a multi-host run, plus the
/// exact per-host worker commands — the runbook in executable form.
fn cmd_shard_plan(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    let (model, run) = specs_from_args(&args)?;
    if run.dist_workers == 0 {
        bail!("shard-plan needs --dist-workers W (or run.dist_workers in --config)");
    }
    let plan = ShardPlan::new(&model, &run, run.dist_workers)?;
    let out = PathBuf::from(args.get("plan-out").unwrap_or("plan.toml"));
    ensure_parent_dir(&out)?;
    plan.save(&out)?;
    println!(
        "wrote {} (plan {}, {} worker(s) x {} shard(s), sampler={}, attrs={})",
        out.display(),
        plan.hash_hex(),
        plan.num_workers(),
        plan.num_shards,
        plan.sampler.name(),
        plan.attr_mode.name(),
    );
    println!("# optional: build the shared setup prologue once (workers then");
    println!("# append `--artifact setup.art` and skip their setup phases):");
    println!("#   magquilt setup --plan {} --out setup.art", out.display());
    println!("# run one worker per host (any order, reruns are safe):");
    for w in 0..plan.num_workers() {
        let (lo, hi) = plan.worker_range(w).expect("range");
        println!(
            "#   magquilt shard-worker --plan {} --worker {w} --segment-dir segs/   \
             # shards [{lo}, {hi})",
            out.display()
        );
    }
    println!("# then collect the segment files and:");
    println!(
        "#   magquilt merge-segments --segments segs/ --plan {} --out graph.bin",
        out.display()
    );
    Ok(())
}

/// Build the deterministic setup prologue once and persist it as a
/// content-addressed artifact (see docs/setup-artifact.md). With
/// `--plan F` the prologue is exactly the one every worker of that plan
/// would build; otherwise the model/run flags describe it.
fn cmd_setup(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    let artifact = match args.get("plan") {
        Some(p) => {
            let plan = ShardPlan::load(Path::new(p))?;
            dist::build_plan_artifact(&plan)?
        }
        None => {
            let (model, run) = specs_from_args(&args)?;
            coordinator_from(&run).build_setup(&model, run.seed, run.sampler)?
        }
    };
    let out = match args.get("out").or_else(|| args.get("output")) {
        Some(o) => PathBuf::from(o),
        None => PathBuf::from(crate::setup::artifact_file_name(&artifact.hash_hex())),
    };
    ensure_parent_dir(&out)?;
    artifact.save(&out)?;
    let h = artifact.header();
    println!(
        "wrote {} (artifact {}, sampler={}, pieces={}, attrs={}, n=2^{}, d={}, seed={}, \
         built in {:.1} ms on {} setup thread(s))",
        out.display(),
        artifact.hash_hex(),
        h.sampler.name(),
        h.piece_mode.name(),
        h.attr_mode.name(),
        h.log2_nodes,
        h.attributes,
        h.seed,
        h.setup_ms,
        h.setup_threads,
    );
    Ok(())
}

/// `magquilt artifact info <file>`: describe a setup artifact without
/// hydrating a run from it.
fn cmd_artifact(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    match args.positional(0) {
        Some("info") => {
            let path = args
                .positional(1)
                .ok_or_else(|| anyhow!("usage: magquilt artifact info <file>"))?;
            print_artifact_info(Path::new(path))
        }
        _ => bail!("usage: magquilt artifact info <file>"),
    }
}

/// Full decode + describe of one artifact file (also what
/// `magquilt stats <file>.art` prints). Loading validates the integrity
/// hash, so a clean printout doubles as a corruption check.
fn print_artifact_info(path: &Path) -> Result<()> {
    let bytes = std::fs::metadata(path)
        .with_context(|| format!("reading setup artifact {}", path.display()))?
        .len();
    let artifact = crate::setup::SetupArtifact::load(path)?;
    let h = artifact.header();
    println!("artifact: {} ({} bytes, integrity OK)", path.display(), bytes);
    println!("identity: {}", artifact.hash_hex());
    println!(
        "model: n=2^{} d={} mu={} theta={:?}",
        h.log2_nodes, h.attributes, h.mu, h.theta
    );
    println!(
        "run: sampler={} pieces={} attrs={} seed={}",
        h.sampler.name(),
        h.piece_mode.name(),
        h.attr_mode.name(),
        h.seed
    );
    println!(
        "payload: {} node configuration(s), partition of {} set(s) over {} node(s), \
         product DAG: {}",
        artifact.attrs().num_nodes(),
        artifact.partition().size(),
        artifact.partition().num_nodes(),
        if artifact.conditioner().is_some() { "yes" } else { "no" },
    );
    println!(
        "provenance: built in {:.1} ms on {} setup thread(s)",
        h.setup_ms, h.setup_threads
    );
    Ok(())
}

/// Execute one worker's slice of a plan (the per-host command of a
/// multi-host run, and what `sample --dist-workers` spawns locally).
/// `--resume` skips work whose output a previous (crashed) attempt
/// already landed; `--inject-fault SPEC` deterministically fails a
/// chosen write window (tests / CI only).
fn cmd_shard_worker(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["resume", "trace", "report"])?;
    let plan_path = args
        .get("plan")
        .ok_or_else(|| anyhow!("usage: magquilt shard-worker --plan F --worker I"))?;
    let plan_path = Path::new(plan_path);
    let worker: usize = args
        .get_parsed("worker")?
        .ok_or_else(|| anyhow!("usage: magquilt shard-worker --plan F --worker I"))?;
    let plan = ShardPlan::load(plan_path)?;
    let segment_dir = match args.get("segment-dir") {
        Some(d) => PathBuf::from(d),
        None => match plan_path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
            _ => PathBuf::from("."),
        },
    };
    let progress = Arc::new(ProgressState::new());
    let opts = dist::WorkerOptions {
        resume: args.has_flag("resume"),
        artifact: args.get("artifact").map(PathBuf::from),
        fault: args.get("inject-fault").map(dist::FaultPlan::parse).transpose()?,
        trace: args.has_flag("trace"),
        report: args.has_flag("report"),
        progress: Some(Arc::clone(&progress)),
    };
    // The heartbeat tells a supervising driver this process is alive —
    // each beat also publishes the live progress counters for the
    // driver's `progress:` line and `magquilt top`. It stops (and its
    // file is removed) when the guard drops, whether the run succeeds
    // or errors out.
    let heartbeat = dist::Heartbeat::start_with_progress(
        &segment_dir,
        &plan.hash_hex(),
        worker,
        Some(progress),
    );
    let report = dist::run_worker_with(&plan, worker, &segment_dir, &opts);
    drop(heartbeat);
    let report = report?;
    warn_dropped(report.stats.dropped_resamples);
    print_setup(&report.stats.setup);
    if report.resumed_shards > 0 {
        println!(
            "worker {}: resumed — {} owned shard(s) already on disk, skipped",
            report.worker, report.resumed_shards,
        );
    }
    println!(
        "worker {}: shards [{}, {}), ran {} of {} job(s); {} owned segment(s) \
         ({} edges), {} overflow run(s) ({} edges) in {:.1} ms",
        report.worker,
        report.owned.0,
        report.owned.1,
        report.jobs_run,
        report.jobs_total,
        report.summary.owned_segments,
        report.summary.owned_edges,
        report.summary.overflow_files,
        report.summary.overflow_edges,
        report.stats.wall_ms,
    );
    Ok(())
}

/// Classify (and with `--fix`, repair or quarantine) every file in a
/// segment directory — see [`crate::dist::doctor`].
fn cmd_doctor(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["fix"])?;
    let dir = args
        .positional(0)
        .ok_or_else(|| anyhow!("usage: magquilt doctor <segment dir> [--plan F] [--fix]"))?;
    let dir = Path::new(dir);
    if !dir.is_dir() {
        bail!("doctor: {} is not a directory", dir.display());
    }
    // Plan resolution: --plan wins, then the directory's own manifest;
    // without either the doctor still runs name-and-header checks against
    // the majority plan hash.
    let plan = match args.get("plan") {
        Some(p) => Some(ShardPlan::load(Path::new(p))?),
        None => {
            let local = dir.join(dist::PLAN_FILE);
            if local.is_file() { Some(ShardPlan::load(&local)?) } else { None }
        }
    };
    let fix = args.has_flag("fix");
    let report = dist::doctor(dir, plan.as_ref(), fix)?;
    match (&report.hash, &plan) {
        (Some(h), Some(_)) => println!("doctor: {} | plan {h}", dir.display()),
        (Some(h), None) => println!(
            "doctor: {} | no plan manifest; majority hash {h} (topology checks skipped)",
            dir.display()
        ),
        (None, _) => println!("doctor: {} | no recognizable artifacts", dir.display()),
    }
    for entry in &report.entries {
        let reason = match &entry.status {
            dist::FileStatus::Truncated(r)
            | dist::FileStatus::ForeignPlan(r)
            | dist::FileStatus::OrphanedOverflow(r)
            | dist::FileStatus::Misplaced(r)
            | dist::FileStatus::StaleMarker(r) => format!(" ({r})"),
            _ => String::new(),
        };
        let action = match entry.action {
            dist::DoctorAction::Kept => "kept",
            dist::DoctorAction::Removed => "removed",
            dist::DoctorAction::Quarantined => "quarantined",
            dist::DoctorAction::WouldRemove => "would remove (--fix)",
            dist::DoctorAction::WouldQuarantine => "would quarantine (--fix)",
        };
        println!("  {:18} {:24} {}{}", entry.status.label(), action, entry.name, reason);
    }
    if report.healthy() {
        println!("doctor: directory is healthy ({} file(s))", report.entries.len());
    } else if fix {
        println!(
            "doctor: removed {} file(s), quarantined {} into {}/",
            report.removed,
            report.quarantined,
            dir.join(dist::QUARANTINE_DIR).display()
        );
    } else {
        println!(
            "doctor: {} file(s) to remove, {} to quarantine — rerun with --fix to apply",
            report.removed, report.quarantined
        );
    }
    Ok(())
}

/// Fold a segment directory into the final `MAGQEDG1` file.
fn cmd_merge_segments(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["remove-segments"])?;
    let dir = args
        .get("segments")
        .ok_or_else(|| anyhow!("usage: magquilt merge-segments --segments DIR --out PATH"))?;
    let dir = Path::new(dir);
    let out = args
        .get("out")
        .or_else(|| args.get("output"))
        .ok_or_else(|| anyhow!("usage: magquilt merge-segments --segments DIR --out PATH"))?;
    let out = Path::new(out);
    ensure_parent_dir(out)?;
    let plan_path = match args.get("plan") {
        Some(p) => PathBuf::from(p),
        None => dir.join(dist::PLAN_FILE),
    };
    let plan = ShardPlan::load(&plan_path)?;
    let run_id = plan.hash_hex();
    let trace_path = args.get("trace").map(PathBuf::from);
    let report_path = args.get("report").map(PathBuf::from);
    let trace = if trace_path.is_some() {
        TraceHandle::new(&run_id, "merge", None)
    } else {
        TraceHandle::disabled()
    };
    let mut opts = dist::MergeOptions {
        remove_inputs: args.has_flag("remove-segments"),
        merge_threads: plan.merge_threads,
        trace: trace.clone(),
        ..Default::default()
    };
    // Per-host overrides: the plan records a default, but the merge host
    // is often not a worker host — neither knob changes a byte of output.
    if let Some(t) = args.get_parsed::<usize>("merge-threads")? {
        opts.merge_threads = t;
    }
    if let Some(b) = args.get_parsed::<u64>("spill-budget")? {
        opts.spill_budget = b;
    }
    let report = dist::merge_segments_with(dir, &plan, out, &opts)?;
    println!(
        "{}",
        console::merged_summary_line(
            report.shards.len(),
            report.overflow_runs() as u64,
            report.duplicates_dropped(),
        )
    );
    println!(
        "{}",
        console::merge_line(
            report.merge_ms,
            report.merge_threads,
            report.deferred_shards,
            report.spilled_shards,
        )
    );
    println!("wrote {} ({} edges)", out.display(), report.total_edges);
    if let Some(p) = &trace_path {
        ensure_parent_dir(p)?;
        trace.write_to(p)?;
        eprintln!("trace: wrote {}", p.display());
    }
    if let Some(p) = &report_path {
        write_report_file(p, &dist::merge_report_json(&run_id, &report))?;
    }
    Ok(())
}

/// The default path: collect the graph in memory, optionally write/stat it.
fn cmd_generate_collect(
    args: &Args,
    model: &ModelSpec,
    params: &MagmParams,
    run: &RunSpec,
) -> Result<()> {
    let tel = RunTelemetry::new(model, run);
    let start = std::time::Instant::now();
    let (graph, stats) = match &run.artifact {
        Some(p) => {
            let coord = match run.sampler {
                SamplerKind::Quilt | SamplerKind::Hybrid => {
                    coordinator_from(run).trace(tel.trace.clone())
                }
                other => bail!(
                    "--artifact needs the quilt or hybrid sampler, not {}",
                    other.name()
                ),
            };
            let (artifact, load_ms) = obtain_artifact(model, run, &coord, Path::new(p))?;
            let report = coord.sample_with_artifact(artifact, load_ms)?;
            warn_dropped(report.dropped_resamples);
            print_setup(&report.setup);
            let stats = report.stats();
            (report.graph, Some(stats))
        }
        None if tel.enabled() => {
            // Telemetry needs the coordinated samplers: the trace events
            // and report fields are the coordinator's run statistics.
            let coord = match run.sampler {
                SamplerKind::Quilt | SamplerKind::Hybrid => {
                    coordinator_from(run).trace(tel.trace.clone())
                }
                other => bail!(
                    "--trace/--report need the quilt or hybrid sampler, not {}",
                    other.name()
                ),
            };
            let report = match run.sampler {
                SamplerKind::Quilt => coord.sample_quilt(params, run.seed),
                SamplerKind::Hybrid => coord.sample_hybrid(params, run.seed),
                _ => unreachable!("the match above rejects other samplers"),
            };
            warn_dropped(report.dropped_resamples);
            print_setup(&report.setup);
            let stats = report.stats();
            (report.graph, Some(stats))
        }
        None => (sample_with(params, run)?, None),
    };
    let ms = start.elapsed().as_secs_f64() * 1e3;
    println!(
        "sampled {} edges over {} nodes in {:.1} ms ({:.0} edges/s)",
        graph.num_edges(),
        graph.num_nodes(),
        ms,
        graph.num_edges() as f64 / (ms / 1e3).max(1e-9)
    );
    if let Some(path) = &run.output {
        let path = Path::new(path);
        ensure_parent_dir(path)?;
        if args.has_flag("binary") || path.extension().is_some_and(|e| e == "bin") {
            write_edge_list_binary(&graph, path)?;
        } else {
            write_edge_list_text(&graph, path)?;
        }
        println!("wrote {}", path.display());
    }
    if args.has_flag("stats") {
        let summary = summarize(&graph, 2000, run.seed);
        print!("{}", summary.report());
    }
    if let Some(stats) = &stats {
        tel.finish(stats)?;
    }
    Ok(())
}

/// Degrees-and-counts-only run: the graph is never held in memory.
fn cmd_generate_counting(model: &ModelSpec, params: &MagmParams, run: &RunSpec) -> Result<()> {
    if run.output.is_some() {
        bail!("--sink counting never writes a graph; drop --output or use --sink binary");
    }
    let tel = RunTelemetry::new(model, run);
    let coord = coordinator_for(run)?.trace(tel.trace.clone());
    let (counts, stats) = match &run.artifact {
        Some(p) => {
            let (artifact, load_ms) = obtain_artifact(model, run, &coord, Path::new(p))?;
            coord.sample_with_artifact_sink(artifact, load_ms, CountingSink::new())?
        }
        None => match run.sampler {
            SamplerKind::Quilt => {
                coord.sample_quilt_with_sink(params, run.seed, CountingSink::new())?
            }
            SamplerKind::Hybrid => {
                coord.sample_hybrid_with_sink(params, run.seed, CountingSink::new())?
            }
            _ => unreachable!("coordinator_for rejects other samplers"),
        },
    };
    warn_dropped(stats.dropped_resamples);
    print_setup(&stats.setup);
    println!(
        "sampled {} edges over {} nodes in {:.1} ms ({:.0} edges/s, {} workers, {} shards)",
        counts.num_edges, counts.num_nodes, stats.wall_ms, stats.edges_per_sec,
        stats.workers, stats.num_shards
    );
    let mean = if counts.num_nodes == 0 {
        0.0
    } else {
        counts.num_edges as f64 / counts.num_nodes as f64
    };
    println!(
        "self-loops {} | max out/in degree {} / {} | mean out-degree {mean:.3}",
        counts.self_loops,
        counts.max_out_degree(),
        counts.max_in_degree(),
    );
    tel.finish(&stats)?;
    Ok(())
}

/// Stream the sample straight into the binary edge-list file.
fn cmd_generate_binary(
    args: &Args,
    model: &ModelSpec,
    params: &MagmParams,
    run: &RunSpec,
) -> Result<()> {
    if args.has_flag("stats") {
        bail!("--stats needs the collect sink; run `magquilt stats <file>` on the output");
    }
    let path = run
        .output
        .as_deref()
        .ok_or_else(|| anyhow!("--sink binary needs --output (or --out) <path>"))?;
    let path = Path::new(path);
    ensure_parent_dir(path)?;
    let tel = RunTelemetry::new(model, run);
    let coord = coordinator_for(run)?.trace(tel.trace.clone());
    let mut sink = BinaryFileSink::create(path);
    if let Some(dir) = &run.spill_dir {
        sink = sink.spill_dir(dir);
    }
    if let Some(bytes) = run.spill_budget {
        sink = sink.spill_budget(bytes);
    }
    let (written, stats) = match &run.artifact {
        Some(p) => {
            let (artifact, load_ms) = obtain_artifact(model, run, &coord, Path::new(p))?;
            coord.sample_with_artifact_sink(artifact, load_ms, sink)?
        }
        None => match run.sampler {
            SamplerKind::Quilt => coord.sample_quilt_with_sink(params, run.seed, sink)?,
            SamplerKind::Hybrid => coord.sample_hybrid_with_sink(params, run.seed, sink)?,
            _ => unreachable!("coordinator_for rejects other samplers"),
        },
    };
    warn_dropped(stats.dropped_resamples);
    print_setup(&stats.setup);
    println!("{}", console::spill_line(&stats.spill));
    println!(
        "wrote {} ({} edges, {:.1} ms, {} workers, {} shards)",
        path.display(),
        written,
        stats.wall_ms,
        stats.workers,
        stats.num_shards
    );
    tel.finish(&stats)?;
    Ok(())
}

fn ensure_parent_dir(path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    Ok(())
}

/// A coordinator configured from the run spec (no sampler gate — let the
/// caller produce the right error for its context).
fn coordinator_from(run: &RunSpec) -> Coordinator {
    Coordinator::new()
        .workers(run.workers)
        .shards(run.shards)
        .setup_threads(run.setup_threads)
        .attr_mode(run.effective_attr_mode())
        .piece_mode(run.piece_mode)
}

/// A coordinator configured from the run spec; the streaming sinks only
/// make sense for the coordinated samplers.
fn coordinator_for(run: &RunSpec) -> Result<Coordinator> {
    match run.sampler {
        SamplerKind::Quilt | SamplerKind::Hybrid => Ok(coordinator_from(run)),
        other => bail!(
            "sink counting|binary needs the quilt or hybrid sampler, not {}",
            other.name()
        ),
    }
}

/// Load the setup artifact at `path` (cross-checked against this run's
/// identity), or build and persist it when the file is absent. Returns
/// the artifact plus the load time (0.0 on a fresh build).
fn obtain_artifact(
    model: &ModelSpec,
    run: &RunSpec,
    coord: &Coordinator,
    path: &Path,
) -> Result<(crate::setup::SetupArtifact, f64)> {
    if path.exists() {
        let expected = crate::setup::ArtifactHeader::from_model(
            model,
            run.seed,
            run.sampler,
            run.piece_mode,
            run.effective_attr_mode(),
        );
        let start = std::time::Instant::now();
        let artifact = crate::setup::SetupArtifact::load(path)?;
        let load_ms = start.elapsed().as_secs_f64() * 1e3;
        artifact.check_matches(&expected)?;
        eprintln!("artifact: loaded {} ({})", path.display(), artifact.hash_hex());
        Ok((artifact, load_ms))
    } else {
        let artifact = coord.build_setup(model, run.seed, run.sampler)?;
        ensure_parent_dir(path)?;
        artifact.save(path)?;
        eprintln!(
            "artifact: built and wrote {} ({}) — later runs will load it",
            path.display(),
            artifact.hash_hex()
        );
        Ok((artifact, 0.0))
    }
}

/// One-line setup-pipeline timing breakdown (leader-side phases). The
/// wording lives in [`crate::trace::console`], where tests pin the exact
/// strings CI's smoke legs grep.
fn print_setup(setup: &crate::coordinator::SetupStats) {
    println!("{}", console::setup_line(setup));
}

/// Warn when balls were abandoned after exhausting duplicate resamples
/// (saturated blocks; the count used to be silently lost).
fn warn_dropped(dropped_resamples: u64) {
    if dropped_resamples > 0 {
        eprintln!(
            "warning: {dropped_resamples} ball(s) abandoned after exhausting duplicate \
             resamples (saturated blocks)"
        );
    }
}

/// Dispatch to the selected sampler.
pub fn sample_with(params: &MagmParams, run: &RunSpec) -> Result<EdgeList> {
    Ok(match run.sampler {
        SamplerKind::Quilt => {
            let report = coordinator_for(run)?.sample_quilt(params, run.seed);
            warn_dropped(report.dropped_resamples);
            print_setup(&report.setup);
            report.graph
        }
        SamplerKind::Hybrid => {
            let report = coordinator_for(run)?.sample_hybrid(params, run.seed);
            warn_dropped(report.dropped_resamples);
            print_setup(&report.setup);
            report.graph
        }
        SamplerKind::Naive => {
            let mut rng = Rng::new(run.seed);
            let attrs = AttributeAssignment::sample_with_mode(
                params,
                &mut rng,
                run.effective_attr_mode(),
                resolved_setup_threads(run),
            );
            crate::magm::naive_sample(params, &attrs, &mut rng)
        }
        SamplerKind::NaiveXla => {
            let runtime =
                crate::runtime::XlaRuntime::load_default().context("loading XLA artifacts")?;
            let mut rng = Rng::new(run.seed);
            let attrs = AttributeAssignment::sample_with_mode(
                params,
                &mut rng,
                run.effective_attr_mode(),
                resolved_setup_threads(run),
            );
            crate::runtime::naive_xla_sample(&runtime, params, &attrs, &mut rng)?
        }
    })
}

/// Resolve `--setup-threads 0` (auto) for the non-coordinated samplers
/// the same way the coordinator does for its pool: match the available
/// parallelism, capped at 16.
fn resolved_setup_threads(run: &RunSpec) -> usize {
    if run.setup_threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(16)
    } else {
        run.setup_threads
    }
}

fn cmd_stats(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    let path = args
        .positional(0)
        .ok_or_else(|| anyhow!("usage: magquilt stats <edge-list file | segment dir>"))?;
    let path = Path::new(path);
    if path.is_dir() {
        return cmd_stats_segments(&args, path);
    }
    if path
        .file_name()
        .is_some_and(|n| crate::setup::is_artifact_file(&n.to_string_lossy()))
    {
        return print_artifact_info(path);
    }
    let graph = read_graph_sniffed(path)?;
    let summary = summarize(&graph, 2000, 0);
    print!("{}", summary.report());
    Ok(())
}

/// Read an edge list, recognizing the binary format by its magic bytes
/// instead of the file extension — segment files (`.seg`/`.ovf`) and
/// arbitrarily named outputs read the same way as `.bin`.
fn read_graph_sniffed(path: &Path) -> Result<EdgeList> {
    use std::io::Read;
    let mut magic = [0u8; 8];
    let is_binary = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_exact(&mut magic)
        .map(|()| &magic == BINARY_MAGIC)
        .unwrap_or(false); // shorter than a header: try the text reader
    Ok(if is_binary { read_edge_list_binary(path)? } else { read_edge_list_text(path)? })
}

/// Pre-merge inspection of a distributed run's segment directory: loads
/// the plan (from `--plan` or `<dir>/plan.toml`), validates every
/// segment/overflow file (name, plan hash, header, sortedness, source
/// spans, truncation), and prints the per-shard picture a merge would
/// produce — without writing anything. Mixed plan hashes, incomplete
/// runs, and corrupt files are hard errors.
fn cmd_stats_segments(args: &Args, dir: &Path) -> Result<()> {
    let plan_path = match args.get("plan") {
        Some(p) => PathBuf::from(p),
        None => dir.join(dist::PLAN_FILE),
    };
    let plan = ShardPlan::load(&plan_path)?;
    let report = dist::validate_segments(dir, &plan)?;
    println!(
        "segment dir {} | plan {} | {} worker(s) x {} shard(s)",
        dir.display(),
        plan.hash_hex(),
        plan.num_workers(),
        plan.num_shards,
    );
    println!(
        "{:>6} {:>6} {:>12} {:>9} {:>12} {:>8} {:>12}",
        "shard", "owner", "seg_edges", "ovf_runs", "ovf_edges", "dups", "merged"
    );
    for row in &report.shards {
        println!(
            "{:>6} {:>6} {:>12} {:>9} {:>12} {:>8} {:>12}",
            row.shard,
            plan.owner_of_shard(row.shard),
            row.owner_edges,
            row.overflow_runs,
            row.overflow_edges,
            row.duplicates_dropped,
            row.merged_edges,
        );
    }
    println!(
        "all segments valid: {} edge(s) after merge, {} overflow run(s), \
         {} cross-worker duplicate(s)",
        report.total_edges,
        report.overflow_runs(),
        report.duplicates_dropped(),
    );
    Ok(())
}

/// Render the live fleet view of a distributed run from its segment
/// directory — the same aggregate `progress:` line the driver prints,
/// built from the workers' heartbeat payloads. Works from any host that
/// sees the (possibly shared) directory, while the run is in flight.
fn cmd_top(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    let dir = args
        .positional(0)
        .ok_or_else(|| anyhow!("usage: magquilt top <segment dir> [--plan F]"))?;
    let dir = Path::new(dir);
    if !dir.is_dir() {
        bail!("top: {} is not a directory", dir.display());
    }
    let plan_path = match args.get("plan") {
        Some(p) => PathBuf::from(p),
        None => dir.join(dist::PLAN_FILE),
    };
    let plan = ShardPlan::load(&plan_path)?;
    println!("top: {} | plan {}", dir.display(), plan.hash_hex());
    println!("{}", dist::fleet_progress_line(plan.num_workers(), dir, &plan.hash_hex()));
    Ok(())
}

/// Pretty-print one machine-readable `report.json`, or field-diff two
/// of them (`--compare`). Validates the format and required keys first,
/// so a clean printout doubles as a schema check.
fn cmd_report(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    let path = args
        .positional(0)
        .ok_or_else(|| anyhow!("usage: magquilt report <report.json> [--compare OTHER]"))?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading report {path}"))?;
    let kind = validate_report(&text)?;
    match args.get("compare") {
        Some(other) => {
            let other_text = std::fs::read_to_string(other)
                .with_context(|| format!("reading report {other}"))?;
            validate_report(&other_text)?;
            let diff = compare(&text, &other_text)?;
            if diff.is_empty() {
                println!("reports agree on every field");
            } else {
                print!("{diff}");
            }
        }
        None => {
            println!("report: kind {kind}");
            print!("{}", pretty(&text)?);
        }
    }
    Ok(())
}

fn cmd_experiment(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    let id = args
        .positional(0)
        .ok_or_else(|| anyhow!("usage: magquilt experiment <id|all> [--max-log2n N] ..."))?;
    let mut scale = Scale::default();
    if let Some(v) = args.get_parsed::<u32>("max-log2n")? {
        scale.max_log2n = v;
    }
    if let Some(v) = args.get_parsed::<u32>("naive-max-log2n")? {
        scale.naive_max_log2n = v;
    }
    if let Some(v) = args.get_parsed::<u32>("trials")? {
        scale.trials = v.max(1);
    }
    if let Some(v) = args.get_parsed::<u64>("seed")? {
        scale.seed = v;
    }
    let out_dir = PathBuf::from(args.get("out").unwrap_or("out"));
    std::fs::create_dir_all(&out_dir)?;

    let ids: Vec<&str> =
        if id == "all" { ALL_EXPERIMENTS.to_vec() } else { vec![id] };
    for id in ids {
        eprintln!("== running {id} (scale: max_log2n={}, trials={}) ==", scale.max_log2n, scale.trials);
        let start = std::time::Instant::now();
        let results = run_experiment(id, scale)?;
        for r in &results {
            print!("{}", r.to_tsv());
            let path = out_dir.join(format!("{}.tsv", r.id));
            std::fs::write(&path, r.to_tsv())?;
            let md = out_dir.join(format!("{}.md", r.id));
            std::fs::write(&md, r.to_markdown())?;
        }
        eprintln!("== {id} done in {:.1}s ==", start.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_artifacts_check(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    let dir = args
        .get("dir")
        .map(PathBuf::from)
        .unwrap_or_else(crate::runtime::default_artifacts_dir);
    let runtime = crate::runtime::XlaRuntime::load(&dir)?;
    println!("platform: {}", runtime.platform());
    println!("entries: {}", runtime.manifest().entries.len());

    // Numerical smoke check: XLA edge probabilities vs the pure-Rust
    // d-way product, on a random model.
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 128, 12);
    let mut rng = Rng::new(7);
    let attrs = AttributeAssignment::sample(&params, &mut rng);
    let kernels = crate::runtime::MagmKernels::new(&runtime, params.thetas());
    let src: Vec<u32> = (0..64).collect();
    let dst: Vec<u32> = (64..128).collect();
    let q = kernels.edge_prob_block(&attrs, &src, &dst)?;
    let mut max_err = 0.0f64;
    for (r, &i) in src.iter().enumerate() {
        for (c, &j) in dst.iter().enumerate() {
            let want = crate::magm::edge_probability(&params, &attrs, i, j);
            let got = q[r * dst.len() + c] as f64;
            max_err = max_err.max((got - want).abs());
        }
    }
    println!("edge_prob_block max |err| vs pure-Rust: {max_err:.3e}");
    if max_err > 1e-5 {
        bail!("artifacts check FAILED: max error {max_err:.3e} > 1e-5");
    }
    println!("artifacts check OK");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("magquilt {}", crate::VERSION);
    println!("paper: Quilting Stochastic Kronecker Product Graphs (AISTATS 2012)");
    println!("samplers: quilt | hybrid | naive | naive-xla");
    println!("workers available: {}", std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn args_parse_options_and_flags() {
        let a = Args::parse(&s(&["pos1", "--mu", "0.7", "--stats", "pos2"]), &["stats"]).unwrap();
        assert_eq!(a.positional(0), Some("pos1"));
        assert_eq!(a.positional(1), Some("pos2"));
        assert_eq!(a.get("mu"), Some("0.7"));
        assert!(a.has_flag("stats"));
        assert_eq!(a.get_parsed::<f64>("mu").unwrap(), Some(0.7));
    }

    #[test]
    fn args_missing_value_errors() {
        assert!(Args::parse(&s(&["--mu"]), &[]).is_err());
    }

    #[test]
    fn specs_from_cli_overrides() {
        let a = Args::parse(
            &s(&["--log2-nodes", "8", "--mu", "0.7", "--theta", "0.1,0.2,0.3,0.4",
                 "--sampler", "hybrid", "--piece-mode", "rejection", "--seed", "5",
                 "--shards", "6"]),
            &[],
        )
        .unwrap();
        let (model, run) = specs_from_args(&a).unwrap();
        assert_eq!(model.log2_nodes, 8);
        assert_eq!(model.attributes, 8);
        assert_eq!(model.mu, 0.7);
        assert_eq!(model.theta, [0.1, 0.2, 0.3, 0.4]);
        assert_eq!(run.sampler, SamplerKind::Hybrid);
        assert_eq!(run.piece_mode, crate::quilt::PieceMode::Rejection);
        assert_eq!(run.seed, 5);
        assert_eq!(run.shards, 6);
    }

    #[test]
    fn setup_threads_and_attr_mode_from_cli() {
        let a = Args::parse(&s(&["--setup-threads", "4", "--attr-mode", "chunked"]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.setup_threads, 4);
        assert_eq!(run.attr_mode, Some(crate::magm::AttrSampleMode::Chunked));
        // Defaults: auto threads, unset mode (single-process resolves it
        // to the legacy sequential stream).
        let a = Args::parse(&s(&[]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.setup_threads, 0);
        assert_eq!(run.attr_mode, None);
        assert_eq!(run.effective_attr_mode(), crate::magm::AttrSampleMode::Sequential);
        // Bad mode rejected.
        let a = Args::parse(&s(&["--attr-mode", "bogus"]), &[]).unwrap();
        assert!(specs_from_args(&a).is_err());
    }

    #[test]
    fn dist_flags_from_cli() {
        let a = Args::parse(
            &s(&["--dist-workers", "3", "--segment-dir", "/tmp/segs", "--merge-threads", "4"]),
            &[],
        )
        .unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.dist_workers, 3);
        assert_eq!(run.segment_dir.as_deref(), Some("/tmp/segs"));
        assert_eq!(run.merge_threads, 4);
        // Defaults: single-process, auto merge threads.
        let a = Args::parse(&s(&[]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.dist_workers, 0);
        assert_eq!(run.segment_dir, None);
        assert_eq!(run.merge_threads, 0);
        // Non-numeric count rejected.
        let a = Args::parse(&s(&["--merge-threads", "lots"]), &[]).unwrap();
        assert!(specs_from_args(&a).is_err());
    }

    #[test]
    fn dist_command_misuse_is_an_error() {
        // Distributed sampling writes the binary format to --out.
        assert!(run(&s(&["sample", "--log2-nodes", "6", "--dist-workers", "2"])).is_err());
        assert!(run(&s(&[
            "sample", "--log2-nodes", "6", "--dist-workers", "2", "--sink", "counting",
            "--out", "/tmp/x.bin"
        ]))
        .is_err());
        // The naive samplers cannot be distributed.
        assert!(run(&s(&[
            "sample", "--log2-nodes", "6", "--sampler", "naive", "--dist-workers", "2",
            "--out", "/tmp/x.bin"
        ]))
        .is_err());
        // Subcommand usage errors.
        assert!(run(&s(&["shard-plan", "--log2-nodes", "6"])).is_err(), "needs --dist-workers");
        assert!(run(&s(&["shard-worker"])).is_err());
        assert!(run(&s(&["shard-worker", "--plan", "/nonexistent/plan.toml", "--worker", "0"]))
            .is_err());
        assert!(run(&s(&["merge-segments", "--segments", "/tmp"])).is_err(), "needs --out");
    }

    #[test]
    fn fault_tolerance_flags_from_cli() {
        let a = Args::parse(
            &s(&["--worker-retries", "5", "--worker-backoff-ms", "125"]),
            &[],
        )
        .unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.worker_retries, 5);
        assert_eq!(run.worker_backoff_ms, 125);
        // Defaults come from RunSpec.
        let a = Args::parse(&s(&[]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.worker_retries, 2);
        assert_eq!(run.worker_backoff_ms, 500);
        // Non-numeric values rejected.
        let a = Args::parse(&s(&["--worker-retries", "many"]), &[]).unwrap();
        assert!(specs_from_args(&a).is_err());
    }

    #[test]
    fn doctor_and_fault_misuse_are_errors() {
        // doctor needs a directory.
        assert!(run(&s(&["doctor"])).is_err());
        assert!(run(&s(&["doctor", "/nonexistent/segdir"])).is_err());
        // A driver-level fault spec must name a target worker…
        assert!(run(&s(&[
            "sample", "--log2-nodes", "6", "--dist-workers", "2", "--out", "/tmp/x.bin",
            "--inject-fault", "crash-before-marker"
        ]))
        .is_err());
        // …and a bogus spec is rejected before anything runs.
        assert!(run(&s(&[
            "sample", "--log2-nodes", "6", "--dist-workers", "2", "--out", "/tmp/x.bin",
            "--inject-fault", "explode@w0"
        ]))
        .is_err());
    }

    #[test]
    fn out_is_an_alias_for_output() {
        let a = Args::parse(&s(&["--out", "graph.bin"]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.output.as_deref(), Some("graph.bin"));
        // --output wins when both are given.
        let a = Args::parse(&s(&["--out", "a.bin", "--output", "b.bin"]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.output.as_deref(), Some("b.bin"));
    }

    #[test]
    fn spill_flags_from_cli() {
        let a = Args::parse(&s(&["--spill-dir", "/tmp/sp", "--spill-budget", "0"]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.spill_dir.as_deref(), Some("/tmp/sp"));
        assert_eq!(run.spill_budget, Some(0));
        // Defaults: the sink decides.
        let a = Args::parse(&s(&[]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.spill_dir, None);
        assert_eq!(run.spill_budget, None);
        // Non-numeric budget rejected.
        let a = Args::parse(&s(&["--spill-budget", "lots"]), &[]).unwrap();
        assert!(specs_from_args(&a).is_err());
    }

    #[test]
    fn bad_sink_rejected() {
        assert!(run(&s(&["generate", "--log2-nodes", "6", "--sink", "bogus"])).is_err());
        // Streaming sinks need the coordinated samplers.
        assert!(run(&s(&[
            "generate", "--log2-nodes", "6", "--sampler", "naive", "--sink", "counting"
        ]))
        .is_err());
        // Binary sink without an output path is an error.
        assert!(run(&s(&["generate", "--log2-nodes", "6", "--sink", "binary"])).is_err());
    }

    #[test]
    fn bad_piece_mode_rejected() {
        let a = Args::parse(&s(&["--piece-mode", "bogus"]), &[]).unwrap();
        assert!(specs_from_args(&a).is_err());
    }

    #[test]
    fn bad_theta_rejected() {
        let a = Args::parse(&s(&["--theta", "0.1,0.2"]), &[]).unwrap();
        assert!(specs_from_args(&a).is_err());
    }

    #[test]
    fn unknown_command_fails() {
        assert!(run(&s(&["frobnicate"])).is_err());
    }

    #[test]
    fn artifact_flag_lands_in_run_spec() {
        let a = Args::parse(&s(&["--artifact", "cache/setup.art"]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.artifact.as_deref(), Some("cache/setup.art"));
        let a = Args::parse(&s(&[]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.artifact, None);
    }

    #[test]
    fn setup_and_artifact_round_trip_through_cli() {
        let dir = std::env::temp_dir().join("magquilt_cli_artifact");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let art = dir.join("setup.art");
        let art_s = art.to_string_lossy().into_owned();
        run(&s(&["setup", "--log2-nodes", "6", "--seed", "9", "--out", &art_s])).unwrap();
        assert!(art.exists());
        // Describe it — both spellings decode (and integrity-check) it.
        run(&s(&["artifact", "info", &art_s])).unwrap();
        run(&s(&["stats", &art_s])).unwrap();
        // A hydrated sample is byte-identical to a fresh one.
        let out_a = dir.join("a.bin").to_string_lossy().into_owned();
        let out_f = dir.join("f.bin").to_string_lossy().into_owned();
        run(&s(&[
            "sample", "--log2-nodes", "6", "--seed", "9", "--artifact", &art_s, "--out", &out_a,
        ]))
        .unwrap();
        run(&s(&["sample", "--log2-nodes", "6", "--seed", "9", "--out", &out_f])).unwrap();
        assert_eq!(
            std::fs::read(dir.join("a.bin")).unwrap(),
            std::fs::read(dir.join("f.bin")).unwrap()
        );
        // --artifact on a missing path builds and persists it first.
        let built = dir.join("built.art");
        let built_s = built.to_string_lossy().into_owned();
        let out_b = dir.join("b.bin").to_string_lossy().into_owned();
        run(&s(&[
            "sample", "--log2-nodes", "6", "--seed", "9", "--artifact", &built_s, "--out", &out_b,
        ]))
        .unwrap();
        assert!(built.exists(), "--artifact persists a freshly built prologue");
        assert_eq!(
            std::fs::read(dir.join("b.bin")).unwrap(),
            std::fs::read(dir.join("f.bin")).unwrap()
        );
        // Mismatched run parameters are rejected, not silently resampled.
        let err = run(&s(&[
            "sample", "--log2-nodes", "6", "--seed", "10", "--artifact", &art_s, "--out", &out_a,
        ]))
        .unwrap_err();
        assert!(format!("{err:#}").contains("does not match"), "{err:#}");
    }

    #[test]
    fn telemetry_flags_land_in_run_spec() {
        let a = Args::parse(
            &s(&["--trace", "/tmp/run.trace.jsonl", "--report", "/tmp/run.report.json"]),
            &[],
        )
        .unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.trace.as_deref(), Some("/tmp/run.trace.jsonl"));
        assert_eq!(run.report.as_deref(), Some("/tmp/run.report.json"));
        // Off by default.
        let a = Args::parse(&s(&[]), &[]).unwrap();
        let (_, run) = specs_from_args(&a).unwrap();
        assert_eq!(run.trace, None);
        assert_eq!(run.report, None);
    }

    #[test]
    fn sample_telemetry_round_trip() {
        let dir = std::env::temp_dir().join("magquilt_cli_telemetry");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let plain = dir.join("plain.bin").to_string_lossy().into_owned();
        let traced = dir.join("traced.bin").to_string_lossy().into_owned();
        let trc = dir.join("run.trace.jsonl");
        let rpt = dir.join("run.report.json");
        let trc_s = trc.to_string_lossy().into_owned();
        let rpt_s = rpt.to_string_lossy().into_owned();
        run(&s(&["sample", "--log2-nodes", "6", "--seed", "11", "--out", &plain])).unwrap();
        run(&s(&[
            "sample", "--log2-nodes", "6", "--seed", "11", "--out", &traced, "--trace", &trc_s,
            "--report", &rpt_s,
        ]))
        .unwrap();
        assert_eq!(
            std::fs::read(dir.join("plain.bin")).unwrap(),
            std::fs::read(dir.join("traced.bin")).unwrap(),
            "telemetry must not change output bytes"
        );
        let trace_text = std::fs::read_to_string(&trc).unwrap();
        assert!(trace_text.starts_with("{\"format\":\"MAGQTRC1\""), "{trace_text}");
        assert!(trace_text.contains("\"event\":\"run_done\""), "{trace_text}");
        let report_text = std::fs::read_to_string(&rpt).unwrap();
        assert_eq!(validate_report(&report_text).unwrap(), "sample");
        // The report command decodes it, and a self-compare is clean.
        run(&s(&["report", &rpt_s])).unwrap();
        run(&s(&["report", &rpt_s, "--compare", &rpt_s])).unwrap();
        // The naive sampler has no run statistics to report.
        assert!(run(&s(&[
            "sample", "--log2-nodes", "6", "--sampler", "naive", "--trace", &trc_s, "--out",
            &plain,
        ]))
        .is_err());
    }

    #[test]
    fn top_and_report_misuse_are_errors() {
        assert!(run(&s(&["top"])).is_err());
        assert!(run(&s(&["top", "/nonexistent/segdir"])).is_err());
        assert!(run(&s(&["report"])).is_err());
        assert!(run(&s(&["report", "/nonexistent/report.json"])).is_err());
        let bogus = std::env::temp_dir().join("magquilt_cli_bogus_report.json");
        std::fs::write(&bogus, "{\"format\":\"NOPE\"}").unwrap();
        let bogus_s = bogus.to_string_lossy().into_owned();
        assert!(run(&s(&["report", &bogus_s])).is_err());
        let _ = std::fs::remove_file(&bogus);
    }

    #[test]
    fn setup_and_artifact_misuse_are_errors() {
        // No prologue exists for the naive samplers.
        assert!(run(&s(&["setup", "--log2-nodes", "6", "--sampler", "naive"])).is_err());
        assert!(run(&s(&[
            "sample", "--log2-nodes", "6", "--sampler", "naive", "--artifact", "/tmp/x.art"
        ]))
        .is_err());
        // artifact needs a subcommand and a file.
        assert!(run(&s(&["artifact"])).is_err());
        assert!(run(&s(&["artifact", "info"])).is_err());
        assert!(run(&s(&["artifact", "info", "/nonexistent/setup.art"])).is_err());
        // setup from a missing plan manifest.
        assert!(run(&s(&["setup", "--plan", "/nonexistent/plan.toml"])).is_err());
    }
}
