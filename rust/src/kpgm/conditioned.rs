//! Conditioned (block-restricted) ball dropping: a rejection-free variant
//! of Algorithm 1 for quilt pieces.
//!
//! The quilting sampler (paper Algorithm 2) keeps, from each full-space
//! KPGM sample, only the edges whose endpoints are configurations present
//! in a partition-set pair `(D_k, D_l)`. Sampling the full `2^d × 2^d`
//! space and filtering costs `O(B² · d · |E_KPGM|)` while the retained
//! output is only `O(|E|)`: the acceptance rate collapses as `B` grows.
//!
//! This module removes the rejection loop. Following the conditioning view
//! of the ball-dropping process (Yun & Vishwanathan, arXiv:1202.6001) the
//! quadrisection descent is restricted to the *reachable* configuration
//! pairs: at every level the four `θ`-quadrant weights are renormalized by
//! the probability mass of the block cells below each quadrant, so each
//! leaf `(x, y) ∈ C_k × C_l` is reached with probability exactly
//! `P[x, y] / m_kl` where `m_kl = Σ_{(x,y) ∈ C_k × C_l} P[x, y]` is the
//! restricted mass. The per-piece edge count is then drawn from
//! `Poisson(m_kl)` clamped to `|C_k|·|C_l|` cells — the sparse limit of
//! the full-space process's retained count, which keeps the conditioned
//! path cell-by-cell consistent with Algorithm 1's (see
//! [`PieceSampler::draw_edge_count`]).
//!
//! Data structures:
//!
//! * [`ConfigForest`] — a hash-consed binary prefix trie over attribute
//!   configurations. Isomorphic suffix sets are merged into *classes*
//!   (one interner per level), so the `B` nested sets of a quilt partition
//!   share almost all of their structure. Each registered set is a
//!   [`ConfigTrie`]: a root class plus per-level reachability bitmasks.
//! * [`ConditionedBallDropSampler`] — the product DAG over (row-class,
//!   col-class) pairs reachable from any of the `B²` piece roots, built
//!   once per partition. Every pair node stores the four child links and
//!   cumulative u64 quadrant thresholds (the same one-`next_u64`-per-level
//!   trick as [`super::BallDropSampler::drop_one`]), and the restricted
//!   mass / squared mass are aggregated bottom-up in the same pass.
//!   Because classes are shared, the `B²` pieces price in roughly one
//!   product DAG, not `B²` of them.
//!
//! Complexity: setup is `O(d · Σ_k |C_k|)` for the forest plus the product
//! DAG size (bounded by the reachable class pairs, which hash-consing
//! keeps near the largest single piece); each drop is `O(d)` with zero
//! rejections; each piece draws `≈ m_kl` balls, so total sampling work is
//! `O(d · |E|)` instead of `O(B² · d · |E_KPGM|)`.

use anyhow::{bail, Result};

use crate::hashutil::FastMap;
use crate::rng::Rng;
use crate::setup::wire::{Reader, Writer};

use super::ThetaSeq;

/// Sentinel for "no child" in class and pair-node links.
const NONE: u32 = u32::MAX;

/// Reachability bitmasks are materialized for prefix lengths up to this
/// (memory `Σ_ℓ 2^ℓ` bits ≈ 16 KB per set at the gate); deeper levels are
/// answered by the trie itself.
const MASK_LEVEL_GATE: usize = 16;

/// Pair nodes per work chunk in the threaded bottom-up mass aggregation.
/// Fixed (independent of the thread count): small levels collapse to one
/// chunk and run inline with zero spawn overhead.
const AGG_CHUNK: usize = 2048;

/// One hash-consed trie class: the children are class ids at the next
/// level ([`NONE`] = no configuration has that bit here).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ClassNode {
    children: [u32; 2],
}

/// Hash-consed prefix-trie arena shared by all sets of one partition.
///
/// A *class* at level `ℓ` stands for a distinct set of length-`(d−ℓ)`
/// suffixes; two prefixes (possibly from different sets) with identical
/// suffix sets share one class. Level `d` holds the single empty-suffix
/// leaf class.
///
/// Forests can be built **sharded**: register disjoint groups of sets
/// into private per-shard forests (in parallel), then merge them with
/// [`ConfigForest::adopt_trie`] — the merge re-interns classes in the
/// exact order serial registration would have created them, so the
/// merged arena is bit-for-bit the serial one.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigForest {
    depth: usize,
    /// `levels[ℓ]` = classes at prefix length `ℓ`, `ℓ ∈ 0..=depth`.
    levels: Vec<Vec<ClassNode>>,
    /// Per-level interner: packed `(child0, child1)` → class id.
    interners: Vec<FastMap<u64, u32>>,
}

impl ConfigForest {
    /// Empty forest for `depth`-bit configurations (`1 ≤ depth ≤ 63`).
    pub fn new(depth: usize) -> Self {
        assert!((1..=63).contains(&depth), "depth {depth} outside [1, 63]");
        let mut levels = vec![Vec::new(); depth + 1];
        // The unique empty-suffix leaf class.
        levels[depth].push(ClassNode { children: [NONE, NONE] });
        ConfigForest { depth, levels, interners: vec![FastMap::default(); depth + 1] }
    }

    /// Number of attribute levels d.
    #[inline]
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Total classes across all levels (a measure of structure sharing).
    pub fn num_classes(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// Children of class `id` at `level`.
    #[inline]
    fn class(&self, level: usize, id: u32) -> [u32; 2] {
        self.levels[level][id as usize].children
    }

    /// Register a set of configurations (sorted, distinct, `< 2^depth`) and
    /// return its trie handle. Identical sets return identical roots.
    pub fn register_set(&mut self, sorted_configs: &[u64]) -> ConfigTrie {
        debug_assert!(sorted_configs.windows(2).all(|w| w[0] < w[1]), "configs must be sorted and distinct");
        debug_assert!(
            sorted_configs.iter().all(|&c| self.depth == 63 || c < (1u64 << self.depth)),
            "config outside the 2^depth space"
        );
        let root = self.intern_slice(0, sorted_configs);

        // Per-level live-prefix bitmasks (prefix value = top ℓ bits).
        let mask_levels = self.depth.min(MASK_LEVEL_GATE);
        let mut masks: Vec<Vec<u64>> =
            (0..=mask_levels).map(|l| vec![0u64; (1usize << l).div_ceil(64)]).collect();
        for &c in sorted_configs {
            for (l, mask) in masks.iter_mut().enumerate() {
                let prefix = (c >> (self.depth - l)) as usize;
                mask[prefix >> 6] |= 1u64 << (prefix & 63);
            }
        }
        ConfigTrie { root, num_configs: sorted_configs.len(), masks }
    }

    /// Hash-consing recursion: class of the suffix set `slice` below a
    /// prefix of length `level`.
    fn intern_slice(&mut self, level: usize, slice: &[u64]) -> u32 {
        if level == self.depth {
            return 0; // the leaf class
        }
        let bit = self.depth - 1 - level;
        let split = slice.partition_point(|&c| (c >> bit) & 1 == 0);
        let c0 = if split == 0 { NONE } else { self.intern_slice(level + 1, &slice[..split]) };
        let c1 = if split == slice.len() {
            NONE
        } else {
            self.intern_slice(level + 1, &slice[split..])
        };
        let key = ((c0 as u64) << 32) | c1 as u64;
        if let Some(&id) = self.interners[level].get(&key) {
            return id;
        }
        let id = self.levels[level].len() as u32;
        self.levels[level].push(ClassNode { children: [c0, c1] });
        self.interners[level].insert(key, id);
        id
    }

    /// Re-intern a trie registered in `src` into `self`, returning the
    /// equivalent trie rooted in `self`'s arena.
    ///
    /// New classes are created in the same DFS post-order (children
    /// before parent, 0-child first) as [`Self::register_set`]'s
    /// recursion, so adopting per-shard forests **in set order**
    /// reproduces the serial arena exactly — class ids included. The
    /// `memo` caches `src → self` class ids and must be reused for every
    /// trie adopted from the same `src` (shared substructure is then
    /// walked once).
    pub fn adopt_trie(
        &mut self,
        src: &ConfigForest,
        trie: &ConfigTrie,
        memo: &mut AdoptMemo,
    ) -> ConfigTrie {
        assert_eq!(self.depth, src.depth, "forest depths must match");
        let root = self.adopt_class(src, 0, trie.root, memo);
        ConfigTrie { root, num_configs: trie.num_configs, masks: trie.masks.clone() }
    }

    /// Recursive re-intern of one `src` class (children first).
    fn adopt_class(
        &mut self,
        src: &ConfigForest,
        level: usize,
        id: u32,
        memo: &mut AdoptMemo,
    ) -> u32 {
        if level == self.depth {
            return 0; // the shared empty-suffix leaf class
        }
        if let Some(&g) = memo.levels[level].get(&id) {
            return g;
        }
        let [c0, c1] = src.class(level, id);
        let g0 = if c0 == NONE { NONE } else { self.adopt_class(src, level + 1, c0, memo) };
        let g1 = if c1 == NONE { NONE } else { self.adopt_class(src, level + 1, c1, memo) };
        let key = ((g0 as u64) << 32) | g1 as u64;
        let g = match self.interners[level].get(&key) {
            Some(&existing) => existing,
            None => {
                let g = self.levels[level].len() as u32;
                self.levels[level].push(ClassNode { children: [g0, g1] });
                self.interners[level].insert(key, g);
                g
            }
        };
        memo.levels[level].insert(id, g);
        g
    }

    /// Classes at level 0 (for validating trie roots decoded alongside
    /// this forest).
    pub(crate) fn num_root_classes(&self) -> usize {
        self.levels[0].len()
    }

    /// Serialize into a setup-artifact body: the class arena level by
    /// level, in its exact serial interning order (class ids are
    /// meaningful — the tries and the product DAG index into them). The
    /// interner maps are derived state and are rebuilt on decode.
    pub(crate) fn encode(&self, w: &mut Writer) {
        w.put_u32(self.depth as u32);
        for level in &self.levels {
            w.put_u64(level.len() as u64);
            for node in level {
                w.put_u32(node.children[0]);
                w.put_u32(node.children[1]);
            }
        }
    }

    /// Decode the counterpart of [`ConfigForest::encode`] from untrusted
    /// bytes: validates the leaf level, every child link, and hash-consing
    /// uniqueness, then rebuilds the per-level interners — so the decoded
    /// forest compares equal to the source and keeps absorbing
    /// `register_set`/`adopt_trie` calls exactly as the fresh one would.
    pub(crate) fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let depth = r.take_u32("forest depth")? as usize;
        if !(1..=63).contains(&depth) {
            bail!("artifact body corrupt: forest depth {depth} outside [1, 63]");
        }
        let mut levels = Vec::with_capacity(depth + 1);
        for _ in 0..=depth {
            let n = r.take_len(8, "forest classes")?;
            let mut level = Vec::with_capacity(n);
            for _ in 0..n {
                let c0 = r.take_u32("forest class child")?;
                let c1 = r.take_u32("forest class child")?;
                level.push(ClassNode { children: [c0, c1] });
            }
            levels.push(level);
        }
        if levels[depth].len() != 1 || levels[depth][0].children != [NONE, NONE] {
            bail!("artifact body corrupt: forest leaf level is not the single empty-suffix class");
        }
        let mut interners: Vec<FastMap<u64, u32>> = (0..=depth)
            .map(|l| crate::hashutil::fast_map_with_capacity(levels[l].len()))
            .collect();
        for level in 0..depth {
            let next_len = levels[level + 1].len() as u64;
            for (id, node) in levels[level].iter().enumerate() {
                let [c0, c1] = node.children;
                for c in [c0, c1] {
                    if c != NONE && c as u64 >= next_len {
                        bail!(
                            "artifact body corrupt: forest class link {c} outside level {}",
                            level + 1
                        );
                    }
                }
                let key = ((c0 as u64) << 32) | c1 as u64;
                if interners[level].insert(key, id as u32).is_some() {
                    bail!(
                        "artifact body corrupt: duplicate hash-consed class in forest level \
                         {level}"
                    );
                }
            }
        }
        Ok(ConfigForest { depth, levels, interners })
    }
}

/// Per-source-forest memo for [`ConfigForest::adopt_trie`]: source class
/// id → adopted class id, one map per level. Create one per shard forest
/// and reuse it across all of that shard's tries.
#[derive(Debug)]
pub struct AdoptMemo {
    levels: Vec<FastMap<u32, u32>>,
}

impl AdoptMemo {
    /// Empty memo for a `depth`-level source forest.
    pub fn new(depth: usize) -> Self {
        AdoptMemo { levels: vec![FastMap::default(); depth + 1] }
    }

    /// Empty memo pre-sized for adopting **all** of `src`: each level's
    /// map reserves one slot per source class, so a full-forest adoption
    /// (the trie-merge path) never rehashes mid-merge.
    pub fn for_source(src: &ConfigForest) -> Self {
        AdoptMemo {
            levels: src
                .levels
                .iter()
                .map(|lvl| crate::hashutil::fast_map_with_capacity(lvl.len()))
                .collect(),
        }
    }
}

/// One registered configuration set: root class into a [`ConfigForest`]
/// plus per-level reachability bitmasks.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigTrie {
    root: u32,
    num_configs: usize,
    /// `masks[ℓ]` = bitset of live prefixes of length `ℓ` (gated).
    masks: Vec<Vec<u64>>,
}

impl ConfigTrie {
    /// Root class id (level 0) in the owning forest.
    #[inline]
    pub fn root(&self) -> u32 {
        self.root
    }

    /// Number of configurations in the set.
    #[inline]
    pub fn num_configs(&self) -> usize {
        self.num_configs
    }

    /// Number of levels with a materialized reachability mask.
    ///
    /// The masks are a diagnostic/query surface ([`Self::is_live`]); the
    /// conditioned descent itself walks the hash-consed classes, not the
    /// masks.
    pub fn mask_levels(&self) -> usize {
        self.masks.len()
    }

    /// Whether `prefix` (of bit-length `level`) is a prefix of some
    /// configuration in the set; `None` if the level has no mask.
    pub fn is_live(&self, level: usize, prefix: u64) -> Option<bool> {
        let mask = self.masks.get(level)?;
        let p = prefix as usize;
        Some((mask[p >> 6] >> (p & 63)) & 1 == 1)
    }

    /// Serialize into a setup-artifact body.
    pub(crate) fn encode(&self, w: &mut Writer) {
        w.put_u32(self.root);
        w.put_u64(self.num_configs as u64);
        w.put_u64(self.masks.len() as u64);
        for mask in &self.masks {
            for &word in mask {
                w.put_u64(word);
            }
        }
    }

    /// Decode the counterpart of [`ConfigTrie::encode`] from untrusted
    /// bytes. Mask levels are gated at build time, so the word counts are
    /// implied by the level index — a claimed level count beyond the gate
    /// is rejected before any allocation.
    pub(crate) fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let root = r.take_u32("trie root")?;
        let num_configs = usize::try_from(r.take_u64("trie config count")?)
            .map_err(|_| anyhow::anyhow!("artifact body corrupt: trie config count overflow"))?;
        let num_levels = usize::try_from(r.take_u64("trie mask levels")?)
            .map_err(|_| anyhow::anyhow!("artifact body corrupt: trie mask level overflow"))?;
        if num_levels > MASK_LEVEL_GATE + 1 {
            bail!(
                "artifact body corrupt: {num_levels} trie mask levels exceeds the gate \
                 ({})",
                MASK_LEVEL_GATE + 1
            );
        }
        let mut masks = Vec::with_capacity(num_levels);
        for l in 0..num_levels {
            let words = (1usize << l).div_ceil(64);
            let mut mask = Vec::with_capacity(words);
            for _ in 0..words {
                mask.push(r.take_u64("trie mask word")?);
            }
            masks.push(mask);
        }
        Ok(ConfigTrie { root, num_configs, masks })
    }
}

/// Draw `X ~ N(mean, var)` rounded and clamped to `[0, max_cells]` —
/// Algorithm 1 lines 3–5 with the clamp centralized so the full-space
/// sampler (`max_cells = n²`) and the block-restricted sampler
/// (`max_cells = |D_k|·|D_l|`) share it.
#[inline]
pub(crate) fn draw_count_clamped(rng: &mut Rng, mean: f64, var: f64, max_cells: f64) -> u64 {
    let x = rng.normal_with(mean, var.max(0.0).sqrt());
    x.round().clamp(0.0, max_cells) as u64
}

/// Scale four weights to cumulative u64 thresholds: a uniform draw `r`
/// selects quadrant `(r >= t0) + (r >= t1) + (r >= t2)`. Shared by the
/// full-space descent ([`super::BallDropSampler`]) and the conditioned
/// descent so their rounding behavior stays identical.
pub(crate) fn cumulative_thresholds(w: &[f64; 4], total: f64) -> [u64; 3] {
    let scale = (u64::MAX as f64) / total;
    let c0 = w[0] * scale;
    let c1 = c0 + w[1] * scale;
    let c2 = c1 + w[2] * scale;
    [c0 as u64, c1 as u64, c2 as u64]
}

/// Cumulative u64 thresholds over four weights plus the heaviest quadrant
/// (used as a fallback when a raw draw lands exactly on a zero-width
/// boundary or in float-rounding slack past the last cumulative bound).
fn quadrant_thresholds(w: &[f64; 4], total: f64) -> ([u64; 3], u8) {
    if total <= 0.0 {
        return ([u64::MAX; 3], 0);
    }
    let mut fallback = 0u8;
    for q in 1..4 {
        if w[q] > w[fallback as usize] {
            fallback = q as u8;
        }
    }
    (cumulative_thresholds(w, total), fallback)
}

/// One node of the product DAG: a reachable (row-class, col-class) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
struct PairNode {
    /// Quadrant `(a, b)` (row-major index `2a + b`) → pair id at the next
    /// level; [`NONE`] = no retained cell below that quadrant.
    children: [u32; 4],
    /// Cumulative quadrant thresholds over `θ_ℓ[a,b] ·` downstream mass.
    thresholds: [u64; 3],
    /// Heaviest live quadrant (boundary-draw fallback).
    fallback: u8,
}

/// Per-piece root into the product DAG plus its restricted aggregates.
#[derive(Debug, Clone, Copy, PartialEq)]
struct PieceRoot {
    node: u32,
    /// `m_kl = Σ_{(x,y) ∈ C_k × C_l} P[x, y]`.
    mass: f64,
    /// `v_kl = Σ_{(x,y) ∈ C_k × C_l} P[x, y]²`.
    mass_sq: f64,
    /// `|C_k| · |C_l|` — the hard cap on distinct edges in the block.
    num_cells: u64,
}

/// Rejection-free ball dropper over the `B²` blocks of a quilt partition.
///
/// Built once per partition from the per-set tries; [`Self::piece`] hands
/// out lightweight per-block samplers that share the product DAG.
///
/// Dense-block budget: the product DAG of a block is bounded by
/// `O(d · |C_k|·|C_l|)` pair nodes, so conditioning a near-full block
/// (cells comparable to `4^d`) would cost more to set up than the plain
/// descent spends dropping — while on exactly those blocks the full-space
/// acceptance rate `|C_k|·|C_l| / 4^d` is already high. A `cell_budget`
/// therefore excludes blocks with more cells than the budget from the DAG
/// ([`Self::piece`] returns `None` there and callers keep Algorithm 1);
/// the sparse blocks — the ones whose acceptance collapses as `B` grows —
/// are all conditioned. The split is a pure function of the partition and
/// the budget, so seeded runs stay reproducible.
#[derive(Debug, Clone, PartialEq)]
pub struct ConditionedBallDropSampler {
    depth: usize,
    num_sets: usize,
    /// `levels[ℓ]` = reachable pair nodes at level `ℓ`, `ℓ ∈ 0..depth`.
    levels: Vec<Vec<PairNode>>,
    /// Row-major `num_sets × num_sets` piece roots (`None` = over budget).
    roots: Vec<Option<PieceRoot>>,
}

impl ConditionedBallDropSampler {
    /// Build the product DAG for all `sets.len()²` block pairs, with no
    /// dense-block budget (every piece is conditioned).
    pub fn build(thetas: &ThetaSeq, forest: &ConfigForest, sets: &[ConfigTrie]) -> Self {
        Self::build_budgeted(thetas, forest, sets, u64::MAX)
    }

    /// Build the product DAG for every block pair whose cell count
    /// `|C_k|·|C_l|` is at most `cell_budget`; larger blocks are left out
    /// ([`Self::piece`] returns `None`) and should use the full-space
    /// descent, which is efficient precisely on those dense blocks.
    ///
    /// `sets` must have been registered in `forest`, and `thetas.depth()`
    /// must equal the forest depth.
    pub fn build_budgeted(
        thetas: &ThetaSeq,
        forest: &ConfigForest,
        sets: &[ConfigTrie],
        cell_budget: u64,
    ) -> Self {
        Self::build_budgeted_threaded(thetas, forest, sets, cell_budget, 1)
    }

    /// As [`Self::build_budgeted`], parallelizing the bottom-up restricted
    /// mass aggregation across up to `threads` setup threads.
    ///
    /// Within one level every pair node depends only on the next level's
    /// (already final) masses, so the level's nodes split into fixed
    /// [`AGG_CHUNK`]-sized chunks computed concurrently and reassembled
    /// in index order — the identical float operations in the identical
    /// order per node, hence a bit-for-bit identical DAG for every thread
    /// count. The top-down pair discovery is a hash-interning scan and
    /// stays serial (it is a small fraction of the build).
    pub fn build_budgeted_threaded(
        thetas: &ThetaSeq,
        forest: &ConfigForest,
        sets: &[ConfigTrie],
        cell_budget: u64,
        threads: usize,
    ) -> Self {
        let depth = thetas.depth();
        assert_eq!(forest.depth(), depth, "forest depth must match the theta sequence");
        let b = sets.len();

        // ---- Discovery (top-down): distinct reachable class pairs. ----
        let mut pair_classes: Vec<Vec<(u32, u32)>> = Vec::with_capacity(depth + 1);
        let mut children: Vec<Vec<[u32; 4]>> = Vec::with_capacity(depth);
        let mut interner: FastMap<u64, u32> = FastMap::default();
        let mut root_nodes: Vec<Option<u32>> = Vec::with_capacity(b * b);
        let mut level0: Vec<(u32, u32)> = Vec::new();
        for k in 0..b {
            for l in 0..b {
                let cells = sets[k].num_configs() as u64 * sets[l].num_configs() as u64;
                if cells > cell_budget {
                    root_nodes.push(None);
                    continue;
                }
                let (rk, rl) = (sets[k].root(), sets[l].root());
                let key = ((rk as u64) << 32) | rl as u64;
                let id = *interner.entry(key).or_insert_with(|| {
                    level0.push((rk, rl));
                    (level0.len() - 1) as u32
                });
                root_nodes.push(Some(id));
            }
        }
        pair_classes.push(level0);
        for level in 0..depth {
            interner.clear();
            let mut next: Vec<(u32, u32)> = Vec::new();
            let mut ch_level: Vec<[u32; 4]> = Vec::with_capacity(pair_classes[level].len());
            for &(cr, cc) in &pair_classes[level] {
                let rn = forest.class(level, cr);
                let cn = forest.class(level, cc);
                let mut ch = [NONE; 4];
                for (q, slot) in ch.iter_mut().enumerate() {
                    let rchild = rn[q >> 1];
                    let cchild = cn[q & 1];
                    if rchild != NONE && cchild != NONE {
                        let key = ((rchild as u64) << 32) | cchild as u64;
                        *slot = *interner.entry(key).or_insert_with(|| {
                            next.push((rchild, cchild));
                            (next.len() - 1) as u32
                        });
                    }
                }
                ch_level.push(ch);
            }
            children.push(ch_level);
            pair_classes.push(next);
        }

        // ---- Masses + thresholds (bottom-up, parallel per level). ----
        let mut levels: Vec<Vec<PairNode>> = vec![Vec::new(); depth];
        let mut mass_next: Vec<f64> = vec![1.0; pair_classes[depth].len()];
        let mut mass_sq_next: Vec<f64> = vec![1.0; pair_classes[depth].len()];
        for level in (0..depth).rev() {
            let w_level = thetas.level(level).weights();
            let n_nodes = pair_classes[level].len();
            let chunks: Vec<&[[u32; 4]]> = if threads > 1 {
                children[level].chunks(AGG_CHUNK).collect()
            } else {
                vec![children[level].as_slice()]
            };
            let mass_ref = &mass_next;
            let mass_sq_ref = &mass_sq_next;
            let parts = crate::parallel::map_indexed(chunks, threads, |_, chunk| {
                let mut nodes = Vec::with_capacity(chunk.len());
                let mut mass = Vec::with_capacity(chunk.len());
                let mut mass_sq = Vec::with_capacity(chunk.len());
                for ch in chunk {
                    let mut w = [0.0f64; 4];
                    let mut wsq = [0.0f64; 4];
                    for q in 0..4 {
                        if ch[q] != NONE {
                            w[q] = w_level[q] * mass_ref[ch[q] as usize];
                            wsq[q] = w_level[q] * w_level[q] * mass_sq_ref[ch[q] as usize];
                        }
                    }
                    let total = w[0] + w[1] + w[2] + w[3];
                    let (thresholds, fallback) = quadrant_thresholds(&w, total);
                    nodes.push(PairNode { children: *ch, thresholds, fallback });
                    mass.push(total);
                    mass_sq.push(wsq[0] + wsq[1] + wsq[2] + wsq[3]);
                }
                (nodes, mass, mass_sq)
            });
            let mut nodes = Vec::with_capacity(n_nodes);
            let mut mass_cur = Vec::with_capacity(n_nodes);
            let mut mass_sq_cur = Vec::with_capacity(n_nodes);
            for (nd, m, msq) in parts {
                nodes.extend(nd);
                mass_cur.extend(m);
                mass_sq_cur.extend(msq);
            }
            levels[level] = nodes;
            mass_next = mass_cur;
            mass_sq_next = mass_sq_cur;
        }

        let mut roots = Vec::with_capacity(b * b);
        for k in 0..b {
            for l in 0..b {
                roots.push(root_nodes[k * b + l].map(|node| PieceRoot {
                    node,
                    mass: mass_next[node as usize],
                    mass_sq: mass_sq_next[node as usize],
                    num_cells: sets[k].num_configs() as u64 * sets[l].num_configs() as u64,
                }));
            }
        }
        ConditionedBallDropSampler { depth, num_sets: b, levels, roots }
    }

    /// Number of attribute levels d.
    #[inline]
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Number of partition sets B (pieces are `B²`).
    #[inline]
    pub fn num_sets(&self) -> usize {
        self.num_sets
    }

    /// Total pair nodes in the shared product DAG (setup-cost metric).
    pub fn num_pair_nodes(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// The sampler for block `(D_k, D_l)` (0-based set indices), or
    /// `None` if the block exceeded the build's cell budget (dense block:
    /// callers should use the full-space descent there).
    #[inline]
    pub fn piece(&self, k: usize, l: usize) -> Option<PieceSampler<'_>> {
        assert!(k < self.num_sets && l < self.num_sets, "piece ({k},{l}) out of range");
        self.roots[k * self.num_sets + l].map(|root| PieceSampler { dag: self, root })
    }

    /// Serialize into a setup-artifact body: pair nodes level by level
    /// (ids meaningful, as with [`ConfigForest::encode`]), then the
    /// row-major `B²` piece roots. Thresholds are exact u64s and the
    /// masses round-trip by bit pattern, so a hydrated DAG drives the
    /// identical descent draws.
    pub(crate) fn encode(&self, w: &mut Writer) {
        w.put_u32(self.depth as u32);
        w.put_u64(self.num_sets as u64);
        for level in &self.levels {
            w.put_u64(level.len() as u64);
            for node in level {
                for &c in &node.children {
                    w.put_u32(c);
                }
                for &t in &node.thresholds {
                    w.put_u64(t);
                }
                w.put_u8(node.fallback);
            }
        }
        for root in &self.roots {
            match root {
                None => w.put_u8(0),
                Some(pr) => {
                    w.put_u8(1);
                    w.put_u32(pr.node);
                    w.put_f64(pr.mass);
                    w.put_f64(pr.mass_sq);
                    w.put_u64(pr.num_cells);
                }
            }
        }
    }

    /// Decode the counterpart of
    /// [`ConditionedBallDropSampler::encode`] from untrusted bytes, with
    /// every pair-node child link, quadrant fallback, and piece-root id
    /// bounds-checked (a corrupt link would otherwise panic mid-descent).
    pub(crate) fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let depth = r.take_u32("dag depth")? as usize;
        if !(1..=63).contains(&depth) {
            bail!("artifact body corrupt: dag depth {depth} outside [1, 63]");
        }
        let num_sets = usize::try_from(r.take_u64("dag set count")?)
            .map_err(|_| anyhow::anyhow!("artifact body corrupt: dag set count overflow"))?;
        let mut levels = Vec::with_capacity(depth);
        for _ in 0..depth {
            // 4 children (u32) + 3 thresholds (u64) + fallback (u8).
            let n = r.take_len(4 * 4 + 3 * 8 + 1, "dag pair nodes")?;
            let mut nodes = Vec::with_capacity(n);
            for _ in 0..n {
                let mut children = [0u32; 4];
                for slot in &mut children {
                    *slot = r.take_u32("pair-node child")?;
                }
                let mut thresholds = [0u64; 3];
                for slot in &mut thresholds {
                    *slot = r.take_u64("pair-node threshold")?;
                }
                let fallback = r.take_u8("pair-node fallback")?;
                if fallback > 3 {
                    bail!("artifact body corrupt: pair-node fallback quadrant {fallback}");
                }
                nodes.push(PairNode { children, thresholds, fallback });
            }
            levels.push(nodes);
        }
        // Child links of level ℓ index level ℓ+1 (the last level's point
        // into the implicit leaf layer and are never dereferenced).
        for level in 0..depth.saturating_sub(1) {
            let next_len = levels[level + 1].len() as u64;
            for node in &levels[level] {
                for &c in &node.children {
                    if c != NONE && c as u64 >= next_len {
                        bail!(
                            "artifact body corrupt: pair-node link {c} outside dag level {}",
                            level + 1
                        );
                    }
                }
            }
        }
        let num_roots = num_sets
            .checked_mul(num_sets)
            .ok_or_else(|| anyhow::anyhow!("artifact body corrupt: dag set count overflow"))?;
        if num_roots > r.remaining() {
            bail!(
                "artifact body truncated: dag claims {num_sets}\u{b2} piece roots but only {} \
                 bytes remain",
                r.remaining()
            );
        }
        let top = levels.first().map_or(0, |l| l.len());
        let mut roots = Vec::with_capacity(num_roots);
        for _ in 0..num_roots {
            match r.take_u8("piece-root flag")? {
                0 => roots.push(None),
                1 => {
                    let node = r.take_u32("piece-root node")?;
                    if node as usize >= top {
                        bail!("artifact body corrupt: piece root {node} outside dag level 0");
                    }
                    let mass = r.take_f64("piece-root mass")?;
                    let mass_sq = r.take_f64("piece-root mass_sq")?;
                    let num_cells = r.take_u64("piece-root cells")?;
                    roots.push(Some(PieceRoot { node, mass, mass_sq, num_cells }));
                }
                b => bail!("artifact body corrupt: piece-root flag byte {b}"),
            }
        }
        Ok(ConditionedBallDropSampler { depth, num_sets, levels, roots })
    }
}

/// Rejection-free sampler for one block `(D_k, D_l)`.
#[derive(Debug, Clone, Copy)]
pub struct PieceSampler<'a> {
    dag: &'a ConditionedBallDropSampler,
    root: PieceRoot,
}

impl PieceSampler<'_> {
    /// The restricted mass `m_kl` (expected edges of the block).
    #[inline]
    pub fn restricted_mass(&self) -> f64 {
        self.root.mass
    }

    /// The restricted squared mass `v_kl` (variance term).
    #[inline]
    pub fn restricted_mass_sq(&self) -> f64 {
        self.root.mass_sq
    }

    /// `|C_k| · |C_l|`: the number of cells (distinct possible edges).
    #[inline]
    pub fn num_cells(&self) -> u64 {
        self.root.num_cells
    }

    /// Draw the block edge count `X_kl ~ Poisson(m_kl)` clamped to the
    /// block's cell count.
    ///
    /// Poisson — not the paper's `N(m, m − v)` — because the quantity
    /// being replaced is the *retained* count of the full-space process:
    /// a `Binomial(X, m_kl / m)` thinning of a huge `X`, whose sparse
    /// limit is exactly `Poisson(m_kl)`. When the caller then drops
    /// `X_kl` i.i.d. balls and **collapses** duplicates, Poisson thinning
    /// makes every cell's hit count an independent `Poisson(P[x, y])`, so
    /// each cell is included independently with probability `1 − e^{−P}`
    /// — the same marginal as the rejection path (a normal draw, or
    /// resample-to-distinct placement, would systematically over-include
    /// cells of small high-mass blocks). For large `m_kl` the Poisson
    /// draw is itself normal-approximated, converging to Algorithm 1's
    /// count draw. The clamp to `|C_k|·|C_l|` only binds on saturated
    /// blocks, where it bounds worst-case work.
    pub fn draw_edge_count(&self, rng: &mut Rng) -> u64 {
        rng.poisson(self.root.mass).min(self.root.num_cells)
    }

    /// One conditioned quadrisection descent: returns the configuration
    /// pair `(x, y) ∈ C_k × C_l` with probability `P[x, y] / m_kl`.
    ///
    /// Must not be called on a zero-mass block (no reachable cells);
    /// [`Self::draw_edge_count`] returns 0 there.
    #[inline]
    pub fn drop_one(&self, rng: &mut Rng) -> (u64, u64) {
        debug_assert!(self.root.mass > 0.0, "drop_one on a zero-mass block");
        let mut idx = self.root.node as usize;
        let mut s: u64 = 0;
        let mut t: u64 = 0;
        for level in &self.dag.levels {
            let node = &level[idx];
            let r = rng.next_u64();
            let mut q = (r >= node.thresholds[0]) as usize
                + (r >= node.thresholds[1]) as usize
                + (r >= node.thresholds[2]) as usize;
            if node.children[q] == NONE {
                q = node.fallback as usize;
            }
            s = (s << 1) | (q >> 1) as u64;
            t = (t << 1) | (q & 1) as u64;
            idx = node.children[q] as usize;
        }
        (s, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::{edge_probability, Initiator};

    fn forest_with(depth: usize, sets: &[&[u64]]) -> (ConfigForest, Vec<ConfigTrie>) {
        let mut forest = ConfigForest::new(depth);
        let tries = sets.iter().map(|s| forest.register_set(s)).collect();
        (forest, tries)
    }

    #[test]
    fn identical_sets_share_roots_and_classes() {
        let (forest, tries) = forest_with(4, &[&[1, 5, 9], &[1, 5, 9], &[1, 5]]);
        assert_eq!(tries[0].root(), tries[1].root());
        assert_ne!(tries[0].root(), tries[2].root());
        // Sharing keeps the arena near one trie's size, not three.
        assert!(forest.num_classes() <= 2 * 4 * 3 + 5);
    }

    #[test]
    fn adopted_forest_matches_serial_registration() {
        // Serial registration in set order vs a 2-shard build (stride
        // assignment: shard 0 gets sets 0 and 2, shard 1 gets 1 and 3)
        // merged by adopt_trie in set order: the arenas — ids included —
        // and the tries must be identical.
        let d = 4;
        let sets: Vec<Vec<u64>> = vec![vec![1, 5, 9], vec![2, 5], vec![1, 5, 9], vec![0, 7, 13]];
        let mut serial = ConfigForest::new(d);
        let serial_tries: Vec<ConfigTrie> = sets.iter().map(|s| serial.register_set(s)).collect();

        let mut shard0 = ConfigForest::new(d);
        let mut shard1 = ConfigForest::new(d);
        let s0 = vec![shard0.register_set(&sets[0]), shard0.register_set(&sets[2])];
        let s1 = vec![shard1.register_set(&sets[1]), shard1.register_set(&sets[3])];

        let mut merged = ConfigForest::new(d);
        let mut m0 = AdoptMemo::new(d);
        let mut m1 = AdoptMemo::new(d);
        let merged_tries = vec![
            merged.adopt_trie(&shard0, &s0[0], &mut m0),
            merged.adopt_trie(&shard1, &s1[0], &mut m1),
            merged.adopt_trie(&shard0, &s0[1], &mut m0),
            merged.adopt_trie(&shard1, &s1[1], &mut m1),
        ];
        assert_eq!(merged, serial);
        assert_eq!(merged_tries, serial_tries);
        // Hash consing across shards: identical sets share one root.
        assert_eq!(merged_tries[0].root(), merged_tries[2].root());
    }

    #[test]
    fn threaded_dag_build_matches_serial() {
        // Sets large enough that mid-levels exceed AGG_CHUNK pair nodes,
        // so the threaded build genuinely splits per-level work; the DAG
        // must still be bit-for-bit the serial one.
        let d = 12;
        let thetas = ThetaSeq::homogeneous(Initiator::THETA2, d as u32);
        let mut rng = crate::rng::Rng::new(71);
        let mut cfgs = std::collections::BTreeSet::new();
        while cfgs.len() < 1500 {
            cfgs.insert(rng.below(1u64 << d));
        }
        let a: Vec<u64> = cfgs.iter().copied().collect();
        let b: Vec<u64> = a.iter().copied().filter(|&c| c % 3 != 0).collect();
        let (forest, tries) = forest_with(d, &[&a, &b]);
        let serial = ConditionedBallDropSampler::build(&thetas, &forest, &tries);
        assert!(
            serial.num_pair_nodes() > 4 * AGG_CHUNK,
            "test DAG too small to exercise chunking: {}",
            serial.num_pair_nodes()
        );
        for threads in [2usize, 4, 8] {
            let par = ConditionedBallDropSampler::build_budgeted_threaded(
                &thetas,
                &forest,
                &tries,
                u64::MAX,
                threads,
            );
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn masks_reflect_live_prefixes() {
        let (_, tries) = forest_with(3, &[&[0b001, 0b101]]);
        let t = &tries[0];
        assert_eq!(t.is_live(0, 0), Some(true));
        assert_eq!(t.is_live(1, 0), Some(true)); // prefix 0 of 001
        assert_eq!(t.is_live(1, 1), Some(true)); // prefix 1 of 101
        assert_eq!(t.is_live(2, 0b00), Some(true));
        assert_eq!(t.is_live(2, 0b01), Some(false));
        assert_eq!(t.is_live(2, 0b10), Some(true));
        assert_eq!(t.is_live(3, 0b001), Some(true));
        assert_eq!(t.is_live(3, 0b011), Some(false));
        assert_eq!(t.num_configs(), 2);
    }

    #[test]
    fn full_space_mass_matches_algorithm_one() {
        // Conditioning on the full configuration space must reproduce the
        // unconditioned m and v of Algorithm 1 exactly.
        let d = 4;
        let thetas = ThetaSeq::homogeneous(Initiator::THETA1, d as u32);
        let all: Vec<u64> = (0..1u64 << d).collect();
        let (forest, tries) = forest_with(d, &[&all]);
        let cond = ConditionedBallDropSampler::build(&thetas, &forest, &tries);
        let piece = cond.piece(0, 0).expect("within budget");
        assert!((piece.restricted_mass() - thetas.expected_edges()).abs() < 1e-9);
        assert!((piece.restricted_mass_sq() - thetas.sum_sq_product()).abs() < 1e-9);
        assert_eq!(piece.num_cells(), 1 << (2 * d));
    }

    #[test]
    fn restricted_mass_matches_bruteforce() {
        let d = 5;
        let thetas = ThetaSeq::homogeneous(Initiator::THETA2, d as u32);
        let a: Vec<u64> = vec![0, 3, 7, 12, 21, 30];
        let b: Vec<u64> = vec![1, 3, 8, 21, 31];
        let (forest, tries) = forest_with(d, &[&a, &b]);
        let cond = ConditionedBallDropSampler::build(&thetas, &forest, &tries);
        let piece = cond.piece(0, 1).expect("within budget");
        let mut want = 0.0;
        let mut want_sq = 0.0;
        for &x in &a {
            for &y in &b {
                let p = edge_probability(&thetas, x as u32, y as u32);
                want += p;
                want_sq += p * p;
            }
        }
        assert!((piece.restricted_mass() - want).abs() < 1e-12, "m: {} vs {want}", piece.restricted_mass());
        assert!((piece.restricted_mass_sq() - want_sq).abs() < 1e-12);
        assert_eq!(piece.num_cells(), (a.len() * b.len()) as u64);
    }

    #[test]
    fn drop_distribution_matches_restricted_conditional() {
        // Empirical per-cell frequency of drop_one must equal P / m_kl.
        let d = 3;
        let thetas = ThetaSeq::homogeneous(Initiator::THETA2, d as u32);
        let a: Vec<u64> = vec![0b000, 0b010, 0b101, 0b111];
        let b: Vec<u64> = vec![0b001, 0b100, 0b110];
        let (forest, tries) = forest_with(d, &[&a, &b]);
        let cond = ConditionedBallDropSampler::build(&thetas, &forest, &tries);
        let piece = cond.piece(0, 1).expect("within budget");
        let m = piece.restricted_mass();
        let trials = 300_000u32;
        let mut rng = Rng::new(401);
        let mut counts: FastMap<(u64, u64), u32> = FastMap::default();
        for _ in 0..trials {
            let cell = piece.drop_one(&mut rng);
            assert!(a.contains(&cell.0), "row {} outside C_k", cell.0);
            assert!(b.contains(&cell.1), "col {} outside C_l", cell.1);
            *counts.entry(cell).or_insert(0) += 1;
        }
        for &x in &a {
            for &y in &b {
                let want = edge_probability(&thetas, x as u32, y as u32) / m;
                let got = *counts.get(&(x, y)).unwrap_or(&0) as f64 / trials as f64;
                let sigma = (want * (1.0 - want) / trials as f64).sqrt();
                assert!(
                    (got - want).abs() < 5.0 * sigma + 1e-4,
                    "cell ({x},{y}): got {got:.5}, want {want:.5}"
                );
            }
        }
    }

    #[test]
    fn edge_count_clamps_to_block_cells() {
        // Saturated θ on a tiny block: the count draw must cap at the cell
        // count, not the full-space n².
        let thetas = ThetaSeq::homogeneous(Initiator::new([1.0, 1.0, 1.0, 1.0]), 3);
        let a: Vec<u64> = vec![0, 1];
        let b: Vec<u64> = vec![5];
        let (forest, tries) = forest_with(3, &[&a, &b]);
        let cond = ConditionedBallDropSampler::build(&thetas, &forest, &tries);
        let piece = cond.piece(0, 1).expect("within budget");
        assert_eq!(piece.num_cells(), 2);
        let mut rng = Rng::new(409);
        for _ in 0..200 {
            assert!(piece.draw_edge_count(&mut rng) <= 2);
        }
    }

    #[test]
    fn cell_budget_excludes_dense_blocks() {
        // Budget 6 cells: the 3×3 block is excluded, 3×1 and 1×1 stay.
        let thetas = ThetaSeq::homogeneous(Initiator::THETA1, 3);
        let big: Vec<u64> = vec![0, 3, 6];
        let small: Vec<u64> = vec![5];
        let (forest, tries) = forest_with(3, &[&big, &small]);
        let cond = ConditionedBallDropSampler::build_budgeted(&thetas, &forest, &tries, 6);
        assert!(cond.piece(0, 0).is_none(), "9-cell block must be over budget");
        assert!(cond.piece(0, 1).is_some());
        assert!(cond.piece(1, 0).is_some());
        assert!(cond.piece(1, 1).is_some());
        // Unbudgeted build conditions everything.
        let all = ConditionedBallDropSampler::build(&thetas, &forest, &tries);
        assert!(all.piece(0, 0).is_some());
    }

    #[test]
    fn forest_trie_and_dag_round_trip_through_wire() {
        let d = 6;
        let thetas = ThetaSeq::homogeneous(Initiator::THETA1, d as u32);
        let a: Vec<u64> = vec![0, 3, 7, 12, 21, 30, 41, 63];
        let b: Vec<u64> = vec![3, 8, 21, 31, 41];
        let (forest, tries) = forest_with(d, &[&a, &b]);
        let dag = ConditionedBallDropSampler::build(&thetas, &forest, &tries);

        let mut w = Writer::new();
        forest.encode(&mut w);
        for t in &tries {
            t.encode(&mut w);
        }
        dag.encode(&mut w);
        let bytes = w.into_bytes();

        let mut r = Reader::new(&bytes);
        let forest2 = ConfigForest::decode(&mut r).unwrap();
        let tries2: Vec<ConfigTrie> =
            (0..tries.len()).map(|_| ConfigTrie::decode(&mut r).unwrap()).collect();
        let dag2 = ConditionedBallDropSampler::decode(&mut r).unwrap();
        assert!(r.is_empty());
        // Equality includes the forest's interner maps: decode rebuilds
        // them from the arena, so hash-consing keeps working.
        assert_eq!(forest2, forest);
        assert_eq!(tries2, tries);
        assert_eq!(dag2, dag);
        // The rebuilt interners dedupe: registering a set already present
        // returns the existing root instead of growing the arena.
        let mut forest3 = forest2.clone();
        let classes_before = forest3.num_classes();
        let re = forest3.register_set(&a);
        assert_eq!(re.root(), tries[0].root());
        assert_eq!(forest3.num_classes(), classes_before);
        // A hydrated DAG drives the identical descent: same seed, same
        // cells drawn.
        let mut r1 = Rng::new(433);
        let mut r2 = Rng::new(433);
        let p1 = dag.piece(0, 1).unwrap();
        let p2 = dag2.piece(0, 1).unwrap();
        assert_eq!(p1.restricted_mass().to_bits(), p2.restricted_mass().to_bits());
        for _ in 0..500 {
            assert_eq!(p1.drop_one(&mut r1), p2.drop_one(&mut r2));
        }
    }

    #[test]
    fn decode_rejects_corrupt_links_and_flags() {
        let d = 3;
        let thetas = ThetaSeq::homogeneous(Initiator::THETA1, d as u32);
        let (forest, tries) = forest_with(d, &[&[0, 3, 7], &[5]]);
        let dag = ConditionedBallDropSampler::build(&thetas, &forest, &tries);

        // Forest: a class link pointing outside the next level.
        let mut w = Writer::new();
        forest.encode(&mut w);
        let good = w.into_bytes();
        assert!(ConfigForest::decode(&mut Reader::new(&good)).is_ok());
        let mut bad = good.clone();
        // depth u32, then level-0 count u64, then the first child u32.
        let child_off = 4 + 8;
        bad[child_off..child_off + 4].copy_from_slice(&9999u32.to_le_bytes());
        let err = ConfigForest::decode(&mut Reader::new(&bad)).unwrap_err().to_string();
        assert!(err.contains("outside level"), "{err}");
        // Truncation anywhere is an error.
        assert!(ConfigForest::decode(&mut Reader::new(&good[..good.len() - 3])).is_err());

        // Trie: a mask-level count past the gate is rejected pre-allocation.
        let mut w = Writer::new();
        w.put_u32(0);
        w.put_u64(1);
        w.put_u64(u64::MAX);
        let bytes = w.into_bytes();
        let err = ConfigTrie::decode(&mut Reader::new(&bytes)).unwrap_err().to_string();
        assert!(err.contains("mask level"), "{err}");

        // DAG: fallback quadrant out of range.
        let mut w = Writer::new();
        dag.encode(&mut w);
        let good = w.into_bytes();
        assert!(ConditionedBallDropSampler::decode(&mut Reader::new(&good)).is_ok());
        // depth u32 + num_sets u64 + level-0 count u64, then node 0:
        // children 16 B + thresholds 24 B, fallback next.
        let fb_off = 4 + 8 + 8 + 16 + 24;
        let mut bad = good.clone();
        bad[fb_off] = 7;
        let err =
            ConditionedBallDropSampler::decode(&mut Reader::new(&bad)).unwrap_err().to_string();
        assert!(err.contains("fallback"), "{err}");
        assert!(ConditionedBallDropSampler::decode(&mut Reader::new(&good[..fb_off])).is_err());
    }

    #[test]
    fn asymmetric_pieces_use_their_own_sets() {
        // piece(k, l) conditions rows on set k and cols on set l.
        let d = 2;
        let thetas = ThetaSeq::homogeneous(Initiator::THETA1, d as u32);
        let a: Vec<u64> = vec![0b00];
        let b: Vec<u64> = vec![0b11];
        let (forest, tries) = forest_with(d, &[&a, &b]);
        let cond = ConditionedBallDropSampler::build(&thetas, &forest, &tries);
        let mut rng = Rng::new(419);
        assert_eq!(cond.piece(0, 1).unwrap().drop_one(&mut rng), (0b00, 0b11));
        assert_eq!(cond.piece(1, 0).unwrap().drop_one(&mut rng), (0b11, 0b00));
        let p01 = cond.piece(0, 1).unwrap().restricted_mass();
        let want = edge_probability(&thetas, 0b00, 0b11);
        assert!((p01 - want).abs() < 1e-12);
    }
}
