//! KPGM samplers: naive per-entry Bernoulli and Algorithm 1 (ball drop).

use crate::graph::{EdgeList, NodeId};
use crate::hashutil::{fast_set_with_capacity, FastSet};
use crate::rng::Rng;

use super::{edge_probability, ThetaSeq};

/// What to do when the quadrisection descent lands on an already-sampled
/// edge (paper §2.1: "the generated edge is rejected and a new edge is
/// sampled").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DuplicatePolicy {
    /// Re-sample until a fresh edge is placed (the paper's text; default).
    #[default]
    Resample,
    /// Silently collapse duplicates (the Algorithm-1 pseudo-code's set
    /// union); yields slightly fewer edges.
    Collapse,
}

/// Naive `O(n² d)` KPGM sampler: one Bernoulli per adjacency entry.
pub fn naive_sample(thetas: &ThetaSeq, rng: &mut Rng) -> EdgeList {
    let n = thetas.num_nodes();
    let mut g = EdgeList::new(n);
    for i in 0..n as NodeId {
        for j in 0..n as NodeId {
            let p = edge_probability(thetas, i, j);
            if rng.bernoulli(p) {
                g.push(i, j);
            }
        }
    }
    g
}

/// Paper **Algorithm 1**: expected `O(log2(n) |E|)` ball-drop sampler.
#[derive(Debug, Clone)]
pub struct BallDropSampler {
    thetas: ThetaSeq,
    policy: DuplicatePolicy,
    /// Cap on resample attempts per edge (safety valve for tiny dense
    /// graphs where distinct edges run out).
    max_attempts: u32,
    /// Per-level cumulative quadrant thresholds scaled to the full u64
    /// range: one raw `next_u64` + three branchless compares replace the
    /// float categorical draw in the descent hot loop (§Perf: 11.5 →
    /// ~2 ns/level).
    thresholds: Vec<[u64; 3]>,
}

/// Scale per-level weights to u64 thresholds. A uniform draw `r` selects
/// quadrant `(r >= t0) + (r >= t1) + (r >= t2)`.
fn level_thresholds(weights: &[f64; 4]) -> [u64; 3] {
    let total: f64 = weights.iter().sum();
    debug_assert!(total > 0.0, "all-zero initiator level");
    super::conditioned::cumulative_thresholds(weights, total)
}

impl BallDropSampler {
    /// New sampler over the given per-level parameters.
    pub fn new(thetas: ThetaSeq) -> Self {
        let thresholds = thetas.levels().iter().map(|l| level_thresholds(&l.weights())).collect();
        BallDropSampler {
            thetas,
            policy: DuplicatePolicy::Resample,
            max_attempts: 64,
            thresholds,
        }
    }

    /// Set the duplicate policy.
    pub fn policy(mut self, policy: DuplicatePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// The parameter sequence.
    pub fn thetas(&self) -> &ThetaSeq {
        &self.thetas
    }

    /// Draw the number of edges `X ~ N(m, m − v)` (Algorithm 1 lines 3–5),
    /// clamped to `[0, n²]` — the full-space cell count.
    pub fn draw_edge_count(&self, rng: &mut Rng) -> u64 {
        let n = self.thetas.num_nodes() as f64;
        self.draw_edge_count_capped(rng, n * n)
    }

    /// As [`Self::draw_edge_count`] but clamped to an explicit `max_cells`
    /// (callers sampling a restricted block must cap at the block's cell
    /// count, not the full-space `n²`, or the draw overcounts).
    pub fn draw_edge_count_capped(&self, rng: &mut Rng, max_cells: f64) -> u64 {
        let m = self.thetas.expected_edges();
        let v = self.thetas.sum_sq_product();
        super::draw_count_clamped(rng, m, m - v, max_cells)
    }

    /// One quadrisection descent (Algorithm 1 lines 7–16): returns the
    /// (source, target) cell the ball lands in.
    #[inline]
    pub fn drop_one(&self, rng: &mut Rng) -> (NodeId, NodeId) {
        let mut s: u64 = 0;
        let mut t: u64 = 0;
        for th in &self.thresholds {
            let r = rng.next_u64();
            // branchless quadrant select: 0..4 in row-major (a, b) order
            let idx = (r >= th[0]) as u64 + (r >= th[1]) as u64 + (r >= th[2]) as u64;
            s = (s << 1) | (idx >> 1);
            t = (t << 1) | (idx & 1);
        }
        (s as NodeId, t as NodeId)
    }

    /// Sample a full graph.
    pub fn sample(&self, rng: &mut Rng) -> EdgeList {
        let x = self.draw_edge_count(rng);
        self.sample_with_count(x, rng)
    }

    /// Sample exactly `x` ball drops (post-dedup size may be smaller under
    /// [`DuplicatePolicy::Collapse`]).
    pub fn sample_with_count(&self, x: u64, rng: &mut Rng) -> EdgeList {
        self.sample_with_count_reporting(x, rng).0
    }

    /// As [`Self::sample_with_count`], also returning how many balls were
    /// abandoned because `max_attempts` resamples all hit duplicates
    /// (always 0 under [`DuplicatePolicy::Collapse`], where duplicates
    /// merge by design rather than being retried).
    pub fn sample_with_count_reporting(&self, x: u64, rng: &mut Rng) -> (EdgeList, u64) {
        let n = self.thetas.num_nodes();
        let mut g = EdgeList::with_capacity(n, x as usize);
        let mut seen: FastSet<u64> = fast_set_with_capacity(x as usize * 2);
        let mut dropped = 0u64;
        for _ in 0..x {
            match self.policy {
                DuplicatePolicy::Collapse => {
                    let (s, t) = self.drop_one(rng);
                    if seen.insert(edge_key(s, t)) {
                        g.push(s, t);
                    }
                }
                DuplicatePolicy::Resample => {
                    let mut placed = false;
                    for _ in 0..self.max_attempts {
                        let (s, t) = self.drop_one(rng);
                        if seen.insert(edge_key(s, t)) {
                            g.push(s, t);
                            placed = true;
                            break;
                        }
                    }
                    // Pathological saturation: the ball is abandoned, and
                    // (unlike the old silent drop) reported to the caller.
                    if !placed {
                        dropped += 1;
                    }
                }
            }
        }
        (g, dropped)
    }
}

#[inline]
fn edge_key(s: NodeId, t: NodeId) -> u64 {
    ((s as u64) << 32) | t as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kpgm::Initiator;

    #[test]
    fn naive_sample_rate_matches_probability() {
        // d = 2, check aggregate edge count against expectation.
        let thetas = ThetaSeq::homogeneous(Initiator::THETA1, 2);
        let mut rng = Rng::new(71);
        let trials = 2000;
        let mut total = 0usize;
        for _ in 0..trials {
            total += naive_sample(&thetas, &mut rng).num_edges();
        }
        let mean = total as f64 / trials as f64;
        let want = thetas.expected_edges(); // 2.4^2 = 5.76
        assert!((mean - want).abs() < 0.15, "mean={mean} want={want}");
    }

    #[test]
    fn edge_count_draw_concentrates_on_m() {
        let thetas = ThetaSeq::homogeneous(Initiator::THETA1, 10);
        let s = BallDropSampler::new(thetas.clone());
        let mut rng = Rng::new(73);
        let m = thetas.expected_edges();
        let draws: Vec<f64> = (0..2000).map(|_| s.draw_edge_count(&mut rng) as f64).collect();
        let mean = crate::stats::mean(&draws);
        assert!((mean - m).abs() / m < 0.01, "mean={mean} m={m}");
    }

    #[test]
    fn drop_one_respects_level_weights() {
        // All mass on (1, 0) at every level -> always the bottom-left cell.
        let t = Initiator::new([0.0, 0.0, 1.0, 0.0]);
        let s = BallDropSampler::new(ThetaSeq::homogeneous(t, 3));
        let mut rng = Rng::new(79);
        for _ in 0..50 {
            assert_eq!(s.drop_one(&mut rng), (7, 0));
        }
    }

    #[test]
    fn drop_distribution_matches_p() {
        // Empirical cell frequencies of drop_one ∝ P_ij.
        let thetas = ThetaSeq::homogeneous(Initiator::THETA2, 2);
        let s = BallDropSampler::new(thetas.clone());
        let mut rng = Rng::new(83);
        let n = 4usize;
        let trials = 400_000;
        let mut counts = vec![vec![0u32; n]; n];
        for _ in 0..trials {
            let (a, b) = s.drop_one(&mut rng);
            counts[a as usize][b as usize] += 1;
        }
        let m = thetas.expected_edges();
        for i in 0..n {
            for j in 0..n {
                let want = edge_probability(&thetas, i as NodeId, j as NodeId) / m;
                let got = counts[i][j] as f64 / trials as f64;
                assert!(
                    (got - want).abs() < 5.0 * (want / trials as f64).sqrt() + 1e-4,
                    "cell ({i},{j}): got {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn resample_policy_produces_distinct_edges() {
        let s = BallDropSampler::new(ThetaSeq::homogeneous(Initiator::THETA2, 6));
        let mut rng = Rng::new(89);
        let mut g = s.sample(&mut rng);
        let edges_before = g.num_edges();
        let removed = g.dedup();
        assert_eq!(removed, 0, "resample policy must not emit duplicates");
        assert!(edges_before > 0);
    }

    #[test]
    fn collapse_policy_no_duplicates_either() {
        let s = BallDropSampler::new(ThetaSeq::homogeneous(Initiator::THETA2, 6))
            .policy(DuplicatePolicy::Collapse);
        let mut rng = Rng::new(97);
        let mut g = s.sample(&mut rng);
        assert_eq!(g.dedup(), 0);
    }

    #[test]
    fn ball_drop_mean_edges_tracks_expectation() {
        let thetas = ThetaSeq::homogeneous(Initiator::THETA1, 8);
        let s = BallDropSampler::new(thetas.clone());
        let mut rng = Rng::new(101);
        let trials = 30;
        let mut total = 0usize;
        for _ in 0..trials {
            total += s.sample(&mut rng).num_edges();
        }
        let mean = total as f64 / trials as f64;
        let want = thetas.expected_edges(); // 2.4^8 ≈ 1100
        // Resampling keeps distinct edges so the count is ≈ the draw.
        assert!((mean - want).abs() / want < 0.1, "mean={mean} want={want}");
    }

    #[test]
    fn exhausted_resamples_are_counted() {
        // 2×2 saturated space, 100 requested balls: at most 4 can place;
        // every other ball must be reported as an abandoned resample.
        let t = Initiator::new([1.0, 1.0, 1.0, 1.0]);
        let s = BallDropSampler::new(ThetaSeq::homogeneous(t, 1));
        let mut rng = Rng::new(131);
        let (g, dropped) = s.sample_with_count_reporting(100, &mut rng);
        assert!(g.num_edges() <= 4);
        assert_eq!(g.num_edges() as u64 + dropped, 100, "every ball places or reports");
        assert!(dropped >= 96);
    }

    #[test]
    fn saturated_graph_does_not_hang() {
        // All-ones theta: every cell certain; tiny graph saturates fast.
        let t = Initiator::new([1.0, 1.0, 1.0, 1.0]);
        let s = BallDropSampler::new(ThetaSeq::homogeneous(t, 2));
        let mut rng = Rng::new(103);
        let g = s.sample_with_count(100, &mut rng); // > 16 cells requested
        assert!(g.num_edges() <= 16);
        let mut g2 = g.clone();
        assert_eq!(g2.dedup(), 0);
    }
}
