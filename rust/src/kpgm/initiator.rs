//! Initiator matrices and per-level parameter sequences.

/// A 2×2 initiator matrix with entries in `[0, 1]`, row-major.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Initiator {
    entries: [f64; 4],
}

impl Initiator {
    /// Kim & Leskovec's Θ1 (paper eq. 13).
    pub const THETA1: Initiator = Initiator { entries: [0.15, 0.7, 0.7, 0.85] };

    /// Moreno & Neville's Θ2 (paper eq. 13).
    pub const THETA2: Initiator = Initiator { entries: [0.35, 0.52, 0.52, 0.95] };

    /// From row-major entries; panics outside `[0, 1]`.
    pub fn new(entries: [f64; 4]) -> Self {
        for (i, &e) in entries.iter().enumerate() {
            assert!((0.0..=1.0).contains(&e), "initiator entry {i} = {e} outside [0, 1]");
        }
        Initiator { entries }
    }

    /// Entry `(a, b)`, `a, b ∈ {0, 1}`.
    #[inline]
    pub fn get(&self, a: usize, b: usize) -> f64 {
        debug_assert!(a < 2 && b < 2);
        self.entries[2 * a + b]
    }

    /// Row-major entries `[θ00, θ01, θ10, θ11]`.
    #[inline]
    pub fn entries(&self) -> [f64; 4] {
        self.entries
    }

    /// Sum of entries (the per-level factor of the expected edge count m).
    #[inline]
    pub fn sum(&self) -> f64 {
        self.entries.iter().sum()
    }

    /// Sum of squared entries (the per-level factor of v in Algorithm 1).
    #[inline]
    pub fn sum_sq(&self) -> f64 {
        self.entries.iter().map(|e| e * e).sum()
    }

    /// Transpose (swaps θ01/θ10) — used to reduce μ < 0.5 to μ > 0.5 (§4.1).
    pub fn transpose(&self) -> Initiator {
        Initiator { entries: [self.entries[0], self.entries[2], self.entries[1], self.entries[3]] }
    }

    /// Quadrisection weights in the categorical order (00, 01, 10, 11).
    #[inline]
    pub fn weights(&self) -> [f64; 4] {
        self.entries
    }
}

/// Per-level initiator sequence `Θ̃ = {Θ^(1), …, Θ^(d)}` (paper eq. 4).
#[derive(Debug, Clone, PartialEq)]
pub struct ThetaSeq {
    levels: Vec<Initiator>,
}

impl ThetaSeq {
    /// Heterogeneous levels.
    pub fn new(levels: Vec<Initiator>) -> Self {
        assert!(!levels.is_empty(), "need at least one level");
        assert!(levels.len() <= 63, "depth > 63 would overflow node ids");
        ThetaSeq { levels }
    }

    /// The same matrix at every level (the paper's experimental setup).
    pub fn homogeneous(theta: Initiator, d: u32) -> Self {
        Self::new(vec![theta; d as usize])
    }

    /// Number of levels d.
    #[inline]
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Number of KPGM nodes, `2^d`.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        1usize << self.levels.len()
    }

    /// Level `k` (0-based, 0 = most significant bit).
    #[inline]
    pub fn level(&self, k: usize) -> &Initiator {
        &self.levels[k]
    }

    /// All levels.
    #[inline]
    pub fn levels(&self) -> &[Initiator] {
        &self.levels
    }

    /// Expected number of edges `m = Π_k sum(Θ^(k))` (Algorithm 1 line 3).
    pub fn expected_edges(&self) -> f64 {
        self.levels.iter().map(|t| t.sum()).product()
    }

    /// `v = Π_k sum(Θ^(k)²)` (Algorithm 1 line 4); the |E| draw uses
    /// variance `m − v`.
    pub fn sum_sq_product(&self) -> f64 {
        self.levels.iter().map(|t| t.sum_sq()).product()
    }

    /// Stack as `[d, 2, 2]` f32 row-major — the runtime's theta layout.
    pub fn to_f32_stack(&self) -> Vec<f32> {
        self.levels
            .iter()
            .flat_map(|t| t.entries().into_iter().map(|e| e as f32))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_is_row_major() {
        let t = Initiator::new([0.1, 0.2, 0.3, 0.4]);
        assert_eq!(t.get(0, 0), 0.1);
        assert_eq!(t.get(0, 1), 0.2);
        assert_eq!(t.get(1, 0), 0.3);
        assert_eq!(t.get(1, 1), 0.4);
    }

    #[test]
    #[should_panic(expected = "outside [0, 1]")]
    fn rejects_out_of_range() {
        Initiator::new([0.0, 0.5, 1.1, 0.2]);
    }

    #[test]
    fn sums() {
        let t = Initiator::new([0.1, 0.2, 0.3, 0.4]);
        assert!((t.sum() - 1.0).abs() < 1e-12);
        assert!((t.sum_sq() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn transpose_swaps_off_diagonal() {
        let t = Initiator::new([0.1, 0.2, 0.3, 0.4]).transpose();
        assert_eq!(t.get(0, 1), 0.3);
        assert_eq!(t.get(1, 0), 0.2);
    }

    #[test]
    fn expected_edges_theta1() {
        // sum(Θ1) = 2.4; d = 3 -> m = 2.4^3
        let seq = ThetaSeq::homogeneous(Initiator::THETA1, 3);
        assert!((seq.expected_edges() - 2.4f64.powi(3)).abs() < 1e-9);
        assert_eq!(seq.num_nodes(), 8);
        assert_eq!(seq.depth(), 3);
    }

    #[test]
    fn f32_stack_layout() {
        let seq = ThetaSeq::new(vec![Initiator::THETA1, Initiator::THETA2]);
        let s = seq.to_f32_stack();
        assert_eq!(s.len(), 8);
        assert_eq!(s[0], 0.15f32);
        assert_eq!(s[4], 0.35f32);
    }
}
