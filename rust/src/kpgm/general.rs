//! Generalized KPGM with K×K initiator matrices (paper §2: "one can use
//! larger initiator matrices").
//!
//! The binary (2×2) model in the parent module is the paper's experimental
//! setting and keeps a bit-twiddling hot path; this module lifts every
//! piece to arbitrary K ≥ 2: node indices become base-K digit strings,
//! the quadrisection of Algorithm 1 becomes a K²-section, and the MAGM
//! attributes become categorical (see [`crate::magm`]'s general support
//! and [`crate::quilt::GeneralQuiltSampler`]).

use crate::graph::{EdgeList, NodeId};
use crate::rng::Rng;

/// A K×K initiator matrix with entries in `[0, 1]`, row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct GenInitiator {
    k: usize,
    entries: Vec<f64>,
}

impl GenInitiator {
    /// From row-major entries; length must be a perfect square.
    pub fn new(entries: Vec<f64>) -> Self {
        let k = (entries.len() as f64).sqrt().round() as usize;
        assert_eq!(k * k, entries.len(), "initiator must be square");
        assert!(k >= 2, "initiator must be at least 2x2");
        for (i, &e) in entries.iter().enumerate() {
            assert!((0.0..=1.0).contains(&e), "entry {i} = {e} outside [0, 1]");
        }
        GenInitiator { k, entries }
    }

    /// Side length K.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Entry (a, b).
    #[inline]
    pub fn get(&self, a: usize, b: usize) -> f64 {
        self.entries[a * self.k + b]
    }

    /// Sum of entries.
    pub fn sum(&self) -> f64 {
        self.entries.iter().sum()
    }

    /// Sum of squared entries.
    pub fn sum_sq(&self) -> f64 {
        self.entries.iter().map(|e| e * e).sum()
    }
}

/// Per-level K×K initiator sequence; all levels must share K.
#[derive(Debug, Clone, PartialEq)]
pub struct GenThetaSeq {
    levels: Vec<GenInitiator>,
    k: usize,
}

impl GenThetaSeq {
    /// Heterogeneous levels (same K everywhere).
    pub fn new(levels: Vec<GenInitiator>) -> Self {
        assert!(!levels.is_empty());
        let k = levels[0].k();
        assert!(levels.iter().all(|l| l.k() == k), "all levels must share K");
        let d = levels.len() as u32;
        assert!(
            (k as f64).powi(d as i32) <= 2f64.powi(62),
            "K^d must fit in a u64 configuration"
        );
        GenThetaSeq { levels, k }
    }

    /// The same matrix at every level.
    pub fn homogeneous(theta: GenInitiator, d: u32) -> Self {
        Self::new(vec![theta; d as usize])
    }

    /// Number of levels d.
    #[inline]
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Side length K.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of nodes `K^d`.
    pub fn num_nodes(&self) -> u64 {
        (self.k as u64).pow(self.depth() as u32)
    }

    /// Level k (0 = most significant digit).
    #[inline]
    pub fn level(&self, k: usize) -> &GenInitiator {
        &self.levels[k]
    }

    /// All levels.
    #[inline]
    pub fn levels(&self) -> &[GenInitiator] {
        &self.levels
    }

    /// Expected edge (ball) count `Π_k Σ Θ^(k)`.
    pub fn expected_edges(&self) -> f64 {
        self.levels.iter().map(|l| l.sum()).product()
    }

    /// `Π_k Σ (Θ^(k))²` (variance term of the |E| draw).
    pub fn sum_sq_product(&self) -> f64 {
        self.levels.iter().map(|l| l.sum_sq()).product()
    }

    /// Edge probability for base-K digit strings `i`, `j` (most significant
    /// digit = level 0).
    pub fn edge_probability(&self, i: u64, j: u64) -> f64 {
        let d = self.depth();
        let k = self.k as u64;
        let mut p = 1.0;
        let mut div = k.pow(d as u32 - 1);
        for level in &self.levels {
            let a = ((i / div) % k) as usize;
            let b = ((j / div) % k) as usize;
            p *= level.get(a, b);
            div /= k.max(1);
            if div == 0 {
                break;
            }
        }
        p
    }
}

/// Algorithm 1 generalized to K×K levels: the descent samples one of K²
/// cells per level via precomputed cumulative u64 thresholds.
#[derive(Debug, Clone)]
pub struct GenBallDropSampler {
    thetas: GenThetaSeq,
    /// Per level: K²−1 cumulative thresholds over the u64 range.
    thresholds: Vec<Vec<u64>>,
}

impl GenBallDropSampler {
    /// New sampler.
    pub fn new(thetas: GenThetaSeq) -> Self {
        let thresholds = thetas
            .levels()
            .iter()
            .map(|l| {
                let k = l.k();
                let total = l.sum();
                let scale = (u64::MAX as f64) / total;
                let mut cum = 0.0;
                let mut t = Vec::with_capacity(k * k - 1);
                for a in 0..k {
                    for b in 0..k {
                        if t.len() == k * k - 1 {
                            break;
                        }
                        cum += l.get(a, b) * scale;
                        t.push(cum as u64);
                    }
                }
                t
            })
            .collect();
        GenBallDropSampler { thetas, thresholds }
    }

    /// The parameter sequence.
    pub fn thetas(&self) -> &GenThetaSeq {
        &self.thetas
    }

    /// Draw |E| ~ N(m, m − v), clamped to the full `n²` cell space.
    pub fn draw_edge_count(&self, rng: &mut Rng) -> u64 {
        let m = self.thetas.expected_edges();
        let v = self.thetas.sum_sq_product();
        let n = self.thetas.num_nodes() as f64;
        super::draw_count_clamped(rng, m, m - v, n * n)
    }

    /// One descent: returns the (source, target) cell as base-K strings.
    pub fn drop_one(&self, rng: &mut Rng) -> (u64, u64) {
        let k = self.thetas.k() as u64;
        let mut s = 0u64;
        let mut t = 0u64;
        for th in &self.thresholds {
            let r = rng.next_u64();
            // binary search over K²−1 thresholds (K small: linear is fine)
            let mut idx = 0u64;
            for &bound in th {
                idx += (r >= bound) as u64;
            }
            s = s * k + idx / k;
            t = t * k + idx % k;
        }
        (s, t)
    }

    /// Sample a graph (resampling duplicates like Algorithm 1).
    pub fn sample(&self, rng: &mut Rng) -> EdgeList {
        let n = self.thetas.num_nodes() as usize;
        let x = self.draw_edge_count(rng);
        let mut seen = crate::hashutil::fast_set_with_capacity(x as usize * 2);
        let mut g = EdgeList::with_capacity(n, x as usize);
        for _ in 0..x {
            for _ in 0..64 {
                let (s, t) = self.drop_one(rng);
                if seen.insert(s.wrapping_mul(0x1_0000_0001).wrapping_add(t)) {
                    g.push(s as NodeId, t as NodeId);
                    break;
                }
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn theta3() -> GenInitiator {
        GenInitiator::new(vec![0.9, 0.4, 0.2, 0.4, 0.7, 0.3, 0.2, 0.3, 0.8])
    }

    #[test]
    fn edge_probability_matches_kron_power() {
        let t = theta3();
        let seq = GenThetaSeq::homogeneous(t.clone(), 2);
        // P = t (x) t: entry (i, j) with digits (i1 i0), (j1 j0).
        for i in 0..9u64 {
            for j in 0..9u64 {
                let want = t.get((i / 3) as usize, (j / 3) as usize)
                    * t.get((i % 3) as usize, (j % 3) as usize);
                let got = seq.edge_probability(i, j);
                assert!((got - want).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn reduces_to_binary_model() {
        // K = 2 must agree with the specialized ThetaSeq path.
        let g2 = GenInitiator::new(vec![0.15, 0.7, 0.7, 0.85]);
        let gen = GenThetaSeq::homogeneous(g2, 5);
        let bin = crate::kpgm::ThetaSeq::homogeneous(crate::kpgm::Initiator::THETA1, 5);
        for i in 0..32u64 {
            for j in 0..32u64 {
                let a = gen.edge_probability(i, j);
                let b = crate::kpgm::edge_probability(&bin, i as u32, j as u32);
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn drop_distribution_tracks_p() {
        let seq = GenThetaSeq::homogeneous(theta3(), 2);
        let sampler = GenBallDropSampler::new(seq.clone());
        let mut rng = Rng::new(271);
        let trials = 300_000;
        let mut counts = vec![vec![0u32; 9]; 9];
        for _ in 0..trials {
            let (s, t) = sampler.drop_one(&mut rng);
            counts[s as usize][t as usize] += 1;
        }
        let m = seq.expected_edges();
        for i in 0..9u64 {
            for j in 0..9u64 {
                let want = seq.edge_probability(i, j) / m;
                let got = counts[i as usize][j as usize] as f64 / trials as f64;
                let sigma = (want * (1.0 - want) / trials as f64).sqrt();
                assert!((got - want).abs() < 5.0 * sigma + 1e-4, "({i},{j}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn sample_rate_matches_expectation() {
        let seq = GenThetaSeq::homogeneous(theta3(), 4); // n = 81
        let sampler = GenBallDropSampler::new(seq.clone());
        let mut rng = Rng::new(277);
        let trials = 40;
        let total: usize = (0..trials).map(|_| sampler.sample(&mut rng).num_edges()).sum();
        let mean = total as f64 / trials as f64;
        let want = seq.expected_edges(); // 4.2^4
        assert!((mean - want).abs() / want < 0.1, "mean={mean} want={want}");
    }

    #[test]
    #[should_panic(expected = "square")]
    fn non_square_rejected() {
        GenInitiator::new(vec![0.1, 0.2, 0.3]);
    }
}
