//! Stochastic Kronecker Product Graph Model (Leskovec et al. 2010).
//!
//! `P = Θ^(1) ⊗ Θ^(2) ⊗ … ⊗ Θ^(d)` (paper eq. 3); equivalently
//! `P_ij = Π_k θ^(k)[b_k(i), b_k(j)]` where `b_k(i)` is the k-th most
//! significant bit of `i` (paper eq. 6).
//!
//! Three samplers:
//! * [`naive_sample`] — `O(n² d)` per-entry Bernoulli (the baseline),
//! * [`BallDropSampler`] — paper **Algorithm 1**: draw `|E| ~ N(m, m−v)`,
//!   then place each edge by a d-level quadrisection descent. Expected
//!   `O(log2(n)·|E|)`.
//! * [`ConditionedBallDropSampler`] — Algorithm 1 restricted to a block
//!   of retained configuration pairs: every descent is renormalized by
//!   downstream reachable mass so no ball is ever discarded (the
//!   rejection-free engine behind the quilting pieces).

mod conditioned;
pub mod general;
mod initiator;
mod sampler;

pub use conditioned::{AdoptMemo, ConditionedBallDropSampler, ConfigForest, ConfigTrie,
                      PieceSampler};
pub(crate) use conditioned::draw_count_clamped;
pub use initiator::{Initiator, ThetaSeq};
pub use sampler::{naive_sample, BallDropSampler, DuplicatePolicy};

use crate::graph::NodeId;

/// Edge probability `P_ij` for node ids under the Kronecker bit convention
/// (level k consumes the k-th most significant of the `d` bits).
pub fn edge_probability(thetas: &ThetaSeq, i: NodeId, j: NodeId) -> f64 {
    let d = thetas.depth();
    let mut p = 1.0;
    for k in 0..d {
        let shift = (d - 1 - k) as u32;
        let a = ((i >> shift) & 1) as usize;
        let b = ((j >> shift) & 1) as usize;
        p *= thetas.level(k).get(a, b);
    }
    p
}

/// Materialize the full `2^d × 2^d` probability matrix (tests/Fig. 1 only).
pub fn probability_matrix(thetas: &ThetaSeq) -> Vec<Vec<f64>> {
    let n = thetas.num_nodes();
    (0..n)
        .map(|i| (0..n).map(|j| edge_probability(thetas, i as NodeId, j as NodeId)).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_probability_matches_kronecker_product() {
        // d = 2: P = theta ⊗ theta, checked entry by entry.
        let t = Initiator::THETA1;
        let thetas = ThetaSeq::homogeneous(t, 2);
        let n = 4;
        for i in 0..n {
            for j in 0..n {
                let want = t.get(i / 2, j / 2) * t.get(i % 2, j % 2);
                let got = edge_probability(&thetas, i as NodeId, j as NodeId);
                assert!((got - want).abs() < 1e-12, "({i},{j}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn heterogeneous_levels_order() {
        // P = A ⊗ B: level 0 (MSB) must use A.
        let a = Initiator::new([0.1, 0.2, 0.3, 0.4]);
        let b = Initiator::new([0.9, 0.8, 0.7, 0.6]);
        let thetas = ThetaSeq::new(vec![a, b]);
        // entry (2, 1): MSB bits (1, 0) -> A[1,0] = 0.3; LSB bits (0, 1) -> B[0,1] = 0.8
        let got = edge_probability(&thetas, 2, 1);
        assert!((got - 0.3 * 0.8).abs() < 1e-12);
    }

    #[test]
    fn probability_matrix_fractal_structure() {
        // Top-left quadrant equals theta00 * P_{d-1}.
        let thetas = ThetaSeq::homogeneous(Initiator::THETA2, 3);
        let sub = ThetaSeq::homogeneous(Initiator::THETA2, 2);
        let p = probability_matrix(&thetas);
        let q = probability_matrix(&sub);
        let t00 = Initiator::THETA2.get(0, 0);
        for i in 0..4 {
            for j in 0..4 {
                assert!((p[i][j] - t00 * q[i][j]).abs() < 1e-12);
            }
        }
    }
}
