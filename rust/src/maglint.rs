//! `maglint` — the determinism-invariant lint, as a standalone binary.
//!
//! Usage: `cargo run --bin maglint [repo-root]` (the root defaults to the
//! directory holding `Cargo.toml`). Exits 0 when the tree is clean and 1
//! when any invariant is violated, printing findings as
//! `file:line: [rule] message` relative to `rust/src`. The rules and the
//! annotation syntax are documented in `docs/determinism.md` and in the
//! module docs of `rust/src/lint/mod.rs`.
//!
//! The engine is included by path rather than through the library crate,
//! so this binary has no code dependency on the library: when the library
//! is mid-refactor and failing to compile, the lint can still be built
//! and run directly (`rustc --edition 2021 rust/src/maglint.rs` after
//! vendoring `anyhow`, or from any checkout whose lib builds, pointing it
//! at the broken tree via the path argument) — a linter that dies with
//! the patient is no use during surgery.

#[path = "lint/mod.rs"]
mod lint;

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    match lint::lint_tree(&root) {
        Ok(findings) if findings.is_empty() => {
            println!("maglint: clean ({})", root.join("rust/src").display());
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            eprintln!("maglint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(err) => {
            eprintln!("maglint: error: {err:#}");
            ExitCode::FAILURE
        }
    }
}
