//! One home for the CLI's human-readable status lines.
//!
//! CI's release-smoke job greps several of these strings verbatim
//! (`.github/workflows/ci.yml`); before this module they were `format!`
//! literals scattered through `cli.rs`, so a wording tweak silently
//! broke the smoke legs. The unit tests below pin the exact renderings
//! the smoke greps match — change a string here and the test names the
//! CI leg you are about to break.

use crate::coordinator::SetupStats;
use crate::graph::SpillSummary;

/// The `setup:` line: phase timings for a fresh build, or the artifact
/// identity when the prologue was hydrated (the non-zero hash is the
/// visible witness that setup was skipped).
/// CI grep: `setup: artifact [0-9a-f]{16} hydrated` and `setup:`.
pub fn setup_line(setup: &SetupStats) -> String {
    if setup.artifact_hash != 0 {
        return format!(
            "setup: artifact {:016x} hydrated in {:.1} ms — attrs/partition/tries/dag skipped \
             ({} setup threads at build, {} attrs)",
            setup.artifact_hash,
            setup.artifact_load_ms,
            setup.setup_threads,
            setup.attr_mode.name(),
        );
    }
    format!(
        "setup: attrs {:.1} ms | partition {:.1} ms | tries {:.1} ms (merge {:.1} ms) \
         | dag {:.1} ms ({} setup threads, {} attrs)",
        setup.attrs_ms,
        setup.partition_ms,
        setup.trie_ms,
        setup.trie_merge_ms,
        setup.dag_ms,
        setup.setup_threads,
        setup.attr_mode.name(),
    )
}

/// The `spill:` line for the binary sink.
/// CI grep: `spill: [0-9]+ shard\(s\) spilled`.
pub fn spill_line(spill: &SpillSummary) -> String {
    format!(
        "spill: {} shard(s) spilled, {} bytes in {} run(s); {} shard(s) deferred in memory",
        spill.spilled_shards,
        spill.spill_bytes,
        spill.spill_runs,
        spill.deferred_shards - spill.spilled_shards,
    )
}

/// The `merge:` timing line (driver and `merge-segments`).
/// CI grep: `merge: .* 4 merge thread`.
pub fn merge_line(merge_ms: f64, merge_threads: usize, deferred: usize, spilled: usize) -> String {
    format!(
        "merge: {merge_ms:.1} ms on {merge_threads} merge thread(s) \
         ({deferred} deferred, {spilled} spilled)"
    )
}

/// The `dist:` restart-recovery line.
/// CI grep: `dist: 1 worker restart\(s\) recovered by resume`.
pub fn dist_restart_line(restarts: usize) -> String {
    format!("dist: {restarts} worker restart(s) recovered by resume")
}

/// The `dist:` merge-summary line.
/// CI grep: `dist: merged 8 shard\(s\) from 2 worker\(s\)`.
pub fn dist_merged_line(
    shards: usize,
    workers: usize,
    overflow_runs: u64,
    duplicates_dropped: u64,
) -> String {
    format!(
        "dist: merged {shards} shard(s) from {workers} worker(s); {overflow_runs} overflow \
         run(s), {duplicates_dropped} cross-worker duplicate(s) collapsed"
    )
}

/// The `merged ...` summary line printed by `merge-segments`.
pub fn merged_summary_line(
    shards: usize,
    overflow_runs: u64,
    duplicates_dropped: u64,
) -> String {
    format!(
        "merged {shards} shard(s): {overflow_runs} overflow run(s), \
         {duplicates_dropped} cross-worker duplicate(s) collapsed"
    )
}

/// The throttled live-progress line the distributed driver prints (and
/// `magquilt top` renders from a shared segment directory).
/// CI grep: `^progress: w[0-9]+/[0-9]+ jobs`.
pub fn progress_line(
    workers_reporting: usize,
    workers_total: usize,
    jobs_done: u64,
    jobs_total: u64,
    edges: u64,
) -> String {
    format!(
        "progress: w{workers_reporting}/{workers_total} jobs {jobs_done}/{jobs_total} edges {}",
        human_count(edges)
    )
}

/// Compact human count: `812`, `1.2k`, `3.4M`, `1.2G`, `7.0T`.
pub fn human_count(n: u64) -> String {
    const UNITS: [(u64, &str); 4] =
        [(1_000_000_000_000, "T"), (1_000_000_000, "G"), (1_000_000, "M"), (1_000, "k")];
    for (scale, suffix) in UNITS {
        if n >= scale {
            return format!("{:.1}{suffix}", n as f64 / scale as f64);
        }
    }
    format!("{n}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::magm::AttrSampleMode;

    fn fresh_setup() -> SetupStats {
        SetupStats {
            attrs_ms: 1.25,
            partition_ms: 2.5,
            trie_ms: 3.75,
            trie_merge_ms: 0.5,
            dag_ms: 4.0,
            setup_threads: 4,
            attr_mode: AttrSampleMode::Chunked,
            artifact_hash: 0,
            artifact_load_ms: 0.0,
        }
    }

    #[test]
    fn setup_line_fresh_matches_ci_grep() {
        let line = setup_line(&fresh_setup());
        assert_eq!(
            line,
            "setup: attrs 1.2 ms | partition 2.5 ms | tries 3.8 ms (merge 0.5 ms) \
             | dag 4.0 ms (4 setup threads, chunked attrs)"
        );
        // ci.yml parallel-setup smoke: grep -q "setup:"
        assert!(line.starts_with("setup:"));
    }

    #[test]
    fn setup_line_hydrated_matches_ci_grep() {
        let mut s = fresh_setup();
        s.artifact_hash = 0x00ff_00ff_00ff_00ff;
        s.artifact_load_ms = 7.5;
        let line = setup_line(&s);
        assert_eq!(
            line,
            "setup: artifact 00ff00ff00ff00ff hydrated in 7.5 ms — \
             attrs/partition/tries/dag skipped (4 setup threads at build, chunked attrs)"
        );
        // ci.yml setup-artifact smoke: grep -E "setup: artifact [0-9a-f]{16} hydrated"
        assert!(line.contains("setup: artifact 00ff00ff00ff00ff hydrated"));
    }

    #[test]
    fn spill_line_matches_ci_grep() {
        let spill = SpillSummary {
            deferred_shards: 5,
            spilled_shards: 2,
            spill_runs: 3,
            spill_bytes: 4096,
        };
        let line = spill_line(&spill);
        assert_eq!(
            line,
            "spill: 2 shard(s) spilled, 4096 bytes in 3 run(s); 3 shard(s) deferred in memory"
        );
        // ci.yml forced-spill smoke: grep -E "spill: [0-9]+ shard\(s\) spilled"
        assert!(line.starts_with("spill: 2 shard(s) spilled"));
    }

    #[test]
    fn merge_line_matches_ci_grep() {
        let line = merge_line(12.34, 4, 1, 2);
        assert_eq!(line, "merge: 12.3 ms on 4 merge thread(s) (1 deferred, 2 spilled)");
        // ci.yml parallel-merge smoke: grep -E "merge: .* 4 merge thread"
        assert!(line.contains("4 merge thread"));
    }

    #[test]
    fn dist_lines_match_ci_greps() {
        // ci.yml crash-inject smoke: "dist: 1 worker restart\(s\) recovered by resume"
        assert_eq!(dist_restart_line(1), "dist: 1 worker restart(s) recovered by resume");
        let line = dist_merged_line(8, 2, 5, 7);
        assert_eq!(
            line,
            "dist: merged 8 shard(s) from 2 worker(s); 5 overflow run(s), \
             7 cross-worker duplicate(s) collapsed"
        );
        // ci.yml distributed smoke: grep -E "dist: merged 8 shard\(s\) from 2 worker\(s\)"
        assert!(line.starts_with("dist: merged 8 shard(s) from 2 worker(s)"));
    }

    #[test]
    fn merged_summary_line_is_stable() {
        assert_eq!(
            merged_summary_line(8, 5, 7),
            "merged 8 shard(s): 5 overflow run(s), 7 cross-worker duplicate(s) collapsed"
        );
    }

    #[test]
    fn progress_line_matches_ci_grep() {
        let line = progress_line(3, 4, 812, 1024, 1_200_000_000);
        assert_eq!(line, "progress: w3/4 jobs 812/1024 edges 1.2G");
        // ci.yml telemetry smoke: grep -E "^progress: w[0-9]+/[0-9]+ jobs"
        assert!(line.starts_with("progress: w3/4 jobs"));
    }

    #[test]
    fn human_count_scales() {
        assert_eq!(human_count(812), "812");
        assert_eq!(human_count(1_234), "1.2k");
        assert_eq!(human_count(3_400_000), "3.4M");
        assert_eq!(human_count(1_200_000_000), "1.2G");
        assert_eq!(human_count(7_000_000_000_000), "7.0T");
    }
}
