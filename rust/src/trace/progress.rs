//! Live progress records: the PR-8 heartbeat files, upgraded.
//!
//! A worker's heartbeat file (`hb-<plan>-wNNNN.beat`) used to be an
//! empty mtime-only touch. It now carries a small `magquilt-progress-v1`
//! key=value record (same self-describing text convention as the
//! `done-*.ok` markers) that the supervising driver — and `magquilt top`
//! on a shared filesystem — parses into a one-line aggregate status:
//!
//! ```text
//! progress: w3/4 jobs 812/1024 edges 1.2G
//! ```
//!
//! An empty or unparseable heartbeat is tolerated everywhere (a legacy
//! worker binary still supervises fine); progress is observability only
//! and never feeds the merge or any output-determining state.

use std::sync::atomic::{AtomicU64, Ordering};

/// Progress record format tag.
pub const PROGRESS_FORMAT: &str = "magquilt-progress-v1";

/// Shared live counters, bumped from sampler worker threads and the
/// sink delivery loop with relaxed atomics (no ordering requirement —
/// a progress snapshot is allowed to be slightly stale).
#[derive(Debug, Default)]
pub struct ProgressState {
    /// Sampling jobs completed.
    pub jobs_done: AtomicU64,
    /// Total sampling jobs planned (0 until planning finishes).
    pub jobs_total: AtomicU64,
    /// Edges emitted through sealed shards.
    pub edges: AtomicU64,
    /// Shards sealed (delivered to the sink).
    pub shards_sealed: AtomicU64,
    /// Bytes of edge payload written (8 bytes per binary edge).
    pub bytes_written: AtomicU64,
}

impl ProgressState {
    /// New zeroed state.
    pub fn new() -> ProgressState {
        ProgressState::default()
    }

    /// Point-in-time copy.
    pub fn snapshot(&self) -> ProgressSnapshot {
        ProgressSnapshot {
            jobs_done: self.jobs_done.load(Ordering::Relaxed),
            jobs_total: self.jobs_total.load(Ordering::Relaxed),
            edges: self.edges.load(Ordering::Relaxed),
            shards_sealed: self.shards_sealed.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
        }
    }

    /// Render the heartbeat-file payload for `worker` of plan `plan`.
    pub fn render(&self, plan: &str, worker: usize) -> String {
        self.snapshot().render(plan, worker)
    }
}

/// Plain-value snapshot of a [`ProgressState`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProgressSnapshot {
    /// Sampling jobs completed.
    pub jobs_done: u64,
    /// Total sampling jobs planned.
    pub jobs_total: u64,
    /// Edges emitted through sealed shards.
    pub edges: u64,
    /// Shards sealed.
    pub shards_sealed: u64,
    /// Bytes of edge payload written.
    pub bytes_written: u64,
}

impl ProgressSnapshot {
    /// Render as a `magquilt-progress-v1` record.
    pub fn render(&self, plan: &str, worker: usize) -> String {
        format!(
            "format = {PROGRESS_FORMAT}\nplan = {plan}\nworker = {worker}\n\
             jobs_done = {}\njobs_total = {}\nedges = {}\nshards_sealed = {}\n\
             bytes_written = {}\n",
            self.jobs_done, self.jobs_total, self.edges, self.shards_sealed, self.bytes_written,
        )
    }
}

/// A parsed progress record: the snapshot plus its provenance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProgressRecord {
    /// Plan hash the worker is executing.
    pub plan: String,
    /// Worker index.
    pub worker: usize,
    /// The counters.
    pub counts: ProgressSnapshot,
}

/// Parse a heartbeat payload. Returns `None` for empty files (legacy
/// mtime-only heartbeats), wrong format tags, or malformed records —
/// progress is best-effort by design.
pub fn parse_progress(text: &str) -> Option<ProgressRecord> {
    let mut plan = None;
    let mut worker = None;
    let mut counts = ProgressSnapshot::default();
    let mut format_ok = false;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (key, value) = line.split_once('=')?;
        let (key, value) = (key.trim(), value.trim());
        match key {
            "format" => format_ok = value == PROGRESS_FORMAT,
            "plan" => plan = Some(value.to_string()),
            "worker" => worker = value.parse().ok(),
            "jobs_done" => counts.jobs_done = value.parse().ok()?,
            "jobs_total" => counts.jobs_total = value.parse().ok()?,
            "edges" => counts.edges = value.parse().ok()?,
            "shards_sealed" => counts.shards_sealed = value.parse().ok()?,
            "bytes_written" => counts.bytes_written = value.parse().ok()?,
            _ => {} // forward-compatible: ignore unknown keys
        }
    }
    if !format_ok {
        return None;
    }
    Some(ProgressRecord { plan: plan?, worker: worker?, counts })
}

/// Sum worker records into the driver's aggregate view.
pub fn aggregate(records: &[ProgressRecord]) -> ProgressSnapshot {
    let mut total = ProgressSnapshot::default();
    for r in records {
        total.jobs_done += r.counts.jobs_done;
        total.jobs_total += r.counts.jobs_total;
        total.edges += r.counts.edges;
        total.shards_sealed += r.counts.shards_sealed;
        total.bytes_written += r.counts.bytes_written;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_parse_round_trip() {
        let state = ProgressState::new();
        state.jobs_total.store(1024, Ordering::Relaxed);
        state.jobs_done.store(812, Ordering::Relaxed);
        state.edges.store(5_000_000, Ordering::Relaxed);
        state.shards_sealed.store(6, Ordering::Relaxed);
        state.bytes_written.store(40_000_000, Ordering::Relaxed);
        let text = state.render("00ff00ff00ff00ff", 3);
        assert!(text.starts_with("format = magquilt-progress-v1\n"));
        let rec = parse_progress(&text).unwrap();
        assert_eq!(rec.plan, "00ff00ff00ff00ff");
        assert_eq!(rec.worker, 3);
        assert_eq!(rec.counts, state.snapshot());
    }

    #[test]
    fn legacy_empty_heartbeat_parses_to_none() {
        assert_eq!(parse_progress(""), None);
        assert_eq!(parse_progress("\n\n"), None);
    }

    #[test]
    fn malformed_records_parse_to_none() {
        assert!(parse_progress("format = magquilt-progress-v1\nplan = x\n").is_none()); // no worker
        assert!(parse_progress("plan = x\nworker = 0\n").is_none()); // no format tag
        assert!(parse_progress("format = magquilt-progress-v2\nplan = x\nworker = 0\n").is_none());
        assert!(parse_progress("format = magquilt-progress-v1\nplan = x\nworker = zero\n")
            .is_none());
        assert!(parse_progress("format = magquilt-progress-v1\nnot a kv line\n").is_none());
    }

    #[test]
    fn unknown_keys_are_forward_compatible() {
        let text = "format = magquilt-progress-v1\nplan = p\nworker = 1\n\
                    jobs_done = 2\njobs_total = 4\nedges = 10\nshards_sealed = 1\n\
                    bytes_written = 80\nfuture_key = 9\n";
        let rec = parse_progress(text).unwrap();
        assert_eq!(rec.counts.jobs_done, 2);
        assert_eq!(rec.counts.bytes_written, 80);
    }

    #[test]
    fn aggregate_sums_workers() {
        let mk = |w: usize, done: u64, total: u64, edges: u64| ProgressRecord {
            plan: "p".into(),
            worker: w,
            counts: ProgressSnapshot {
                jobs_done: done,
                jobs_total: total,
                edges,
                shards_sealed: 1,
                bytes_written: edges * 8,
            },
        };
        let agg = aggregate(&[mk(0, 400, 512, 100), mk(1, 412, 512, 250)]);
        assert_eq!(agg.jobs_done, 812);
        assert_eq!(agg.jobs_total, 1024);
        assert_eq!(agg.edges, 350);
        assert_eq!(agg.bytes_written, 2800);
    }
}
